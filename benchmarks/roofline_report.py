"""Render the dry-run roofline table (deliverable g) from
benchmarks/results/dryrun.jsonl."""
from __future__ import annotations

import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun.jsonl")


def load(path: str = RESULTS, tag: str = None):
    rows = {}
    if not os.path.exists(path):
        return rows
    with open(path) as f:
        for line in f:
            try:
                r = json.loads(line)
            except json.JSONDecodeError:
                continue
            if tag and r.get("tag") != tag:
                continue
            key = (r["arch"], r["shape"], r["mesh"], r.get("tag", "base"))
            rows[key] = r            # later lines win (reruns)
    return rows


def render(rows, mesh="single", tag="base"):
    hdr = (f"{'arch':22s} {'shape':12s} {'compute_s':>10s} {'memory_s':>10s} "
           f"{'coll_s':>10s} {'dom':>10s} {'frac':>5s} {'useful':>7s} "
           f"{'GB/dev':>7s} {'ok':>3s}")
    lines = [hdr, "-" * len(hdr)]
    for (arch, shape, m, t), r in sorted(rows.items()):
        if m != mesh or t != tag:
            continue
        if not r.get("ok"):
            lines.append(f"{arch:22s} {shape:12s} FAILED: "
                         f"{r.get('error', '?')[:60]}")
            continue
        lines.append(
            f"{arch:22s} {shape:12s} {r['compute_s']:10.3e} "
            f"{r['memory_s']:10.3e} {r['collective_s']:10.3e} "
            f"{r['dominant']:>10s} {r['roofline_frac']:5.2f} "
            f"{r['useful_ratio']:7.2f} "
            f"{r.get('bytes_per_device', 0) / 1e9:7.2f}  ok")
    return "\n".join(lines)


def main():
    rows = load()
    n_ok = sum(1 for r in rows.values() if r.get("ok"))
    print(f"\n# Roofline table ({n_ok}/{len(rows)} cells ok)")
    for mesh in ("single", "multipod"):
        print(f"\n## mesh = {mesh}")
        print(render(rows, mesh=mesh))
    from .common import emit
    for (arch, shape, m, t), r in sorted(rows.items()):
        if r.get("ok") and t == "base":
            emit(f"roofline_{arch}_{shape}_{m}", r.get("compile_s", 0) * 1e6,
                 f"dom={r['dominant']} frac={r['roofline_frac']:.3f}")


if __name__ == "__main__":
    main()
