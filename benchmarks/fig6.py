"""Paper Figure 6 — scaling under increasing task concurrency (1→32 GSM8K
replicas on qwen3-0.6b, 100 steps each): time, throughput, util, idle."""
from __future__ import annotations

from .common import Timer, emit, run_policy

CONCURRENCY = (1, 2, 4, 8, 16, 32)
POLS = ("single_disagg", "multilora_sync", "marlaas")


def run(verbose: bool = True):
    out = {}
    for n in CONCURRENCY:
        for pol in POLS:
            out[(pol, n)] = run_policy(pol, "qwen3-0.6b", "gsm8k", n, 100)
    if verbose:
        print("\n# Fig 6 — concurrency scaling (GSM8K × 100 steps, sim)")
        print(f"{'policy':16s} {'n':>3s} {'hrs':>7s} {'steps/hr':>9s} "
              f"{'util%':>7s} {'idle%':>7s}")
        for (pol, n), s in out.items():
            print(f"{pol:16s} {n:3d} {s['time_hrs']:7.2f} "
                  f"{s['steps_per_hr']:9.1f} {s['utilization_pct']:7.2f} "
                  f"{s['idle_pct']:7.2f}")
    return out


def main():
    with Timer() as t:
        out = run()
    for (pol, n), s in out.items():
        emit(f"fig6_{pol}_n{n}", t.seconds * 1e6 / len(out),
             f"steps_per_hr={s['steps_per_hr']:.1f} "
             f"util={s['utilization_pct']:.2f}%")


if __name__ == "__main__":
    main()
