"""Paper Table 4 — ablation: MARLaaS (full) vs w/o async (synchronous
barrier) vs w/o multi-LoRA (per-task weight streaming). Ten concurrent
AMC12 replicas on qwen3-0.6b for one epoch (≈25 steps each)."""
from __future__ import annotations

from .common import Timer, emit, run_policy

PAPER = {  # throughput steps/hr, util %, idle %, hours
    "marlaas": (255.6, 22.55, 17.73, 1.81),
    "w/o async": (86.4, 7.04, 45.01, 8.13),
    "w/o multi-LoRA": (54.0, 5.29, 34.12, 12.98),
}

VARIANTS = {
    "marlaas": "marlaas",
    "w/o async": "multilora_sync",
    "w/o multi-LoRA": "marlaas_nomlora",
}
STEPS = 25


def run(verbose: bool = True):
    out = {}
    for label, pol in VARIANTS.items():
        out[label] = run_policy(pol, "qwen3-0.6b", "amc12", 10, STEPS)
    if verbose:
        print("\n# Table 4 — ablation (10× AMC12, one epoch, sim)")
        print(f"{'variant':16s}{'steps/hr':>9s} {'util%':>7s} "
              f"{'idle%':>7s} {'hrs':>6s}  | paper: sph/util/idle/hrs")
        for label, s in out.items():
            p = PAPER[label]
            print(f"{label:16s}{s['steps_per_hr']:9.1f} "
                  f"{s['utilization_pct']:7.2f} {s['idle_pct']:7.2f} "
                  f"{s['time_hrs']:6.2f}  | {p[0]:.1f}/{p[1]:.2f}/"
                  f"{p[2]:.2f}/{p[3]:.2f}")
    return out


def main():
    with Timer() as t:
        out = run()
    for label, s in out.items():
        emit(f"table4_{label.replace(' ', '_').replace('/', '')}",
             t.seconds * 1e6 / 3,
             f"steps_per_hr={s['steps_per_hr']:.1f} "
             f"util={s['utilization_pct']:.2f}% idle={s['idle_pct']:.2f}% "
             f"hrs={s['time_hrs']:.2f}")


if __name__ == "__main__":
    main()
