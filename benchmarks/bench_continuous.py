"""Continuous batching vs round-fused rollout (paper §4.1/§4.5).

Workload: N tenants, each with its own LoRA, submitting mixed-length rows
(alternating 16 / 64 ``max_new_tokens`` — the length skew that makes the
round barrier expensive). Both schedulers get the SAME decode-slot capacity
(= same KV memory): the round-fused baseline runs ``generate()`` on
slot-capacity-sized chunks of the cross-tenant queue, barriering each chunk
on its slowest row; the continuous engine streams the identical queue
through its persistent slot pool, evicting and refilling per row.

tokens/sec counts generated tokens over rollout wall time (best of
``PASSES`` timed passes after a full warm-up pass; row lengths are
deterministic given the per-request PRNG keys, so every pass and both
schedulers see identical tokens). Rows terminate naturally (EOS or budget)
— unpredictable lengths are precisely the regime where the round barrier
loses. Parity additionally checks continuous output == round-fused output
token-for-token.

  PYTHONPATH=src python -m benchmarks.bench_continuous [tenants ...]
"""
from __future__ import annotations

import dataclasses
import random
import sys
import time

import jax

from repro.configs import REGISTRY, reduced
from repro.data import tokenizer as tok
from repro.envs.tasks import make_env
from repro.lora.adapters import init_lora
from repro.models import init_params
from repro.rollout.engine import (ContinuousRolloutEngine, RolloutEngine,
                                  RolloutRequest)

MAX_SLOTS = 8
ROWS_PER_TENANT = 6
MAX_LEN = 128
SHORT, LONG = 16, 64
PASSES = 3

_STATE = {}


def _model():
    """Tiny CPU model, built once on first use (import stays cheap)."""
    if not _STATE:
        cfg = dataclasses.replace(reduced(REGISTRY["granite-3-2b"],
                                          dtype="float32"),
                                  vocab_size=tok.VOCAB_SIZE)
        _STATE["cfg"] = cfg
        _STATE["params"] = init_params(jax.random.PRNGKey(0), cfg)
    return _STATE["cfg"], _STATE["params"]


def _workload(n_tenants: int):
    cfg, _ = _model()
    env = make_env("gsm8k")
    rng = random.Random(0)
    trees = [init_lora(jax.random.PRNGKey(100 + i), cfg)
             for i in range(n_tenants)]
    reqs = []
    for row in range(ROWS_PER_TENANT):          # round-robin across tenants:
        for t in range(n_tenants):              # chunks mix short & long rows
            prompt, truth = env.sample_prompt(rng)
            reqs.append(RolloutRequest(
                f"tenant{t}", t, prompt, truth, env,
                max_new_tokens=SHORT if t % 2 == 0 else LONG,
                seed=len(reqs)))
    return reqs, trees


def _gen_tokens(results):
    return sum(len(r["tokens"]) - r["prompt_len"] for r in results)


def run_round_fused(reqs, trees):
    """generate() on slot-capacity chunks: each chunk barriers on its
    slowest row — the §4.1 stall."""
    cfg, params = _model()
    eng = RolloutEngine(cfg, params, max_len=MAX_LEN, seed=0)
    # full untimed pass warms every (chunk-width, prompt-bucket) compile;
    # both schedulers get the same treatment
    for i in range(0, len(reqs), MAX_SLOTS):
        eng.generate(reqs[i:i + MAX_SLOTS], trees)
    wall = float("inf")
    for _ in range(PASSES):
        results = []
        t0 = time.monotonic()
        for i in range(0, len(reqs), MAX_SLOTS):
            chunk = reqs[i:i + MAX_SLOTS]
            res, _ = eng.generate(chunk, trees)
            results.extend(res)
        wall = min(wall, time.monotonic() - t0)
    return results, wall


def run_continuous(reqs, trees):
    cfg, params = _model()
    eng = ContinuousRolloutEngine(cfg, params, max_slots=MAX_SLOTS,
                                  max_adapters=len(trees), max_len=MAX_LEN,
                                  seed=0)
    # full untimed pass (identical queue) warms every refill/step compile
    eng.run_requests(list(reqs), trees, deadline_s=600)
    wall = float("inf")
    for _ in range(PASSES):
        eng.stats = type(eng.stats)()           # fresh stats per pass
        t0 = time.monotonic()
        results, stats = eng.run_requests(reqs, trees, deadline_s=600)
        wall = min(wall, time.monotonic() - t0)
    return results, wall, stats


def bench(n_tenants: int):
    reqs, trees = _workload(n_tenants)
    fused_res, fused_wall = run_round_fused(reqs, trees)
    cont_res, cont_wall, stats = run_continuous(reqs, trees)

    parity = all(a["tokens"] == b["tokens"]
                 for a, b in zip(fused_res, cont_res))
    fused_tps = _gen_tokens(fused_res) / fused_wall
    cont_tps = _gen_tokens(cont_res) / cont_wall
    speedup = cont_tps / fused_tps
    print(f"bench_continuous,tenants={n_tenants},"
          f"fused_tok_s={fused_tps:.1f},cont_tok_s={cont_tps:.1f},"
          f"speedup={speedup:.2f}x,"
          f"slot_util={100 * stats.slot_utilization():.1f}%,"
          f"parity={'ok' if parity else 'FAIL'}")
    return speedup, parity


def main(argv):
    tenant_counts = [int(a) for a in argv] or [4, 8, 16]
    ok = True
    for n in tenant_counts:
        speedup, parity = bench(n)
        if n == 8 and speedup < 1.5:
            print(f"FAIL: 8-tenant speedup {speedup:.2f}x < 1.5x")
            ok = False
        if not parity:
            print(f"FAIL: continuous/one-shot token mismatch at {n} tenants")
            ok = False
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
