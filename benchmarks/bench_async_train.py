"""Event-driven off-policy trainer vs round-synchronous baseline (ISSUE 7
tentpole gate).

Workload: 16 tenants through one threaded MARLaaS runtime — 8 plain gsm8k
tenants plus 8 agentic search tenants whose forced tool call costs
ENV_LATENCY seconds in the disaggregated env stage (the row parks, its
decode slot is recycled). The regime is LATENCY-BOUND by construction:
the model is tiny and budgets are short, so an arm's time-to-final-commit
is dominated by how well it hides the per-round tool-latency chain.

Two arms over the IDENTICAL tenant set (same seeds, same deterministic
forced-CALL pattern):

  sync   — baseline: strict on-policy round loop. A tenant's round N+1
           cannot start until round N commits, so each agentic tenant
           serializes TARGET_STEPS park latencies end to end.
  async  — this PR: bounded staleness (max_staleness versions ahead) with
           per-tenant completed-episode queues. Rollout pipelines the
           whole issue window at once, so successive rounds' parks
           overlap and each tenant pays the latency roughly once.

Metrics: time-to-final-commit (wall seconds from run start to the LAST
commit of any tenant) and the trainer idle-with-work fraction (seconds
the trainer sat waiting while a dispatchable micro-batch existed, over
its first-to-last-train span — sub-threshold partial assemblies are not
dispatchable work). Gates:

    ttfc(sync) / ttfc(async)   >= 1.2x
    trainer_idle_frac(async)   <= 0.1

A third arm re-runs async with end-to-end episode tracing ON (ISSUE 9):
it must reproduce each episode's submission→commit latency as the sum of
its per-stage components (±1%), name a bottleneck stage for every one of
the 16 tenants, and stay within the tracing-overhead gate

    ttfc(traced) / ttfc(async) <= 1.03

(the workload is deterministic — both arms generate identical tokens, so
the ttfc ratio IS the tokens/sec ratio). The traced arm's Perfetto trace
lands in BENCH_async_train_trace.json (CI artifact; open at
ui.perfetto.dev — park→env→resume flow arrows link the stage tracks).

Measured arms run against a persistent JAX compilation cache populated by
a full-size warm pass of each arm: the engine jits per-instance closures,
so without the on-disk cache every fresh runtime would re-XLA-compile all
~90 refill/decode/train shape buckets and the bench would time the
compiler, not the scheduler.

  PYTHONPATH=src python -m benchmarks.bench_async_train [--json out.json]
"""
from __future__ import annotations

import dataclasses
import json
import sys
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.configs import REGISTRY, reduced
from repro.core.manager import TaskSpec
from repro.core.runtime import MARLaaSRuntime, RuntimeConfig
from repro.data import tokenizer as tok
from repro.models import init_params
import repro.rollout.engine as eng_mod
import repro.rollout.prefill as pf_mod

PLAIN_TENANTS = 8
AGENTIC_TENANTS = 8
N_TENANTS = PLAIN_TENANTS + AGENTIC_TENANTS
DECODE_SLOTS = 16
MAX_LEN = 32
GROUP_SIZE = 2
NUM_GROUPS = 1
TARGET_STEPS = 3
PLAIN_BUDGET, AGENTIC_BUDGET = 4, 6
ENV_LATENCY = 1.5             # per forced tool call (deterministic: std 0)
CALL_AT = 2                   # sampled-token counter that emits CALL
MAX_STALENESS = 2
ENV_WORKERS = 32              # >= concurrent parks: workers never queue
GATE_SPEEDUP = 1.2
GATE_IDLE_FRAC = 0.1
GATE_TRACE_OVERHEAD = 1.03    # ttfc(traced) / ttfc(async) ceiling
GATE_TRACE_RESIDUAL = 0.01    # max |Σcomponents - e2e| / e2e per episode
TRACE_ARTIFACT = "BENCH_async_train_trace.json"

_STATE = {}


def _compile_cache():
    """Persistent XLA compile cache for this process: the engine jits
    per-instance closures, so each fresh runtime re-traces every shape
    bucket — with the cache, only the warm pass compiles and the measured
    arms load cached executables in milliseconds."""
    if _STATE.get("cache"):
        return
    jax.config.update("jax_compilation_cache_dir",
                      tempfile.mkdtemp(prefix="bench_async_train_xla_"))
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
    _STATE["cache"] = True


def _bias_sampler():
    """Deterministic forced-CALL pattern: every row samples CALL at token
    counter CALL_AT (a no-op for the non-agentic gsm8k tenants) and EOS is
    remapped away so row lengths are exactly their budgets. Applied once,
    identically to both arms."""
    if _STATE.get("biased"):
        return
    orig = pf_mod._sample_rows

    def biased(logits, keys, counters, temps):
        s = orig(logits, keys, counters, temps)
        s = jnp.where(s == tok.EOS, 10, s)
        return jnp.where(counters == CALL_AT, tok.CALL, s)

    pf_mod._sample_rows = biased
    eng_mod._sample_rows = biased
    _STATE["biased"] = True


def _model():
    if "cfg" not in _STATE:
        cfg = dataclasses.replace(reduced(REGISTRY["granite-3-2b"],
                                          dtype="float32"),
                                  vocab_size=tok.VOCAB_SIZE)
        _STATE["cfg"] = cfg
        _STATE["params"] = init_params(jax.random.PRNGKey(0), cfg)
    return _STATE["cfg"], _STATE["params"]


def _runtime(async_train: bool, trace: bool = False):
    """One arm's runtime over the mixed 16-tenant workload. Both arms build
    from the same base params and the same per-tenant seeds."""
    _compile_cache()
    _bias_sampler()
    cfg, params = _model()
    rt = MARLaaSRuntime(cfg, params, RuntimeConfig(
        policy="marlaas", max_len=MAX_LEN, max_slots=DECODE_SLOTS,
        max_adapter_slots=N_TENANTS, seed=0,
        env_stage=True, env_workers=ENV_WORKERS,
        async_train=async_train, max_staleness=MAX_STALENESS,
        min_train_rows=0, trace=trace))
    for i in range(N_TENANTS):
        agentic = i >= N_TENANTS // 2
        env = "search" if agentic else "gsm8k"
        rt.submit_task(TaskSpec(
            f"{env}-{i}", env, group_size=GROUP_SIZE, num_groups=NUM_GROUPS,
            max_new_tokens=AGENTIC_BUDGET if agentic else PLAIN_BUDGET,
            target_steps=TARGET_STEPS))
        if agentic:
            rt.envs[f"{env}-{i}"].env_latency_mean = ENV_LATENCY
            rt.envs[f"{env}-{i}"].env_latency_std = 0.0
    return rt


def _run_once(async_train: bool, trace: bool = False) -> dict:
    rt = _runtime(async_train, trace=trace)
    t0 = time.monotonic()
    rt.run(timeout_s=600.0)
    assert rt.mgr.all_done(), "arm did not complete"
    last_commit = max(st.last_step_at for _, st in rt.mgr.task_items())
    idle = rt.rec.trainer_idle_stats()
    d = rt.mgr.drop_counters()
    out = {
        "time_to_final_commit_s": last_commit - t0,
        "wall_s": time.monotonic() - t0,
        "total_steps": rt.mgr.total_steps_done(),
        "rows_trained": rt._rows_trained,
        "rows_completed": rt._rows_completed,
        "trainer_idle_with_work_s": idle["trainer_idle_with_work_s"],
        "trainer_idle_frac": idle["trainer_idle_frac"],
        "trainer_span_s": idle["trainer_span_s"],
        **d,
    }
    if trace:
        out["trace_doc"] = rt.tracer.export_chrome()
        out["trace_dropped_events"] = rt.tracer.dropped_events
    return out


def run_arm(async_train: bool, reps: int = 2, trace: bool = False) -> dict:
    """Best-of-`reps` measured runs (min time-to-final-commit): refill
    shape buckets are timing-dependent, so even after the warm pass a
    measured run can stumble into one novel bucket and pay its compile —
    the repeated run takes the cached path. Drop counters and row totals
    must agree across reps (the workload is deterministic)."""
    runs = [_run_once(async_train, trace=trace) for _ in range(reps)]
    best = min(runs, key=lambda r: r["time_to_final_commit_s"])
    best["ttfc_runs"] = [round(r["time_to_final_commit_s"], 3)
                         for r in runs]
    return best


def _validate_trace(doc: dict) -> dict:
    """Critical-path acceptance on the traced arm's export: every
    committed episode's per-stage components sum to its E2E latency
    (within GATE_TRACE_RESIDUAL), every tenant gets a named bottleneck,
    and the park→env→resume hand-offs appear as s/f flow-event pairs."""
    from repro.obs.report import analyze, load_episodes
    res = analyze(load_episodes(doc))
    tenants = res["tenants"]
    flows = [e for e in doc["traceEvents"] if e["ph"] in ("s", "f")]
    kinds = {e["name"] for e in flows}
    ok = (res["episodes"] > 0
          and res["max_relative_residual"] <= GATE_TRACE_RESIDUAL
          and len(tenants) == N_TENANTS
          and all(v["bottleneck"] for v in tenants.values())
          and {"park", "resume"} <= kinds)
    return {
        "trace_episodes": res["episodes"],
        "trace_max_residual": res["max_relative_residual"],
        "trace_tenants": len(tenants),
        "trace_flow_events": len(flows),
        "trace_bottlenecks": {t: v["bottleneck"]
                              for t, v in sorted(tenants.items())},
        "trace_valid": bool(ok),
    }


def bench():
    # warm pass: a FULL-SIZE run of each arm AT THE REAL tool latency
    # compiles every jit shape bucket the measured arms will hit — refill
    # width x length buckets are timing-dependent (they depend on how many
    # rows return from the env stage between refills), so a smaller or
    # faster warm run would miss buckets and the measured arms would time
    # XLA, not scheduling. The compiled executables land in the
    # persistent cache where the measured runtimes' fresh jit closures
    # find them.
    for mode in (False, True):
        _runtime(mode).run(timeout_s=600.0)
    out = {"config": {
        "plain_tenants": PLAIN_TENANTS, "agentic_tenants": AGENTIC_TENANTS,
        "decode_slots": DECODE_SLOTS, "group_size": GROUP_SIZE,
        "num_groups": NUM_GROUPS, "target_steps": TARGET_STEPS,
        "budgets": [PLAIN_BUDGET, AGENTIC_BUDGET],
        "env_latency_s": ENV_LATENCY, "max_staleness": MAX_STALENESS}}
    out["async"] = run_arm(True)
    out["sync"] = run_arm(False)
    # tracing-overhead arm: async again with the tracer on — same tokens,
    # same schedule pressure, plus the trace acceptance checks
    traced = run_arm(True, trace=True)
    doc = traced.pop("trace_doc")
    with open(TRACE_ARTIFACT, "w") as f:
        json.dump(doc, f)
    print(f"wrote {TRACE_ARTIFACT}")
    traced.update(_validate_trace(doc))
    out["traced"] = traced
    speedup = (out["sync"]["time_to_final_commit_s"]
               / out["async"]["time_to_final_commit_s"])
    overhead = (traced["time_to_final_commit_s"]
                / out["async"]["time_to_final_commit_s"])
    out["ttfc_speedup"] = float(speedup)
    out["trace_overhead"] = float(overhead)
    out["gate_speedup"] = GATE_SPEEDUP
    out["gate_idle_frac"] = GATE_IDLE_FRAC
    out["gate_trace_overhead"] = GATE_TRACE_OVERHEAD
    ok = (speedup >= GATE_SPEEDUP
          and out["async"]["trainer_idle_frac"] <= GATE_IDLE_FRAC
          and overhead <= GATE_TRACE_OVERHEAD
          and traced["trace_valid"])
    # all arms must do the same amount of committed training
    if any(out[arm]["total_steps"] != out["async"]["total_steps"]
           or out[arm]["rows_trained"] != out["async"]["rows_trained"]
           for arm in ("sync", "traced")):
        ok = False
    out["pass"] = bool(ok)
    print(f"bench_async_train,tenants={N_TENANTS},slots={DECODE_SLOTS},"
          f"steps={TARGET_STEPS},staleness={MAX_STALENESS},"
          f"sync_ttfc={out['sync']['time_to_final_commit_s']:.2f}s,"
          f"async_ttfc={out['async']['time_to_final_commit_s']:.2f}s,"
          f"speedup={speedup:.2f}x,"
          f"async_idle_frac={out['async']['trainer_idle_frac']:.3f},"
          f"sync_idle_frac={out['sync']['trainer_idle_frac']:.3f},"
          f"stale_dropped={out['async']['stale_rows_dropped']},"
          f"trace_overhead={overhead:.3f},"
          f"trace_residual={traced['trace_max_residual']:.4f},"
          f"trace_eps={traced['trace_episodes']},"
          f"{'ok' if out['pass'] else 'FAIL'}")
    return out


def main(argv):
    json_path = None
    if "--json" in argv:
        i = argv.index("--json")
        if i + 1 >= len(argv):
            print("usage: bench_async_train [--json OUT.json]")
            return 2
        json_path = argv[i + 1]
    out = bench()
    if json_path:
        with open(json_path, "w") as f:
            json.dump(out, f, indent=2)
        print(f"wrote {json_path}")
    from benchmarks.common import bench_record, write_bench_json
    rec = bench_record(
        "async_train", GATE_SPEEDUP,
        out["async"]["time_to_final_commit_s"],
        out["sync"]["time_to_final_commit_s"],
        higher_is_better=False,
        extra={"trainer_idle_frac": out["async"]["trainer_idle_frac"],
               "gate_idle_frac": GATE_IDLE_FRAC,
               "stale_rows_dropped": out["async"]["stale_rows_dropped"],
               "trace_overhead": out["trace_overhead"],
               "gate_trace_overhead": GATE_TRACE_OVERHEAD,
               "trace_max_residual": out["traced"]["trace_max_residual"],
               "trace_episodes": out["traced"]["trace_episodes"],
               "trace_valid": out["traced"]["trace_valid"]})
    rec["pass"] = out["pass"]
    write_bench_json("BENCH_async_train.json", rec)
    return 0 if out["pass"] else 1


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
