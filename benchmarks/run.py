"""Benchmark harness aggregator — one module per paper table/figure.
Each prints ``name,us_per_call,derived`` CSV lines (plus a readable table).

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run table2     # one table
"""
import sys


def main() -> None:
    from . import (fig1, fig6, fig7, kernels, roofline_report, table1,
                   table2, table3, table4)
    mods = {"table1": table1, "table2": table2, "table3": table3,
            "table4": table4, "fig1": fig1, "fig6": fig6, "fig7": fig7,
            "kernels": kernels, "roofline": roofline_report}
    wanted = sys.argv[1:] or list(mods)
    print("name,us_per_call,derived")
    for name in wanted:
        mods[name].main()


if __name__ == '__main__':
    main()
