"""Paper Table 2 — end-to-end training performance (wall hours, steps/hr)
across scheduling regimes and model scales: 10 replicas of the search-agent
workload × 100 steps each."""
from __future__ import annotations

from repro.core.policies import POLICIES

from .common import Timer, emit, run_policy

PAPER = {   # (hours, steps/hr) per (policy, scale)
    ("single_disagg", "qwen3-0.6b"): (18.33, 54.0),
    ("single_colloc", "qwen3-0.6b"): (10.64, 93.6),
    ("multilora_sync", "qwen3-0.6b"): (6.07, 164.88),
    ("marlaas", "qwen3-0.6b"): (3.42, 292.83),
    ("single_disagg", "qwen3-14b"): (24.48, 39.6),
    ("single_colloc", "qwen3-14b"): (12.70, 79.2),
    ("multilora_sync", "qwen3-14b"): (16.21, 61.56),
    ("marlaas", "qwen3-14b"): (3.72, 226.8),
    ("single_disagg", "qwen3-32b"): (25.13, 38.88),
    ("single_colloc", "qwen3-32b"): (17.98, 55.62),
    ("multilora_sync", "qwen3-32b"): (18.89, 52.92),
    ("marlaas", "qwen3-32b"): (9.87, 101.30),
}

N_TASKS, STEPS = 10, 100


def run(verbose: bool = True):
    out = {}
    for scale in ("qwen3-0.6b", "qwen3-14b", "qwen3-32b"):
        for pol in POLICIES:
            s = run_policy(pol, scale, "search", N_TASKS, STEPS)
            out[(pol, scale)] = s
    if verbose:
        print("\n# Table 2 — end-to-end (10× search-agent × 100 steps, sim)")
        print(f"{'policy':16s} {'scale':12s} {'hrs':>7s} {'steps/hr':>9s}"
              f" {'paper_hrs':>9s} {'paper_sph':>9s}")
        for (pol, scale), s in out.items():
            ph, ps = PAPER[(pol, scale)]
            print(f"{pol:16s} {scale:12s} {s['time_hrs']:7.2f} "
                  f"{s['steps_per_hr']:9.1f} {ph:9.2f} {ps:9.1f}")
    return out


def main():
    with Timer() as t:
        out = run()
    for (pol, scale), s in out.items():
        emit(f"table2_{pol}_{scale}", t.seconds * 1e6 / len(out),
             f"hrs={s['time_hrs']:.2f} steps_per_hr={s['steps_per_hr']:.1f}")


if __name__ == "__main__":
    main()
