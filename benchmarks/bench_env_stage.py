"""Disaggregated env-interaction stage vs freeze-in-slot baseline (ISSUE 4
tentpole gate).

Workload: agentic high-latency tenants mixed with plain tenants through a
shared slot engine — AGENTIC_TENANTS tenants run multi-turn multi-hop
search episodes whose tool calls cost ENV_LATENCY seconds each (the
paper's external tool/judge latency), alongside PLAIN_TENANTS tenants of
short math rows that keep the scheduler queue non-empty.

Two engines over the IDENTICAL workload (same seeds, same forced-CALL
pattern, same tool responses — token streams are bit-identical by
construction, asserted below):

  frozen    — baseline: a row that emits CALL freezes in its decode slot
              (advance=0) for the whole env latency; the slot is dead
              weight (booked as tool_wait_slot_steps).
  envstage  — this PR: the row PARKS (slot vacated and instantly refilled
              from the queue) while an EnvWorker runs the call; the
              response resumes through the prefill path. No slot is ever
              held by an I/O-waiting row.

Both modes run with the disaggregated prefill stage on, so the ONLY
difference is where tool-waiting rows live. Metric: rollout tokens/sec
over a full drain of the mixed workload. Gate:

    tokens_per_sec(envstage) / tokens_per_sec(frozen) >= 1.2x

Agentic rows emit CALL deterministically (the sampler is biased at fixed
per-row token counters), so both modes replay the exact same episodes.

  PYTHONPATH=src python -m benchmarks.bench_env_stage [--json out.json]
"""
from __future__ import annotations

import dataclasses
import json
import random
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import REGISTRY, reduced
from repro.data import tokenizer as tok
from repro.envs.tasks import make_env
from repro.lora.adapters import init_lora
from repro.models import init_params
import repro.rollout.engine as eng_mod
import repro.rollout.prefill as pf_mod
from repro.rollout.engine import ContinuousRolloutEngine, RolloutRequest

PLAIN_TENANTS = 2
AGENTIC_TENANTS = 2
N_TENANTS = PLAIN_TENANTS + AGENTIC_TENANTS
DECODE_SLOTS = 4
MAX_LEN = 64
PLAIN_ROWS = 10               # rows per plain tenant
AGENTIC_ROWS = 8              # rows per agentic tenant
PLAIN_BUDGET, AGENTIC_BUDGET = 8, 8
ENV_LATENCY = 0.12            # per tool call (deterministic: std 0)
HOPS = 2                      # tool turns per agentic episode
CALL_AT = (1, 10)             # per-row sampled-token counters that emit CALL
ENV_WORKERS = 8
GATE = 1.2

_STATE = {}


def _bias_sampler():
    """Deterministic forced-CALL pattern: rows sample CALL at fixed token
    counters (EOS remapped away so row lengths are deterministic). Applies
    identically to every engine/mode — token streams stay bit-identical."""
    if _STATE.get("biased"):
        return
    orig = pf_mod._sample_rows

    def biased(logits, keys, counters, temps):
        s = orig(logits, keys, counters, temps)
        s = jnp.where(s == tok.EOS, 10, s)
        hit = jnp.zeros(counters.shape, bool)
        for c in CALL_AT:
            hit = hit | (counters == c)
        return jnp.where(hit, tok.CALL, s)

    pf_mod._sample_rows = biased
    eng_mod._sample_rows = biased
    _STATE["biased"] = True


def _model():
    if "cfg" not in _STATE:
        cfg = dataclasses.replace(reduced(REGISTRY["granite-3-2b"],
                                          dtype="float32"),
                                  vocab_size=tok.VOCAB_SIZE)
        _STATE["cfg"] = cfg
        _STATE["params"] = init_params(jax.random.PRNGKey(0), cfg)
        _STATE["trees"] = [init_lora(jax.random.PRNGKey(100 + t), cfg)
                           for t in range(N_TENANTS)]
    return _STATE["cfg"], _STATE["params"], _STATE["trees"]


def _requests():
    """Deterministic mixed workload: same requests (prompts, truths, seeds)
    for both modes."""
    plain_env = make_env("gsm8k")
    agentic_env = make_env("hopsearch", kb_size=16, hops=HOPS, seed=0)
    agentic_env.env_latency_mean = ENV_LATENCY
    agentic_env.env_latency_std = 0.0
    rng = random.Random(0)
    reqs = []
    for t in range(N_TENANTS):
        agentic = t >= PLAIN_TENANTS
        env = agentic_env if agentic else plain_env
        rows = AGENTIC_ROWS if agentic else PLAIN_ROWS
        budget = AGENTIC_BUDGET if agentic else PLAIN_BUDGET
        for i in range(rows):
            prompt, truth = env.sample_prompt(rng)
            reqs.append(RolloutRequest(
                f"t{t}", t, prompt, truth, env, max_new_tokens=budget,
                seed=t * 4096 + i))
    return reqs


def _drain(eng, reqs):
    for r in reqs:
        eng.submit(r)
    n, t0 = 0, time.monotonic()
    guard = t0 + 600.0
    while not eng.idle() and time.monotonic() < guard:
        progressed = eng.step()
        n += len(eng.drain_completions())
        if not progressed:
            time.sleep(0.0002)      # waiting on env/prefill stages only
    wall = time.monotonic() - t0
    assert n == len(reqs), f"only {n}/{len(reqs)} rows completed"
    return wall


def run_mode(mode: str):
    """One engine per mode; the IDENTICAL workload drains twice — the first
    pass warms every jit variant (refill widths/buckets, splice) on the
    SAME engine, the second is measured. Throughput would otherwise gate on
    compile pauses, not scheduling."""
    _bias_sampler()
    cfg, params, trees = _model()
    eng = ContinuousRolloutEngine(
        cfg, params, max_slots=DECODE_SLOTS, max_adapters=N_TENANTS,
        max_len=MAX_LEN, seed=0, scheduler="srpt", disagg_prefill=True,
        env_stage=(mode == "envstage"), env_workers=ENV_WORKERS)
    for t in range(N_TENANTS):
        eng.set_adapters(t, trees[t])
    _drain(eng, _requests())                 # warm pass (compiles)
    from repro.rollout.engine import RolloutStats
    eng.stats = RolloutStats()               # measure the second pass only
    wall = _drain(eng, _requests())
    stats = eng.stats
    eng.shutdown()
    return wall, stats


def bench():
    out = {"config": {
        "plain_tenants": PLAIN_TENANTS, "agentic_tenants": AGENTIC_TENANTS,
        "decode_slots": DECODE_SLOTS, "plain_rows": PLAIN_ROWS,
        "agentic_rows": AGENTIC_ROWS, "env_latency_s": ENV_LATENCY,
        "hops": HOPS, "env_workers": ENV_WORKERS,
        "budgets": [PLAIN_BUDGET, AGENTIC_BUDGET]}}
    for mode in ("frozen", "envstage"):
        wall, stats = run_mode(mode)
        out[mode] = {
            "wall_s": wall,
            "tokens_per_sec": stats.tokens_generated / wall,
            "tokens_generated": stats.tokens_generated,
            "decode_steps": stats.decode_steps,
            "tool_wait_slot_steps": stats.tool_wait_slot_steps,
            "parks": stats.parks,
            "resumes": stats.resumes,
            "env_wait_s": stats.env_wait_seconds,
            "env_wait_by_task": dict(stats.env_wait_by_task),
            "slot_utilization": stats.slot_utilization(),
        }
    ratio = (out["envstage"]["tokens_per_sec"]
             / out["frozen"]["tokens_per_sec"])
    out["tokens_per_sec_speedup"] = float(ratio)
    out["gate"] = GATE
    out["pass"] = bool(ratio >= GATE)
    # identical workload sanity: bit-identical token streams => same totals
    if out["frozen"]["tokens_generated"] != out["envstage"]["tokens_generated"]:
        out["pass"] = False
    # the disaggregation guarantee itself: no slot ever held a waiting row
    if out["envstage"]["tool_wait_slot_steps"] != 0:
        out["pass"] = False
    if out["envstage"]["parks"] == 0 or out["frozen"]["tool_wait_slot_steps"] == 0:
        out["pass"] = False                  # the agentic path never engaged
    print(f"bench_env_stage,plain={PLAIN_TENANTS},agentic={AGENTIC_TENANTS},"
          f"lat={ENV_LATENCY*1e3:.0f}ms,hops={HOPS},"
          f"frozen={out['frozen']['tokens_per_sec']:.0f}tok/s,"
          f"envstage={out['envstage']['tokens_per_sec']:.0f}tok/s,"
          f"speedup={ratio:.2f}x,"
          f"frozen_wait_steps={out['frozen']['tool_wait_slot_steps']},"
          f"envstage_wait_steps={out['envstage']['tool_wait_slot_steps']},"
          f"{'ok' if out['pass'] else 'FAIL'}")
    return out


def main(argv):
    json_path = None
    if "--json" in argv:
        i = argv.index("--json")
        if i + 1 >= len(argv):
            print("usage: bench_env_stage [--json OUT.json]")
            return 2
        json_path = argv[i + 1]
    out = bench()
    if json_path:
        with open(json_path, "w") as f:
            json.dump(out, f, indent=2)
        print(f"wrote {json_path}")
    from benchmarks.common import bench_record, write_bench_json
    write_bench_json("BENCH_env_stage.json", bench_record(
        "env_stage", GATE, out["envstage"]["tokens_per_sec"],
        out["frozen"]["tokens_per_sec"], extra={"pass": out["pass"]}))
    return 0 if out["pass"] else 1


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
