"""Paper Table 3 — NPU utilization % and idle % under the same Table-2
setup (10× search-agent, 100 steps)."""
from __future__ import annotations

from repro.core.policies import POLICIES

from .common import Timer, emit, run_policy

PAPER = {
    ("single_disagg", "qwen3-0.6b"): (1.56, 74.18),
    ("single_colloc", "qwen3-0.6b"): (3.78, 58.03),
    ("multilora_sync", "qwen3-0.6b"): (1.78, 85.16),
    ("marlaas", "qwen3-0.6b"): (6.67, 40.52),
    ("single_disagg", "qwen3-14b"): (4.45, 72.52),
    ("single_colloc", "qwen3-14b"): (5.51, 73.71),
    ("multilora_sync", "qwen3-14b"): (3.08, 86.70),
    ("marlaas", "qwen3-14b"): (8.67, 40.46),
    ("single_disagg", "qwen3-32b"): (1.58, 93.18),
    ("single_colloc", "qwen3-32b"): (2.65, 81.06),
    ("multilora_sync", "qwen3-32b"): (1.77, 87.88),
    ("marlaas", "qwen3-32b"): (4.35, 78.98),
}


def run(verbose: bool = True):
    out = {}
    for scale in ("qwen3-0.6b", "qwen3-14b", "qwen3-32b"):
        for pol in POLICIES:
            out[(pol, scale)] = run_policy(pol, scale, "search", 10, 100)
    if verbose:
        print("\n# Table 3 — utilization / idle (10× search-agent, sim)")
        print(f"{'policy':16s} {'scale':12s} {'util%':>7s} {'idle%':>7s}"
              f" {'paper_u':>8s} {'paper_i':>8s}")
        for (pol, scale), s in out.items():
            pu, pi = PAPER[(pol, scale)]
            print(f"{pol:16s} {scale:12s} {s['utilization_pct']:7.2f} "
                  f"{s['idle_pct']:7.2f} {pu:8.2f} {pi:8.2f}")
    return out


def main():
    with Timer() as t:
        out = run()
    for (pol, scale), s in out.items():
        emit(f"table3_{pol}_{scale}", t.seconds * 1e6 / len(out),
             f"util={s['utilization_pct']:.2f}% idle={s['idle_pct']:.2f}%")


if __name__ == "__main__":
    main()
