"""Paged KV-cache block pool + snapshot/restore resume vs dense-cache +
replay (ISSUE 5 tentpole gates).

Gate 1 — rollout throughput on a RESUME-HEAVY agentic mix (tool turns ≫ 1,
long prompts): every tool turn parks the row, and the resume either

  dense   — baseline: prefill-REPLAYS prompt + generated prefix from
            tokens (an N-turn episode recomputes O(N·len) prefill, booked
            as ``RolloutStats.replay_tokens``), or
  paged   — this PR: SPLICES the row's snapshotted KV pages + SSM state
            back into freshly allocated pool pages (host↔device memcpy,
            no forward pass; ``replay_tokens == 0`` by construction).

Both modes run the env-interaction stage over the IDENTICAL workload
(same seeds, same forced-CALL pattern — token streams are bit-identical,
asserted below). Gate: tokens_per_sec(paged) / tokens_per_sec(dense)
>= 1.2x, with paged replay_tokens == 0.

Gate 2 — resident-row packing under one HBM budget for a MIXED-LENGTH
tenant set: the dense cache forces admission to charge every row
``prompt + max_new_tokens`` (the reservation physically exists), while
page accounting charges ``ceil(expected_len / page)`` pages. Short-ish
tenants (warm length predictor) then pack >= 1.5x more resident rows
into the same budget. Computed with the production estimators
(``task_state_bytes`` vs ``task_state_bytes_paged``) on the full granite
config.

  PYTHONPATH=src python -m benchmarks.bench_paged_kv [--json out.json]
"""
from __future__ import annotations

import dataclasses
import json
import random
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_record, write_bench_json
from repro.configs import REGISTRY, reduced
from repro.core.admission import task_state_bytes, task_state_bytes_paged
from repro.core.manager import TaskSpec
from repro.data import tokenizer as tok
from repro.envs.tasks import make_env
from repro.lora.adapters import init_lora
from repro.models import init_params
import repro.rollout.engine as eng_mod
import repro.rollout.prefill as pf_mod
from repro.rollout.engine import ContinuousRolloutEngine, RolloutRequest

N_TENANTS = 3
ROWS_PER_TENANT = 6
DECODE_SLOTS = 4
MAX_LEN = 320
PAGE = 32
PROMPT_FILL = 220             # filler tokens ahead of the real prompt: the
                              # replay cost this PR kills is O(prefix)
BUDGET = 14                   # sampled tokens per row
HOPS = 6                      # tool turns per episode (6 parks + resumes)
# per-row GEN-stream counters emitting CALL — spaced past each ~6-token
# forced RESP…ENDRESP block so every entry lands on a SAMPLED position
CALL_AT = (1, 9, 17, 25, 33, 41)
ENV_LATENCY = 0.01
ENV_WORKERS = 16
KV_POOL_PAGES = 56            # restore headroom above the 4-slot resident
                              # working set (restores allocate pages BEFORE
                              # a slot frees; a tight pool stalls them)
GATE_TPS = 1.2
GATE_ROWS = 1.5

_STATE = {}


def _bias_sampler():
    """Deterministic forced-CALL pattern (same trick as bench_env_stage):
    rows sample CALL at fixed token counters, EOS remapped away. Applies
    identically to both modes — token streams stay bit-identical."""
    if _STATE.get("biased"):
        return
    orig = pf_mod._sample_rows

    def biased(logits, keys, counters, temps):
        s = orig(logits, keys, counters, temps)
        s = jnp.where(s == tok.EOS, 10, s)
        hit = jnp.zeros(counters.shape, bool)
        for c in CALL_AT:
            hit = hit | (counters == c)
        return jnp.where(hit, tok.CALL, s)

    pf_mod._sample_rows = biased
    eng_mod._sample_rows = biased
    _STATE["biased"] = True


def _model():
    if "cfg" not in _STATE:
        # big enough that a replay prefill costs REAL compute (the tiny
        # test preset is dispatch-bound and machine-noise drowns the
        # replay cost the gate measures)
        cfg = dataclasses.replace(
            reduced(REGISTRY["granite-3-2b"], dtype="float32"),
            num_layers=4, d_model=256, num_heads=4, num_kv_heads=2,
            head_dim=64, d_ff=512, vocab_size=tok.VOCAB_SIZE)
        _STATE["cfg"] = cfg
        _STATE["params"] = init_params(jax.random.PRNGKey(0), cfg)
        _STATE["trees"] = [init_lora(jax.random.PRNGKey(100 + t), cfg)
                           for t in range(N_TENANTS)]
    return _STATE["cfg"], _STATE["params"], _STATE["trees"]


def _requests():
    env = make_env("hopsearch", kb_size=16, hops=HOPS, seed=0)
    env.env_latency_mean = ENV_LATENCY
    env.env_latency_std = 0.0
    rng = random.Random(0)
    filler = (tok.encode("x" * 7 + " ") * 32)[:PROMPT_FILL]
    reqs = []
    for t in range(N_TENANTS):
        for i in range(ROWS_PER_TENANT):
            prompt, truth = env.sample_prompt(rng)
            # long prefix: the rightmost-entity lookup ignores the filler,
            # but every REPLAY re-prefills it — per turn, per episode
            prompt = [prompt[0]] + filler + prompt[1:]
            reqs.append(RolloutRequest(
                f"t{t}", t, prompt, truth, env, max_new_tokens=BUDGET,
                seed=t * 4096 + i))
    return reqs


def _drain(eng, reqs):
    for r in reqs:
        eng.submit(r)
    n, t0 = 0, time.monotonic()
    guard = t0 + 900.0
    while not eng.idle() and time.monotonic() < guard:
        progressed = eng.step()
        n += len(eng.drain_completions())
        if not progressed:
            time.sleep(0.0002)
    wall = time.monotonic() - t0
    assert n == len(reqs), f"only {n}/{len(reqs)} rows completed"
    return wall


def run_mode(mode: str):
    """One engine per mode; warm pass compiles every jit variant on the
    same engine, the second pass is measured."""
    _bias_sampler()
    cfg, params, trees = _model()
    eng = ContinuousRolloutEngine(
        cfg, params, max_slots=DECODE_SLOTS, max_adapters=N_TENANTS,
        max_len=MAX_LEN, seed=0, scheduler="srpt",
        env_stage=True, env_workers=ENV_WORKERS,
        paged_kv=(mode == "paged"), kv_page_size=PAGE,
        kv_pool_pages=KV_POOL_PAGES, resume_restore=True)
    for t in range(N_TENANTS):
        eng.set_adapters(t, trees[t])
    _drain(eng, _requests())                 # warm pass (compiles)
    from repro.rollout.engine import RolloutStats
    eng.stats = RolloutStats()               # measure the second pass only
    wall = _drain(eng, _requests())
    stats = eng.stats
    pool = eng.page_stats()
    eng.shutdown()
    return wall, stats, pool


def packing_gate():
    """Gate 2: resident rows admitted under one HBM budget — worst-case
    max_len reservations vs page accounting with a warm length predictor
    on a mixed-length tenant set."""
    cfg = REGISTRY["granite-3-2b"]
    prompt_len, page = 64, PAGE
    budget = 2e9
    # mixed tenant set: most tenants answer short (EMA ~ 48 sampled
    # tokens), a minority runs to their full 512-token budget
    tenants = []
    for i in range(64):
        spec = TaskSpec(f"t{i}", "gsm8k", group_size=8, num_groups=2,
                        max_new_tokens=512)
        expected = 512.0 if i % 8 == 0 else 48.0
        tenants.append((spec, expected))

    def admitted_rows(estimator):
        used, rows = 0.0, 0
        for spec, expected in tenants:
            need = estimator(spec, expected)
            if used + need > budget:
                continue
            used += need
            rows += spec.rows_per_batch
        return rows

    dense_rows = admitted_rows(
        lambda spec, _: task_state_bytes(cfg, spec, prompt_len))
    paged_rows = admitted_rows(
        lambda spec, expected: task_state_bytes_paged(
            cfg, spec, prompt_len, page_size=page,
            expected_new_tokens=expected))
    return dense_rows, paged_rows


def bench():
    out = {"config": {
        "tenants": N_TENANTS, "rows_per_tenant": ROWS_PER_TENANT,
        "decode_slots": DECODE_SLOTS, "max_len": MAX_LEN, "page": PAGE,
        "prompt_fill": PROMPT_FILL, "budget": BUDGET, "hops": HOPS,
        "env_latency_s": ENV_LATENCY}}
    for mode in ("dense", "paged"):
        wall, stats, pool = run_mode(mode)
        out[mode] = {
            "wall_s": wall,
            "tokens_per_sec": stats.tokens_generated / wall,
            "tokens_generated": stats.tokens_generated,
            "decode_steps": stats.decode_steps,
            "parks": stats.parks, "resumes": stats.resumes,
            "replays": stats.replays, "replay_tokens": stats.replay_tokens,
            "restores": stats.restores,
            "replay_tokens_saved": stats.replay_tokens_saved,
            "prefill_seconds": stats.prefill_seconds,
            "page_pool": pool,
        }
    tps_ratio = (out["paged"]["tokens_per_sec"]
                 / out["dense"]["tokens_per_sec"])
    dense_rows, paged_rows = packing_gate()
    row_ratio = paged_rows / max(1, dense_rows)
    out["packing"] = {"dense_rows": dense_rows, "paged_rows": paged_rows,
                      "ratio": row_ratio, "gate": GATE_ROWS}
    out["tokens_per_sec_speedup"] = float(tps_ratio)
    out["gate"] = GATE_TPS
    out["pass"] = bool(tps_ratio >= GATE_TPS and row_ratio >= GATE_ROWS)
    # identical workload sanity: bit-identical token streams => same totals
    if out["dense"]["tokens_generated"] != out["paged"]["tokens_generated"]:
        out["pass"] = False
    # the tentpole guarantee: restore-resume never replays
    if out["paged"]["replay_tokens"] != 0 or out["paged"]["restores"] == 0:
        out["pass"] = False
    if out["dense"]["replay_tokens"] == 0:
        out["pass"] = False                  # baseline never replayed: the
                                             # workload isn't resume-heavy
    print(f"bench_paged_kv,tenants={N_TENANTS},hops={HOPS},"
          f"prefix={PROMPT_FILL},"
          f"dense={out['dense']['tokens_per_sec']:.0f}tok/s,"
          f"paged={out['paged']['tokens_per_sec']:.0f}tok/s,"
          f"speedup={tps_ratio:.2f}x,"
          f"dense_replay_tokens={out['dense']['replay_tokens']},"
          f"paged_replay_tokens={out['paged']['replay_tokens']},"
          f"rows {dense_rows}->{paged_rows} ({row_ratio:.2f}x),"
          f"{'ok' if out['pass'] else 'FAIL'}")
    return out


def main(argv):
    json_path = None
    if "--json" in argv:
        i = argv.index("--json")
        if i + 1 >= len(argv):
            print("usage: bench_paged_kv [--json OUT.json]")
            return 2
        json_path = argv[i + 1]
    out = bench()
    if json_path:
        with open(json_path, "w") as f:
            json.dump(out, f, indent=2)
        print(f"wrote {json_path}")
    # uniform cross-PR schema (benchmarks/common.py satellite)
    write_bench_json("BENCH_paged_kv.json", bench_record(
        "paged_kv", GATE_TPS, out["paged"]["tokens_per_sec"],
        out["dense"]["tokens_per_sec"],
        extra={"packing": out["packing"],
               "replay_tokens_dense": out["dense"]["replay_tokens"],
               "replay_tokens_paged": out["paged"]["replay_tokens"],
               "pass": out["pass"]}))
    return 0 if out["pass"] else 1


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
