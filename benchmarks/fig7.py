"""Paper Figure 7 — user-facing latency vs concurrency: TTFS (time to first
step) and TPTS (time per training step)."""
from __future__ import annotations

from .common import Timer, emit, run_policy

CONCURRENCY = (1, 2, 4, 8, 16, 32)
POLS = ("single_disagg", "multilora_sync", "marlaas")


def run(verbose: bool = True):
    out = {}
    for n in CONCURRENCY:
        for pol in POLS:
            out[(pol, n)] = run_policy(pol, "qwen3-0.6b", "gsm8k", n, 20)
    if verbose:
        print("\n# Fig 7 — TTFS / TPTS vs concurrency (sim)")
        print(f"{'policy':16s} {'n':>3s} {'ttfs_mean_s':>12s} "
              f"{'ttfs_max_s':>11s} {'tpts_mean_s':>12s}")
        for (pol, n), s in out.items():
            print(f"{pol:16s} {n:3d} {s['ttfs_mean_s']:12.1f} "
                  f"{s['ttfs_max_s']:11.1f} {s['tpts_mean_s']:12.1f}")
    return out


def main():
    with Timer() as t:
        out = run()
    for (pol, n), s in out.items():
        emit(f"fig7_{pol}_n{n}", t.seconds * 1e6 / len(out),
             f"ttfs={s['ttfs_mean_s']:.1f}s tpts={s['tpts_mean_s']:.1f}s")


if __name__ == "__main__":
    main()
