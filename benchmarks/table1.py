"""Paper Table 1 — rollout latency and synchronization-induced waiting time
when jointly training three heterogeneous tasks (GSM8K, wiki-search, AMC12)
under synchronized multi-task execution."""
from __future__ import annotations

from repro.configs import get_config
from repro.core.manager import TaskSpec
from repro.core.simulator import PAPER_WORKLOADS, Simulator

from .common import Timer, calibrate, emit, hardware_for

PAPER = {"gsm8k": (23.45, 59.50), "search": (27.98, 10.99),
         "amc12": (70.58, 15.75)}


def run(verbose: bool = True):
    hw = hardware_for("qwen3-0.6b")
    calibrate(hw)
    cfg = get_config("qwen3-0.6b")
    sim = Simulator(cfg, hw, seed=0)
    done = {}
    for env in ("gsm8k", "search", "amc12"):
        sim.submit_rollout(TaskSpec(env, env), PAPER_WORKLOADS[env], 0,
                           (lambda e=env: done.setdefault(e, sim.clock.t)))
    sim.run()
    barrier = max(done.values())
    # the barrier waits for the straggler; then training runs sequentially —
    # each task additionally waits for the jobs trained before it
    train_s = {}
    order = sorted(done, key=done.get)
    acc = 0.0
    rows = {}
    for env in order:
        rows[env] = {"rollout_latency_s": done[env],
                     "wait_s": (barrier - done[env]) + acc}
        acc += sim.submit_train(TaskSpec(env, env), PAPER_WORKLOADS[env], 0,
                                lambda: None)
    if verbose:
        print("\n# Table 1 — heterogeneous sync rollout latency / wait (sim)")
        print(f"{'task':8s} {'rollout_s':>10s} {'wait_s':>8s}"
              f" {'paper_roll':>10s} {'paper_wait':>10s}")
        for env in ("gsm8k", "search", "amc12"):
            r = rows[env]
            print(f"{env:8s} {r['rollout_latency_s']:10.2f} "
                  f"{r['wait_s']:8.2f} {PAPER[env][0]:10.2f} "
                  f"{PAPER[env][1]:10.2f}")
    return rows


def main():
    with Timer() as t:
        rows = run()
    for env, r in rows.items():
        emit(f"table1_{env}", t.seconds * 1e6 / 3,
             f"rollout={r['rollout_latency_s']:.2f}s wait={r['wait_s']:.2f}s")


if __name__ == "__main__":
    main()
