"""Global copy-on-write prefix cache vs private-pages paged KV (ISSUE 8
tentpole gates).

Workload: GRPO groups of N=8 same-prompt rows per tenant (long template
prompts) on a resume-heavy agentic mix (forced tool turns -> park/resume
every few tokens). Identical seeds + forced-CALL pattern in both modes —
token streams are bit-identical, asserted below.

  private — PR 5 baseline (prefix_cache off): every row prefills its full
            prompt into private pages; park/resume round-trips host
            snapshots (``snapshots`` > 0).
  shared  — this PR: the group leader prefills once, siblings map their
            block tables onto the SAME retained pages (tail included) and
            fork copy-on-write on first divergent write; park/resume is a
            pure retain + block-table splice (``device_resident_resumes``
            > 0, zero host snapshot bytes).

Gate 1 — prefill-FLOPs: total ``prefill_tokens`` (shared) must be <= 1/2
of the private baseline (the suffix-only installs erase the group's
duplicate prompt prefills).

Gate 2 — resident-row packing under one HBM budget: the group-shared
admission estimator (prompt pages charged once per group) must admit
>= 1.3x the rows of the private page-granular estimator. Computed with
the production estimators on the full granite config.

Zero-host-bytes invariant: shared-mode ``snapshots == 0`` and
``snapshot_drops`` unchanged from the baseline (both 0), with
``device_resident_resumes`` > 0.

  PYTHONPATH=src python -m benchmarks.bench_prefix_cache [--json out.json]
"""
from __future__ import annotations

import dataclasses
import json
import random
import sys
import time

import jax
import jax.numpy as jnp

from benchmarks.common import bench_record, write_bench_json
from repro.configs import REGISTRY, reduced
from repro.core.admission import (task_state_bytes_paged,
                                  task_state_bytes_shared)
from repro.core.manager import TaskSpec
from repro.data import tokenizer as tok
from repro.envs.tasks import make_env
from repro.lora.adapters import init_lora
from repro.models import init_params
import repro.rollout.engine as eng_mod
import repro.rollout.prefill as pf_mod
from repro.rollout.engine import ContinuousRolloutEngine, RolloutRequest

N_TENANTS = 2
GROUP = 8                     # GRPO siblings per tenant (gate: N >= 8)
DECODE_SLOTS = 4
MAX_LEN = 256
PAGE = 32
PROMPT_FILL = 150             # shared template ahead of the real prompt:
                              # the duplicate prefill cost COW sharing kills
BUDGET = 10                   # sampled tokens per row
HOPS = 3                      # tool turns per episode (parks + resumes)
CALL_AT = (1, 9, 17)          # spaced past each forced RESP block
ENV_LATENCY = 0.01
ENV_WORKERS = 16
KV_POOL_PAGES = 72            # resident set + parked rows + radix index
GATE_PREFILL = 2.0            # >= 2x prefill-token reduction
GATE_ROWS = 1.3               # >= 1.3x admitted resident rows

_STATE = {}


def _bias_sampler():
    """Deterministic forced-CALL pattern (bench_env_stage trick): rows
    sample CALL at fixed counters, EOS remapped away — identical in both
    modes, so token streams stay bit-identical."""
    if _STATE.get("biased"):
        return
    orig = pf_mod._sample_rows

    def biased(logits, keys, counters, temps):
        s = orig(logits, keys, counters, temps)
        s = jnp.where(s == tok.EOS, 10, s)
        hit = jnp.zeros(counters.shape, bool)
        for c in CALL_AT:
            hit = hit | (counters == c)
        return jnp.where(hit, tok.CALL, s)

    pf_mod._sample_rows = biased
    eng_mod._sample_rows = biased
    _STATE["biased"] = True


def _model():
    if "cfg" not in _STATE:
        cfg = dataclasses.replace(
            reduced(REGISTRY["granite-3-2b"], dtype="float32"),
            num_layers=4, d_model=256, num_heads=4, num_kv_heads=2,
            head_dim=64, d_ff=512, vocab_size=tok.VOCAB_SIZE)
        _STATE["cfg"] = cfg
        _STATE["params"] = init_params(jax.random.PRNGKey(0), cfg)
        _STATE["trees"] = [init_lora(jax.random.PRNGKey(100 + t), cfg)
                          for t in range(N_TENANTS)]
    return _STATE["cfg"], _STATE["params"], _STATE["trees"]


def _requests():
    """N_TENANTS GRPO groups: all GROUP rows of a tenant share ONE long
    prompt (template + question), differing only in seed."""
    env = make_env("hopsearch", kb_size=16, hops=HOPS, seed=0)
    env.env_latency_mean = ENV_LATENCY
    env.env_latency_std = 0.0
    rng = random.Random(0)
    filler = (tok.encode("x" * 7 + " ") * 32)[:PROMPT_FILL]
    reqs = []
    for t in range(N_TENANTS):
        prompt, truth = env.sample_prompt(rng)
        prompt = [prompt[0]] + filler + prompt[1:]
        for i in range(GROUP):
            reqs.append(RolloutRequest(
                f"t{t}", t, prompt, truth, env, max_new_tokens=BUDGET,
                seed=t * 4096 + i))
    return reqs


def _drain(eng, reqs):
    toks = 0
    for r in reqs:
        eng.submit(r)
    n, t0 = 0, time.monotonic()
    guard = t0 + 900.0
    while not eng.idle() and time.monotonic() < guard:
        progressed = eng.step()
        for c in eng.drain_completions():
            n += 1
            toks += len(c.tokens)
        if not progressed:
            time.sleep(0.0002)
    wall = time.monotonic() - t0
    assert n == len(reqs), f"only {n}/{len(reqs)} rows completed"
    return wall, toks


def run_mode(mode: str):
    """One engine per mode; warm pass compiles every jit variant (and, in
    shared mode, seeds the radix index), the second pass is measured."""
    _bias_sampler()
    cfg, params, trees = _model()
    eng = ContinuousRolloutEngine(
        cfg, params, max_slots=DECODE_SLOTS, max_adapters=N_TENANTS,
        max_len=MAX_LEN, seed=0, scheduler="srpt",
        env_stage=True, env_workers=ENV_WORKERS,
        paged_kv=True, kv_page_size=PAGE, kv_pool_pages=KV_POOL_PAGES,
        resume_restore=True, prefix_cache=(mode == "shared"))
    for t in range(N_TENANTS):
        eng.set_adapters(t, trees[t])
    _drain(eng, _requests())                 # warm pass (compiles)
    from repro.rollout.engine import RolloutStats
    eng.stats = RolloutStats()               # measure the second pass only
    wall, toks = _drain(eng, _requests())
    eng.check_page_invariants()
    stats = eng.stats
    pool = eng.page_stats()
    eng.shutdown()
    return wall, toks, stats, pool


def packing_gate():
    """Gate 2: rows admitted under one HBM budget — private page-granular
    charges vs group-shared charges (full prompt pages once per group)."""
    cfg = REGISTRY["granite-3-2b"]
    prompt_len, page, budget = 256, PAGE, 2e9
    tenants = [TaskSpec(f"t{i}", "gsm8k", group_size=8, num_groups=2,
                        max_new_tokens=512) for i in range(64)]

    def admitted_rows(estimator):
        used, rows = 0.0, 0
        for spec in tenants:
            need = estimator(spec)
            if used + need > budget:
                continue
            used += need
            rows += spec.rows_per_batch
        return rows

    private = admitted_rows(lambda spec: task_state_bytes_paged(
        cfg, spec, prompt_len, page_size=page, expected_new_tokens=48.0))
    shared = admitted_rows(lambda spec: task_state_bytes_shared(
        cfg, spec, prompt_len, page_size=page, expected_new_tokens=48.0))
    return private, shared


def bench():
    out = {"config": {
        "tenants": N_TENANTS, "group": GROUP, "decode_slots": DECODE_SLOTS,
        "max_len": MAX_LEN, "page": PAGE, "prompt_fill": PROMPT_FILL,
        "budget": BUDGET, "hops": HOPS, "env_latency_s": ENV_LATENCY}}
    for mode in ("private", "shared"):
        wall, toks, stats, pool = run_mode(mode)
        out[mode] = {
            "wall_s": wall,
            "tokens_per_sec": stats.tokens_generated / wall,
            "tokens_generated": stats.tokens_generated,
            "completion_tokens": toks,
            "prefill_tokens": stats.prefill_tokens,
            "prefix_hits": stats.prefix_hits,
            "prefix_hit_tokens": stats.prefix_hit_tokens,
            "cow_forks": stats.cow_forks,
            "parks": stats.parks, "resumes": stats.resumes,
            "restores": stats.restores,
            "snapshots": stats.snapshots,
            "snapshot_drops": stats.snapshot_drops,
            "device_resident_resumes": stats.device_resident_resumes,
            "fused_forced_tokens": stats.fused_forced_tokens,
            "page_pool": pool,
        }
    pf_ratio = (out["private"]["prefill_tokens"]
                / max(1, out["shared"]["prefill_tokens"]))
    private_rows, shared_rows = packing_gate()
    row_ratio = shared_rows / max(1, private_rows)
    out["packing"] = {"private_rows": private_rows,
                      "shared_rows": shared_rows,
                      "ratio": row_ratio, "gate": GATE_ROWS}
    out["prefill_reduction"] = float(pf_ratio)
    out["gate"] = GATE_PREFILL
    ok = pf_ratio >= GATE_PREFILL and row_ratio >= GATE_ROWS
    # identical workload sanity: bit-identical token streams
    if out["private"]["completion_tokens"] != out["shared"]["completion_tokens"]:
        ok = False
    # sharing actually engaged: group siblings hit, tail pages forked
    if out["shared"]["prefix_hits"] == 0 or out["shared"]["cow_forks"] == 0:
        ok = False
    # zero-host-bytes park/resume: device-resident resumes with NO host
    # snapshots, snapshot_drops unchanged from the baseline
    if out["shared"]["device_resident_resumes"] == 0:
        ok = False
    if out["shared"]["snapshots"] != 0:
        ok = False
    if out["shared"]["snapshot_drops"] != out["private"]["snapshot_drops"]:
        ok = False
    out["pass"] = bool(ok)
    print(f"bench_prefix_cache,tenants={N_TENANTS},group={GROUP},"
          f"prefix={PROMPT_FILL},"
          f"private_prefill={out['private']['prefill_tokens']},"
          f"shared_prefill={out['shared']['prefill_tokens']},"
          f"reduction={pf_ratio:.2f}x,"
          f"cow_forks={out['shared']['cow_forks']},"
          f"dev_resumes={out['shared']['device_resident_resumes']},"
          f"rows {private_rows}->{shared_rows} ({row_ratio:.2f}x),"
          f"{'ok' if out['pass'] else 'FAIL'}")
    return out


def main(argv):
    json_path = None
    if "--json" in argv:
        i = argv.index("--json")
        if i + 1 >= len(argv):
            print("usage: bench_prefix_cache [--json OUT.json]")
            return 2
        json_path = argv[i + 1]
    out = bench()
    if json_path:
        with open(json_path, "w") as f:
            json.dump(out, f, indent=2)
        print(f"wrote {json_path}")
    # uniform cross-PR schema (benchmarks/common.py): prefill tokens,
    # lower is better — ratio = private/shared >= gate passes
    write_bench_json("BENCH_prefix_cache.json", bench_record(
        "prefix_cache", GATE_PREFILL, out["shared"]["prefill_tokens"],
        out["private"]["prefill_tokens"], higher_is_better=False,
        extra={"packing": out["packing"],
               "cow_forks": out["shared"]["cow_forks"],
               "prefix_hits": out["shared"]["prefix_hits"],
               "device_resident_resumes":
                   out["shared"]["device_resident_resumes"],
               "tokens_per_sec_shared": out["shared"]["tokens_per_sec"],
               "tokens_per_sec_private": out["private"]["tokens_per_sec"],
               "pass": out["pass"]}))
    return 0 if out["pass"] else 1


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
