"""Shared benchmark scaffolding: paper-scale hardware models, workload
calibration, and pretty-printing.

Calibration: one scalar `calib` (per model scale) anchors the simulator's
absolute decode latency to the paper's measured Table 1 rollout latency
(GSM8K on qwen3-0.6b = 23.45 s). Relative behaviour across scheduling
regimes comes from the model structure, never from the knob.
"""
from __future__ import annotations

import time
from typing import Dict, List

from repro.configs import get_config
from repro.core.admission import AdmissionConfig
from repro.core.manager import TaskSpec
from repro.core.metrics import summarize
from repro.core.policies import run_sim
from repro.core.simulator import (HardwareModel, PAPER_WORKLOADS, Simulator,
                                  WorkloadModel)

PAPER_T1_GSM8K_S = 23.45       # paper Table 1, rollout latency seconds


def hardware_for(model_name: str) -> HardwareModel:
    """Paper §5: 0.6B→2 train devs, 14B→4, 32B→16 (two nodes = 32 devs)."""
    if model_name == "qwen3-32b":
        return HardwareModel(n_devices=32, train_devices=16)
    if model_name == "qwen3-14b":
        return HardwareModel(n_devices=16, train_devices=4)
    return HardwareModel(n_devices=16, train_devices=2)


def calibrate(hw: HardwareModel, model_name: str = "qwen3-0.6b") -> float:
    """Anchor the simulator to the paper's measured solo GSM8K rollout
    latency (Table 1: 23.45 s on qwen3-0.6b): solve the fixed per-decode-step
    latency so the solo run matches; the bandwidth model still governs the
    saturated (high-concurrency / big-model) regime. Sets hw.step_overhead_s
    and returns it."""
    cfg = get_config(model_name)
    wl = PAPER_WORKLOADS["gsm8k"]
    N = cfg.active_param_count()
    prefill_s = (2 * N * wl.prompt_len * wl.rows
                 / (hw.rollout_devices * hw.peak_flops_per_dev
                    * hw.prefill_mfu))
    hw.step_overhead_s = max(0.0, (PAPER_T1_GSM8K_S - prefill_s) / wl.gen_len)
    return hw.step_overhead_s


def make_specs(env: str, n: int, steps: int) -> List[TaskSpec]:
    return [TaskSpec(f"{env}-{i}", env, target_steps=steps) for i in range(n)]


def run_policy(policy: str, model_name: str, env: str, n_tasks: int,
               steps: int, budget: float = 400e9) -> Dict[str, float]:
    cfg = get_config(model_name)
    hw = hardware_for(model_name)
    calibrate(hw)
    specs = make_specs(env, n_tasks, steps)
    wls = {s.task_id: PAPER_WORKLOADS[env] for s in specs}
    mgr, rec = run_sim(policy, cfg, hw, specs, wls,
                       AdmissionConfig(memory_budget_bytes=budget))
    return summarize(mgr, rec)


class Timer:
    def __enter__(self):
        self.t0 = time.monotonic()
        return self

    def __exit__(self, *a):
        self.seconds = time.monotonic() - self.t0


def emit(name: str, us_per_call: float, derived: str):
    """The harness-wide CSV line: name,us_per_call,derived."""
    print(f"{name},{us_per_call:.1f},{derived}")


def bench_record(name: str, gate: float, measured: float, baseline: float,
                 *, higher_is_better: bool = True, extra: dict = None
                 ) -> dict:
    """Uniform cross-PR benchmark schema (CI artifact contract): every
    engine benchmark emits ``{name, gate, measured, baseline, ratio,
    pass}`` plus free-form `extra`, so the perf trajectory is
    machine-readable across PRs regardless of what each bench measures.
    `measured`/`baseline` are in the bench's native unit; `ratio` is
    oriented so that >= `gate` passes (inverted when lower is better)."""
    if higher_is_better:
        ratio = measured / baseline if baseline else 0.0
    else:
        ratio = baseline / measured if measured else 0.0
    rec = {"name": name, "gate": float(gate), "measured": float(measured),
           "baseline": float(baseline), "ratio": float(ratio),
           "pass": bool(ratio >= gate)}
    if extra:
        rec.update(extra)
    return rec


def write_bench_json(path: str, record: dict):
    """Write one bench record (the ``BENCH_<name>.json`` artifact)."""
    import json
    with open(path, "w") as f:
        json.dump(record, f, indent=2)
    print(f"wrote {path}")
