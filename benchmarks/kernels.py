"""Kernel micro-benchmarks. CPU interpret-mode wall times are NOT TPU
numbers — the derived column therefore reports the analytic TPU-v5e
expectation (bytes/flops through the roofline constants), which is what the
kernels are tiled for."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.launch.roofline import HBM_BW, PEAK_FLOPS

from .common import emit


def _time(fn, *args, iters=3):
    fn(*args)                        # compile/warm
    t0 = time.monotonic()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.monotonic() - t0) / iters * 1e6


def bench_sgmv():
    R, d, r, dout, T = 256, 2048, 16, 2048, 8
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    x = jax.random.normal(ks[0], (R, d), jnp.float32)
    a = jax.random.normal(ks[1], (T, d, r), jnp.float32) * 0.1
    b = jax.random.normal(ks[2], (T, r, dout), jnp.float32) * 0.1
    ids = jax.random.randint(ks[3], (R,), 0, T)
    us_ref = _time(jax.jit(ref.sgmv_ref), x, a, b, ids)
    flops = 2 * R * r * (d + dout)
    bytes_ = (R * (d + dout) * 4 + T * r * (d + dout) * 4)
    tpu_us = max(flops / PEAK_FLOPS, bytes_ / HBM_BW) * 1e6
    emit("kernel_sgmv_ref_cpu", us_ref,
         f"flops={flops:.2e} tpu_v5e_roofline_us={tpu_us:.2f}")
    # the O(T)-matmul reference does T× the work — the kernel's win
    ref_flops = 2 * R * r * (d + dout) * T
    emit("kernel_sgmv_speedup_vs_ref", us_ref,
         f"kernel_does_{flops/ref_flops:.3f}x_ref_flops")


def bench_gqa_decode():
    B, H, KVH, hd, S = 8, 32, 8, 128, 4096
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    q = jax.random.normal(ks[0], (B, H, hd), jnp.bfloat16)
    ck = jax.random.normal(ks[1], (B, S, KVH, hd), jnp.bfloat16)
    cv = jax.random.normal(ks[2], (B, S, KVH, hd), jnp.bfloat16)
    pos = jnp.full((B,), S, jnp.int32)
    us = _time(jax.jit(ref.gqa_decode_ref), q, ck, cv, pos)
    bytes_ = 2 * B * S * KVH * hd * 2          # K+V read once
    tpu_us = bytes_ / HBM_BW * 1e6
    emit("kernel_gqa_decode_ref_cpu", us,
         f"cache_bytes={bytes_:.2e} tpu_v5e_bw_bound_us={tpu_us:.2f}")


def bench_token_logprob():
    R, d, V = 512, 1024, 32768
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    h = jax.random.normal(ks[0], (R, d), jnp.float32)
    w = jax.random.normal(ks[1], (d, V), jnp.float32) * 0.1
    t = jax.random.randint(ks[2], (R,), 0, V)
    us = _time(jax.jit(lambda *a: ref.token_logprob_ref(*a)[0]), h, w, t)
    naive_bytes = R * V * 4 * 3                # logits write+read+softmax
    fused_bytes = (R * d + d * V) * 4
    emit("kernel_token_logprob_ref_cpu", us,
         f"fused_saves={naive_bytes / fused_bytes:.1f}x_hbm_traffic")


def main():
    bench_sgmv()
    bench_gqa_decode()
    bench_token_logprob()


if __name__ == "__main__":
    main()
