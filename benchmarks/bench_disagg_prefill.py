"""Disaggregated async prefill vs fused-refill baseline (ISSUE 3 tentpole).

Workload: mixed prompt lengths through a shared slot engine — SHORT_TENANTS
tenants with short prompts and short budgets (the interference victims)
alongside LONG_TENANTS tenants whose long prompts dominate prefill cost.
Every tenant streams rounds of ROWS rows, resubmitting the moment its
previous round completes, so long-prompt prefills arrive continuously while
the short tenants decode.

Two engines over the IDENTICAL workload (same scheduler, same seeds, same
token streams — the engines are bit-identical by construction):

  fused   — baseline: every refill prefill runs as one fused jitted call ON
            the decode stream; a long prompt stalls decode for all resident
            tenants (booked as decode_stall_seconds).
  disagg  — this PR: prefill runs chunked on async worker threads; the
            decode stream only splices ready rows (scatter-only call), so
            short tenants' decode proceeds while long prompts prefill.

Metric: wall-clock per-round latency of the SHORT tenants (what a latency-
sensitive tenant of the service experiences), p95 across rounds. Gate:

    p95(fused) / p95(disagg) >= 1.2x

The win is core-count independent: even on one core, chunked prefill
yields the decode stream between chunks, so short rounds stop paying for
whole long prompts. decode-stall seconds are reported for both modes —
~0 for disagg while the fused baseline stalls on every refill.

  PYTHONPATH=src python -m benchmarks.bench_disagg_prefill [--json out.json]
"""
from __future__ import annotations

import dataclasses
import json
import random
import sys
import time

import jax
import numpy as np

from repro.configs import REGISTRY, reduced
from repro.data import tokenizer as tok
from repro.envs.tasks import make_env
from repro.lora.adapters import init_lora
from repro.models import init_params
from repro.rollout.engine import ContinuousRolloutEngine, RolloutRequest

SHORT_TENANTS = 2
LONG_TENANTS = 2
N_TENANTS = SHORT_TENANTS + LONG_TENANTS
DECODE_SLOTS = 4
MAX_LEN = 320
ROWS = 2
SHORT_ROUNDS = 8          # measured rounds per short tenant
LONG_PROMPT = 256         # long-prompt tokens (prefill-dominated)
SHORT_BUDGET, LONG_BUDGET = 6, 4
PREFILL_CHUNK = 64
PREFILL_WORKERS = 2
GATE = 1.2

_STATE = {}


def _model():
    if not _STATE:
        cfg = dataclasses.replace(reduced(REGISTRY["granite-3-2b"],
                                          dtype="float32"),
                                  vocab_size=tok.VOCAB_SIZE)
        _STATE["cfg"] = cfg
        _STATE["params"] = init_params(jax.random.PRNGKey(0), cfg)
        _STATE["trees"] = [init_lora(jax.random.PRNGKey(100 + t), cfg)
                           for t in range(N_TENANTS)]
    return _STATE["cfg"], _STATE["params"], _STATE["trees"]


def _prompts():
    """Deterministic per-(tenant, round, row) prompts: tenants < SHORT are
    natural short env prompts; the rest are stretched to LONG_PROMPT."""
    env = make_env("gsm8k")
    rng = random.Random(0)
    table = {}
    for t in range(N_TENANTS):
        for r in range(64):           # enough rounds for the long streamers
            for i in range(ROWS):
                prompt, truth = env.sample_prompt(rng)
                if t >= SHORT_TENANTS:
                    prompt = (prompt * 64)[:LONG_PROMPT]
                table[(t, r, i)] = (prompt, truth)
    return env, table


def _stream(eng, env, table):
    """Stream rounds until every SHORT tenant finished SHORT_ROUNDS; long
    tenants resubmit continuously so prefill pressure never lets up.
    Returns short-round wall latencies."""
    rounds_done = [0] * N_TENANTS
    inflight = [0] * N_TENANTS
    ready_at = [0.0] * N_TENANTS
    short_lat = []
    t0 = time.monotonic()
    guard = t0 + 600.0

    def short_done():
        return all(rounds_done[t] >= SHORT_ROUNDS
                   for t in range(SHORT_TENANTS))

    while not short_done() and time.monotonic() < guard:
        for t in range(N_TENANTS):
            if inflight[t] == 0:
                if t < SHORT_TENANTS and rounds_done[t] >= SHORT_ROUNDS:
                    continue
                r = rounds_done[t]
                budget = SHORT_BUDGET if t < SHORT_TENANTS else LONG_BUDGET
                for i in range(ROWS):
                    prompt, truth = table[(t, r % 64, i)]
                    eng.submit(RolloutRequest(
                        f"t{t}", t, prompt, truth, env,
                        max_new_tokens=budget, seed=t * 4096 + r * 8 + i))
                inflight[t] = ROWS
        progressed = eng.step()
        now = time.monotonic()
        for c in eng.drain_completions():
            t = int(c.task_id[1:])
            inflight[t] -= 1
            if inflight[t] == 0:
                rounds_done[t] += 1
                if t < SHORT_TENANTS:
                    short_lat.append(now - t0 - ready_at[t])
                ready_at[t] = now - t0
        if not progressed:
            time.sleep(0.0002)        # waiting on the async prefill stage
    assert len(short_lat) == SHORT_TENANTS * SHORT_ROUNDS, (
        f"only {len(short_lat)} short rounds completed")
    return short_lat


def run_mode(mode: str):
    """One engine per mode; the IDENTICAL workload streams twice — the
    first pass warms every jit variant (refill width/prompt buckets, chunk
    offsets, splice) on the SAME engine instance, the second is measured.
    p95 would otherwise gate on compile pauses, not scheduling."""
    cfg, params, trees = _model()
    env, table = _prompts()
    eng = ContinuousRolloutEngine(
        cfg, params, max_slots=DECODE_SLOTS, max_adapters=N_TENANTS,
        max_len=MAX_LEN, seed=0, scheduler="srpt",
        disagg_prefill=(mode == "disagg"), prefill_chunk=PREFILL_CHUNK,
        prefill_workers=PREFILL_WORKERS)
    for t in range(N_TENANTS):
        eng.set_adapters(t, trees[t])
    _stream(eng, env, table)                 # warm pass (compiles)
    eng.drain(120.0)                         # finish the long stragglers
    eng.drain_completions()
    from repro.rollout.engine import RolloutStats
    eng.stats = RolloutStats()               # measure the second pass only
    lat = _stream(eng, env, table)
    stats = eng.stats
    eng.shutdown()
    return lat, stats


def bench():
    out = {"config": {
        "short_tenants": SHORT_TENANTS, "long_tenants": LONG_TENANTS,
        "decode_slots": DECODE_SLOTS, "rows_per_round": ROWS,
        "short_rounds": SHORT_ROUNDS, "long_prompt": LONG_PROMPT,
        "budgets": [SHORT_BUDGET, LONG_BUDGET],
        "prefill_chunk": PREFILL_CHUNK, "prefill_workers": PREFILL_WORKERS}}
    for mode in ("fused", "disagg"):
        lat, stats = run_mode(mode)
        out[mode] = {
            "p50_s": float(np.percentile(lat, 50)),
            "p95_s": float(np.percentile(lat, 95)),
            "mean_s": float(np.mean(lat)),
            "max_s": float(np.max(lat)),
            "decode_stall_s": stats.decode_stall_seconds,
            "prefill_s": stats.prefill_seconds,
            "decode_s": stats.decode_seconds,
            "splice_s": stats.splice_seconds,
            "splices": stats.splices,
            "prefill_chunks": stats.prefill_chunks,
            "decode_steps": stats.decode_steps,
        }
    ratio = out["fused"]["p95_s"] / out["disagg"]["p95_s"]
    out["p95_speedup"] = float(ratio)
    out["gate"] = GATE
    out["pass"] = bool(ratio >= GATE)
    # the disaggregation guarantee itself: decode never ran prefill work
    if out["disagg"]["decode_stall_s"] != 0.0:
        out["pass"] = False
    if out["disagg"]["prefill_chunks"] <= out["disagg"]["splices"]:
        out["pass"] = False                  # chunking never engaged
    print(f"bench_disagg_prefill,short={SHORT_TENANTS},long={LONG_TENANTS},"
          f"long_prompt={LONG_PROMPT},"
          f"fused_p95={out['fused']['p95_s']*1e3:.0f}ms,"
          f"disagg_p95={out['disagg']['p95_s']*1e3:.0f}ms,"
          f"p95_speedup={ratio:.2f}x,"
          f"fused_stall={out['fused']['decode_stall_s']:.2f}s,"
          f"disagg_stall={out['disagg']['decode_stall_s']:.2f}s,"
          f"{'ok' if out['pass'] else 'FAIL'}")
    return out


def main(argv):
    json_path = None
    if "--json" in argv:
        i = argv.index("--json")
        if i + 1 >= len(argv):
            print("usage: bench_disagg_prefill [--json OUT.json]")
            return 2
        json_path = argv[i + 1]
    out = bench()
    if json_path:
        with open(json_path, "w") as f:
            json.dump(out, f, indent=2)
        print(f"wrote {json_path}")
    from benchmarks.common import bench_record, write_bench_json
    write_bench_json("BENCH_disagg_prefill.json", bench_record(
        "disagg_prefill", GATE, out["disagg"]["p95_s"],
        out["fused"]["p95_s"], higher_is_better=False,
        extra={"pass": out["pass"]}))
    return 0 if out["pass"] else 1


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
