"""Fault-tolerance gate: chaos-scripted 16-tenant mixed workload (ISSUE 10).

Workload: the bench_async_train shape — 8 plain gsm8k tenants + 8 agentic
search tenants with a deterministic forced-CALL pattern — through the
fully disaggregated threaded runtime (async prefill workers, env-stage
workers, event-driven off-policy trainer).

Three arms:

  base    — fault-free. Run TWICE: the first doubles as the jit warm
            pass, and the two runs' reward histories must be
            bit-identical (chaos-off determinism — with ``chaos=None``
            no injector object exists, so the fault hooks cost one
            attribute check and cannot perturb the stream).
  chaos   — a capped deterministic fault script over every site the
            supervisor covers: prefill-worker kills and env-worker kills
            (restart + in-flight recovery), transient tool errors
            (retry-then-succeed), and a permanent tool-error burst that
            trips at least one agentic tenant's circuit breaker
            (fail_threshold=1) through quarantine and back out.

Gates (all must hold):

  - the chaos run COMPLETES: every tenant reaches target_steps (faults
    are capped, so every breaker trip must recover — an abandoned or
    wedged tenant fails the bench);
  - the extended row-conservation invariant holds EXACTLY on both arms:
    completed == trained + stale_dropped + discarded_tails + failed
    + quarantine_dropped + orphaned;
  - the script actually fired: worker kills on both stages, supervisor
    restarts, >= 1 quarantine trip;
  - healthy-tenant goodput (trained rows/sec over tenants untouched by
    faults) >= GATE_GOODPUT x the fault-free arm's;
  - chaos-off determinism: the two base runs' rewards are identical and
    an all-zero ChaosConfig builds no injector at all.

  PYTHONPATH=src python -m benchmarks.bench_chaos [--json out.json]
"""
from __future__ import annotations

import dataclasses
import json
import sys
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.configs import REGISTRY, reduced
from repro.core.chaos import ChaosConfig
from repro.core.manager import TaskSpec
from repro.core.runtime import MARLaaSRuntime, RuntimeConfig
from repro.data import tokenizer as tok
from repro.models import init_params
import repro.rollout.engine as eng_mod
import repro.rollout.prefill as pf_mod

PLAIN_TENANTS = 8
AGENTIC_TENANTS = 8
N_TENANTS = PLAIN_TENANTS + AGENTIC_TENANTS
DECODE_SLOTS = 16
MAX_LEN = 32
GROUP_SIZE = 2
NUM_GROUPS = 1
TARGET_STEPS = 3
PLAIN_BUDGET, AGENTIC_BUDGET = 4, 6
ENV_LATENCY = 0.2             # per forced tool call (deterministic: std 0)
CALL_AT = 2                   # sampled-token counter that emits CALL
MAX_STALENESS = 2
ENV_WORKERS = 16
GATE_GOODPUT = 0.85           # healthy-tenant goodput vs fault-free

CHAOS = ChaosConfig(
    seed=0,
    prefill_worker_kill=1.0,      # first pickups die; supervisor restarts
    env_worker_kill=1.0,
    tool_error_transient=1.0,     # retry-then-succeed burst
    transient_fail_count=1,
    tool_error_permanent=1.0,     # breaker-tripping burst
    max_faults_per_site=2)        # ...all exactly twice, then never again

_STATE = {}


def _compile_cache():
    if _STATE.get("cache"):
        return
    jax.config.update("jax_compilation_cache_dir",
                      tempfile.mkdtemp(prefix="bench_chaos_xla_"))
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
    _STATE["cache"] = True


def _bias_sampler():
    """Deterministic forced-CALL pattern (see bench_async_train): every
    row samples CALL at token counter CALL_AT and EOS is remapped away,
    so tool-call traffic never depends on what the tiny random model
    happens to sample."""
    if _STATE.get("biased"):
        return
    orig = pf_mod._sample_rows

    def biased(logits, keys, counters, temps):
        s = orig(logits, keys, counters, temps)
        s = jnp.where(s == tok.EOS, 10, s)
        return jnp.where(counters == CALL_AT, tok.CALL, s)

    pf_mod._sample_rows = biased
    eng_mod._sample_rows = biased
    _STATE["biased"] = True


def _model():
    if "cfg" not in _STATE:
        cfg = dataclasses.replace(reduced(REGISTRY["granite-3-2b"],
                                          dtype="float32"),
                                  vocab_size=tok.VOCAB_SIZE)
        _STATE["cfg"] = cfg
        _STATE["params"] = init_params(jax.random.PRNGKey(0), cfg)
    return _STATE["cfg"], _STATE["params"]


def _runtime(chaos):
    _compile_cache()
    _bias_sampler()
    cfg, params = _model()
    rt = MARLaaSRuntime(cfg, params, RuntimeConfig(
        policy="marlaas", max_len=MAX_LEN, max_slots=DECODE_SLOTS,
        max_adapter_slots=N_TENANTS, seed=0,
        disagg_prefill=True, prefill_workers=2,
        env_stage=True, env_workers=ENV_WORKERS,
        async_train=True, max_staleness=MAX_STALENESS, min_train_rows=0,
        chaos=chaos, tool_retry_base_s=0.01, tool_retry_max_s=0.1,
        breaker_fail_threshold=1, breaker_cooldown_s=0.3,
        breaker_max_trips=4))
    for i in range(N_TENANTS):
        agentic = i >= PLAIN_TENANTS
        env = "search" if agentic else "gsm8k"
        rt.submit_task(TaskSpec(
            f"{env}-{i}", env, group_size=GROUP_SIZE, num_groups=NUM_GROUPS,
            max_new_tokens=AGENTIC_BUDGET if agentic else PLAIN_BUDGET,
            target_steps=TARGET_STEPS))
        if agentic:
            rt.envs[f"{env}-{i}"].env_latency_mean = ENV_LATENCY
            rt.envs[f"{env}-{i}"].env_latency_std = 0.0
    return rt


def _accounting(rt) -> dict:
    acc = rt.row_accounting()
    acc["exact"] = acc["completed"] == (
        acc["trained"] + acc["stale_dropped"] + acc["discarded_tails"]
        + acc["failed"] + acc["quarantine_dropped"] + acc["orphaned"])
    return acc


def _healthy_goodput(rt, t0: float) -> dict:
    """Trained rows/sec over the tenants no fault ever touched (every
    tenant in the fault-free arm). Timed to the LAST healthy commit —
    quarantined tenants' cooldown stalls must not dilate the healthy
    denominator."""
    healthy = [st for _, st in rt.mgr.task_items()
               if st.failed_rows == 0 and st.quarantine_dropped_rows == 0]
    rows = sum(st.steps_done * rt.mgr.train_threshold(st.spec)
               for st in healthy)
    t1 = max((st.last_step_at for st in healthy if st.last_step_at),
             default=t0)
    span = max(1e-9, t1 - t0)
    return {"healthy_tenants": len(healthy), "healthy_rows": rows,
            "healthy_span_s": span, "goodput_rows_per_s": rows / span}


def _run_arm(chaos) -> dict:
    rt = _runtime(chaos)
    t0 = time.monotonic()
    rt.run(timeout_s=600.0)
    done = all(st.done for _, st in rt.mgr.task_items())
    at_target = all(st.steps_done >= TARGET_STEPS
                    for _, st in rt.mgr.task_items())
    c = rt.rec.counters_snapshot()
    out = {
        "wall_s": time.monotonic() - t0,
        "completed": done, "all_at_target": at_target,
        "rewards": {tid: list(st.reward_history)
                    for tid, st in rt.mgr.task_items()},
        "accounting": _accounting(rt),
        "goodput": _healthy_goodput(rt, t0),
        "chaos_injected": dict(rt.chaos.counts()) if rt.chaos else {},
        "supervisor": {k: v for k, v in c.items()
                       if k.startswith(("supervisor_", "env_", "chaos_"))},
        "quarantine_trips": c.get("quarantine_trips", 0),
        "quarantine_recoveries": c.get("quarantine_recoveries", 0),
        "quarantine_abandoned": c.get("quarantine_abandoned", 0),
        "breaker_timeline": [(round(t, 3), tid, s)
                             for t, tid, s in rt.rec.breaker_timeline()],
        **rt.mgr.drop_counters(),
    }
    return out


def bench():
    out = {"config": {
        "plain_tenants": PLAIN_TENANTS, "agentic_tenants": AGENTIC_TENANTS,
        "decode_slots": DECODE_SLOTS, "group_size": GROUP_SIZE,
        "target_steps": TARGET_STEPS, "env_latency_s": ENV_LATENCY,
        "max_staleness": MAX_STALENESS,
        "chaos": dataclasses.asdict(CHAOS)}}
    warm = _run_arm(None)               # fault-free + jit warm pass
    base = _run_arm(None)               # fault-free, cache-hot (measured)
    chaos = _run_arm(CHAOS)
    # chaos-off determinism: identical reward streams run-to-run, and a
    # disabled config builds no injector object at all
    deterministic = warm["rewards"] == base["rewards"]
    no_injector = _runtime(ChaosConfig()).chaos is None
    for arm in (warm, base, chaos):
        arm.pop("rewards")
    out["base"], out["chaos"] = base, chaos
    ratio = (chaos["goodput"]["goodput_rows_per_s"]
             / max(1e-9, base["goodput"]["goodput_rows_per_s"]))
    inj = chaos["chaos_injected"]
    faults_fired = (inj.get("prefill_worker_kill", 0) >= 1
                    and inj.get("env_worker_kill", 0) >= 1
                    and inj.get("tool_error_permanent", 0) >= 1
                    and chaos["supervisor"].get(
                        "supervisor_prefill_worker_restarts", 0) >= 1
                    and chaos["supervisor"].get(
                        "supervisor_env_worker_restarts", 0) >= 1
                    and chaos["quarantine_trips"] >= 1)
    out["goodput_ratio"] = float(ratio)
    out["gate_goodput"] = GATE_GOODPUT
    out["chaos_off_deterministic"] = bool(deterministic and no_injector)
    ok = (chaos["completed"] and chaos["all_at_target"]
          and base["accounting"]["exact"] and chaos["accounting"]["exact"]
          and faults_fired
          and ratio >= GATE_GOODPUT
          and out["chaos_off_deterministic"])
    out["pass"] = bool(ok)
    print(f"bench_chaos,tenants={N_TENANTS},slots={DECODE_SLOTS},"
          f"steps={TARGET_STEPS},"
          f"base_wall={base['wall_s']:.2f}s,"
          f"chaos_wall={chaos['wall_s']:.2f}s,"
          f"goodput_ratio={ratio:.3f},"
          f"kills={inj.get('prefill_worker_kill', 0)}+"
          f"{inj.get('env_worker_kill', 0)},"
          f"tool_faults={inj.get('tool_error_transient', 0)}+"
          f"{inj.get('tool_error_permanent', 0)},"
          f"trips={chaos['quarantine_trips']},"
          f"recoveries={chaos['quarantine_recoveries']},"
          f"failed_rows={chaos['failed_rows']},"
          f"quarantine_dropped={chaos['quarantine_dropped_rows']},"
          f"invariant={'exact' if chaos['accounting']['exact'] else 'BROKEN'},"
          f"{'ok' if out['pass'] else 'FAIL'}")
    return out


def main(argv):
    json_path = None
    if "--json" in argv:
        i = argv.index("--json")
        if i + 1 >= len(argv):
            print("usage: bench_chaos [--json OUT.json]")
            return 2
        json_path = argv[i + 1]
    out = bench()
    if json_path:
        with open(json_path, "w") as f:
            json.dump(out, f, indent=2)
        print(f"wrote {json_path}")
    from benchmarks.common import bench_record, write_bench_json
    rec = bench_record(
        "chaos", GATE_GOODPUT,
        out["chaos"]["goodput"]["goodput_rows_per_s"],
        out["base"]["goodput"]["goodput_rows_per_s"],
        extra={"chaos_completed": out["chaos"]["completed"],
               "invariant_exact": out["chaos"]["accounting"]["exact"],
               "chaos_injected": out["chaos"]["chaos_injected"],
               "quarantine_trips": out["chaos"]["quarantine_trips"],
               "quarantine_recoveries": out["chaos"]["quarantine_recoveries"],
               "failed_rows": out["chaos"]["failed_rows"],
               "quarantine_dropped_rows":
                   out["chaos"]["quarantine_dropped_rows"],
               "chaos_off_deterministic": out["chaos_off_deterministic"]})
    rec["pass"] = out["pass"]
    write_bench_json("BENCH_chaos.json", rec)
    return 0 if out["pass"] else 1


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
