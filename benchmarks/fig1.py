"""Paper Figure 1 — reward trajectories under multi-tenant load: MARLaaS
keeps per-task reward improving with N concurrent LoRA tasks comparable to
single-task training. REAL runtime (threads + JAX GRPO) at toy scale, NOT
the simulator: tiny SFT-warmed base, copy-task tenants, graded rewards.
"""
from __future__ import annotations

import dataclasses
import random

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import REGISTRY, LoRAConfig, reduced
from repro.core.manager import TaskSpec
from repro.core.runtime import MARLaaSRuntime, RuntimeConfig
from repro.data import tokenizer as tok
from repro.envs.tasks import make_env
from repro.models import init_params
from repro.train.optimizer import AdamWConfig
from repro.train.sft import make_sft_step, sft_init

from .common import Timer, emit


def _warmed_base(key, cfg, steps=40):
    params = init_params(key, cfg)
    env = make_env("copy", length=2, alphabet="012")
    rng = random.Random(0)
    sft = jax.jit(make_sft_step(cfg, AdamWConfig(lr=3e-3), trainable="full"))
    opt = sft_init(params)
    for _ in range(steps):
        rows, S = 16, 16
        tokens = np.zeros((rows, S), np.int32)
        p_l = np.zeros((rows,), np.int32)
        t_l = np.zeros((rows,), np.int32)
        for j in range(rows):
            prompt, truth = env.sample_prompt(rng)
            seq = prompt + tok.encode(truth) + [tok.EOS]
            tokens[j, :len(seq)] = seq
            p_l[j], t_l[j] = len(prompt), len(seq)
        batch = {"tokens": jnp.asarray(tokens), "prompt_lens": jnp.asarray(p_l),
                 "total_lens": jnp.asarray(t_l)}
        params, opt, _ = sft(None, params, opt, batch)
    return params


def run(n_tasks=3, steps=4, verbose=True):
    cfg = dataclasses.replace(
        reduced(REGISTRY["granite-3-2b"], dtype="float32"),
        vocab_size=tok.VOCAB_SIZE, lora=LoRAConfig(rank=8, alpha=32.0))
    params = _warmed_base(jax.random.PRNGKey(0), cfg)
    rt = MARLaaSRuntime(cfg, params, RuntimeConfig(policy="marlaas",
                                                   max_len=48, seed=0))
    for i in range(n_tasks):
        rt.submit_task(TaskSpec(f"copy-{i}", "copy", group_size=4,
                                num_groups=2, max_new_tokens=4,
                                target_steps=steps, lr=3e-3))
    rt.run(timeout_s=420)
    curves = {tid: st.reward_history for tid, st in rt.mgr.tasks.items()}
    if verbose:
        print(f"\n# Fig 1 — reward under {n_tasks}-tenant load "
              f"(real runtime, SFT-warmed toy base)")
        for tid, c in curves.items():
            print(f"  {tid}: " + " ".join(f"{r:.2f}" for r in c))
    return curves


def main():
    with Timer() as t:
        curves = run()
    mean_first = np.mean([c[0] for c in curves.values() if c])
    mean_last = np.mean([c[-1] for c in curves.values() if c])
    emit("fig1_multi_tenant_reward", t.seconds * 1e6,
         f"reward_first={mean_first:.3f} reward_last={mean_last:.3f} "
         f"tasks={len(curves)}")


if __name__ == "__main__":
    main()
