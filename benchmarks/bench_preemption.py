"""Preemptive multi-tenant slot scheduling vs FIFO (ISSUE 2 tentpole).

Workload: N_TENANTS tenants (default 16), each running ROUNDS sequential
rollout rounds of ROWS requests, through a shared engine with only
ADAPTER_SLOTS stacked-LoRA slots (default 4) and DECODE_SLOTS decode slots.
Budgets alternate short/long across tenants — the length skew that makes
head-of-line blocking expensive.

Two schedulers over the IDENTICAL workload:

  fifo        — PR-1 behaviour: FIFO queue pop, and an adapter slot is only
                reclaimed when its tenant has finished ALL its rounds
                (finished-tasks-only reclamation). Tenants beyond the first
                ADAPTER_SLOTS wait in waves.
  preemptive  — this PR: SRPT + priority + starvation-bound queue pop, and
                LRU eviction of idle tenants' adapters between rounds, so
                all tenants stream through the 4 slots.

Round latency = (last completion of the round) - (round became READY),
where round r+1 is ready the moment round r completes and round 0 at t=0 —
i.e. adapter-slot queueing delay counts, which is what a tenant of the
service actually experiences. Latency is measured in engine DECODE STEPS
(each step is one fixed-width fused dispatch over the pool — constant
device time), so host jit-compile pauses can't pollute the comparison;
wall-clock percentiles are reported alongside. Gate:
p95_steps(fifo) / p95_steps(preemptive) >= 1.2x.

A second scenario exercises the preemption/replay path itself: a
high-priority VIP tenant arrives while every decode slot is held by
long-budget background rows. Without preemption its short round waits for
a natural eviction; with `preempt_slots` the lowest-priority
longest-remaining rows are evicted (and later prefix-replayed) so the VIP
starts immediately. Reported as vip_latency_steps with/without and
replay counts (informational; the p95 gate above is the hard gate).

  PYTHONPATH=src python -m benchmarks.bench_preemption [--json out.json]
"""
from __future__ import annotations

import dataclasses
import json
import random
import sys
import time

import jax
import numpy as np

from repro.configs import REGISTRY, reduced
from repro.data import tokenizer as tok
from repro.envs.tasks import make_env
from repro.lora.adapters import init_lora
from repro.lora.multilora import AdapterResidency
from repro.models import init_params
from repro.rollout.engine import ContinuousRolloutEngine, RolloutRequest

N_TENANTS = 16
ADAPTER_SLOTS = 4
DECODE_SLOTS = 4
ROUNDS = 2
ROWS = 2
MAX_LEN = 64
SHORT, LONG = 6, 18
GATE = 1.2

_STATE = {}


def _model():
    if not _STATE:
        cfg = dataclasses.replace(reduced(REGISTRY["granite-3-2b"],
                                          dtype="float32"),
                                  vocab_size=tok.VOCAB_SIZE)
        _STATE["cfg"] = cfg
        _STATE["params"] = init_params(jax.random.PRNGKey(0), cfg)
        _STATE["trees"] = [init_lora(jax.random.PRNGKey(100 + t), cfg)
                           for t in range(N_TENANTS)]
    return _STATE["cfg"], _STATE["params"], _STATE["trees"]


def _prompts():
    """Deterministic per-(tenant, round, row) prompts and seeds."""
    env = make_env("gsm8k")
    rng = random.Random(0)
    table = {}
    for t in range(N_TENANTS):
        for r in range(ROUNDS):
            for i in range(ROWS):
                table[(t, r, i)] = env.sample_prompt(rng)
    return env, table


def run_mode(mode: str):
    """Drive the engine as the streaming runtime does: a tenant submits its
    next round the moment its previous one completes AND its adapter can be
    made resident. Returns per-round latencies + engine/residency stats."""
    cfg, params, trees = _model()
    env, table = _prompts()
    eng = ContinuousRolloutEngine(
        cfg, params, max_slots=DECODE_SLOTS, max_adapters=ADAPTER_SLOTS,
        max_len=MAX_LEN, seed=0,
        scheduler=("fifo" if mode == "fifo" else "srpt"))
    res = AdapterResidency(ADAPTER_SLOTS, eng.set_adapters)

    rounds_done = [0] * N_TENANTS
    inflight = [0] * N_TENANTS
    ready_at = [0.0] * N_TENANTS        # round became ready (t0 for round 0)
    ready_step = [0] * N_TENANTS        # ... in engine decode steps
    latencies = []                      # wall seconds (compile-noisy on CPU)
    step_latencies = []                 # decode steps (the gated metric)

    def in_use(tenant_name):
        t = int(tenant_name[1:])
        if mode == "fifo":
            # PR-1 reclamation: resident until the tenant finished ALL work
            return rounds_done[t] < ROUNDS
        return tenant_name in eng.active_tenants()

    t0 = time.monotonic()
    guard = t0 + 600.0
    while (any(r < ROUNDS for r in rounds_done)
           or not eng.idle()) and time.monotonic() < guard:
        # grant adapter slots oldest-ready first (identical fairness in both
        # modes — what differs is whether a slot CAN be reclaimed: LRU of
        # idle tenants vs only-when-finished)
        waiting = sorted(
            (t for t in range(N_TENANTS)
             if not inflight[t] and rounds_done[t] < ROUNDS),
            key=lambda t: (ready_at[t], t))
        for t in waiting:
            slot = res.acquire(f"t{t}", trees[t], in_use=in_use)
            if slot is None:
                continue                     # slots pinned; resident tenants
                                             # further down may still hit
            r = rounds_done[t]
            for i in range(ROWS):
                prompt, truth = table[(t, r, i)]
                eng.submit(RolloutRequest(
                    f"t{t}", slot, prompt, truth, env,
                    max_new_tokens=SHORT if t % 2 == 0 else LONG,
                    seed=t * 1000 + r * 10 + i))
            inflight[t] = ROWS
        eng.step()
        now = time.monotonic()
        for c in eng.drain_completions():
            t = int(c.task_id[1:])
            inflight[t] -= 1
            if inflight[t] == 0:
                rounds_done[t] += 1
                latencies.append(now - t0 - ready_at[t])
                step_latencies.append(eng.stats.decode_steps - ready_step[t])
                ready_at[t] = now - t0           # next round ready NOW
                ready_step[t] = eng.stats.decode_steps
    assert len(latencies) == N_TENANTS * ROUNDS, (
        f"{mode}: only {len(latencies)} rounds completed")
    return latencies, step_latencies, eng.stats, res


def run_vip(preempt: bool):
    """4 background tenants keep all decode slots busy with LONG rows; a
    priority-5 VIP round of SHORT rows arrives mid-run. Returns (VIP round
    latency in decode steps, engine stats)."""
    cfg, params, trees = _model()
    env, table = _prompts()
    eng = ContinuousRolloutEngine(
        cfg, params, max_slots=DECODE_SLOTS, max_adapters=ADAPTER_SLOTS + 1,
        max_len=MAX_LEN, seed=0, scheduler="srpt")
    n_bg = DECODE_SLOTS
    for t in range(n_bg):
        eng.set_adapters(t, trees[t])
        for r in range(ROUNDS):
            for i in range(ROWS):
                prompt, truth = table[(t, r, i)]
                eng.submit(RolloutRequest(
                    f"t{t}", t, prompt, truth, env, max_new_tokens=LONG,
                    seed=t * 1000 + r * 10 + i))
    eng.set_adapters(n_bg, trees[n_bg])
    vip_arrival, vip_left, vip_done_step = 12, None, None
    guard = time.monotonic() + 600.0
    while not eng.idle() and time.monotonic() < guard:
        eng.step()
        if eng.stats.decode_steps >= vip_arrival and vip_left is None:
            vip_left = ROWS
            for i in range(ROWS):
                prompt, truth = table[(n_bg, 0, i)]
                eng.submit(RolloutRequest(
                    "vip", n_bg, prompt, truth, env, max_new_tokens=SHORT,
                    seed=9000 + i, priority=5))
            if preempt:
                eng.preempt_slots(ROWS)       # victims replay later
        for c in eng.drain_completions():
            if c.task_id == "vip":
                vip_left -= 1
                if vip_left == 0:
                    vip_done_step = eng.stats.decode_steps
    assert vip_done_step is not None, "vip round never completed"
    return vip_done_step - vip_arrival, eng.stats


def bench():
    out = {"config": {"tenants": N_TENANTS, "adapter_slots": ADAPTER_SLOTS,
                      "decode_slots": DECODE_SLOTS, "rounds": ROUNDS,
                      "rows_per_round": ROWS, "budgets": [SHORT, LONG]}}
    for mode in ("fifo", "preemptive"):
        run_mode(mode)                       # untimed warm-up (compiles)
        lat, slat, stats, res = run_mode(mode)
        out[mode] = {
            "p50_steps": float(np.percentile(slat, 50)),
            "p95_steps": float(np.percentile(slat, 95)),
            "mean_steps": float(np.mean(slat)),
            "max_steps": float(np.max(slat)),
            "p50_s": float(np.percentile(lat, 50)),
            "p95_s": float(np.percentile(lat, 95)),
            "adapter_installs": res.installs,
            "adapter_evictions": res.evictions,
            "replays": stats.replays,
            "slot_util": stats.slot_utilization(),
        }
    ratio = out["fifo"]["p95_steps"] / out["preemptive"]["p95_steps"]
    out["p95_speedup"] = float(ratio)
    out["gate"] = GATE
    out["pass"] = bool(ratio >= GATE)
    # preemption/replay exercise: VIP arrival into a saturated pool
    run_vip(True)                            # warm-up (compiles)
    vip_wait, _ = run_vip(False)
    vip_pre, stats_pre = run_vip(True)
    out["vip"] = {"latency_steps_no_preempt": int(vip_wait),
                  "latency_steps_preempt": int(vip_pre),
                  "speedup": float(vip_wait / max(1, vip_pre)),
                  "rows_preempted": stats_pre.preemptions,
                  "replays": stats_pre.replays}
    if stats_pre.replays == 0:
        out["pass"] = False                  # preemption path never ran
    print(f"bench_preemption,tenants={N_TENANTS},"
          f"adapter_slots={ADAPTER_SLOTS},"
          f"fifo_p95={out['fifo']['p95_steps']:.0f}steps,"
          f"preemptive_p95={out['preemptive']['p95_steps']:.0f}steps,"
          f"p95_speedup={ratio:.2f}x,"
          f"evictions={out['preemptive']['adapter_evictions']},"
          f"vip_latency={vip_wait}->{vip_pre}steps,"
          f"replays={stats_pre.replays},"
          f"{'ok' if out['pass'] else 'FAIL'}")
    return out


def main(argv):
    json_path = None
    if "--json" in argv:
        i = argv.index("--json")
        if i + 1 >= len(argv):
            print("usage: bench_preemption [--json OUT.json]")
            return 2
        json_path = argv[i + 1]
    out = bench()
    if json_path:
        with open(json_path, "w") as f:
            json.dump(out, f, indent=2)
        print(f"wrote {json_path}")
    from benchmarks.common import bench_record, write_bench_json
    write_bench_json("BENCH_preemption.json", bench_record(
        "preemption", GATE, out["preemptive"]["p95_steps"],
        out["fifo"]["p95_steps"], higher_is_better=False,
        extra={"pass": out["pass"]}))
    return 0 if out["pass"] else 1


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
