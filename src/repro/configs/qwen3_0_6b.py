"""Selectable config — see archs.py for the exact published spec."""
from .archs import QWEN3_0P6B as CONFIG
from .base import reduced, shapes_for

SMOKE = reduced(CONFIG)
SHAPES = shapes_for(CONFIG)
