"""Selectable config — see archs.py for the exact published spec."""
from .archs import MAMBA2_780M as CONFIG
from .base import reduced, shapes_for

SMOKE = reduced(CONFIG)
SHAPES = shapes_for(CONFIG)
