"""Configuration system for the MARLaaS reproduction framework.

Every selectable architecture is described by a frozen ``ModelConfig``; input
shapes by ``ShapeConfig``. Configs are *data* — model code interprets them.

Conventions
-----------
- ``family`` selects the block stack:
    dense   — uniform decoder-only transformer
    moe     — decoder-only with (shared + routed) MoE MLPs
    ssm     — attention-free Mamba2 (SSD) stack
    hybrid  — Mamba2 backbone with a single *shared* attention block applied
              every ``hybrid_attn_every`` layers (Zamba2 style)
    encdec  — encoder-decoder transformer (seamless backbone; stub frontend)
    vlm     — decoder-only, early-fusion (VQ image tokens are ordinary ids)
- All per-layer weights are stacked on a leading layer axis so the forward
  pass can ``lax.scan`` over layers (compile-time O(1) in depth).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int            # routed experts
    top_k: int
    num_shared: int = 0         # always-on shared experts (fused into one MLP)
    expert_d_ff: int = 0        # per-expert hidden size (fine-grained MoE)
    capacity_factor: float = 1.25
    router_noise: float = 0.0


@dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 128        # N
    head_dim: int = 64          # P
    expand: int = 2             # d_inner = expand * d_model
    n_groups: int = 1           # B/C groups (shared across heads)
    conv_width: int = 4
    chunk_size: int = 256       # SSD chunk length (training/prefill)
    dt_min: float = 0.001
    dt_max: float = 0.1

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def num_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class LoRAConfig:
    rank: int = 16
    alpha: float = 32.0
    # which projections receive adapters
    targets: Tuple[str, ...] = ("attn_q", "attn_k", "attn_v", "attn_o",
                                "mlp_in", "mlp_out")
    dtype: str = "float32"

    @property
    def scaling(self) -> float:
        return self.alpha / self.rank


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int = 0          # 0 for attention-free stacks
    num_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0               # dense MLP hidden (0 for pure-MoE / ssm)
    vocab_size: int = 32000

    # --- attention variants ---
    qkv_bias: bool = False                  # qwen1.5
    qk_norm: bool = False                   # chameleon
    attn_softcap: float = 0.0               # gemma2 (tanh softcap on scores)
    logit_softcap: float = 0.0              # gemma2 (tanh softcap on lm logits)
    sliding_window: int = 0                 # gemma2 local layers
    local_global_period: int = 0            # gemma2: every Nth layer is global
    rope_theta: float = 10000.0

    # --- MLP variants ---
    mlp_act: str = "swiglu"                 # swiglu | squared_relu | gelu

    # --- MoE ---
    moe: Optional[MoEConfig] = None

    # --- SSM / hybrid ---
    ssm: Optional[SSMConfig] = None
    hybrid_attn_every: int = 0              # zamba2: shared attn every N blocks

    # --- enc-dec ---
    encoder_layers: int = 0                 # seamless: separate encoder stack
    frontend: str = ""                      # "audio" | "vision" | "" (stub kind)

    # --- misc ---
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    scan_layers: bool = True                # lax.scan over the layer stack
    remat: bool = True                      # checkpoint each scan body
    remat_block: int = 0                    # >0: two-level remat — outer scan
                                            # over L/remat_block blocks stores
                                            # only block inputs (deep stacks)

    lora: LoRAConfig = field(default_factory=LoRAConfig)

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.num_heads and not self.head_dim:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """True if decode state does NOT grow with a dense global KV cache."""
        return self.family in ("ssm", "hybrid")

    def layer_kind(self, i: int) -> str:
        """Block kind at depth i (used by heterogeneous stacks)."""
        if self.family == "ssm":
            return "mamba"
        if self.family == "hybrid":
            k = self.hybrid_attn_every
            return "mamba+attn" if (k and (i + 1) % k == 0) else "mamba"
        if self.family == "moe":
            return "moe"
        return "dense"

    def is_global_attn_layer(self, i: int) -> bool:
        """Gemma2-style alternation: layer i uses global (non-windowed) attn."""
        if not self.local_global_period:
            return True
        return (i % self.local_global_period) == (self.local_global_period - 1)

    # --- memory model used by KV-cache-aware admission (paper §4.3) -----
    def state_bytes_per_token(self, dtype_bytes: int = 2) -> int:
        """Per-token, per-sequence KV bytes (attention archs)."""
        n_attn = self._num_attn_layers()
        return 2 * n_attn * self.kv_dim * dtype_bytes

    def state_bytes_fixed(self, dtype_bytes: int = 2) -> int:
        """Sequence-length-independent state (SSM recurrent state + conv)."""
        if self.ssm is None:
            return 0
        s = self.ssm
        d_in = s.d_inner(self.d_model)
        n_heads = s.num_heads(self.d_model)
        n_ssm = self._num_ssm_layers()
        ssm_state = n_heads * s.head_dim * s.state_dim
        conv_state = (d_in + 2 * s.n_groups * s.state_dim) * s.conv_width
        return n_ssm * (ssm_state + conv_state) * dtype_bytes

    def _num_attn_layers(self) -> int:
        if self.family == "ssm":
            return 0
        if self.family == "hybrid":
            k = self.hybrid_attn_every
            return (self.num_layers // k) if k else 0
        if self.family == "encdec":
            # decoder self-attn + cross-attn caches
            return 2 * self.num_layers
        return self.num_layers

    def _num_ssm_layers(self) -> int:
        if self.family == "ssm":
            return self.num_layers
        if self.family == "hybrid":
            return self.num_layers
        return 0

    def param_count(self) -> int:
        """Analytic parameter count (used for 6·N·D roofline checks)."""
        d = self.d_model
        emb = self.vocab_size * d
        total = emb if self.tie_embeddings else 2 * emb
        dec_layers = self.num_layers

        def attn_params() -> int:
            p = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
            if self.qkv_bias:
                p += self.q_dim + 2 * self.kv_dim
            return p

        def dense_mlp(ff: int) -> int:
            n_mats = 3 if self.mlp_act == "swiglu" else 2
            return n_mats * d * ff

        def mamba_params() -> int:
            s = self.ssm
            d_in = s.d_inner(d)
            nh = s.num_heads(d)
            conv_dim = d_in + 2 * s.n_groups * s.state_dim
            in_proj = d * (2 * d_in + 2 * s.n_groups * s.state_dim + nh)
            return (in_proj + conv_dim * s.conv_width + 2 * nh
                    + d_in + d_in * d)

        for i in range(dec_layers):
            kind = self.layer_kind(i)
            total += 2 * d  # pre-norms
            if kind == "dense":
                total += attn_params() + dense_mlp(self.d_ff)
            elif kind == "moe":
                m = self.moe
                total += attn_params()
                total += m.num_experts * dense_mlp(m.expert_d_ff)
                total += m.num_shared * dense_mlp(m.expert_d_ff)
                total += d * m.num_experts  # router
            elif kind in ("mamba", "mamba+attn"):
                total += mamba_params()
        if self.family == "hybrid" and self.hybrid_attn_every:
            # ONE shared attention(+MLP) block, counted once
            total += attn_params() + dense_mlp(self.d_ff) + 2 * d
        if self.family == "encdec":
            for _ in range(self.encoder_layers):
                total += attn_params() + dense_mlp(self.d_ff) + 2 * d
            # decoder cross-attention
            total += dec_layers * attn_params()
        return total

    def active_param_count(self) -> int:
        """Activated params per token (MoE: shared + top_k routed only)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        full = self.param_count()
        n_mats = 3 if self.mlp_act == "swiglu" else 2
        per_expert = n_mats * self.d_model * m.expert_d_ff
        inactive = self.num_layers * (m.num_experts - m.top_k) * per_expert
        return full - inactive


@dataclass(frozen=True)
class ShapeConfig:
    """One (input-shape × step-kind) cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode
    # decode: seq_len is the KV-cache length; one new token is generated.


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

LM_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def shapes_for(cfg: ModelConfig) -> Tuple[ShapeConfig, ...]:
    """Applicable shape cells for an architecture.

    ``long_500k`` requires sub-quadratic decode state; pure full-attention
    archs (incl. gemma2, whose *global* layers are dense attention) skip it —
    see DESIGN.md §5.
    """
    out = []
    for s in LM_SHAPES:
        if s.name == "long_500k" and not cfg.sub_quadratic:
            continue
        out.append(s)
    return tuple(out)


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    base = dict(
        num_layers=2,
        d_model=64,
        num_heads=4 if cfg.num_heads else 0,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads else 0,
        head_dim=16 if cfg.num_heads else 0,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        scan_layers=cfg.scan_layers,
        remat=False,
    )
    if cfg.moe is not None:
        base["moe"] = MoEConfig(num_experts=4, top_k=2,
                                num_shared=min(cfg.moe.num_shared, 1),
                                expert_d_ff=64)
    if cfg.ssm is not None:
        base["ssm"] = SSMConfig(state_dim=16, head_dim=16, expand=2,
                                n_groups=1, conv_width=4, chunk_size=32)
    if cfg.family == "hybrid":
        base["hybrid_attn_every"] = 2
        base["num_heads"] = 4
        base["num_kv_heads"] = 4
        base["head_dim"] = 16  # must be d_inner-compatible? attn is on d_model
        base["d_ff"] = 128
    if cfg.family == "encdec":
        base["encoder_layers"] = 2
    if cfg.local_global_period:
        base["local_global_period"] = 2
        base["sliding_window"] = 16
    base["lora"] = LoRAConfig(rank=4, alpha=8.0, targets=cfg.lora.targets)
    base.update(overrides)
    return dataclasses.replace(cfg, **base)
