"""Selectable config — see archs.py for the exact published spec."""
from .archs import SEAMLESS_M4T_LARGE_V2 as CONFIG
from .base import reduced, shapes_for

SMOKE = reduced(CONFIG)
SHAPES = shapes_for(CONFIG)
