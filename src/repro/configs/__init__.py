from .base import (LM_SHAPES, DECODE_32K, LONG_500K, PREFILL_32K, TRAIN_4K,
                   LoRAConfig, MoEConfig, ModelConfig, ShapeConfig, SSMConfig,
                   reduced, shapes_for)
from .archs import (ASSIGNED, PAPER_MODELS, REGISTRY, get_config)

__all__ = [
    "LM_SHAPES", "DECODE_32K", "LONG_500K", "PREFILL_32K", "TRAIN_4K",
    "LoRAConfig", "MoEConfig", "ModelConfig", "ShapeConfig", "SSMConfig",
    "reduced", "shapes_for", "ASSIGNED", "PAPER_MODELS", "REGISTRY",
    "get_config",
]
