"""Selectable config — see archs.py for the exact published spec."""
from .archs import CHAMELEON_34B as CONFIG
from .base import reduced, shapes_for

SMOKE = reduced(CONFIG)
SHAPES = shapes_for(CONFIG)
