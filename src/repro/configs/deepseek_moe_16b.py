"""Selectable config — see archs.py for the exact published spec."""
from .archs import DEEPSEEK_MOE_16B as CONFIG
from .base import reduced, shapes_for

SMOKE = reduced(CONFIG)
SHAPES = shapes_for(CONFIG)
