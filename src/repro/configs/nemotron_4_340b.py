"""Selectable config — see archs.py for the exact published spec."""
from .archs import NEMOTRON_4_340B as CONFIG
from .base import reduced, shapes_for

SMOKE = reduced(CONFIG)
SHAPES = shapes_for(CONFIG)
