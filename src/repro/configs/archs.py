"""The 10 assigned architectures (exact public configs) + the paper's own
Qwen3 models used in MARLaaS's experiments.

Sources are cited per entry; `[...; tier]` follows the assignment sheet.
"""
from __future__ import annotations

from .base import LoRAConfig, MoEConfig, ModelConfig, SSMConfig

# --------------------------------------------------------------------------
# Assigned pool (10 archs)
# --------------------------------------------------------------------------

GRANITE_3_2B = ModelConfig(
    # [hf:ibm-granite/granite-3.0-2b-base; hf]
    name="granite-3-2b", family="dense",
    num_layers=40, d_model=2048, num_heads=32, num_kv_heads=8, head_dim=64,
    d_ff=8192, vocab_size=49155, mlp_act="swiglu", rope_theta=10000.0,
)

QWEN15_110B = ModelConfig(
    # [hf:Qwen/Qwen1.5-*; hf] — QKV bias
    name="qwen1.5-110b", family="dense",
    num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=49152, vocab_size=152064, mlp_act="swiglu", qkv_bias=True,
    rope_theta=1000000.0, tie_embeddings=False,
)

NEMOTRON_4_340B = ModelConfig(
    # [arXiv:2402.16819; unverified] — squared-ReLU MLP (no gating)
    name="nemotron-4-340b", family="dense",
    num_layers=96, d_model=18432, num_heads=96, num_kv_heads=8, head_dim=192,
    d_ff=73728, vocab_size=256000, mlp_act="squared_relu",
    tie_embeddings=False,
)

GEMMA2_27B = ModelConfig(
    # [arXiv:2408.00118; hf] — local/global alternation + logit softcaps
    name="gemma2-27b", family="dense",
    num_layers=46, d_model=4608, num_heads=32, num_kv_heads=16, head_dim=128,
    d_ff=36864, vocab_size=256000, mlp_act="swiglu",
    attn_softcap=50.0, logit_softcap=30.0,
    sliding_window=4096, local_global_period=2,
)

ZAMBA2_1P2B = ModelConfig(
    # [arXiv:2411.15242; hf] — Mamba2 backbone + ONE shared attn(+MLP) block.
    # The shared block carries per-invocation LoRA in the original — the same
    # mechanism MARLaaS uses for tenancy (see DESIGN.md §5).
    name="zamba2-1.2b", family="hybrid",
    num_layers=38, d_model=2048, num_heads=32, num_kv_heads=32, head_dim=64,
    d_ff=8192, vocab_size=32000, mlp_act="swiglu",
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, n_groups=1),
    hybrid_attn_every=6,
)

DEEPSEEK_MOE_16B = ModelConfig(
    # [arXiv:2401.06066; hf] — fine-grained MoE: 2 shared + 64 routed top-6.
    # (We apply MoE at every layer; HF layer-0-dense detail noted in DESIGN.)
    name="deepseek-moe-16b", family="moe",
    num_layers=28, d_model=2048, num_heads=16, num_kv_heads=16, head_dim=128,
    d_ff=0, vocab_size=102400, mlp_act="swiglu", tie_embeddings=False,
    moe=MoEConfig(num_experts=64, top_k=6, num_shared=2, expert_d_ff=1408),
)

DBRX_132B = ModelConfig(
    # [hf:databricks/dbrx-base; unverified] — 16 experts top-4
    name="dbrx-132b", family="moe",
    num_layers=40, d_model=6144, num_heads=48, num_kv_heads=8, head_dim=128,
    d_ff=0, vocab_size=100352, mlp_act="swiglu",
    moe=MoEConfig(num_experts=16, top_k=4, num_shared=0, expert_d_ff=10752),
    rope_theta=500000.0, tie_embeddings=False,
)

MAMBA2_780M = ModelConfig(
    # [arXiv:2405.21060; unverified] — pure SSD stack, attention-free
    name="mamba2-780m", family="ssm",
    num_layers=48, d_model=1536, num_heads=0, num_kv_heads=0, head_dim=0,
    d_ff=0, vocab_size=50280,
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, n_groups=1),
    lora=LoRAConfig(targets=("ssm_in", "ssm_out")),
)

SEAMLESS_M4T_LARGE_V2 = ModelConfig(
    # [arXiv:2308.11596; hf] — enc-dec backbone; audio frontend is a stub
    # (input_specs() provides precomputed frame embeddings).
    name="seamless-m4t-large-v2", family="encdec",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=16, head_dim=64,
    d_ff=8192, vocab_size=256206, mlp_act="gelu",
    encoder_layers=24, frontend="audio", tie_embeddings=False,
)

CHAMELEON_34B = ModelConfig(
    # [arXiv:2405.09818; unverified] — early-fusion; VQ image tokens are
    # ordinary ids in the 65536 vocab; qk-norm per the paper.
    name="chameleon-34b", family="vlm",
    num_layers=48, d_model=8192, num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=22016, vocab_size=65536, mlp_act="swiglu", qk_norm=True,
    frontend="vision", tie_embeddings=False,
)

# --------------------------------------------------------------------------
# The paper's own base models (MARLaaS §5: Qwen3-0.6B / 14B / 32B)
# --------------------------------------------------------------------------

QWEN3_0P6B = ModelConfig(
    name="qwen3-0.6b", family="dense",
    num_layers=28, d_model=1024, num_heads=16, num_kv_heads=8, head_dim=128,
    d_ff=3072, vocab_size=151936, mlp_act="swiglu", qk_norm=True,
    rope_theta=1000000.0,
)

QWEN3_14B = ModelConfig(
    name="qwen3-14b", family="dense",
    num_layers=40, d_model=5120, num_heads=40, num_kv_heads=8, head_dim=128,
    d_ff=17408, vocab_size=151936, mlp_act="swiglu", qk_norm=True,
    rope_theta=1000000.0, tie_embeddings=False,
)

QWEN3_32B = ModelConfig(
    name="qwen3-32b", family="dense",
    num_layers=64, d_model=5120, num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=25600, vocab_size=151936, mlp_act="swiglu", qk_norm=True,
    rope_theta=1000000.0, tie_embeddings=False,
)

ASSIGNED = (
    GRANITE_3_2B, QWEN15_110B, NEMOTRON_4_340B, GEMMA2_27B, ZAMBA2_1P2B,
    DEEPSEEK_MOE_16B, DBRX_132B, MAMBA2_780M, SEAMLESS_M4T_LARGE_V2,
    CHAMELEON_34B,
)

PAPER_MODELS = (QWEN3_0P6B, QWEN3_14B, QWEN3_32B)

REGISTRY = {c.name: c for c in ASSIGNED + PAPER_MODELS}


def get_config(name: str) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(REGISTRY)}")
    return REGISTRY[name]
