"""Selectable config — see archs.py for the exact published spec."""
from .archs import DBRX_132B as CONFIG
from .base import reduced, shapes_for

SMOKE = reduced(CONFIG)
SHAPES = shapes_for(CONFIG)
