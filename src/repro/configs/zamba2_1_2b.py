"""Selectable config — see archs.py for the exact published spec."""
from .archs import ZAMBA2_1P2B as CONFIG
from .base import reduced, shapes_for

SMOKE = reduced(CONFIG)
SHAPES = shapes_for(CONFIG)
