"""Discrete-event simulator for MARLaaS scheduling at paper scale.

Runs the SAME MultiTaskManager + admission control as the real runtime, but
executes rollout/env/train phases in virtual time against a first-principles
hardware model, so paper Tables 2–4 and Figs 6–7 (0.6B/14B/32B × multi-NPU,
up to 32 tenants) are reproducible on a 1-core CPU box.

Hardware/latency model (documented in EXPERIMENTS.md §Benchmarks):
- decode is HBM-bound. The rollout pool steps its *fused* batch once per
  `(param_bytes + Σ_rows kv_bytes) / (pool_HBM_bw · eff)` seconds — weight
  reads are shared across all resident tenants, which is exactly the
  multi-LoRA batching advantage. Baselines WITHOUT multi-LoRA pay the
  weight read per task (no fusion possible).
- prefill/training are compute-bound: `2·N·tokens / (pool_peak · mfu)` and
  `6·N·tokens / (train_peak · mfu)`.
- environment interaction removes a job from the pool for a sampled latency
  (external tools/judge — consumes no accelerator).
- a single `calib` factor scales absolute rollout latency to the paper's
  measured Table 1 values (their Ascend stack ≠ our TPU-v5e constants);
  relative behaviour across regimes comes from the model, not the knob.

Event engine: heap of (virtual_time, seq, fn). Membership changes in the
decode set trigger rate recomputation (processor-sharing with shared
weight reads).
"""
from __future__ import annotations

import heapq
import itertools
import random
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from repro.configs import ModelConfig
from .admission import AdmissionConfig, AdmissionController, task_state_bytes
from .manager import MultiTaskManager, TaskSpec
from .metrics import MetricsRecorder


@dataclass
class HardwareModel:
    n_devices: int = 16
    train_devices: int = 2          # paper §5: 0.6B→2, 14B→4, 32B→16
    peak_flops_per_dev: float = 197e12
    hbm_bw_per_dev: float = 819e9
    mem_eff: float = 0.55
    prefill_mfu: float = 0.40
    train_mfu: float = 0.35
    train_overhead_s: float = 0.6   # commit/weight-sync/launch overhead
    step_overhead_s: float = 0.0    # fixed per-decode-step latency (engine
                                    # launch/RPC; dominates small-batch decode)
    calib: float = 1.0              # absolute-latency calibration (Table 1)

    @property
    def rollout_devices(self) -> int:
        return self.n_devices - self.train_devices


@dataclass
class WorkloadModel:
    """Per-task rollout/train cost profile derived from env + model cfg."""
    prompt_len: int
    gen_len: int                    # decode tokens per row
    rows: int                       # batch rows per rollout
    n_tool_calls: int = 0
    env_latency_mean: float = 0.0
    env_latency_std: float = 0.0

    @property
    def tokens_per_batch(self) -> int:
        return self.rows * (self.prompt_len + self.gen_len)


# paper §5 workload definitions (max gen length × batch size)
PAPER_WORKLOADS = {
    "gsm8k": WorkloadModel(prompt_len=128, gen_len=2048, rows=64),
    "amc12": WorkloadModel(prompt_len=192, gen_len=4096, rows=32),
    "search": WorkloadModel(prompt_len=256, gen_len=1024, rows=32,
                            n_tool_calls=3, env_latency_mean=6.0,
                            env_latency_std=2.0),
}


class SimClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t


@dataclass
class _DecodeJob:
    task_id: str
    version: int
    rows: int
    kv_bytes: float
    segments: List[Tuple[str, float]]     # ("decode", tokens) | ("env", s)
    seg_idx: int = 0
    tokens_left: float = 0.0
    entered_pool_at: float = 0.0
    on_done: Optional[Callable] = None
    multi_lora: bool = True
    trace: Optional[int] = None     # repro.obs trace id (tracing enabled)
    flow_in: int = 0                # pending hand-off arrow (resume→pool)


class Simulator:
    """Virtual-time executor; policies drive it via schedule()/callbacks."""

    def __init__(self, cfg: ModelConfig, hw: HardwareModel, seed: int = 0,
                 trace: bool = False):
        self.cfg = cfg
        self.hw = hw
        self.clock = SimClock()
        self.heap: List[Tuple[float, int, Callable]] = []
        self._seq = itertools.count()
        self.rng = random.Random(seed)
        self.rec = MetricsRecorder({"rollout": hw.rollout_devices,
                                    "train": hw.train_devices})
        # virtual-time episode tracing (ISSUE 9): the tracer reads the SIM
        # clock, so sim traces share the threaded runtime's span structure
        # (same canonical states, same park/resume flow arrows) with
        # virtual timestamps — the parity property tests pin this
        self.tracer = None
        if trace:
            from repro.obs import Tracer
            self.tracer = Tracer(clock=self.clock)
        self.param_bytes = cfg.param_count() * 2
        # decode pool state
        self.decode_set: Dict[str, _DecodeJob] = {}
        self._decode_wait: List[_DecodeJob] = []   # exclusive-job FIFO
        self._decode_rate_t0 = 0.0
        self._decode_step_s = None
        self._decode_event_seq = 0
        # train engine
        self.train_busy_until = 0.0

    # -- event engine -----------------------------------------------------
    def schedule(self, delay: float, fn: Callable):
        heapq.heappush(self.heap, (self.clock.t + max(0.0, delay),
                                   next(self._seq), fn))

    def run(self, until: float = float("inf"), stop: Callable[[], bool] = None):
        while self.heap:
            t, _, fn = heapq.heappop(self.heap)
            if t > until:
                break
            self._advance_decode(t)
            self.clock.t = t
            fn()
            if stop is not None and stop():
                break

    # -- decode pool: fused token stepping --------------------------------
    def _pool_bw(self) -> float:
        return self.hw.rollout_devices * self.hw.hbm_bw_per_dev * self.hw.mem_eff

    def _step_seconds(self) -> Optional[float]:
        """Seconds per one fused decode step for the current resident set."""
        if not self.decode_set:
            return None
        jobs = self.decode_set.values()
        if all(j.multi_lora for j in jobs):
            weight_reads = 1
        else:
            weight_reads = len(self.decode_set)   # no fusion: per-task read
        bytes_per_step = (weight_reads * self.param_bytes
                          + sum(j.kv_bytes for j in jobs))
        # decode steps are latency-bound until the fused batch saturates HBM
        # bandwidth — the regime boundary that makes multi-LoRA batching
        # nearly free at low concurrency (paper Fig 6 knee).
        return max(self.hw.step_overhead_s,
                   self.hw.calib * bytes_per_step / self._pool_bw())

    def _advance_decode(self, t_now: float):
        """Progress all resident decode jobs from the last rate change."""
        if self._decode_step_s is None or not self.decode_set:
            self._decode_rate_t0 = t_now
            return
        dt = t_now - self._decode_rate_t0
        if dt <= 0:
            return
        toks = dt / self._decode_step_s
        for j in self.decode_set.values():
            j.tokens_left = max(0.0, j.tokens_left - toks)
        if self.decode_set:
            self.rec.record("rollout", "decode", "+".join(self.decode_set),
                            self._decode_rate_t0, t_now,
                            self.hw.rollout_devices)
        self._decode_rate_t0 = t_now

    def _reschedule_decode(self):
        """Recompute fused step time; schedule next earliest completion."""
        self._decode_step_s = self._step_seconds()
        self._decode_rate_t0 = self.clock.t
        if not self.decode_set:
            return
        nxt = min(j.tokens_left for j in self.decode_set.values())
        self._decode_event_seq += 1
        seq = self._decode_event_seq
        eta = nxt * self._decode_step_s

        def fire(seq=seq):
            if seq != self._decode_event_seq:
                return        # superseded by a membership change
            self._on_decode_tick()

        self.schedule(eta, fire)

    def _on_decode_tick(self):
        finished = [j for j in self.decode_set.values() if j.tokens_left <= 1e-9]
        for j in finished:
            del self.decode_set[j.task_id]
            self._job_segment_done(j)
        while self._decode_wait and not self.decode_set:
            nxt = self._decode_wait.pop(0)
            self.decode_set[nxt.task_id] = nxt
            self._tr_pool_enter(nxt)
            if nxt.multi_lora:      # fused jobs can co-admit queued peers
                while self._decode_wait and self._decode_wait[0].multi_lora:
                    p = self._decode_wait.pop(0)
                    self.decode_set[p.task_id] = p
                    self._tr_pool_enter(p)
            break
        self._reschedule_decode()

    # -- tracing hooks (virtual-time mirror of the engine's span model) ----
    def _tr_pool_enter(self, j: _DecodeJob):
        """Job joins the decode pool: open its residency span."""
        if self.tracer is None or j.trace is None:
            return
        j.entered_pool_at = self.clock.t
        self.tracer.mark(j.trace, "decode", self.clock.t)

    def _tr_pool_exit(self, j: _DecodeJob, flow_out: int = 0):
        """Close the residency span (park hand-off or completion)."""
        if self.tracer is None or j.trace is None:
            return
        self.tracer.span(("rollout", "pool"), j.task_id,
                         j.entered_pool_at, self.clock.t, trace=j.trace,
                         flow_in=j.flow_in, flow_out=flow_out)
        j.flow_in = 0

    def _job_segment_done(self, j: _DecodeJob):
        tr = self.tracer if j.trace is not None else None
        j.seg_idx += 1
        if j.seg_idx >= len(j.segments):
            if tr is not None:      # final segment is always decode
                self._tr_pool_exit(j)
                tr.mark(j.trace, "completed", self.clock.t)
            if j.on_done:
                j.on_done()
            return
        kind, amount = j.segments[j.seg_idx]
        if kind == "env":
            # park: the job leaves the pool for the env interaction and
            # resumes via a (virtual, zero-duration) replay prefill — the
            # SAME canonical state sequence and park/resume flow arrows the
            # threaded engine emits, with the sim's instantaneous analogs
            if tr is not None:
                fid = tr.next_flow("park")
                self._tr_pool_exit(j, flow_out=fid)
                tr.mark(j.trace, "parked", self.clock.t)
                tr.mark(j.trace, "env", self.clock.t)
                rfid = tr.next_flow("resume")
                tr.span(("env", "pool"), j.task_id, self.clock.t,
                        self.clock.t + amount, trace=j.trace,
                        flow_in=fid, flow_out=rfid)
                j.flow_in = rfid
            self.rec.record("env", "env", j.task_id, self.clock.t,
                            self.clock.t + amount, 0)

            # after the external wait, advance to the next (decode) segment
            def resume():
                if tr is not None:
                    tr.mark(j.trace, "resume_queued", self.clock.t)
                    tr.mark(j.trace, "prefill", self.clock.t)
                self._job_segment_done(j)

            self.schedule(amount, resume)
        else:
            j.tokens_left = amount
            self._job_enter_pool(j)

    def _job_enter_pool(self, j: _DecodeJob):
        # without multi-LoRA fusion the engine serves ONE adapter at a time
        # (paper Table 4 "w/o multi-LoRA"): jobs queue for exclusive access
        if not j.multi_lora and self.decode_set:
            self._decode_wait.append(j)
            return
        if j.multi_lora and self.decode_set and not all(
                x.multi_lora for x in self.decode_set.values()):
            self._decode_wait.append(j)
            return
        self._advance_decode(self.clock.t)
        self.decode_set[j.task_id] = j
        self._tr_pool_enter(j)
        self._reschedule_decode()

    # -- public phase API used by policies ---------------------------------
    def submit_rollout(self, spec: TaskSpec, wl: WorkloadModel, version: int,
                       on_done: Callable, *, multi_lora: bool = True,
                       pool_devices: Optional[int] = None):
        """Prefill (compute-bound, brief) then fused decode (+env phases)."""
        devs = pool_devices or self.hw.rollout_devices
        N = self.cfg.active_param_count()
        prefill_s = (self.hw.calib * 2 * N * wl.prompt_len * wl.rows
                     / (devs * self.hw.peak_flops_per_dev * self.hw.prefill_mfu))
        kv_per_row = (self.cfg.state_bytes_per_token(2)
                      * (wl.prompt_len + 0.5 * wl.gen_len)
                      + self.cfg.state_bytes_fixed(2))
        segments: List[Tuple[str, float]] = []
        if wl.n_tool_calls:
            per = wl.gen_len / (wl.n_tool_calls + 1)
            for i in range(wl.n_tool_calls):
                segments.append(("decode", per))
                lat = max(0.1, self.rng.gauss(wl.env_latency_mean,
                                              wl.env_latency_std))
                segments.append(("env", lat))
            segments.append(("decode", per))
        else:
            segments.append(("decode", float(wl.gen_len)))
        job = _DecodeJob(task_id=spec.task_id, version=version, rows=wl.rows,
                         kv_bytes=kv_per_row * wl.rows, segments=segments,
                         tokens_left=segments[0][1], on_done=on_done,
                         multi_lora=multi_lora)
        t0 = self.clock.t
        if self.tracer is not None:
            # one trace per sim job (the sim's episode granularity): queued
            # and prefill are instantaneous-start in virtual time
            job.trace = self.tracer.new_trace(spec.task_id)
            self.tracer.mark(job.trace, "queued", t0)
            self.tracer.mark(job.trace, "prefill", t0)
            self.tracer.span(("prefill", "pool"), spec.task_id, t0,
                             t0 + prefill_s, trace=job.trace)
        self.rec.record("rollout", "prefill", spec.task_id, t0, t0 + prefill_s,
                        devs)

        def start():
            self._job_enter_pool(job)

        self.schedule(prefill_s, start)
        return job

    def submit_train(self, spec: TaskSpec, wl: WorkloadModel, version: int,
                     on_done: Callable, *, pool_devices: Optional[int] = None,
                     trace_ids: Tuple[int, ...] = ()):
        """Serialized train engine (paper §4.5)."""
        devs = pool_devices or self.hw.train_devices
        N = self.cfg.active_param_count()
        tokens = wl.tokens_per_batch
        dur = (self.hw.calib * 6 * N * tokens
               / (devs * self.hw.peak_flops_per_dev * self.hw.train_mfu)
               + self.hw.train_overhead_s)
        start_t = max(self.clock.t, self.train_busy_until)
        self.train_busy_until = start_t + dur
        if self.tracer is not None and trace_ids:
            self.tracer.span(("train", "pool"), spec.task_id, start_t,
                             start_t + dur)
            for tr in trace_ids:
                self.tracer.mark(tr, "train", start_t)
                self.tracer.mark(tr, "committed", start_t + dur)
        self.rec.record("train", "train", spec.task_id, start_t, start_t + dur,
                        devs)
        self.schedule(start_t + dur - self.clock.t, on_done)
        return dur
