"""The multi-task manager M (paper §4.2) — the centre of MARLaaS.

Maintains, per task t: LoRA parameters θ_t^(v), optimizer state φ_t^(v) and
the version counter v; plus the trajectory hand-off between the rollout and
training stages. Two trainer feeds exist:

- **Round-synchronous baseline** (``async_mode=False``): the global FIFO
  buffer Q_buffer of full ``TrajectoryBatch`` rounds. ``next_policy(t)``
  yields a given version exactly once, and with the default
  ``max_staleness=0`` the enqueue/commit admission checks reduce to the
  paper's strict per-task on-policy invariant: the rollout engine only
  generates from the latest committed version and an update is only
  accepted for the exact version its trajectories were generated under.

- **Event-driven off-policy feed** (``async_mode=True``, ROADMAP §2): the
  rollout side streams individual completed episodes in via
  ``enqueue_episode`` the moment each row evicts; episodes buffer until
  their GRPO group (``group_size`` same-prompt rows) is complete, then the
  group joins the tenant's ready queue. The trainer drains complete groups
  at its own pace through ``pop_episodes`` and packs micro-batches as soon
  as the tenant's ``min_train_rows`` threshold is met. Staleness is
  bounded: ``next_policy`` may issue up to ``max_staleness + 1`` rollout
  rounds per committed version (so decode never drains between commits),
  and both enqueue and pop apply a drop-or-train admission check — a
  group whose behaviour version lags the committed version by more than
  ``max_staleness`` is dropped and counted, never trained.

Thread-safe: the real runtime drives it from rollout/train threads; the
simulator drives it single-threaded in virtual time. All timestamps come
through the injected `clock` so both modes share metric definitions.
"""
from __future__ import annotations

import threading
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Deque, Dict, List, Optional, Tuple

from repro.rl.types import TrajectoryBatch


@dataclass
class TaskSpec:
    task_id: str
    env_name: str
    group_size: int = 4
    num_groups: int = 2            # groups per rollout batch
    max_new_tokens: int = 16
    target_steps: int = 20         # requested train steps
    temperature: float = 1.0
    lr: float = 3e-3
    priority: int = 0              # scheduler/preemption tier (higher wins)

    @property
    def rows_per_batch(self) -> int:
        return self.group_size * self.num_groups


@dataclass
class TaskState:
    spec: TaskSpec
    adapters: Any = None            # θ_t^(v)
    opt_state: Any = None           # φ_t^(v)
    version: int = 0
    steps_done: int = 0
    status: str = "pending"         # pending|admitted|preempted|
                                    # quarantined|finished
    rollout_issued_version: int = -1   # highest v handed to the rollout engine
    rounds_issued_for_version: int = 0  # rollout rounds issued under the
                                        # CURRENT version (async staleness
                                        # window; reset on commit)
    rollout_inflight_rows: int = 0     # rows currently resident/queued in the
                                       # continuous engine for this task
    rollout_rows_total: int = 0        # lifetime rows streamed through slots
    stale_rows_dropped: int = 0        # rows refused by the staleness window
    failed_rows: int = 0               # episodes lost to permanent tool
                                       # errors (incl. poisoned-group
                                       # siblings) — counted, never trained
    quarantine_dropped_rows: int = 0   # rows drained while the tenant's
                                       # circuit breaker was open
    abandoned: bool = False            # breaker gave up (trips > max_trips):
                                       # terminal — the run finishes without
                                       # this tenant reaching target_steps
    adapter_slot: Optional[int] = None  # stacked-LoRA slot while resident
    adapter_installs: int = 0          # times the adapter was (re)installed
    preempt_count: int = 0             # admission-driven preemptions suffered
    submitted_at: float = 0.0
    admitted_at: float = 0.0
    first_step_at: Optional[float] = None
    last_step_at: Optional[float] = None
    step_times: List[float] = field(default_factory=list)
    reward_history: List[float] = field(default_factory=list)

    @property
    def done(self) -> bool:
        return self.abandoned or self.steps_done >= self.spec.target_steps


@dataclass
class EpisodeGroup:
    """One complete GRPO group (``group_size`` same-prompt episodes) ready
    to train, as assembled by ``enqueue_episode``. ``version`` is the
    newest behaviour version among the rows (rows are stamped per-row at
    sample time and the stamp survives park/preempt/resume)."""
    task_id: str
    version: int
    rows: List[Any]                # RolloutCompletion-likes, submit order
    seq: int = 0                   # manager-global assembly order (FIFO key)


class MultiTaskManager:
    def __init__(self, clock: Callable[[], float] = None, *,
                 max_staleness: int = 0, min_train_rows: int = 0,
                 async_mode: bool = False):
        import time
        self.clock = clock or time.monotonic
        self.async_mode = async_mode
        self.max_staleness = max_staleness
        self.min_train_rows = min_train_rows
        self.tasks: Dict[str, TaskState] = {}
        self.q_buffer: Deque[TrajectoryBatch] = deque()
        # per-tenant ready queues of complete GRPO groups (async feed) and
        # the partially-assembled groups still waiting for sibling rows
        self.episodes: Dict[str, Deque[EpisodeGroup]] = {}
        self._partial: Dict[Tuple[str, Any], List[Any]] = {}
        self._ep_seq = 0
        # popped-but-uncommitted train work: a trainer crash between pop and
        # commit must not lose the rows (the rollout side already consumed
        # its issue budget for that version — losing them wedges the tenant)
        self._inflight_train: List[Tuple] = []
        # staleness-window drop accounting (drop-or-train decisions)
        self.stale_rows_dropped = 0
        self.stale_groups_dropped = 0
        self.stale_batches_dropped = 0
        self.discarded_tail_rows = 0   # rows arriving after their task done
        # fault accounting (ISSUE 10): with these two, the PR-7 invariant
        # extends to completed == trained + stale_dropped + discarded_tails
        # + failed + quarantine_dropped — no episode is ever silently lost
        self.failed_rows = 0           # permanent tool errors + poisoned-
                                       # group siblings
        self.quarantine_dropped_rows = 0
        # rows committed by the trainer (runtime increments on commit);
        # lives here rather than on the runtime so it serializes with the
        # checkpoint manifest and the invariant survives a restart
        self.rows_trained = 0
        # completed rows lost to a checkpoint restart (their round had no
        # serialized batch/group, so it regenerates); load_checkpoint
        # computes this so the invariant stays exact across incarnations
        self.orphaned_rows = 0
        # GRPO groups poisoned by a failed episode: a group missing a row
        # can never train, so late siblings count failed instead of
        # buffering in _partial forever
        self._failed_groups: set = set()
        # optional episode tracer (repro.obs): drop-or-train decisions are
        # terminal lifecycle events — a dropped episode must not look like
        # one still waiting for the trainer
        self.tracer = None
        self._lock = threading.RLock()  # guards: tasks/q_buffer/episodes
        self._cv = threading.Condition(self._lock)

    def _trace_drop(self, episodes, reason: str) -> None:
        tr = self.tracer
        if tr is None:
            return
        t = tr.now()
        for ep in episodes:
            meta = getattr(ep, "meta", None)
            trace = meta.get("trace_id") if isinstance(meta, dict) else None
            if trace is not None:
                tr.mark(trace, "dropped", t)
                tr.instant(("manager", "queue"), reason, t, trace=trace)

    # -- task lifecycle -------------------------------------------------
    def submit(self, spec: TaskSpec, adapters=None, opt_state=None) -> TaskState:
        with self._lock:
            st = TaskState(spec=spec, adapters=adapters, opt_state=opt_state,
                           submitted_at=self.clock())
            self.tasks[spec.task_id] = st
            self._cv.notify_all()
            return st

    def admit(self, task_id: str):
        with self._lock:
            st = self.tasks[task_id]
            if st.status == "pending":
                st.status = "admitted"
                st.admitted_at = self.clock()
                self._cv.notify_all()

    # -- admission-driven preemption (paper §4.3) -------------------------
    def preempt(self, task_id: str) -> bool:
        """Mark an admitted task preempted: it issues no NEW rollout rounds
        (next_policy returns None) while its already-queued rows replay at
        the engine's leisure. Returns True if the state changed."""
        with self._lock:
            st = self.tasks[task_id]
            if st.status != "admitted" or st.done:
                return False
            st.status = "preempted"
            st.preempt_count += 1
            self._cv.notify_all()
            return True

    def readmit(self, task_id: str) -> bool:
        with self._lock:
            st = self.tasks[task_id]
            if st.status != "preempted":
                return False
            st.status = "finished" if st.done else "admitted"
            self._cv.notify_all()
            return True

    # -- stacked-LoRA residency (LRU eviction bookkeeping) ----------------
    def adapter_bound(self, task_id: str, slot: int):
        with self._lock:
            st = self.tasks[task_id]
            st.adapter_slot = slot
            st.adapter_installs += 1

    def adapter_unbound(self, task_id: str):
        with self._lock:
            self.tasks[task_id].adapter_slot = None

    def resident_adapters(self) -> Dict[str, int]:
        with self._lock:
            return {tid: st.adapter_slot for tid, st in self.tasks.items()
                    if st.adapter_slot is not None}

    # -- Algorithm 1, line 5: M.next_policy(t) ---------------------------
    def _can_issue(self, st: TaskState) -> bool:   # held: _lock
        """Whether a rollout round may be issued for `st` right now.

        Sync: each committed version is handed out exactly once (the strict
        on-policy invariant). Async: up to ``max_staleness + 1`` rounds per
        committed version AND no more than that many rounds' worth of rows
        outstanding anywhere in the pipeline (engine + ready/partial queues
        + popped-but-uncommitted train work) — the trainer's commit rate is
        the backpressure that paces rollout — AND never more rows than the
        task's remaining train steps can consume."""
        if not self.async_mode:
            return st.rollout_issued_version < st.version
        window = self.max_staleness + 1
        if st.rounds_issued_for_version >= window:
            return False
        rpb = st.spec.rows_per_batch
        outstanding = (st.rollout_inflight_rows
                       + self._queued_rows(st.spec.task_id))
        if outstanding + rpb > rpb * window:
            return False
        # lifetime-demand cap: pipelining past the LAST useful commit only
        # decodes rows that are discarded as tails at shutdown — stop
        # issuing once the rows already in flight cover every train step
        # the task has left (rounds are the issuance quantum, so compare
        # against outstanding alone: a round may overshoot the tail of the
        # demand by up to rpb - 1 rows, never by a whole round)
        need = ((st.spec.target_steps - st.steps_done)
                * self.train_threshold(st.spec))
        return outstanding < need

    def _queued_rows(self, task_id: str) -> int:   # held: _lock
        n = sum(len(g.rows) for g in self.episodes.get(task_id, ()))
        n += sum(len(rows) for (tid, _), rows in self._partial.items()
                 if tid == task_id)
        for item in self._inflight_train:
            if item[0] == "episodes" and item[1] == task_id:
                n += sum(len(g.rows) for g in item[2])
        return n

    def next_policy(self, task_id: str):
        """Return (version, adapters) if a rollout round may be generated
        for this task, else None. Sync mode hands each version out ONCE;
        async mode issues up to ``max_staleness + 1`` rounds per version
        (bounded-staleness pipelining)."""
        with self._lock:
            st = self.tasks[task_id]
            if st.status != "admitted" or st.done:
                return None
            if not self._can_issue(st):
                return None
            st.rollout_issued_version = st.version
            st.rounds_issued_for_version += 1
            return st.version, st.adapters

    def rollout_ready_tasks(self) -> List[str]:
        with self._lock:
            return [tid for tid, st in self.tasks.items()
                    if st.status == "admitted" and not st.done
                    and self._can_issue(st)]

    # -- continuous-rollout occupancy (slot engine) -----------------------
    def rollout_started(self, task_id: str, rows: int):
        """The streaming worker handed `rows` requests for this task to the
        slot engine (they are queued or resident until completion)."""
        with self._lock:
            st = self.tasks[task_id]
            st.rollout_inflight_rows += rows
            st.rollout_rows_total += rows

    def rollout_row_done(self, task_id: str):
        with self._lock:
            st = self.tasks[task_id]
            st.rollout_inflight_rows = max(0, st.rollout_inflight_rows - 1)

    def inflight_rows(self) -> Dict[str, int]:
        with self._lock:
            return {tid: st.rollout_inflight_rows
                    for tid, st in self.tasks.items()
                    if st.rollout_inflight_rows > 0}

    # -- Algorithm 1, line 8: enqueue (round-synchronous feed) -------------
    def enqueue(self, batch: TrajectoryBatch) -> bool:
        """Admit a full rollout round into Q_buffer, subject to the
        staleness window: a batch whose behaviour version lags the
        committed version by more than ``max_staleness`` (0 = the paper's
        strict on-policy invariant) is dropped and counted, never trained.
        Returns whether the batch was admitted."""
        with self._lock:
            st = self.tasks[batch.task_id]
            lag = st.version - batch.version
            if lag < 0:
                raise ValueError(
                    f"task {batch.task_id} batch v{batch.version} is newer "
                    f"than committed v{st.version}")
            if st.status == "quarantined":
                self.quarantine_dropped_rows += batch.num_rows
                st.quarantine_dropped_rows += batch.num_rows
                return False
            if st.done or lag > self.max_staleness:
                self.stale_batches_dropped += 1
                self.stale_rows_dropped += batch.num_rows
                st.stale_rows_dropped += batch.num_rows
                return False
            self.q_buffer.append(batch)
            self._cv.notify_all()
            return True

    # -- Algorithm 1, line 13: pop (global FIFO) --------------------------
    def pop_batch(self, timeout: Optional[float] = None) -> Optional[TrajectoryBatch]:
        """Pop the oldest round, waiting up to `timeout` for one to arrive.

        The wait is a predicate loop (Condition.wait_for re-waits with the
        remaining time after every wake-up): an unrelated ``notify_all``
        (commit, submit, admit, ...) no longer truncates the deadline to
        its first wake. The popped batch is tracked as in-flight until its
        commit — ``recover_inflight`` re-enqueues it if the trainer dies
        in between."""
        with self._cv:
            if not self.q_buffer and timeout:
                self._cv.wait_for(lambda: bool(self.q_buffer), timeout)
            if not self.q_buffer:
                return None
            tb = self.q_buffer.popleft()
            self._inflight_train.append(("batch", tb.task_id, tb))
            return tb

    # -- event-driven off-policy feed (async_mode) ------------------------
    def enqueue_episode(self, task_id: str, version: int, group_key,
                        episode) -> bool:
        """One completed rollout episode, stamped with the adapter version
        that generated it. Buffers under `(task_id, group_key)` until all
        ``group_size`` sibling rows arrive, then publishes the complete
        group to the tenant's ready queue. Drop-or-train admission: rows
        for finished tasks and groups beyond the staleness window are
        dropped (with their already-buffered siblings — a group missing a
        row can never train) and counted. Returns whether admitted."""
        with self._lock:
            st = self.tasks[task_id]
            if st.status == "quarantined":
                buf = self._partial.pop((task_id, group_key), [])
                n = 1 + len(buf)
                self.quarantine_dropped_rows += n
                st.quarantine_dropped_rows += n
                self._trace_drop([episode] + buf, "quarantine_drop")
                return False
            if st.done:
                buf = self._partial.pop((task_id, group_key), [])
                self.discarded_tail_rows += 1 + len(buf)
                self._trace_drop([episode] + buf, "tail_drop")
                return False
            if (task_id, group_key) in self._failed_groups:
                # a sibling already failed: this group can never complete
                self.failed_rows += 1
                st.failed_rows += 1
                self._trace_drop([episode], "failed_drop")
                return False
            lag = st.version - version
            if lag < 0:
                raise ValueError(
                    f"task {task_id} episode v{version} is newer than "
                    f"committed v{st.version}")
            if lag > self.max_staleness:
                buf = self._partial.pop((task_id, group_key), [])
                dropped = 1 + len(buf)
                self.stale_rows_dropped += dropped
                st.stale_rows_dropped += dropped
                self.stale_groups_dropped += 1
                self._trace_drop([episode] + buf, "stale_drop")
                return False
            buf = self._partial.setdefault((task_id, group_key), [])
            buf.append(episode)
            if len(buf) >= st.spec.group_size:
                del self._partial[(task_id, group_key)]
                buf.sort(key=lambda c: getattr(c, "submit_index", 0))
                self._ep_seq += 1
                g = EpisodeGroup(task_id=task_id,
                                 version=max(getattr(c, "version", version)
                                             for c in buf),
                                 rows=buf, seq=self._ep_seq)
                self.episodes.setdefault(task_id, deque()).append(g)
                self._cv.notify_all()
            return True

    def fail_episode(self, task_id: str, group_key, episode) -> int:
        """One episode finished with a permanent tool error (async feed):
        count it failed, poison its GRPO group (already-buffered siblings
        drop with it; late ones drop on arrival — a group missing a row
        can never train), and return the rows lost."""
        with self._lock:
            st = self.tasks[task_id]
            buf = self._partial.pop((task_id, group_key), [])
            n = 1 + len(buf)
            self._failed_groups.add((task_id, group_key))
            self.failed_rows += n
            st.failed_rows += n
            self._trace_drop([episode] + buf, "failed_drop")
            return n

    def note_failed(self, task_id: str, n: int = 1):
        """Count rows lost to tool errors outside the async feed (sync
        round assembly books its own group poisoning)."""
        with self._lock:
            st = self.tasks[task_id]
            self.failed_rows += n
            st.failed_rows += n

    def note_quarantine_dropped(self, task_id: str, n: int = 1):
        """Count rows the engine aborted (or the runtime discarded) while
        the tenant's breaker was open."""
        with self._lock:
            st = self.tasks[task_id]
            self.quarantine_dropped_rows += n
            st.quarantine_dropped_rows += n

    def round_failed(self, task_id: str):
        """Sync mode: an issued round produced NO trainable rows (every
        episode failed) — re-arm issuance so the tenant isn't wedged
        waiting for a commit that can never come."""
        with self._lock:
            st = self.tasks[task_id]
            if (st.status == "admitted" and not st.done
                    and st.rollout_issued_version >= st.version):
                st.rollout_issued_version = st.version - 1
                self._cv.notify_all()

    # -- per-tenant quarantine (circuit breaker, ISSUE 10) -----------------
    def quarantine(self, task_id: str) -> bool:
        """Breaker tripped open: the tenant issues no new rounds and its
        arriving rows drop (counted) until unquarantined. Other tenants
        are untouched — that isolation is the point."""
        with self._lock:
            st = self.tasks[task_id]
            if st.status != "admitted" or st.done:
                return False
            st.status = "quarantined"
            self._cv.notify_all()
            return True

    def unquarantine(self, task_id: str) -> bool:
        """Half-open probe (or full recovery): readmit the tenant and
        re-arm issuance — the quarantined rounds' issue budget was spent
        on drained work, so without the reset the probe round could never
        issue and the breaker would never see an outcome."""
        with self._lock:
            st = self.tasks.get(task_id)
            if st is None or st.status != "quarantined":
                return False
            st.status = "finished" if st.done else "admitted"
            st.rounds_issued_for_version = 0
            st.rollout_issued_version = st.version - 1
            self._cv.notify_all()
            return True

    def drain_tenant(self, task_id: str) -> int:
        """Drop one tenant's queued work — ready groups, partial rows,
        buffered sync rounds — with counted drops. Returns rows dropped."""
        with self._lock:
            return self._drain_tenant_locked(task_id)

    def _drain_tenant_locked(self, task_id: str) -> int:   # held: _lock
        st = self.tasks[task_id]
        n = 0
        for g in self.episodes.pop(task_id, ()):
            n += len(g.rows)
            self._trace_drop(g.rows, "quarantine_drop")
        for key in [k for k in self._partial if k[0] == task_id]:
            rows = self._partial.pop(key)
            n += len(rows)
            self._trace_drop(rows, "quarantine_drop")
        keep: Deque[TrajectoryBatch] = deque()
        for tb in self.q_buffer:
            if tb.task_id == task_id:
                n += tb.num_rows
            else:
                keep.append(tb)
        self.q_buffer = keep
        self._failed_groups = {k for k in self._failed_groups
                               if k[0] != task_id}
        self.quarantine_dropped_rows += n
        st.quarantine_dropped_rows += n
        return n

    def abandon(self, task_id: str) -> int:
        """Terminal give-up (breaker trips exhausted): drain the tenant's
        queued work and mark it done-without-finishing, so the run can
        complete without it. Returns rows dropped by the drain."""
        with self._lock:
            st = self.tasks[task_id]
            n = self._drain_tenant_locked(task_id)
            st.abandoned = True
            st.status = "finished"
            self._cv.notify_all()
            return n

    def train_threshold(self, spec: TaskSpec) -> int:
        """Micro-batch size in rows for one tenant: ``min_train_rows``
        rounded UP to complete GRPO groups (group advantages need all G
        same-prompt rows); 0 = a full round (the synchronous batch size,
        which is what makes ``max_staleness=0`` reduce to the baseline)."""
        if self.min_train_rows <= 0:
            return spec.rows_per_batch
        g = spec.group_size
        return -(-max(self.min_train_rows, g) // g) * g

    def _prune_stale(self) -> None:   # held: _lock
        """Pop-time drop-or-train decision: discard ready groups whose
        version now lags beyond the window (the trainer advanced while
        they queued), counting every drop."""
        for tid, dq in self.episodes.items():
            st = self.tasks[tid]
            keep: Deque[EpisodeGroup] = deque()
            for g in dq:
                if st.done or st.version - g.version > self.max_staleness:
                    n = len(g.rows)
                    if st.done:
                        self.discarded_tail_rows += n
                        self._trace_drop(g.rows, "tail_drop")
                    else:
                        self.stale_rows_dropped += n
                        st.stale_rows_dropped += n
                        self.stale_groups_dropped += 1
                        self._trace_drop(g.rows, "stale_drop")
                else:
                    keep.append(g)
            self.episodes[tid] = keep

    def _select_ready(self) -> Optional[str]:   # held: _lock
        """Tenant with a full micro-batch of ready rows, FIFO by oldest
        ready group (assembly order) so no tenant starves."""
        self._prune_stale()
        best, best_seq = None, None
        for tid, dq in self.episodes.items():
            if not dq:
                continue
            st = self.tasks[tid]
            need = self.train_threshold(st.spec)
            if sum(len(g.rows) for g in dq) < need:
                continue
            if best_seq is None or dq[0].seq < best_seq:
                best, best_seq = tid, dq[0].seq
        return best

    def pop_episodes(self, timeout: Optional[float] = None
                     ) -> Optional[Tuple[str, List[EpisodeGroup]]]:
        """Drain one tenant's micro-batch: exactly ``train_threshold``
        rows of complete groups, oldest first (fixed batch shape ⇒ no
        per-step retrace of the jitted train step). Waits up to `timeout`
        on a predicate loop for a tenant to reach its threshold. The
        popped groups are tracked as in-flight until the matching commit
        (``recover_inflight`` restores them after a trainer crash)."""
        with self._cv:
            tid = self._select_ready()
            if tid is None and timeout:
                self._cv.wait_for(lambda: self._select_ready() is not None,
                                  timeout)
                tid = self._select_ready()
            if tid is None:
                return None
            st = self.tasks[tid]
            need = self.train_threshold(st.spec)
            dq = self.episodes[tid]
            groups: List[EpisodeGroup] = []
            rows = 0
            while dq and rows < need:
                g = dq.popleft()
                groups.append(g)
                rows += len(g.rows)
            self._inflight_train.append(("episodes", tid, groups))
            return tid, groups

    def ready_rows(self, task_id: Optional[str] = None) -> int:
        """Completed-episode rows sitting in ready groups (all tenants or
        one) — the trainer-visible backlog."""
        with self._lock:
            if task_id is not None:
                return sum(len(g.rows)
                           for g in self.episodes.get(task_id, ()))
            return sum(len(g.rows) for dq in self.episodes.values()
                       for g in dq)

    def partial_rows(self, task_id: Optional[str] = None) -> int:
        """Rows buffered in incomplete GRPO groups (awaiting siblings)."""
        with self._lock:
            return sum(len(rows) for (tid, _), rows in self._partial.items()
                       if task_id is None or tid == task_id)

    def dispatchable_rows(self) -> int:
        """Rows the trainer could pop RIGHT NOW: whole micro-batches
        (``train_threshold`` multiples of ready complete-group rows) per
        tenant in async mode, assembled rounds in Q_buffer in sync mode.
        This is the backlog stream behind ``trainer_idle_stats`` — rows
        still assembling toward a threshold are NOT dispatchable work (no
        trainer could legally train them), so they never count as time
        the trainer sat on trainable data."""
        with self._lock:
            if not self.async_mode:
                return sum(tb.num_rows for tb in self.q_buffer)
            n = 0
            for tid, dq in self.episodes.items():
                th = self.train_threshold(self.tasks[tid].spec)
                ready = sum(len(g.rows) for g in dq)
                n += (ready // th) * th
            return n

    def recover_inflight(self) -> int:
        """Re-enqueue popped-but-uncommitted train work at the FRONT of its
        queue — called on trainer-loop (re)entry. Without this, a trainer
        crash between pop and commit silently drops the work while the
        rollout side has already spent its issue budget for that version:
        the tenant deadlocks after restart. Returns items restored."""
        with self._lock:
            n = len(self._inflight_train)
            for item in reversed(self._inflight_train):
                if item[0] == "batch":
                    self.q_buffer.appendleft(item[2])
                else:
                    dq = self.episodes.setdefault(item[1], deque())
                    for g in reversed(item[2]):
                        dq.appendleft(g)
            self._inflight_train.clear()
            if n:
                self._cv.notify_all()
            return n

    def rebind_episode_envs(self, envs: Dict[str, object]) -> int:
        """Re-attach live env handles to restored completed episodes
        (checkpointed episodes serialize with ``env=None`` — env objects
        hold RNGs/sessions that don't pickle). Returns rows rebound."""
        n = 0
        with self._lock:
            for tid, dq in self.episodes.items():
                env = envs.get(tid)
                if env is None:
                    continue
                for g in dq:
                    for c in g.rows:
                        if c.env is None:
                            c.env = env
                            n += 1
        return n

    def _clear_inflight(self, task_id: str) -> None:   # held: _lock
        """Retire the oldest in-flight train item for `task_id` (its commit
        just landed)."""
        for i, item in enumerate(self._inflight_train):
            if item[1] == task_id:
                del self._inflight_train[i]
                return

    def _purge_task_queues(self, task_id: str) -> None:   # held: _lock
        """A finished task trains no more: discard its ready groups and
        partial rows (counted — nothing may leak silently)."""
        n = sum(len(g.rows) for g in self.episodes.pop(task_id, ()))
        for key in [k for k in self._partial if k[0] == task_id]:
            n += len(self._partial.pop(key))
        self.discarded_tail_rows += n
        self._failed_groups = {k for k in self._failed_groups
                               if k[0] != task_id}

    # -- Algorithm 1, line 15: commit θ,φ^(v+1) ---------------------------
    def commit(self, task_id: str, adapters, opt_state, trained_version: int,
               reward_mean: float = 0.0):
        with self._lock:
            st = self.tasks[task_id]
            lag = st.version - trained_version
            assert 0 <= lag <= self.max_staleness, (
                f"commit for v{trained_version} but task at v{st.version} "
                f"— outside the max_staleness={self.max_staleness} window")
            st.adapters = adapters
            st.opt_state = opt_state
            st.version += 1
            st.steps_done += 1
            st.rounds_issued_for_version = 0
            self._clear_inflight(task_id)
            now = self.clock()
            if st.first_step_at is None:
                st.first_step_at = now
            st.step_times.append(now)
            st.last_step_at = now
            st.reward_history.append(float(reward_mean))
            if st.done:
                st.status = "finished"
                self._purge_task_queues(task_id)
            self._cv.notify_all()

    # -- introspection ----------------------------------------------------
    def state(self, task_id: str) -> TaskState:
        """Locked lookup of one task's state (the `tasks` dict is guarded:
        a bare ``mgr.tasks[tid]`` from another thread races `submit`)."""
        with self._lock:
            return self.tasks[task_id]

    def spec_for(self, task_id: str) -> TaskSpec:
        """Locked spec accessor for the rollout/driver threads."""
        with self._lock:
            return self.tasks[task_id].spec

    def version_of(self, task_id: str) -> int:
        with self._lock:
            return self.tasks[task_id].version

    def total_steps_done(self) -> int:
        with self._lock:
            return sum(st.steps_done for st in self.tasks.values())

    def task_items(self) -> List:
        """Snapshot of (task_id, state) pairs — safe to iterate while other
        threads submit new tasks."""
        with self._lock:
            return list(self.tasks.items())

    def drop_counters(self) -> Dict[str, int]:
        """Staleness-window accounting (drop-or-train decisions + finished-
        task tails) for the metrics recorder."""
        with self._lock:
            return {"stale_rows_dropped": self.stale_rows_dropped,
                    "stale_groups_dropped": self.stale_groups_dropped,
                    "stale_batches_dropped": self.stale_batches_dropped,
                    "discarded_tail_rows": self.discarded_tail_rows,
                    "failed_rows": self.failed_rows,
                    "quarantine_dropped_rows": self.quarantine_dropped_rows}

    def all_done(self) -> bool:
        with self._lock:
            return bool(self.tasks) and all(
                st.done for st in self.tasks.values())

    def active_tasks(self) -> List[str]:
        with self._lock:
            return [tid for tid, st in self.tasks.items()
                    if st.status == "admitted" and not st.done]

    def pending_tasks(self) -> List[str]:
        with self._lock:
            return [tid for tid, st in self.tasks.items()
                    if st.status == "pending"]

    def snapshot_versions(self) -> Dict[str, int]:
        with self._lock:
            return {tid: st.version for tid, st in self.tasks.items()}

    def wait(self, predicate, timeout: float = None) -> bool:
        with self._cv:
            return self._cv.wait_for(predicate, timeout)
