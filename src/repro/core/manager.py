"""The multi-task manager M (paper §4.2) — the centre of MARLaaS.

Maintains, per task t: LoRA parameters θ_t^(v), optimizer state φ_t^(v) and
the version counter v; plus the global FIFO trajectory buffer Q_buffer whose
entries are (t, τ_t^(v), v).

Strict per-task policy consistency (paper §1): `next_policy(t)` yields a
given version exactly once — the rollout engine can only generate from the
latest *committed* version, and `commit` only accepts an update for the
exact version the trajectories were generated under. There is no staleness
anywhere in the pipeline by construction; asynchrony is purely cross-task.

Thread-safe: the real runtime drives it from rollout/train threads; the
simulator drives it single-threaded in virtual time. All timestamps come
through the injected `clock` so both modes share metric definitions.
"""
from __future__ import annotations

import threading
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Deque, Dict, List, Optional

from repro.rl.types import TrajectoryBatch


@dataclass
class TaskSpec:
    task_id: str
    env_name: str
    group_size: int = 4
    num_groups: int = 2            # groups per rollout batch
    max_new_tokens: int = 16
    target_steps: int = 20         # requested train steps
    temperature: float = 1.0
    lr: float = 3e-3
    priority: int = 0              # scheduler/preemption tier (higher wins)

    @property
    def rows_per_batch(self) -> int:
        return self.group_size * self.num_groups


@dataclass
class TaskState:
    spec: TaskSpec
    adapters: Any = None            # θ_t^(v)
    opt_state: Any = None           # φ_t^(v)
    version: int = 0
    steps_done: int = 0
    status: str = "pending"         # pending|admitted|preempted|finished
    rollout_issued_version: int = -1   # highest v handed to the rollout engine
    rollout_inflight_rows: int = 0     # rows currently resident/queued in the
                                       # continuous engine for this task
    rollout_rows_total: int = 0        # lifetime rows streamed through slots
    adapter_slot: Optional[int] = None  # stacked-LoRA slot while resident
    adapter_installs: int = 0          # times the adapter was (re)installed
    preempt_count: int = 0             # admission-driven preemptions suffered
    submitted_at: float = 0.0
    admitted_at: float = 0.0
    first_step_at: Optional[float] = None
    last_step_at: Optional[float] = None
    step_times: List[float] = field(default_factory=list)
    reward_history: List[float] = field(default_factory=list)

    @property
    def done(self) -> bool:
        return self.steps_done >= self.spec.target_steps


class MultiTaskManager:
    def __init__(self, clock: Callable[[], float] = None):
        import time
        self.clock = clock or time.monotonic
        self.tasks: Dict[str, TaskState] = {}
        self.q_buffer: Deque[TrajectoryBatch] = deque()
        self._lock = threading.RLock()  # guards: q_buffer
        self._cv = threading.Condition(self._lock)

    # -- task lifecycle -------------------------------------------------
    def submit(self, spec: TaskSpec, adapters=None, opt_state=None) -> TaskState:
        with self._lock:
            st = TaskState(spec=spec, adapters=adapters, opt_state=opt_state,
                           submitted_at=self.clock())
            self.tasks[spec.task_id] = st
            self._cv.notify_all()
            return st

    def admit(self, task_id: str):
        with self._lock:
            st = self.tasks[task_id]
            if st.status == "pending":
                st.status = "admitted"
                st.admitted_at = self.clock()
                self._cv.notify_all()

    # -- admission-driven preemption (paper §4.3) -------------------------
    def preempt(self, task_id: str) -> bool:
        """Mark an admitted task preempted: it issues no NEW rollout rounds
        (next_policy returns None) while its already-queued rows replay at
        the engine's leisure. Returns True if the state changed."""
        with self._lock:
            st = self.tasks[task_id]
            if st.status != "admitted" or st.done:
                return False
            st.status = "preempted"
            st.preempt_count += 1
            self._cv.notify_all()
            return True

    def readmit(self, task_id: str) -> bool:
        with self._lock:
            st = self.tasks[task_id]
            if st.status != "preempted":
                return False
            st.status = "finished" if st.done else "admitted"
            self._cv.notify_all()
            return True

    # -- stacked-LoRA residency (LRU eviction bookkeeping) ----------------
    def adapter_bound(self, task_id: str, slot: int):
        with self._lock:
            st = self.tasks[task_id]
            st.adapter_slot = slot
            st.adapter_installs += 1

    def adapter_unbound(self, task_id: str):
        with self._lock:
            self.tasks[task_id].adapter_slot = None

    def resident_adapters(self) -> Dict[str, int]:
        with self._lock:
            return {tid: st.adapter_slot for tid, st in self.tasks.items()
                    if st.adapter_slot is not None}

    # -- Algorithm 1, line 5: M.next_policy(t) ---------------------------
    def next_policy(self, task_id: str):
        """Return (version, adapters) if an unconsumed committed version
        exists for this task, else None. Hands each version out ONCE."""
        with self._lock:
            st = self.tasks[task_id]
            if st.status != "admitted" or st.done:
                return None
            if st.rollout_issued_version >= st.version:
                return None                       # waiting for a commit
            st.rollout_issued_version = st.version
            return st.version, st.adapters

    def rollout_ready_tasks(self) -> List[str]:
        with self._lock:
            return [tid for tid, st in self.tasks.items()
                    if st.status == "admitted" and not st.done
                    and st.rollout_issued_version < st.version]

    # -- continuous-rollout occupancy (slot engine) -----------------------
    def rollout_started(self, task_id: str, rows: int):
        """The streaming worker handed `rows` requests for this task to the
        slot engine (they are queued or resident until completion)."""
        with self._lock:
            st = self.tasks[task_id]
            st.rollout_inflight_rows += rows
            st.rollout_rows_total += rows

    def rollout_row_done(self, task_id: str):
        with self._lock:
            st = self.tasks[task_id]
            st.rollout_inflight_rows = max(0, st.rollout_inflight_rows - 1)

    def inflight_rows(self) -> Dict[str, int]:
        with self._lock:
            return {tid: st.rollout_inflight_rows
                    for tid, st in self.tasks.items()
                    if st.rollout_inflight_rows > 0}

    # -- Algorithm 1, line 8: enqueue -------------------------------------
    def enqueue(self, batch: TrajectoryBatch):
        with self._lock:
            st = self.tasks[batch.task_id]
            assert batch.version == st.version, (
                f"stale trajectory: task {batch.task_id} v{batch.version} "
                f"vs committed v{st.version} — on-policy invariant broken")
            self.q_buffer.append(batch)
            self._cv.notify_all()

    # -- Algorithm 1, line 13: pop (global FIFO) --------------------------
    def pop_batch(self, timeout: Optional[float] = None) -> Optional[TrajectoryBatch]:
        with self._cv:
            if not self.q_buffer and timeout:
                self._cv.wait(timeout)
            if not self.q_buffer:
                return None
            return self.q_buffer.popleft()

    # -- Algorithm 1, line 15: commit θ,φ^(v+1) ---------------------------
    def commit(self, task_id: str, adapters, opt_state, trained_version: int,
               reward_mean: float = 0.0):
        with self._lock:
            st = self.tasks[task_id]
            assert trained_version == st.version, (
                f"commit for v{trained_version} but task at v{st.version}")
            st.adapters = adapters
            st.opt_state = opt_state
            st.version += 1
            st.steps_done += 1
            now = self.clock()
            if st.first_step_at is None:
                st.first_step_at = now
            st.step_times.append(now)
            st.last_step_at = now
            st.reward_history.append(float(reward_mean))
            if st.done:
                st.status = "finished"
            self._cv.notify_all()

    # -- introspection ----------------------------------------------------
    def task_items(self) -> List:
        """Snapshot of (task_id, state) pairs — safe to iterate while other
        threads submit new tasks."""
        with self._lock:
            return list(self.tasks.items())

    def all_done(self) -> bool:
        with self._lock:
            return bool(self.tasks) and all(
                st.done for st in self.tasks.values())

    def active_tasks(self) -> List[str]:
        with self._lock:
            return [tid for tid, st in self.tasks.items()
                    if st.status == "admitted" and not st.done]

    def pending_tasks(self) -> List[str]:
        with self._lock:
            return [tid for tid, st in self.tasks.items()
                    if st.status == "pending"]

    def snapshot_versions(self) -> Dict[str, int]:
        with self._lock:
            return {tid: st.version for tid, st in self.tasks.items()}

    def wait(self, predicate, timeout: float = None) -> bool:
        with self._cv:
            return self._cv.wait_for(predicate, timeout)
