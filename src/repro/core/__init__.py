# The paper's primary contribution — the MARLaaS system itself:
#   manager.py    multi-task manager M (versioned θ/φ store + FIFO Q_buffer)
#   admission.py  KV-cache-aware admission control (generalized to SSM state)
#   runtime.py    real threaded disaggregated runtime (fused multi-LoRA
#                 rollout worker + serialized trainer, Algorithm 1)
#   simulator.py  virtual-time discrete-event executor (paper-scale tables)
#   policies.py   the 4 scheduling regimes + ablation variants
#   metrics.py    occupancy timeline -> util/idle/steps-per-hr/TTFS/TPTS
from .admission import AdmissionConfig, AdmissionController
from .manager import MultiTaskManager, TaskSpec, TaskState
from .metrics import MetricsRecorder, summarize

__all__ = ["AdmissionConfig", "AdmissionController", "MultiTaskManager",
           "TaskSpec", "TaskState", "MetricsRecorder", "summarize"]
