"""Real (threaded) MARLaaS runtime: the disaggregated stages of Fig 5
executing actual JAX rollout + GRPO training on this host.

Stage layout (`rollout_mode="continuous"`, `disagg_prefill=True`,
`env_stage=True` — all three paper stages disaggregated; `paged_kv=True`
replaces the dense per-slot cache with the shared page pool):

    submit ──> SlotScheduler queue ──> PrefillWorker thread(s)
                (SRPT/priority/         chunked prefill on own caches
                 starvation order)             │ ReadyRow (KV/SSM state +
                      ▲                        ▼  first token + logprob)
      resume job      │        RolloutWorker thread <── ready queue
      (restore snap   │          decode stream: scatter-only splice + one
       OR replay +    │          fused decode step over the slot pool —
       forced RESP)   │          NEVER runs a prefill graph
    EnvStage ─────────┘               │ park on tok.CALL (slot vacated,
      EnvWorker pool: latency +       ▼  instantly refilled; paged_kv:
      stateful ToolSession.call  <────┘  KV pages+SSM state snapshot to
      (cancellable: a timed-out          host, pages freed for the next
       call frees its worker NOW)        occupant)
               Trainer thread — round-synchronous baseline: pops full
               rounds off the FIFO Q_buffer; `async_train=True` (ROADMAP
               §2): drains the per-tenant completed-episode queue the
               moment `min_train_rows` complete GRPO groups exist, under a
               `max_staleness` admission window with decoupled-PPO
               importance weighting — runs PolicyUpdate, commits v+1

Event-driven off-policy trainer (`async_train=True`): each engine
completion is stamped with the adapter version that generated it (per-row,
surviving park/preempt/resume) and streams straight into
`MultiTaskManager.enqueue_episode` — no round assembly on the rollout
thread. The manager buffers rows until their GRPO group completes, the
trainer pops per-tenant micro-batches (`min_train_rows` rounded up to
complete groups; 0 = a full round) as soon as they exist, and rollout may
run up to `max_staleness + 1` rounds ahead of the last commit so decode
never drains between commits. Groups beyond the window are dropped and
counted (`n_stale_rows_dropped`), never trained; groups trained at lag ≥ 1
get a truncated importance-weight correction (`is_cap`) on the recorded
behaviour logprobs. With `max_staleness=0` the whole path reduces
token-for-token to the round-synchronous baseline (property-tested).

Paged KV block pool (`paged_kv=True`, ISSUE 5): attention K/V lives in a
shared pool of `kv_pool_pages` pages of `kv_page_size` tokens
(rollout/kvcache.py + kernels/paged_decode.py) instead of a dense
[slots, max_len] reservation — a 10-token row holds one page, not
max_len. Park/preempt snapshots the row's live pages + SSM state to host
(`resume_restore`), and resume SPLICES them back instead of replaying
prompt+prefix through prefill — `RolloutStats.replay_tokens_saved` counts
the recomputation killed; a snapshot dropped under `snapshot_budget_bytes`
pressure falls back to the retained token-replay path (identical output).
Admission switches to page-granular byte charges (`AdmissionConfig.paged`)
so mixed-length tenant sets pack more resident rows per HBM byte.

  RolloutWorker thread — streaming (default): feeds per-task requests into
    the engine's cross-task queue the moment each task's `next_policy`
    version becomes consumable, pumps the engine (splice/refill freed
    slots, one decode step), and assembles completed trajectories from the
    engine's completion stream — so decode never drains between tenant
    groups (paper §4.1/§4.5). With `disagg_prefill=False` (baseline) the
    prefill of incoming rows runs fused ON the decode stream — a long
    prompt stalls every resident tenant (booked as decode-stall time).
    The legacy `rollout_mode="round"` fuses one multi-LoRA generate() per
    round and blocks on its slowest row.
  PrefillWorker thread(s) — `prefill_workers` async workers pop
    scheduler-ordered rows and prefill them in `prefill_chunk`-sized
    chunks (rollout/prefill.py); preempted rows replay through the same
    path token-for-token.
  EnvWorker thread(s) — `env_workers` env-interaction workers
    (rollout/env_stage.py, `env_stage=True`): a row that samples a tool
    call is PARKED (slot freed and refilled) instead of freezing in its
    slot for the env latency; the tool response re-enters the scheduler
    queue as a resume job and splices back through the prefill path —
    token-for-token identical to the freeze-in-slot baseline. With
    `env_stage=False` (baseline) tool calls run on the engine's shared
    thread-pool while the row's slot sits frozen (booked as
    `tool_wait_slot_steps`), overlapping only the other rows' decode.
  Trainer thread — pops FIFO, runs the task's PolicyUpdate, commits v+1.

The same MultiTaskManager/MetricsRecorder as the simulator; scheduling
regimes: marlaas (async), multilora_sync (barrier), single_disagg
(sequential tasks). Per-stage timelines (prefill/decode/splice busy time,
stage queue depths) land in the recorder for the Fig-5 utilization story.

Fault tolerance: `checkpoint_every` writes atomic manager snapshots
(repro.checkpoint); `FailureInjector` can kill a step to exercise
restart-from-checkpoint in tests. Straggler mitigation: rollout rows hitting
the step budget are returned partially (graded reward on what exists) rather
than stalling the batch.
"""
from __future__ import annotations

import random
import threading
import time
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import numpy as np

from repro.configs import ModelConfig
from repro.envs.tasks import make_env
from repro.lora.adapters import init_lora
from repro.lora.multilora import AdapterResidency
from repro.rollout.engine import (ContinuousRolloutEngine, RolloutEngine,
                                  RolloutRequest, to_trajectory_batch)
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import TrainConfig, init_opt_state, make_train_step
from .admission import AdmissionConfig, AdmissionController
from .chaos import ChaosConfig, ChaosInjector
from .manager import MultiTaskManager, TaskSpec
from .metrics import MetricsRecorder
from .supervisor import (ABANDONED, CLOSED, HALF_OPEN, OPEN,  # noqa: F401
                         TenantBreaker, join_or_raise)


@dataclass
class RuntimeConfig:
    policy: str = "marlaas"           # marlaas | multilora_sync | single_disagg
    rollout_mode: str = "continuous"  # continuous (slot engine) | round (fused)
    max_slots: int = 8                # decode slots in the continuous engine
    max_adapter_slots: int = 8        # stacked-LoRA capacity (tenants resident)
    scheduler: str = "srpt"           # slot-queue pop order: srpt | fifo
    starvation_k: int = 8             # refills before a queued row jumps tiers
    preemption: bool = True           # admission may preempt lower-priority
                                      # tenants' resident rows
    disagg_prefill: bool = False      # async prefill stage (Fig 5): refill
                                      # prefills run on worker threads, the
                                      # decode stream only splices; False =
                                      # fused-refill baseline
    prefill_workers: int = 1          # async prefill worker threads
    prefill_chunk: int = 0            # chunked prefill size (0 = whole
                                      # prompt per call); rounded up for
                                      # recurrent-state exactness
    env_stage: bool = False           # disaggregated env-interaction stage:
                                      # rows park on tool calls (slot freed)
                                      # and resume via the prefill path;
                                      # False = freeze-in-slot baseline
    env_workers: int = 2              # env-interaction worker threads
    env_inflight_per_tenant: int = 0  # max concurrent tool calls per tenant
                                      # in the env stage (0 = uncapped): a
                                      # slow-tool tenant can't monopolize
                                      # the worker pool
    max_turns: int = 0                # per-episode tool-turn budget applied
                                      # to every request (0 = env default)
    paged_kv: bool = False            # paged KV-cache block pool (ISSUE 5):
                                      # attention K/V in shared fixed-size
                                      # pages + per-slot block tables instead
                                      # of a dense [slots, max_len] cache;
                                      # False = dense baseline
    kv_page_size: int = 16            # tokens per KV page (max_len must be
                                      # a multiple of it)
    kv_pool_pages: int = 0            # pool size in pages (0 = auto: the
                                      # dense-equivalent max_slots ×
                                      # max_len/page; size DOWN to realize
                                      # the HBM saving — rows the pool can't
                                      # serve finish via cache-capacity
                                      # eviction, never a crash)
    resume_restore: bool = True       # paged only: park/preempt snapshots
                                      # KV pages + SSM state to host and
                                      # resume SPLICES them back (no prefill
                                      # replay); False = always token-replay
    snapshot_budget_bytes: int = 0    # host bytes for parked snapshots
                                      # (0 = unlimited); overflow drops the
                                      # snapshot -> that row replays
    prefix_cache: bool = True         # paged only (ISSUE 8): global
                                      # copy-on-write prefix cache — GRPO
                                      # groups share prompt pages (fork on
                                      # first divergent write), park/resume
                                      # keeps prefix pages device-resident
                                      # (host snapshots become a spill
                                      # tier), and a per-tenant radix index
                                      # lets new rows prefill only their
                                      # uncached suffix; False = private
                                      # pages (PR 5 baseline)
    async_train: bool = False         # event-driven off-policy trainer
                                      # (ROADMAP §2): trainer drains the
                                      # per-tenant completed-episode queue
                                      # at its own pace instead of waiting
                                      # for full-round assembly; False =
                                      # round-synchronous baseline
    max_staleness: int = 1            # bounded staleness window (versions):
                                      # rollout may run this many rounds
                                      # ahead of the last commit; episodes
                                      # lagging further are dropped+counted.
                                      # 0 reduces token-for-token to the
                                      # synchronous baseline
    min_train_rows: int = 0           # micro-batch threshold in rows
                                      # (rounded UP to complete GRPO groups;
                                      # 0 = a full round) — fixed shape per
                                      # tenant, so the jitted step never
                                      # retraces
    is_cap: float = 2.0               # decoupled-PPO importance-weight
                                      # truncation for stale micro-batches
                                      # (active only when async_train and
                                      # max_staleness > 0)
    max_len: int = 96
    use_kernel: bool = False
    seed: int = 0
    rollout_pool_devices: int = 1     # metric bookkeeping (host has 1 CPU)
    train_pool_devices: int = 1
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 0         # commits between snapshots (0 = off)
    env_threads: int = 4
    trace: bool = False               # end-to-end episode tracing (ISSUE 9):
                                      # every submission gets a trace id and
                                      # per-stage lifecycle marks + track
                                      # spans land in `runtime.tracer`
                                      # (repro.obs) for Perfetto export and
                                      # critical-path attribution; off by
                                      # default — the hot loops then carry
                                      # only a `is None` check
    trace_capacity: int = 1_000_000   # tracer ring-buffer size (events);
                                      # overflow drops oldest and counts
    chaos: Optional[ChaosConfig] = None   # deterministic fault injection
                                      # (ISSUE 10): seeded per-site streams
                                      # kill stage workers, fail tool calls,
                                      # drop snapshots, tear checkpoints —
                                      # None = no injector object at all,
                                      # the hot paths carry one `is None`
    tool_retry_max: int = 3           # per-tool-call transient retries
                                      # (exponential backoff + jitter on the
                                      # env-stage queue, no worker blocked)
    tool_retry_base_s: float = 0.05   # first-retry backoff
    tool_retry_max_s: float = 2.0     # backoff ceiling
    tool_retry_episode_cap: int = 0   # total retries per EPISODE across its
                                      # turns (0 = uncapped): a flapping
                                      # tool can't spin one row forever
    supervisor_wedge_s: float = 0.0   # env worker with no heartbeat for
                                      # this long while executing is poisoned
                                      # and replaced (0 = liveness only)
    breaker_fail_threshold: int = 5   # consecutive tool-error episodes that
                                      # trip a tenant's circuit breaker open
    breaker_cooldown_s: float = 2.0   # open -> half-open probe delay
    breaker_max_trips: int = 3        # re-trips before the tenant is
                                      # abandoned (drained + marked done)
    checkpoint_keep_last: int = 0     # snapshot retention (0 = keep all)


class FailureInjector:
    """Crashes the trainer after N commits (tests restart-from-checkpoint).

    `fail_point="pre_commit"` instead kills the trainer BETWEEN pop and
    commit of what would be the Nth commit — the window where a popped
    batch used to be lost silently (the manager's in-flight tracking +
    `recover_inflight` is the fix under test)."""

    def __init__(self, fail_after_commits: Optional[int] = None,
                 fail_point: str = "post_commit"):
        assert fail_point in ("post_commit", "pre_commit")
        self.fail_after = fail_after_commits
        self.fail_point = fail_point
        self.commits = 0

    def on_train(self):
        """Called by the trainer after pop, before commit."""
        if (self.fail_point == "pre_commit" and self.fail_after is not None
                and self.commits + 1 >= self.fail_after):
            self.fail_after = None     # one-shot: the restart must succeed
            raise RuntimeError("injected node failure (pre-commit)")

    def on_commit(self):
        self.commits += 1
        if (self.fail_point == "post_commit" and self.fail_after is not None
                and self.commits >= self.fail_after):
            raise RuntimeError("injected node failure")


class MARLaaSRuntime:
    def __init__(self, cfg: ModelConfig, base_params, rcfg: RuntimeConfig,
                 acfg: Optional[AdmissionConfig] = None,
                 train_cfg: Optional[TrainConfig] = None,
                 failure: Optional[FailureInjector] = None):
        self.cfg = cfg
        self.base_params = base_params
        self.rcfg = rcfg
        self.acfg = acfg or AdmissionConfig(memory_budget_bytes=1e9,
                                            strict=False)
        if rcfg.paged_kv:
            # page-granular admission accounting rides the paged engine
            # (copy, never mutate a caller-shared config object)
            import dataclasses as _dc
            # group-shared prompt charging only where the engine actually
            # shares pages (pure-attention caches; SSM/hybrid rows keep
            # private recurrent state and never radix-match)
            self.acfg = _dc.replace(
                self.acfg, paged=True, page_size=rcfg.kv_page_size,
                prefix_shared=(rcfg.prefix_cache
                               and cfg.family not in ("ssm", "hybrid")))
        if rcfg.async_train and rcfg.rollout_mode != "continuous":
            raise ValueError("async_train requires rollout_mode='continuous' "
                             "(the event-driven trainer consumes the slot "
                             "engine's completion stream)")
        self.mgr = MultiTaskManager(
            max_staleness=rcfg.max_staleness if rcfg.async_train else 0,
            min_train_rows=rcfg.min_train_rows,
            async_mode=rcfg.async_train)
        self.admission = AdmissionController(cfg, self.acfg)
        self.rec = MetricsRecorder({"rollout": rcfg.rollout_pool_devices,
                                    "train": rcfg.train_pool_devices})
        self.tracer = None
        if rcfg.trace:
            from repro.obs import Tracer
            self.tracer = Tracer(capacity=rcfg.trace_capacity)
        self.mgr.tracer = self.tracer      # staleness/tail drops mark traces
        self.engine = RolloutEngine(cfg, base_params, max_len=rcfg.max_len,
                                    use_kernel=rcfg.use_kernel, seed=rcfg.seed)
        self.envs: Dict[str, object] = {}
        self.datagens: Dict[str, random.Random] = {}
        self._train_cfg_base = train_cfg or TrainConfig()
        self._train_steps: Dict[int, object] = {}   # group_size -> jitted fn
        self._tool_pool = ThreadPoolExecutor(max_workers=rcfg.env_threads)
        # deterministic chaos (ISSUE 10): one injector shared by every stage
        # (engine worker kills, env-stage tool faults, snapshot drops) and
        # the checkpoint store (torn publishes)
        self.chaos: Optional[ChaosInjector] = (
            ChaosInjector(rcfg.chaos)
            if rcfg.chaos is not None and rcfg.chaos.enabled else None)
        self.cengine = ContinuousRolloutEngine(
            cfg, base_params, max_slots=rcfg.max_slots,
            max_adapters=rcfg.max_adapter_slots, max_len=rcfg.max_len,
            use_kernel=rcfg.use_kernel, seed=rcfg.seed,
            tool_executor=self._tool_pool, scheduler=rcfg.scheduler,
            starvation_k=rcfg.starvation_k,
            disagg_prefill=rcfg.disagg_prefill,
            prefill_chunk=rcfg.prefill_chunk,
            prefill_workers=rcfg.prefill_workers,
            env_stage=rcfg.env_stage,
            env_workers=rcfg.env_workers,
            env_inflight_per_tenant=rcfg.env_inflight_per_tenant,
            paged_kv=rcfg.paged_kv,
            kv_page_size=rcfg.kv_page_size,
            kv_pool_pages=rcfg.kv_pool_pages,
            resume_restore=rcfg.resume_restore,
            snapshot_budget_bytes=rcfg.snapshot_budget_bytes,
            prefix_cache=rcfg.prefix_cache,
            on_stage=self._on_stage,
            tracer=self.tracer,
            chaos=self.chaos,
            tool_retry_max=rcfg.tool_retry_max,
            tool_retry_base_s=rcfg.tool_retry_base_s,
            tool_retry_max_s=rcfg.tool_retry_max_s,
            tool_retry_episode_cap=rcfg.tool_retry_episode_cap,
            supervise_wedge_s=rcfg.supervisor_wedge_s)
        # ONE source of truth for counters (ISSUE 9 satellite): summarize()
        # merges the engine's RolloutStats int fields with the recorder's
        # explicit counters instead of relying on hand-mirrored incr calls
        self.rec.attach_rollout_stats(self.cengine.stats)
        # LRU tenant -> stacked-LoRA slot map (rollout thread only). The
        # device write happens in _feed_continuous once the consumable
        # version is known (and only when it changed), so the residency's
        # own install hook is a no-op slot assignment.
        self.residency = AdapterResidency(
            rcfg.max_adapter_slots, lambda slot, tree: None,
            on_evict=self._on_adapter_evict)
        self._resident_version: Dict[str, int] = {}   # tenant -> installed v
        # admission-driven preemptions requested by the driver thread,
        # executed on the rollout thread (the engine is single-threaded)
        self._preempt_q: deque = deque()
        # victim decode progress observed at preemption (rollout thread
        # writes, admission tick reads): feeds the remaining-budget-aware
        # readmission re-estimate
        self._preempt_progress: Dict[str, float] = {}
        # per-tenant round counter: GRPO group identity for the episode
        # queue is (round, group-within-round) — rollout thread only
        self._round_seq: Dict[str, int] = {}
        # cumulative completed row count feeding the recorder's
        # trainer-backlog timeline (rollout thread only; the trained-row
        # twin lives on the manager — mgr.rows_trained — so it survives
        # checkpoint restarts and the conservation invariant holds across
        # incarnations, not just within one)
        self._rows_completed = 0
        # per-tenant circuit breaker (ISSUE 10): tool-error episodes are the
        # failure signal, natural finishes the success signal; transitions
        # are applied on the rollout thread (the only thread that may touch
        # the engine), admission-side effects queued to the driver
        self.breaker: Optional[TenantBreaker] = (
            TenantBreaker(fail_threshold=rcfg.breaker_fail_threshold,
                          cooldown_s=rcfg.breaker_cooldown_s,
                          max_trips=rcfg.breaker_max_trips)
            if rcfg.rollout_mode == "continuous" else None)
        # quarantine/readmit/abandon byte accounting requested by the
        # rollout thread, executed by the driver's admission tick
        self._quarantine_admission_q: deque = deque()
        # sync mode: failed-row counts per issued round (tid, version) — a
        # round missing rows can never pack, so its completion check is
        # len(batch) + failed >= rows_per_batch (rollout thread only)
        self._sync_failed: Dict[tuple, int] = {}
        self._stop = threading.Event()
        self.failure = failure
        self.error: Optional[BaseException] = None

    # -- task submission ---------------------------------------------------
    def submit_task(self, spec: TaskSpec, adapters=None, opt_state=None):
        if adapters is None:
            key = jax.random.PRNGKey(hash(spec.task_id) % (2 ** 31))
            adapters = init_lora(key, self.cfg)
        tc = self._tc(spec)
        if opt_state is None:
            opt_state = init_opt_state(self.cfg, tc, self.base_params, adapters)
        self.mgr.submit(spec, adapters, opt_state)
        self.envs[spec.task_id] = make_env(spec.env_name)
        self.datagens[spec.task_id] = random.Random(
            hash((self.rcfg.seed, spec.task_id)) % (2 ** 31))

    def _tc(self, spec: TaskSpec) -> TrainConfig:
        # the importance-weight correction only activates when stale
        # micro-batches are actually admissible — at max_staleness=0 every
        # batch is on-policy and the loss must stay bit-identical to the
        # synchronous baseline
        is_cap = (self.rcfg.is_cap
                  if self.rcfg.async_train and self.rcfg.max_staleness > 0
                  else 0.0)
        return TrainConfig(group_size=spec.group_size,
                           use_logprob_kernel=self.rcfg.use_kernel,
                           is_cap=is_cap,
                           adamw=AdamWConfig(lr=spec.lr))

    def _train_step_for(self, spec: TaskSpec):
        if spec.group_size not in self._train_steps:
            self._train_steps[spec.group_size] = jax.jit(
                make_train_step(self.cfg, self._tc(spec)))
        return self._train_steps[spec.group_size]

    # -- request building ----------------------------------------------------
    def _build_requests(self, tids: List[str], adapter_order: Dict[str, int]):
        reqs = []
        for tid in tids:
            spec = self.mgr.spec_for(tid)
            env = self.envs[tid]
            rng = self.datagens[tid]
            for _ in range(spec.num_groups):
                prompt, truth = env.sample_prompt(rng)
                for _ in range(spec.group_size):
                    reqs.append(RolloutRequest(
                        task_id=tid, adapter_index=adapter_order[tid],
                        prompt=prompt, truth=truth, env=env,
                        max_new_tokens=spec.max_new_tokens,
                        temperature=spec.temperature,
                        priority=spec.priority,
                        max_turns=self.rcfg.max_turns or None))
        return reqs

    # -- rollout worker -------------------------------------------------------
    def _rollout_round(self) -> bool:
        """One fused cross-task rollout round. Returns True if work done."""
        ready = self.mgr.rollout_ready_tasks()
        # admission control gates which tenants join the fused batch
        batch_tids, versions, adapters = [], {}, []
        for tid in ready:
            np_ = self.mgr.next_policy(tid)
            if np_ is None:
                continue
            versions[tid] = np_[0]
            adapters.append(np_[1])
            batch_tids.append(tid)
        if not batch_tids:
            return False
        order = {t: i for i, t in enumerate(batch_tids)}
        reqs = self._build_requests(batch_tids, order)
        t0 = time.monotonic()
        results, stats = self.engine.generate(reqs, adapters,
                                              tool_executor=self._tool_pool)
        t1 = time.monotonic()
        self.rec.record("rollout", "decode", "+".join(batch_tids), t0, t1,
                        self.rcfg.rollout_pool_devices)
        for tid in batch_tids:
            tb = to_trajectory_batch(results, tid, versions[tid],
                                     self.mgr.spec_for(tid).group_size,
                                     pad_to=self.rcfg.max_len)
            self.mgr.enqueue(tb)
        return True

    def _rollout_loop(self):
        try:
            if self.rcfg.rollout_mode == "continuous":
                self._rollout_loop_continuous()
                return
            while not self._stop.is_set():
                did = self._rollout_round()
                if not did:
                    if self.mgr.all_done():
                        return
                    time.sleep(0.002)
        except BaseException as e:       # surface to the driver
            self.error = e
            self._stop.set()

    # -- streaming rollout worker (continuous slot engine) -----------------
    def _on_stage(self, phase: str, task_id: str, t0: float, t1: float):
        """Engine stage hook: prefill intervals arrive from the async
        prefill workers, splice/refill intervals from the rollout thread —
        the recorder is thread-safe. This is what makes prefill-stage vs
        decode-stage busy time separately measurable (Fig 5)."""
        from .metrics import PHASE_INTENSITY
        if phase not in PHASE_INTENSITY:
            raise ValueError(f"unknown stage phase {phase!r} — add it to "
                             "PHASE_INTENSITY or fix the call site")
        self.rec.record("rollout", phase, task_id, t0, t1,  # noqa: RA105
                        self.rcfg.rollout_pool_devices)

    def _on_adapter_evict(self, tid: str, slot: int):
        self.mgr.adapter_unbound(tid)
        self._resident_version.pop(tid, None)
        self.rec.incr("adapter_evictions")

    def _adapter_in_use(self, tid: str) -> bool:
        """A tenant's adapter may not be evicted while it has rows resident
        or queued in the engine (queued requests carry its slot index)."""
        return (tid in self.cengine.active_tenants()
                or self.mgr.state(tid).rollout_inflight_rows > 0)

    def _feed_continuous(self) -> bool:
        """Submit every consumable (task, version) round into the engine
        queue, acquiring the tenant's stacked-LoRA slot through the LRU
        residency map (idle tenants' adapters are evicted on demand, so
        tenant counts ≫ max_adapter_slots stream through). Called from the
        rollout thread only."""
        fed = False
        for tid in self.mgr.rollout_ready_tasks():
            st = self.mgr.state(tid)
            slot = self.residency.acquire(tid, st.adapters,
                                          in_use=self._adapter_in_use)
            if slot is None:
                continue     # every adapter slot pinned; task stays ready
            if st.adapter_slot != slot:          # fresh slot, not a hit
                self.mgr.adapter_bound(tid, slot)
                self.rec.incr("adapter_installs")
            np_ = self.mgr.next_policy(tid)
            if np_ is None:
                continue
            version, adapters = np_
            # one device write per (tenant, version): skip when the resident
            # copy is already this committed tree
            if self._resident_version.get(tid) != version:
                self.cengine.set_adapters(slot, adapters)
                self._resident_version[tid] = version
            reqs = self._build_requests([tid], {tid: slot})
            # GRPO group identity for the episode queue: (round, group) —
            # stamped into row meta alongside the behaviour version so
            # park/preempt/resume can't lose it
            round_no = self._round_seq.get(tid, 0) + 1
            self._round_seq[tid] = round_no
            group_size = self.mgr.spec_for(tid).group_size
            self.mgr.rollout_started(tid, len(reqs))
            for i, r in enumerate(reqs):
                meta = {"task_id": tid, "version": version,
                        "group": (round_no, i // group_size)}
                if self.tracer is not None:
                    # trace is born at submission: the gap until the engine
                    # pops it off its queue is the admission-wait component
                    tr = self.tracer.new_trace(tid)
                    meta["trace_id"] = tr
                    self.tracer.mark(tr, "submitted")
                self.cengine.submit(r, meta=meta)
            fed = True
        return fed

    def _execute_preemptions(self) -> bool:
        """Apply admission-driven preemptions queued by the driver thread
        (the engine may only be touched from the rollout thread). Records
        each victim's decode progress so the driver's admission tick can
        tighten its parked byte reservation (remaining-budget re-estimate —
        partially decoded rows need less KV headroom at readmission)."""
        did = False
        while self._preempt_q:
            victim = self._preempt_q.popleft()
            n = self.cengine.preempt_tenant(victim)
            if n:
                self.rec.incr("preemptions")
                self.rec.incr("preempted_rows", n)
                did = True
            rows, sampled_mean = self.cengine.queued_progress(victim)
            if rows:
                self._preempt_progress[victim] = sampled_mean
        return did

    def _flush_decode_segment(self, now: float):
        if self._seg_tasks and self._seg_t0 is not None and now > self._seg_t0:
            name = "+".join(sorted(self._seg_tasks))
            self.rec.record("rollout", "decode", name,
                            self._seg_t0, now,
                            self.rcfg.rollout_pool_devices)
            if self.tracer is not None:
                # the fused decode stream as one Perfetto track: each slice
                # is a contiguous occupant-set run (same data the recorder
                # books as decode busy time)
                self.tracer.span(("rollout", "decode"), name,
                                 self._seg_t0, now)
        self._seg_t0 = now
        self._seg_tasks = frozenset()

    def _handle_completion(self, comp, rounds: Dict[tuple, list]) -> bool:
        """Route one engine completion into the trainer feed; True if a
        trainer-visible queue advanced. Every completion is accounted:
        `rollout_row_done` always runs, and rows that can never train
        (finished task, beyond the staleness window) are dropped WITH a
        counter instead of leaking in a partial round."""
        tid = comp.task_id
        self.mgr.rollout_row_done(tid)
        self._rows_completed += 1
        if comp.finish_reason == "quarantined":
            # engine-aborted row of a tripped tenant: counted, never trained
            self.mgr.note_quarantine_dropped(tid, 1)
            return False
        failed = comp.finish_reason == "tool_error"
        if self.breaker is not None:
            if failed:
                self.breaker.record_failure(tid)
            elif comp.finish_reason in ("eos", "budget", "capacity",
                                        "turn_limit"):
                # natural finishes close a half-open probe; degraded-but-
                # finished rows (tool_timeout, straggler) are neutral
                self.breaker.record_success(tid)
        if self.rcfg.async_train:
            if failed:
                # permanent tool error: the episode's GRPO group is poisoned
                # (siblings drop with it — a group missing a row can never
                # train), all counted as failed rows
                self.mgr.fail_episode(tid, comp.meta.get("group"), comp)
                return False
            # event-driven feed: the episode joins its GRPO group in the
            # per-tenant queue the moment it evicts — no round assembly
            advanced = self.mgr.enqueue_episode(tid, comp.version,
                                                comp.meta.get("group"), comp)
            self.rec.record_train_backlog(time.monotonic(),
                                          self.mgr.dispatchable_rows())
            return advanced
        st = self.mgr.state(tid)
        key = (tid, comp.version)
        if st.done or st.version - comp.version > self.mgr.max_staleness:
            # this round can never train: drop the completion AND any
            # already-buffered siblings (previously they sat in `rounds`
            # forever — the partial-entry leak)
            stale = rounds.pop(key, [])
            self._sync_failed.pop(key, None)
            self.rec.incr("orphaned_completions", 1 + len(stale))
            return False
        spec = self.mgr.spec_for(tid)
        if failed:
            self._sync_failed[key] = self._sync_failed.get(key, 0) + 1
            self.mgr.note_failed(tid, 1)
        else:
            rounds.setdefault(key, []).append(comp)
        batch = rounds.get(key, [])
        n_failed = self._sync_failed.get(key, 0)
        if len(batch) + n_failed < spec.rows_per_batch:
            return False
        rounds.pop(key, None)
        self._sync_failed.pop(key, None)
        if n_failed:
            # a round missing rows can never pack into full GRPO groups:
            # the surviving siblings drop with the failures and issuance is
            # re-armed so the tenant isn't wedged waiting for a commit
            if batch:
                self.mgr.note_failed(tid, len(batch))
            self.mgr.round_failed(tid)
            return False
        # completions arrive in eviction order; GRPO groups are contiguous
        # rows sharing a prompt, so restore submission order before packing
        batch.sort(key=lambda c: c.submit_index)
        tb = to_trajectory_batch(batch, tid, comp.version, spec.group_size,
                                 pad_to=self.rcfg.max_len)
        if self.tracer is not None:
            tb.meta["trace_ids"] = self._trace_ids_of(batch)
        self.mgr.enqueue(tb)
        self.rec.record_train_backlog(time.monotonic(),
                                      self.mgr.dispatchable_rows())
        return True

    @staticmethod
    def _trace_ids_of(completions) -> List[int]:
        """Trace ids riding a batch's completion metas (traced rows only)."""
        return [c.meta["trace_id"] for c in completions
                if isinstance(c.meta, dict) and "trace_id" in c.meta]

    def _poll_breaker(self, rounds: Dict[tuple, list]):
        """Apply pending circuit-breaker transitions (rollout thread only —
        quarantine aborts the tenant's engine rows, and the engine is
        single-threaded). Admission byte accounting is queued to the
        driver's tick; everything else happens here."""
        now = time.monotonic()
        for tid, state in self.breaker.poll(now):
            self.rec.record_breaker_sample(now, tid, state)
            if self.tracer is not None:
                self.tracer.instant(("supervisor", "breaker"),
                                    f"{tid}:{state}", now)
            if state == OPEN:
                self.rec.incr("quarantine_trips")
                self.mgr.quarantine(tid)
                # in-flight rows abort through the normal completion path
                # (finish_reason "quarantined" -> counted drops); queued
                # manager work drains with counted drops too
                self.cengine.abort_tenant(tid)
                self.mgr.drain_tenant(tid)
                for key in [k for k in rounds if k[0] == tid]:
                    self.mgr.note_quarantine_dropped(tid,
                                                     len(rounds.pop(key)))
                for key in [k for k in self._sync_failed if k[0] == tid]:
                    del self._sync_failed[key]
                self._quarantine_admission_q.append(("quarantine", tid))
            elif state == HALF_OPEN:
                self.rec.incr("quarantine_probes")
                self.mgr.unquarantine(tid)     # probe round may issue
                self._quarantine_admission_q.append(("readmit", tid))
            elif state == CLOSED:
                self.rec.incr("quarantine_recoveries")
            elif state == ABANDONED:
                self.rec.incr("quarantine_abandoned")
                self.cengine.abort_tenant(tid)
                self.mgr.abandon(tid)          # done-without-finishing: the
                                               # admission tick releases its
                                               # parked bytes via st.done

    def _rollout_loop_continuous(self):
        eng = self.cengine
        rounds: Dict[tuple, list] = {}      # (tid, v) -> completions so far
        clean = False                       # exited via all-done, not stop
        self._seg_tasks: frozenset = frozenset()
        self._seg_t0: Optional[float] = None
        last_slot_sample = None
        last_queue_sample = None
        last_env_sample = None
        last_page_sample = None
        while not self._stop.is_set():
            self._execute_preemptions()
            fed = self._feed_continuous()
            progressed = eng.step()
            now = time.monotonic()
            occ, cap = eng.occupancy()
            # step-function timeline: sample only on occupancy change (idle
            # spins would otherwise append hundreds of samples per second)
            if (occ, cap) != last_slot_sample:
                self.rec.record_slot_sample(now, occ, cap)
                last_slot_sample = (occ, cap)
            qd = eng.queue_depths()
            if qd != last_queue_sample:
                self.rec.record_queue_sample(now, *qd)
                last_queue_sample = qd
            if self.rcfg.env_stage:
                ed = eng.env_depths()
                if ed != last_env_sample:
                    self.rec.record_env_sample(now, *ed)
                    last_env_sample = ed
            if self.rcfg.paged_kv:
                ps = eng.page_stats()
                key = (ps["kv_pages_used"], round(ps["kv_page_frag"], 3))
                if key != last_page_sample:
                    self.rec.record_page_sample(
                        now, int(ps["kv_pages_used"]),
                        int(ps["kv_pages_total"]), ps["kv_page_frag"])
                    last_page_sample = key
            # decode timeline: one interval per contiguous occupant-set run,
            # task_id joined with "+" (fused multi-tenant decode)
            tasks_now = eng.occupant_tasks()
            if tasks_now != self._seg_tasks:
                self._flush_decode_segment(now)
                self._seg_tasks = tasks_now
            for comp in eng.drain_completions():
                if self._handle_completion(comp, rounds):
                    progressed = True
            if self.breaker is not None:
                self._poll_breaker(rounds)
            if not progressed and not fed:
                if self.mgr.all_done() and eng.idle():
                    clean = True
                    break
                time.sleep(0.002)
        # final drain: the stop flag can land while completions sit in the
        # engine's out-queue — without this they vanished with the thread,
        # inflight-row counters never returned to zero, and a restart
        # over-counted occupancy (the shutdown half of the rounds-dict leak)
        for comp in eng.drain_completions():
            self._handle_completion(comp, rounds)
        if clean:
            # drain invariants: a clean all-done exit must leave no orphaned
            # completions and every inflight-row counter back at zero
            assert not rounds, (
                f"partial rounds leaked at clean shutdown: "
                f"{[(k, len(v)) for k, v in rounds.items()]}")
            leftover = self.mgr.inflight_rows()
            assert not leftover, (
                f"inflight-row counters nonzero at clean shutdown: {leftover}")
            assert self.mgr.partial_rows() == 0, "partial GRPO groups leaked"
        elif rounds:
            # aborted run (stop flag / injected failure): rows already
            # completed for never-finished rounds are surfaced, not lost
            self.rec.incr("orphaned_completions",
                          sum(len(v) for v in rounds.values()))
            rounds.clear()
        now = time.monotonic()
        occ, cap = eng.occupancy()
        self.rec.record_slot_sample(now, occ, cap)   # close the timeline
        self.rec.record_queue_sample(now, *eng.queue_depths())
        if self.rcfg.paged_kv:
            ps = eng.page_stats()
            self.rec.record_page_sample(now, int(ps["kv_pages_used"]),
                                        int(ps["kv_pages_total"]),
                                        ps["kv_page_frag"])
            # restore-vs-replay counts reach summarize() straight from
            # RolloutStats via rec.counters_snapshot() — the hand-mirrored
            # incr loop that used to sit here is gone (single source of
            # truth; ISSUE 9 satellite)
            # sharing gauges ride the counter channel as end-of-run values
            for name in ("kv_shared_pages", "kv_prefix_pages",
                         "kv_hbm_bytes_per_row"):
                if ps.get(name):
                    self.rec.incr(name, int(ps[name]))
        # fault-tolerance accounting -> summary counters (merged BEFORE the
        # halts below — a wedged worker makes halt raise, and the restart/
        # retry story should survive into the recorder regardless)
        # supervisor.counters is tick-thread-only (this thread) — it is not
        # the recorder's lock-guarded dict of the same name
        for name, n in eng.supervisor.counters.items():  # noqa: RA102
            if n:
                self.rec.incr(f"supervisor_{name}", n)
        if eng._env is not None:
            for name in ("retries", "recovered", "wedged"):
                n = getattr(eng._env, name)
                if n:
                    self.rec.incr(f"env_{name}", n)
        if self.chaos is not None:
            for site, n in self.chaos.counts().items():
                if n:
                    self.rec.incr(f"chaos_{site}", n)
        if self.rcfg.env_stage:
            self.rec.record_env_sample(now, *eng.env_depths())
            if eng._env is not None:
                eng._env.halt()     # env workers die with the rollout loop
        self._flush_decode_segment(now)
        if self.rcfg.disagg_prefill:
            eng._halt_stage()       # workers die with the rollout loop

    # -- trainer ---------------------------------------------------------------
    def _train_one(self, tb, trained_version: Optional[int] = None) -> None:
        import jax.numpy as jnp
        if self.failure:
            self.failure.on_train()    # pre-commit fail point: the popped
                                       # batch is in-flight right now
        if trained_version is None:
            trained_version = tb.version
        st = self.mgr.state(tb.task_id)
        tc = self._tc(st.spec)
        step_fn = self._train_step_for(st.spec)
        batch = {
            "tokens": jnp.asarray(tb.tokens),
            "prompt_lens": jnp.asarray(tb.prompt_lens),
            "total_lens": jnp.asarray(tb.total_lens),
            "rewards": jnp.asarray(tb.rewards),
        }
        if "loss_mask" in tb.meta:
            batch["loss_mask"] = jnp.asarray(tb.meta["loss_mask"])
        if tc.is_cap > 0 and tb.behavior_logprobs is not None:
            # decoupled-PPO correction: the loss reweights by
            # min(exp(old_lp - behavior_lp), is_cap) — behaviour logprobs
            # were recorded at sample time under the generating version
            batch["behavior_logprobs"] = jnp.asarray(tb.behavior_logprobs)
        trace_ids = (tb.meta.get("trace_ids", ())
                     if self.tracer is not None else ())
        t0 = time.monotonic()
        if self.tracer is not None:
            for tr in trace_ids:
                self.tracer.mark(tr, "train", t0)
        new_adapters, new_opt, metrics = step_fn(self.base_params, st.adapters,
                                                 st.opt_state, batch)
        jax.block_until_ready(jax.tree.leaves(new_adapters)[0])
        t1 = time.monotonic()
        self.rec.record("train", "train", tb.task_id, t0, t1,
                        self.rcfg.train_pool_devices)
        self.mgr.commit(tb.task_id, new_adapters, new_opt, trained_version,
                        reward_mean=float(np.mean(tb.rewards)))
        if self.tracer is not None:
            t_commit = self.tracer.now()
            self.tracer.span(("train", "trainer"), tb.task_id, t0, t_commit,
                             flow_in=0, flow_out=0)
            for tr in trace_ids:
                self.tracer.mark(tr, "committed", t_commit)
        self.mgr.rows_trained += tb.num_rows
        self.rec.record_train_backlog(time.monotonic(),
                                      self.mgr.dispatchable_rows())
        if self.failure:
            self.failure.on_commit()
        if (self.rcfg.checkpoint_dir and self.rcfg.checkpoint_every and
                self.mgr.total_steps_done()
                % self.rcfg.checkpoint_every == 0):
            from repro.checkpoint.store import save_checkpoint
            save_checkpoint(self.rcfg.checkpoint_dir, self.mgr,
                            keep_last_n=self.rcfg.checkpoint_keep_last,
                            chaos=self.chaos)

    def _train_loop(self):
        try:
            # a previous trainer incarnation may have died between pop and
            # commit (injected failure / crash): restore its popped work to
            # the queue head before consuming anything new, else the tenant
            # whose issue budget is already spent deadlocks
            requeued = self.mgr.recover_inflight()
            if requeued:
                self.rec.incr("train_work_recovered", requeued)
            if self.rcfg.async_train:
                self._train_loop_async()
                return
            while not self._stop.is_set():
                t0 = time.monotonic()
                tb = self.mgr.pop_batch(timeout=0.05)
                if tb is None:
                    self.rec.record_trainer_wait(t0, time.monotonic())
                    if self.mgr.all_done():
                        return
                    continue
                self.rec.record_train_backlog(time.monotonic(),
                                              self.mgr.dispatchable_rows())
                self._train_one(tb)
        except BaseException as e:
            self.error = e
            self._stop.set()

    def _train_loop_async(self):
        """Event-driven trainer (ROADMAP §2): pop one tenant's micro-batch
        of complete GRPO groups the moment its `min_train_rows` threshold
        is met — never waits for full-round assembly, so trainer idle time
        between commits is bounded by decode throughput, not by the
        slowest row of a round."""
        while not self._stop.is_set():
            t0 = time.monotonic()
            item = self.mgr.pop_episodes(timeout=0.05)
            if item is None:
                self.rec.record_trainer_wait(t0, time.monotonic())
                if self.mgr.all_done():
                    return
                continue
            self.rec.record_train_backlog(time.monotonic(),
                                          self.mgr.dispatchable_rows())
            tid, groups = item
            rows = [r for g in groups for r in g.rows]
            # eviction order -> submission order (same sort as the
            # synchronous packer: at max_staleness=0 the micro-batch is the
            # full round, token-for-token)
            rows.sort(key=lambda c: c.submit_index)
            oldest = min(g.version for g in groups)
            newest = max(g.version for g in groups)
            spec = self.mgr.spec_for(tid)
            tb = to_trajectory_batch(rows, tid, newest, spec.group_size,
                                     pad_to=self.rcfg.max_len)
            if self.tracer is not None:
                tb.meta["trace_ids"] = self._trace_ids_of(rows)
            if self.mgr.version_of(tid) - oldest > 0:
                self.rec.incr("stale_rows_trained", len(rows))
            # commit is checked against the OLDEST behaviour version in the
            # micro-batch — the conservative end of the staleness window
            self._train_one(tb, trained_version=oldest)

    # -- admission driver (priority-ordered, preemption-capable) -----------
    def _pending_by_priority(self) -> List[str]:
        pending = self.mgr.pending_tasks()
        pending.sort(key=lambda t: -self.mgr.spec_for(t).priority)
        return pending

    def _expected_gen(self, tid: str) -> Optional[float]:
        """Expected completion length for page-granular admission charges
        (paged engine only): the engine's per-tenant length EMA — cold
        tenants charge their full budget, warm tenants what they actually
        generate, so admission packs tighter as history accrues."""
        if not self.rcfg.paged_kv:
            return None
        spec = self.mgr.spec_for(tid)
        return self.cengine.predictor.predict(tid, spec.max_new_tokens)

    def _try_admit_with_preemption(self, tid: str) -> bool:
        """Admit `tid`, preempting strictly-lower-priority admitted tasks
        (lowest first) until its byte estimate fits. A preempted victim's
        resident rows are evicted on the rollout thread and replay later;
        its bytes move to the admission controller's preempted set for
        re-admission once capacity frees."""
        spec = self.mgr.spec_for(tid)
        if self.admission.try_admit(spec, 32, self._expected_gen(tid)):
            return True
        if not (self.rcfg.preemption
                and self.rcfg.rollout_mode == "continuous"):
            return False
        items = dict(self.mgr.task_items())
        victims = [t2 for t2, s2 in items.items()
                   if s2.status == "admitted" and not s2.done
                   and s2.spec.priority < spec.priority]
        victims.sort(key=lambda t2: (items[t2].spec.priority,
                                     -items[t2].admitted_at))
        # feasibility: don't preempt anyone unless evicting ALL eligible
        # victims would actually fit the newcomer (else thrash for nothing)
        from .admission import task_state_bytes
        need = task_state_bytes(self.cfg, spec, 32,
                                self.acfg.kv_dtype_bytes)
        freeable = sum(self.admission.admitted_bytes(t2) for t2 in victims)
        if (self.admission.used_bytes - freeable + need
                > self.acfg.memory_budget_bytes):
            return False
        for victim in victims:
            self.admission.preempt(victim)
            self.mgr.preempt(victim)
            self._preempt_q.append(victim)     # engine evicts on its thread
            if self.admission.try_admit(spec, 32,
                                        self._expected_gen(tid)):
                return True
        return False

    def _admission_tick(self):
        """One driver pass: release finished, re-admit preempted, admit
        pending (highest priority first, preempting if allowed)."""
        # quarantine byte accounting requested by the rollout thread: a
        # tripped tenant's reservation parks (frees budget for the healthy),
        # a half-open probe re-charges it — soft, retried next tick if full
        while self._quarantine_admission_q:
            action, tid = self._quarantine_admission_q.popleft()
            if action == "quarantine":
                self.admission.quarantine(tid)
            elif action == "readmit":
                if not self.admission.try_unquarantine(tid):
                    self._quarantine_admission_q.append(("readmit", tid))
                    break              # budget full now; retry next tick
        for tid, st in self.mgr.task_items():
            if st.done and (tid in self.admission.admitted()
                            or tid in self.admission.preempted()):
                self.admission.release(tid)
                self.mgr.readmit(tid)          # preempted+done -> finished
        for tid in sorted(self.admission.preempted(),
                          key=lambda t: -self.mgr.spec_for(t).priority):
            # remaining-budget-aware re-estimate (ROADMAP open item): rows
            # already partially decoded shrink the reservation re-charged at
            # readmission, so preempted tenants pack back in tighter
            progress = self._preempt_progress.pop(tid, None)
            if self.rcfg.paged_kv:
                # ACTUAL page counts (snapshot pages + page-rounded replay
                # prefixes) replace the model-derived estimate entirely —
                # the paged engine knows exactly what restore will allocate
                actual = self.cengine.queued_state_bytes(
                    tid, self.acfg.kv_dtype_bytes)
                if actual:
                    self.admission.reestimate_preempted_bytes(tid, actual)
            elif progress is not None:
                self.admission.reestimate_preempted(
                    tid, self.mgr.spec_for(tid), progress, 32)
            if self.admission.try_readmit(tid):
                self.mgr.readmit(tid)
                self.rec.incr("readmissions")
        for tid in self._pending_by_priority():
            if self._try_admit_with_preemption(tid):
                self.mgr.admit(tid)

    # -- drivers ----------------------------------------------------------------
    def run(self, timeout_s: float = 600.0):
        """Run to completion under the configured policy."""
        for tid in self._pending_by_priority():
            spec = self.mgr.spec_for(tid)
            wl_prompt = 32
            if (self.rcfg.policy == "marlaas"
                    and not self.admission.try_admit(spec, wl_prompt,
                                                     self._expected_gen(tid))
                    and self.acfg.strict):
                continue                      # stays pending until release
            self.mgr.admit(tid)
        if self.rcfg.policy == "marlaas":
            self._run_async(timeout_s)
        elif self.rcfg.policy == "multilora_sync":
            self._run_sync(timeout_s)
        elif self.rcfg.policy == "single_disagg":
            self._run_sequential(timeout_s)
        else:
            raise ValueError(self.rcfg.policy)
        # staleness-window drop-or-train accounting -> summary counters
        # (n_stale_rows_dropped / n_stale_groups_dropped / ...)
        for name, n in self.mgr.drop_counters().items():
            if n:
                self.rec.incr(name, n)
        if self.error:
            raise self.error

    @property
    def _rows_trained(self) -> int:
        # checkpoint-restart moved the canonical counter onto the manager
        # (it serializes with the manifest); kept as a read-only alias
        return self.mgr.rows_trained

    def row_accounting(self) -> Dict[str, int]:
        """Every issued row's terminal fate. The conservation invariant the
        chaos tests assert exactly (extending PR 7's):

            completed == trained + stale_dropped + discarded_tails
                         + failed + quarantine_dropped [+ orphaned]

        `orphaned` is nonzero only on aborted runs — rows stranded at the
        stop flag, or completed rows a checkpoint restart could not carry
        over (their round regenerates; `Manager.orphaned_rows` counts the
        lost copies). A clean single-incarnation run retires every row
        through one of the other paths."""
        d = self.mgr.drop_counters()
        c = self.rec.counters_snapshot()
        return {
            "completed": sum(st.rollout_rows_total
                             for _, st in self.mgr.task_items()),
            "trained": self.mgr.rows_trained,
            "stale_dropped": d["stale_rows_dropped"],
            "discarded_tails": d["discarded_tail_rows"],
            "failed": d["failed_rows"],
            "quarantine_dropped": d["quarantine_dropped_rows"],
            "orphaned": (c.get("orphaned_completions", 0)
                         + self.mgr.orphaned_rows),
        }

    def adopt_checkpoint(self, path) -> None:
        """Restore manager state from a snapshot into THIS (fresh) runtime:
        tasks re-enter pending with their trained adapters/optimizer state,
        surviving completed-episode queues rebind live env handles (envs
        don't serialize), and per-tenant datagens are rebuilt exactly as
        `submit_task` would."""
        from repro.checkpoint.store import load_checkpoint
        load_checkpoint(path, self.mgr)
        for tid, st in self.mgr.task_items():
            self.envs[tid] = make_env(st.spec.env_name)
            self.datagens[tid] = random.Random(
                hash((self.rcfg.seed, tid)) % (2 ** 31))
        self.mgr.rebind_episode_envs(self.envs)

    def run_with_recovery(self, timeout_s: float = 600.0,
                          max_restarts: int = 2) -> "MARLaaSRuntime":
        """Run to completion, restarting from the newest valid checkpoint
        when a stage escalation (or injected crash) kills the run — the
        supervisor's last resort when restart-in-place can't help. Returns
        the runtime instance that finished (a fresh one after a restart:
        engine state is not trusted after a crash, only checkpoints are)."""
        rt = self
        for attempt in range(max_restarts + 1):
            try:
                rt.run(timeout_s)
                return rt
            except BaseException:
                if attempt >= max_restarts or not rt.rcfg.checkpoint_dir:
                    raise
                from repro.checkpoint.store import latest_checkpoint
                path = latest_checkpoint(rt.rcfg.checkpoint_dir)
                if path is None:
                    raise               # nothing to restart from
                fresh = MARLaaSRuntime(rt.cfg, rt.base_params, rt.rcfg,
                                       rt.acfg, rt._train_cfg_base,
                                       failure=None)
                fresh.adopt_checkpoint(path)
                fresh.rec.incr("checkpoint_restarts")
                rt = fresh
        return rt

    def _run_async(self, timeout_s):
        rt = threading.Thread(target=self._rollout_loop, daemon=True,
                              name="marlaas-rollout")
        tt = threading.Thread(target=self._train_loop, daemon=True,
                              name="marlaas-train")
        rt.start(); tt.start()
        deadline = time.monotonic() + timeout_s
        while time.monotonic() < deadline:
            if self.mgr.all_done() or self._stop.is_set():
                break
            # release finished / re-admit preempted / admit pending (with
            # priority preemption) as capacity moves
            self._admission_tick()
            time.sleep(0.01)
        # grace drain: bounded-staleness pipelining may leave rounds issued
        # before the final commit still decoding — let the rollout loop
        # retire them through the normal completion path (counted as
        # discarded tails) so the inflight-row counters return to zero,
        # instead of abandoning resident rows at the stop flag
        if self.mgr.all_done() and not self._stop.is_set():
            rt.join(timeout=min(30.0, max(1.0,
                                          deadline - time.monotonic())))
        self._stop.set()
        join_or_raise([rt, tt], timeout_s=10.0)

    def _run_sync(self, timeout_s):
        """Barrier rounds: fused rollout for all, then train all, repeat."""
        deadline = time.monotonic() + timeout_s
        while not self.mgr.all_done() and time.monotonic() < deadline:
            if not self._rollout_round():
                break
            while True:
                tb = self.mgr.pop_batch()
                if tb is None:
                    break
                self._train_one(tb)
        if self.error:
            raise self.error

    def _run_sequential(self, timeout_s):
        deadline = time.monotonic() + timeout_s
        for tid, st in self.mgr.task_items():
            while not st.done and time.monotonic() < deadline:
                np_ = self.mgr.next_policy(tid)
                if np_ is None:
                    break
                v, _ = np_
                order = {tid: 0}
                reqs = self._build_requests([tid], order)
                t0 = time.monotonic()
                results, _ = self.engine.generate(reqs, [st.adapters],
                                                  tool_executor=self._tool_pool)
                self.rec.record("rollout", "decode", tid, t0, time.monotonic(),
                                self.rcfg.rollout_pool_devices)
                tb = to_trajectory_batch(results, tid, v, st.spec.group_size,
                                         pad_to=self.rcfg.max_len)
                self.mgr.enqueue(tb)
                self._train_one(self.mgr.pop_batch())
        if self.error:
            raise self.error
