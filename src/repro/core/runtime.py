"""Real (threaded) MARLaaS runtime: the disaggregated stages of Fig 5
executing actual JAX rollout + GRPO training on this host.

Stage layout (`rollout_mode="continuous"`, `disagg_prefill=True`,
`env_stage=True` — all three paper stages disaggregated; `paged_kv=True`
replaces the dense per-slot cache with the shared page pool):

    submit ──> SlotScheduler queue ──> PrefillWorker thread(s)
                (SRPT/priority/         chunked prefill on own caches
                 starvation order)             │ ReadyRow (KV/SSM state +
                      ▲                        ▼  first token + logprob)
      resume job      │        RolloutWorker thread <── ready queue
      (restore snap   │          decode stream: scatter-only splice + one
       OR replay +    │          fused decode step over the slot pool —
       forced RESP)   │          NEVER runs a prefill graph
    EnvStage ─────────┘               │ park on tok.CALL (slot vacated,
      EnvWorker pool: latency +       ▼  instantly refilled; paged_kv:
      stateful ToolSession.call  <────┘  KV pages+SSM state snapshot to
      (cancellable: a timed-out          host, pages freed for the next
       call frees its worker NOW)        occupant)
               Trainer thread — pops FIFO, runs PolicyUpdate, commits v+1

Paged KV block pool (`paged_kv=True`, ISSUE 5): attention K/V lives in a
shared pool of `kv_pool_pages` pages of `kv_page_size` tokens
(rollout/kvcache.py + kernels/paged_decode.py) instead of a dense
[slots, max_len] reservation — a 10-token row holds one page, not
max_len. Park/preempt snapshots the row's live pages + SSM state to host
(`resume_restore`), and resume SPLICES them back instead of replaying
prompt+prefix through prefill — `RolloutStats.replay_tokens_saved` counts
the recomputation killed; a snapshot dropped under `snapshot_budget_bytes`
pressure falls back to the retained token-replay path (identical output).
Admission switches to page-granular byte charges (`AdmissionConfig.paged`)
so mixed-length tenant sets pack more resident rows per HBM byte.

  RolloutWorker thread — streaming (default): feeds per-task requests into
    the engine's cross-task queue the moment each task's `next_policy`
    version becomes consumable, pumps the engine (splice/refill freed
    slots, one decode step), and assembles completed trajectories from the
    engine's completion stream — so decode never drains between tenant
    groups (paper §4.1/§4.5). With `disagg_prefill=False` (baseline) the
    prefill of incoming rows runs fused ON the decode stream — a long
    prompt stalls every resident tenant (booked as decode-stall time).
    The legacy `rollout_mode="round"` fuses one multi-LoRA generate() per
    round and blocks on its slowest row.
  PrefillWorker thread(s) — `prefill_workers` async workers pop
    scheduler-ordered rows and prefill them in `prefill_chunk`-sized
    chunks (rollout/prefill.py); preempted rows replay through the same
    path token-for-token.
  EnvWorker thread(s) — `env_workers` env-interaction workers
    (rollout/env_stage.py, `env_stage=True`): a row that samples a tool
    call is PARKED (slot freed and refilled) instead of freezing in its
    slot for the env latency; the tool response re-enters the scheduler
    queue as a resume job and splices back through the prefill path —
    token-for-token identical to the freeze-in-slot baseline. With
    `env_stage=False` (baseline) tool calls run on the engine's shared
    thread-pool while the row's slot sits frozen (booked as
    `tool_wait_slot_steps`), overlapping only the other rows' decode.
  Trainer thread — pops FIFO, runs the task's PolicyUpdate, commits v+1.

The same MultiTaskManager/MetricsRecorder as the simulator; scheduling
regimes: marlaas (async), multilora_sync (barrier), single_disagg
(sequential tasks). Per-stage timelines (prefill/decode/splice busy time,
stage queue depths) land in the recorder for the Fig-5 utilization story.

Fault tolerance: `checkpoint_every` writes atomic manager snapshots
(repro.checkpoint); `FailureInjector` can kill a step to exercise
restart-from-checkpoint in tests. Straggler mitigation: rollout rows hitting
the step budget are returned partially (graded reward on what exists) rather
than stalling the batch.
"""
from __future__ import annotations

import faulthandler
import random
import sys
import threading
import time
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import numpy as np

from repro.configs import ModelConfig
from repro.envs.tasks import make_env
from repro.lora.adapters import init_lora
from repro.lora.multilora import AdapterResidency
from repro.rollout.engine import (ContinuousRolloutEngine, RolloutEngine,
                                  RolloutRequest, to_trajectory_batch)
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import TrainConfig, init_opt_state, make_train_step
from .admission import AdmissionConfig, AdmissionController
from .manager import MultiTaskManager, TaskSpec
from .metrics import MetricsRecorder


def join_or_raise(threads: List[threading.Thread], timeout_s: float = 10.0):
    """Join `threads` within one shared deadline; raise loudly on leaks.

    A thread still alive after the stop flag + join timeout is a wedged
    stage (deadlocked lock, stuck tool call, hung device op). Silently
    returning would leak it into the caller's process — later runs then
    fight it for slots/devices and failures surface far from the cause.
    Instead: dump every thread's stack (faulthandler) and raise."""
    deadline = time.monotonic() + timeout_s
    for t in threads:
        t.join(timeout=max(0.0, deadline - time.monotonic()))
    leaked = [t for t in threads if t.is_alive()]
    if leaked:
        names = ", ".join(t.name for t in leaked)
        faulthandler.dump_traceback(file=sys.stderr)
        raise RuntimeError(
            f"runtime thread(s) still alive {timeout_s:.0f}s after stop: "
            f"{names} — all thread stacks dumped to stderr")


@dataclass
class RuntimeConfig:
    policy: str = "marlaas"           # marlaas | multilora_sync | single_disagg
    rollout_mode: str = "continuous"  # continuous (slot engine) | round (fused)
    max_slots: int = 8                # decode slots in the continuous engine
    max_adapter_slots: int = 8        # stacked-LoRA capacity (tenants resident)
    scheduler: str = "srpt"           # slot-queue pop order: srpt | fifo
    starvation_k: int = 8             # refills before a queued row jumps tiers
    preemption: bool = True           # admission may preempt lower-priority
                                      # tenants' resident rows
    disagg_prefill: bool = False      # async prefill stage (Fig 5): refill
                                      # prefills run on worker threads, the
                                      # decode stream only splices; False =
                                      # fused-refill baseline
    prefill_workers: int = 1          # async prefill worker threads
    prefill_chunk: int = 0            # chunked prefill size (0 = whole
                                      # prompt per call); rounded up for
                                      # recurrent-state exactness
    env_stage: bool = False           # disaggregated env-interaction stage:
                                      # rows park on tool calls (slot freed)
                                      # and resume via the prefill path;
                                      # False = freeze-in-slot baseline
    env_workers: int = 2              # env-interaction worker threads
    env_inflight_per_tenant: int = 0  # max concurrent tool calls per tenant
                                      # in the env stage (0 = uncapped): a
                                      # slow-tool tenant can't monopolize
                                      # the worker pool
    max_turns: int = 0                # per-episode tool-turn budget applied
                                      # to every request (0 = env default)
    paged_kv: bool = False            # paged KV-cache block pool (ISSUE 5):
                                      # attention K/V in shared fixed-size
                                      # pages + per-slot block tables instead
                                      # of a dense [slots, max_len] cache;
                                      # False = dense baseline
    kv_page_size: int = 16            # tokens per KV page (max_len must be
                                      # a multiple of it)
    kv_pool_pages: int = 0            # pool size in pages (0 = auto: the
                                      # dense-equivalent max_slots ×
                                      # max_len/page; size DOWN to realize
                                      # the HBM saving — rows the pool can't
                                      # serve finish via cache-capacity
                                      # eviction, never a crash)
    resume_restore: bool = True       # paged only: park/preempt snapshots
                                      # KV pages + SSM state to host and
                                      # resume SPLICES them back (no prefill
                                      # replay); False = always token-replay
    snapshot_budget_bytes: int = 0    # host bytes for parked snapshots
                                      # (0 = unlimited); overflow drops the
                                      # snapshot -> that row replays
    max_len: int = 96
    use_kernel: bool = False
    seed: int = 0
    rollout_pool_devices: int = 1     # metric bookkeeping (host has 1 CPU)
    train_pool_devices: int = 1
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 0         # commits between snapshots (0 = off)
    env_threads: int = 4


class FailureInjector:
    """Crashes the trainer after N commits (tests restart-from-checkpoint)."""

    def __init__(self, fail_after_commits: Optional[int] = None):
        self.fail_after = fail_after_commits
        self.commits = 0

    def on_commit(self):
        self.commits += 1
        if self.fail_after is not None and self.commits >= self.fail_after:
            raise RuntimeError("injected node failure")


class MARLaaSRuntime:
    def __init__(self, cfg: ModelConfig, base_params, rcfg: RuntimeConfig,
                 acfg: Optional[AdmissionConfig] = None,
                 train_cfg: Optional[TrainConfig] = None,
                 failure: Optional[FailureInjector] = None):
        self.cfg = cfg
        self.base_params = base_params
        self.rcfg = rcfg
        self.acfg = acfg or AdmissionConfig(memory_budget_bytes=1e9,
                                            strict=False)
        if rcfg.paged_kv:
            # page-granular admission accounting rides the paged engine
            # (copy, never mutate a caller-shared config object)
            import dataclasses as _dc
            self.acfg = _dc.replace(self.acfg, paged=True,
                                    page_size=rcfg.kv_page_size)
        self.mgr = MultiTaskManager()
        self.admission = AdmissionController(cfg, self.acfg)
        self.rec = MetricsRecorder({"rollout": rcfg.rollout_pool_devices,
                                    "train": rcfg.train_pool_devices})
        self.engine = RolloutEngine(cfg, base_params, max_len=rcfg.max_len,
                                    use_kernel=rcfg.use_kernel, seed=rcfg.seed)
        self.envs: Dict[str, object] = {}
        self.datagens: Dict[str, random.Random] = {}
        self._train_cfg_base = train_cfg or TrainConfig()
        self._train_steps: Dict[int, object] = {}   # group_size -> jitted fn
        self._tool_pool = ThreadPoolExecutor(max_workers=rcfg.env_threads)
        self.cengine = ContinuousRolloutEngine(
            cfg, base_params, max_slots=rcfg.max_slots,
            max_adapters=rcfg.max_adapter_slots, max_len=rcfg.max_len,
            use_kernel=rcfg.use_kernel, seed=rcfg.seed,
            tool_executor=self._tool_pool, scheduler=rcfg.scheduler,
            starvation_k=rcfg.starvation_k,
            disagg_prefill=rcfg.disagg_prefill,
            prefill_chunk=rcfg.prefill_chunk,
            prefill_workers=rcfg.prefill_workers,
            env_stage=rcfg.env_stage,
            env_workers=rcfg.env_workers,
            env_inflight_per_tenant=rcfg.env_inflight_per_tenant,
            paged_kv=rcfg.paged_kv,
            kv_page_size=rcfg.kv_page_size,
            kv_pool_pages=rcfg.kv_pool_pages,
            resume_restore=rcfg.resume_restore,
            snapshot_budget_bytes=rcfg.snapshot_budget_bytes,
            on_stage=self._on_stage)
        # LRU tenant -> stacked-LoRA slot map (rollout thread only). The
        # device write happens in _feed_continuous once the consumable
        # version is known (and only when it changed), so the residency's
        # own install hook is a no-op slot assignment.
        self.residency = AdapterResidency(
            rcfg.max_adapter_slots, lambda slot, tree: None,
            on_evict=self._on_adapter_evict)
        self._resident_version: Dict[str, int] = {}   # tenant -> installed v
        # admission-driven preemptions requested by the driver thread,
        # executed on the rollout thread (the engine is single-threaded)
        self._preempt_q: deque = deque()
        # victim decode progress observed at preemption (rollout thread
        # writes, admission tick reads): feeds the remaining-budget-aware
        # readmission re-estimate
        self._preempt_progress: Dict[str, float] = {}
        self._stop = threading.Event()
        self.failure = failure
        self.error: Optional[BaseException] = None

    # -- task submission ---------------------------------------------------
    def submit_task(self, spec: TaskSpec, adapters=None, opt_state=None):
        if adapters is None:
            key = jax.random.PRNGKey(hash(spec.task_id) % (2 ** 31))
            adapters = init_lora(key, self.cfg)
        tc = self._tc(spec)
        if opt_state is None:
            opt_state = init_opt_state(self.cfg, tc, self.base_params, adapters)
        self.mgr.submit(spec, adapters, opt_state)
        self.envs[spec.task_id] = make_env(spec.env_name)
        self.datagens[spec.task_id] = random.Random(
            hash((self.rcfg.seed, spec.task_id)) % (2 ** 31))

    def _tc(self, spec: TaskSpec) -> TrainConfig:
        return TrainConfig(group_size=spec.group_size,
                           use_logprob_kernel=self.rcfg.use_kernel,
                           adamw=AdamWConfig(lr=spec.lr))

    def _train_step_for(self, spec: TaskSpec):
        if spec.group_size not in self._train_steps:
            self._train_steps[spec.group_size] = jax.jit(
                make_train_step(self.cfg, self._tc(spec)))
        return self._train_steps[spec.group_size]

    # -- request building ----------------------------------------------------
    def _build_requests(self, tids: List[str], adapter_order: Dict[str, int]):
        reqs = []
        for tid in tids:
            st = self.mgr.tasks[tid]
            env = self.envs[tid]
            rng = self.datagens[tid]
            for _ in range(st.spec.num_groups):
                prompt, truth = env.sample_prompt(rng)
                for _ in range(st.spec.group_size):
                    reqs.append(RolloutRequest(
                        task_id=tid, adapter_index=adapter_order[tid],
                        prompt=prompt, truth=truth, env=env,
                        max_new_tokens=st.spec.max_new_tokens,
                        temperature=st.spec.temperature,
                        priority=st.spec.priority,
                        max_turns=self.rcfg.max_turns or None))
        return reqs

    # -- rollout worker -------------------------------------------------------
    def _rollout_round(self) -> bool:
        """One fused cross-task rollout round. Returns True if work done."""
        ready = self.mgr.rollout_ready_tasks()
        # admission control gates which tenants join the fused batch
        batch_tids, versions = [], {}
        for tid in ready:
            st = self.mgr.tasks[tid]
            if st.status == "pending":
                continue
            np_ = self.mgr.next_policy(tid)
            if np_ is None:
                continue
            versions[tid] = np_[0]
            batch_tids.append(tid)
        if not batch_tids:
            return False
        adapters = [self.mgr.tasks[t].adapters for t in batch_tids]
        order = {t: i for i, t in enumerate(batch_tids)}
        reqs = self._build_requests(batch_tids, order)
        t0 = time.monotonic()
        results, stats = self.engine.generate(reqs, adapters,
                                              tool_executor=self._tool_pool)
        t1 = time.monotonic()
        self.rec.record("rollout", "decode", "+".join(batch_tids), t0, t1,
                        self.rcfg.rollout_pool_devices)
        for tid in batch_tids:
            tb = to_trajectory_batch(results, tid, versions[tid],
                                     self.mgr.tasks[tid].spec.group_size,
                                     pad_to=self.rcfg.max_len)
            self.mgr.enqueue(tb)
        return True

    def _rollout_loop(self):
        try:
            if self.rcfg.rollout_mode == "continuous":
                self._rollout_loop_continuous()
                return
            while not self._stop.is_set():
                did = self._rollout_round()
                if not did:
                    if self.mgr.all_done():
                        return
                    time.sleep(0.002)
        except BaseException as e:       # surface to the driver
            self.error = e
            self._stop.set()

    # -- streaming rollout worker (continuous slot engine) -----------------
    def _on_stage(self, phase: str, task_id: str, t0: float, t1: float):
        """Engine stage hook: prefill intervals arrive from the async
        prefill workers, splice/refill intervals from the rollout thread —
        the recorder is thread-safe. This is what makes prefill-stage vs
        decode-stage busy time separately measurable (Fig 5)."""
        self.rec.record("rollout", phase, task_id, t0, t1,
                        self.rcfg.rollout_pool_devices)

    def _on_adapter_evict(self, tid: str, slot: int):
        self.mgr.adapter_unbound(tid)
        self._resident_version.pop(tid, None)
        self.rec.incr("adapter_evictions")

    def _adapter_in_use(self, tid: str) -> bool:
        """A tenant's adapter may not be evicted while it has rows resident
        or queued in the engine (queued requests carry its slot index)."""
        return (tid in self.cengine.active_tenants()
                or self.mgr.tasks[tid].rollout_inflight_rows > 0)

    def _feed_continuous(self) -> bool:
        """Submit every consumable (task, version) round into the engine
        queue, acquiring the tenant's stacked-LoRA slot through the LRU
        residency map (idle tenants' adapters are evicted on demand, so
        tenant counts ≫ max_adapter_slots stream through). Called from the
        rollout thread only."""
        fed = False
        for tid in self.mgr.rollout_ready_tasks():
            st = self.mgr.tasks[tid]
            slot = self.residency.acquire(tid, st.adapters,
                                          in_use=self._adapter_in_use)
            if slot is None:
                continue     # every adapter slot pinned; task stays ready
            if st.adapter_slot != slot:          # fresh slot, not a hit
                self.mgr.adapter_bound(tid, slot)
                self.rec.incr("adapter_installs")
            np_ = self.mgr.next_policy(tid)
            if np_ is None:
                continue
            version, adapters = np_
            # one device write per (tenant, version): skip when the resident
            # copy is already this committed tree
            if self._resident_version.get(tid) != version:
                self.cengine.set_adapters(slot, adapters)
                self._resident_version[tid] = version
            reqs = self._build_requests([tid], {tid: slot})
            self.mgr.rollout_started(tid, len(reqs))
            for r in reqs:
                self.cengine.submit(r, meta={"task_id": tid,
                                             "version": version})
            fed = True
        return fed

    def _execute_preemptions(self) -> bool:
        """Apply admission-driven preemptions queued by the driver thread
        (the engine may only be touched from the rollout thread). Records
        each victim's decode progress so the driver's admission tick can
        tighten its parked byte reservation (remaining-budget re-estimate —
        partially decoded rows need less KV headroom at readmission)."""
        did = False
        while self._preempt_q:
            victim = self._preempt_q.popleft()
            n = self.cengine.preempt_tenant(victim)
            if n:
                self.rec.incr("preemptions")
                self.rec.incr("preempted_rows", n)
                did = True
            rows, sampled_mean = self.cengine.queued_progress(victim)
            if rows:
                self._preempt_progress[victim] = sampled_mean
        return did

    def _flush_decode_segment(self, now: float):
        if self._seg_tasks and self._seg_t0 is not None and now > self._seg_t0:
            self.rec.record("rollout", "decode",
                            "+".join(sorted(self._seg_tasks)),
                            self._seg_t0, now,
                            self.rcfg.rollout_pool_devices)
        self._seg_t0 = now
        self._seg_tasks = frozenset()

    def _rollout_loop_continuous(self):
        eng = self.cengine
        rounds: Dict[tuple, list] = {}      # (tid, v) -> completions so far
        self._seg_tasks: frozenset = frozenset()
        self._seg_t0: Optional[float] = None
        last_slot_sample = None
        last_queue_sample = None
        last_env_sample = None
        last_page_sample = None
        while not self._stop.is_set():
            self._execute_preemptions()
            fed = self._feed_continuous()
            progressed = eng.step()
            now = time.monotonic()
            occ, cap = eng.occupancy()
            # step-function timeline: sample only on occupancy change (idle
            # spins would otherwise append hundreds of samples per second)
            if (occ, cap) != last_slot_sample:
                self.rec.record_slot_sample(now, occ, cap)
                last_slot_sample = (occ, cap)
            qd = eng.queue_depths()
            if qd != last_queue_sample:
                self.rec.record_queue_sample(now, *qd)
                last_queue_sample = qd
            if self.rcfg.env_stage:
                ed = eng.env_depths()
                if ed != last_env_sample:
                    self.rec.record_env_sample(now, *ed)
                    last_env_sample = ed
            if self.rcfg.paged_kv:
                ps = eng.page_stats()
                key = (ps["kv_pages_used"], round(ps["kv_page_frag"], 3))
                if key != last_page_sample:
                    self.rec.record_page_sample(
                        now, int(ps["kv_pages_used"]),
                        int(ps["kv_pages_total"]), ps["kv_page_frag"])
                    last_page_sample = key
            # decode timeline: one interval per contiguous occupant-set run,
            # task_id joined with "+" (fused multi-tenant decode)
            tasks_now = eng.occupant_tasks()
            if tasks_now != self._seg_tasks:
                self._flush_decode_segment(now)
                self._seg_tasks = tasks_now
            for comp in eng.drain_completions():
                tid = comp.meta["task_id"]
                version = comp.meta["version"]
                self.mgr.rollout_row_done(tid)
                batch = rounds.setdefault((tid, version), [])
                batch.append(comp)
                spec = self.mgr.tasks[tid].spec
                if len(batch) == spec.rows_per_batch:
                    del rounds[(tid, version)]
                    # completions arrive in eviction order; GRPO groups are
                    # contiguous rows sharing a prompt, so restore
                    # submission order before packing
                    batch.sort(key=lambda c: c.submit_index)
                    tb = to_trajectory_batch(batch, tid, version,
                                             spec.group_size,
                                             pad_to=self.rcfg.max_len)
                    self.mgr.enqueue(tb)
                    progressed = True
            if not progressed and not fed:
                if self.mgr.all_done() and eng.idle():
                    break
                time.sleep(0.002)
        now = time.monotonic()
        occ, cap = eng.occupancy()
        self.rec.record_slot_sample(now, occ, cap)   # close the timeline
        self.rec.record_queue_sample(now, *eng.queue_depths())
        if self.rcfg.paged_kv:
            ps = eng.page_stats()
            self.rec.record_page_sample(now, int(ps["kv_pages_used"]),
                                        int(ps["kv_pages_total"]),
                                        ps["kv_page_frag"])
            # restore-vs-replay counts land in summarize() as n_* counters
            for name, n in (("restores", eng.stats.restores),
                            ("replays", eng.stats.replays),
                            ("replay_tokens_saved",
                             eng.stats.replay_tokens_saved),
                            ("snapshots", eng.stats.snapshots),
                            ("snapshot_drops", eng.stats.snapshot_drops),
                            ("pool_exhausted", eng.stats.pool_exhausted)):
                if n:
                    self.rec.incr(name, n)
        if self.rcfg.env_stage:
            self.rec.record_env_sample(now, *eng.env_depths())
            if eng._env is not None:
                eng._env.halt()     # env workers die with the rollout loop
        self._flush_decode_segment(now)
        if self.rcfg.disagg_prefill:
            eng._halt_stage()       # workers die with the rollout loop

    # -- trainer ---------------------------------------------------------------
    def _train_one(self, tb) -> None:
        import jax.numpy as jnp
        st = self.mgr.tasks[tb.task_id]
        step_fn = self._train_step_for(st.spec)
        S = tb.tokens.shape[1]
        batch = {
            "tokens": jnp.asarray(tb.tokens),
            "prompt_lens": jnp.asarray(tb.prompt_lens),
            "total_lens": jnp.asarray(tb.total_lens),
            "rewards": jnp.asarray(tb.rewards),
        }
        if "loss_mask" in tb.meta:
            batch["loss_mask"] = jnp.asarray(tb.meta["loss_mask"])
        t0 = time.monotonic()
        new_adapters, new_opt, metrics = step_fn(self.base_params, st.adapters,
                                                 st.opt_state, batch)
        jax.block_until_ready(jax.tree.leaves(new_adapters)[0])
        t1 = time.monotonic()
        self.rec.record("train", "train", tb.task_id, t0, t1,
                        self.rcfg.train_pool_devices)
        self.mgr.commit(tb.task_id, new_adapters, new_opt, tb.version,
                        reward_mean=float(np.mean(tb.rewards)))
        if self.failure:
            self.failure.on_commit()
        if (self.rcfg.checkpoint_dir and self.rcfg.checkpoint_every and
                sum(s.steps_done for s in self.mgr.tasks.values())
                % self.rcfg.checkpoint_every == 0):
            from repro.checkpoint.store import save_checkpoint
            save_checkpoint(self.rcfg.checkpoint_dir, self.mgr)

    def _train_loop(self):
        try:
            while not self._stop.is_set():
                tb = self.mgr.pop_batch(timeout=0.05)
                if tb is None:
                    if self.mgr.all_done():
                        return
                    continue
                self._train_one(tb)
        except BaseException as e:
            self.error = e
            self._stop.set()

    # -- admission driver (priority-ordered, preemption-capable) -----------
    def _pending_by_priority(self) -> List[str]:
        pending = self.mgr.pending_tasks()
        pending.sort(key=lambda t: -self.mgr.tasks[t].spec.priority)
        return pending

    def _expected_gen(self, tid: str) -> Optional[float]:
        """Expected completion length for page-granular admission charges
        (paged engine only): the engine's per-tenant length EMA — cold
        tenants charge their full budget, warm tenants what they actually
        generate, so admission packs tighter as history accrues."""
        if not self.rcfg.paged_kv:
            return None
        spec = self.mgr.tasks[tid].spec
        return self.cengine.predictor.predict(tid, spec.max_new_tokens)

    def _try_admit_with_preemption(self, tid: str) -> bool:
        """Admit `tid`, preempting strictly-lower-priority admitted tasks
        (lowest first) until its byte estimate fits. A preempted victim's
        resident rows are evicted on the rollout thread and replay later;
        its bytes move to the admission controller's preempted set for
        re-admission once capacity frees."""
        st = self.mgr.tasks[tid]
        if self.admission.try_admit(st.spec, 32, self._expected_gen(tid)):
            return True
        if not (self.rcfg.preemption
                and self.rcfg.rollout_mode == "continuous"):
            return False
        victims = [t2 for t2, s2 in self.mgr.task_items()
                   if s2.status == "admitted" and not s2.done
                   and s2.spec.priority < st.spec.priority]
        victims.sort(key=lambda t2: (self.mgr.tasks[t2].spec.priority,
                                     -self.mgr.tasks[t2].admitted_at))
        # feasibility: don't preempt anyone unless evicting ALL eligible
        # victims would actually fit the newcomer (else thrash for nothing)
        from .admission import task_state_bytes
        need = task_state_bytes(self.cfg, st.spec, 32,
                                self.acfg.kv_dtype_bytes)
        freeable = sum(self.admission.admitted_bytes(t2) for t2 in victims)
        if (self.admission.used_bytes - freeable + need
                > self.acfg.memory_budget_bytes):
            return False
        for victim in victims:
            self.admission.preempt(victim)
            self.mgr.preempt(victim)
            self._preempt_q.append(victim)     # engine evicts on its thread
            if self.admission.try_admit(st.spec, 32,
                                        self._expected_gen(tid)):
                return True
        return False

    def _admission_tick(self):
        """One driver pass: release finished, re-admit preempted, admit
        pending (highest priority first, preempting if allowed)."""
        for tid, st in self.mgr.task_items():
            if st.done and (tid in self.admission.admitted()
                            or tid in self.admission.preempted()):
                self.admission.release(tid)
                self.mgr.readmit(tid)          # preempted+done -> finished
        for tid in sorted(self.admission.preempted(),
                          key=lambda t: -self.mgr.tasks[t].spec.priority):
            # remaining-budget-aware re-estimate (ROADMAP open item): rows
            # already partially decoded shrink the reservation re-charged at
            # readmission, so preempted tenants pack back in tighter
            progress = self._preempt_progress.pop(tid, None)
            if self.rcfg.paged_kv:
                # ACTUAL page counts (snapshot pages + page-rounded replay
                # prefixes) replace the model-derived estimate entirely —
                # the paged engine knows exactly what restore will allocate
                actual = self.cengine.queued_state_bytes(
                    tid, self.acfg.kv_dtype_bytes)
                if actual:
                    self.admission.reestimate_preempted_bytes(tid, actual)
            elif progress is not None:
                self.admission.reestimate_preempted(
                    tid, self.mgr.tasks[tid].spec, progress, 32)
            if self.admission.try_readmit(tid):
                self.mgr.readmit(tid)
                self.rec.incr("readmissions")
        for tid in self._pending_by_priority():
            if self._try_admit_with_preemption(tid):
                self.mgr.admit(tid)

    # -- drivers ----------------------------------------------------------------
    def run(self, timeout_s: float = 600.0):
        """Run to completion under the configured policy."""
        for tid in self._pending_by_priority():
            st = self.mgr.tasks[tid]
            wl_prompt = 32
            if (self.rcfg.policy == "marlaas"
                    and not self.admission.try_admit(st.spec, wl_prompt,
                                                     self._expected_gen(tid))
                    and self.acfg.strict):
                continue                      # stays pending until release
            self.mgr.admit(tid)
        if self.rcfg.policy == "marlaas":
            self._run_async(timeout_s)
        elif self.rcfg.policy == "multilora_sync":
            self._run_sync(timeout_s)
        elif self.rcfg.policy == "single_disagg":
            self._run_sequential(timeout_s)
        else:
            raise ValueError(self.rcfg.policy)
        if self.error:
            raise self.error

    def _run_async(self, timeout_s):
        rt = threading.Thread(target=self._rollout_loop, daemon=True,
                              name="marlaas-rollout")
        tt = threading.Thread(target=self._train_loop, daemon=True,
                              name="marlaas-train")
        rt.start(); tt.start()
        deadline = time.monotonic() + timeout_s
        while time.monotonic() < deadline:
            if self.mgr.all_done() or self._stop.is_set():
                break
            # release finished / re-admit preempted / admit pending (with
            # priority preemption) as capacity moves
            self._admission_tick()
            time.sleep(0.01)
        self._stop.set()
        join_or_raise([rt, tt], timeout_s=10.0)

    def _run_sync(self, timeout_s):
        """Barrier rounds: fused rollout for all, then train all, repeat."""
        deadline = time.monotonic() + timeout_s
        while not self.mgr.all_done() and time.monotonic() < deadline:
            if not self._rollout_round():
                break
            while True:
                tb = self.mgr.pop_batch()
                if tb is None:
                    break
                self._train_one(tb)
        if self.error:
            raise self.error

    def _run_sequential(self, timeout_s):
        deadline = time.monotonic() + timeout_s
        for tid in list(self.mgr.tasks):
            st = self.mgr.tasks[tid]
            while not st.done and time.monotonic() < deadline:
                np_ = self.mgr.next_policy(tid)
                if np_ is None:
                    break
                v, _ = np_
                order = {tid: 0}
                reqs = self._build_requests([tid], order)
                t0 = time.monotonic()
                results, _ = self.engine.generate(reqs, [st.adapters],
                                                  tool_executor=self._tool_pool)
                self.rec.record("rollout", "decode", tid, t0, time.monotonic(),
                                self.rcfg.rollout_pool_devices)
                tb = to_trajectory_batch(results, tid, v, st.spec.group_size,
                                         pad_to=self.rcfg.max_len)
                self.mgr.enqueue(tb)
                self._train_one(self.mgr.pop_batch())
        if self.error:
            raise self.error
