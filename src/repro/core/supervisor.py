"""Stage supervision + tenant circuit breaker (ISSUE 10 tentpole).

``StageSupervisor`` watches the worker pools of the threaded stages
(prefill workers, env workers) from the engine's step loop: a stage whose
pool has dead or wedged members first has its stranded in-flight work
recovered (a dead prefill worker's rows re-enter the scheduler queue, a
dead env worker's jobs are re-queued), then is restarted back to full
complement under bounded exponential backoff. A stage that keeps dying
past its restart budget ESCALATES — by default that raises on the caller
(the rollout thread), which surfaces as ``runtime.error`` and feeds the
existing checkpoint-restart path (``recover_inflight``/``load_checkpoint``,
see ``MARLaaSRuntime.run_with_recovery``).

``TenantBreaker`` is the per-tenant circuit breaker behind quarantine:
repeated episode failures (permanent tool errors) trip a tenant OPEN —
the runtime pauses its admission, drains its queued work with counted
drops, and the other tenants keep full throughput. After a cooldown the
breaker HALF-OPENS and the runtime re-admits one probe round; a clean
probe closes the breaker, another failure re-trips it, and a tenant that
trips more than ``max_trips`` times is ABANDONED (marked terminal so the
run can finish without it). State changes are queued as transitions and
applied by exactly one thread (the rollout loop) — the record_* calls
only mutate breaker-internal state, never runtime structures.

``join_or_raise`` lives here (moved from core/runtime.py, which
re-exports it) so the rollout stages can use it for their own shutdown
paths without importing the runtime module — core.runtime already
imports rollout.engine, and rollout.env_stage importing it back would be
a cycle.
"""
from __future__ import annotations

import faulthandler
import sys
import threading
import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple


def join_or_raise(threads: List[threading.Thread], timeout_s: float = 10.0):
    """Join `threads` within one shared deadline; raise loudly on leaks.

    A thread still alive after the stop flag + join timeout is a wedged
    stage (deadlocked lock, stuck tool call, hung device op). Silently
    returning would leak it into the caller's process — later runs then
    fight it for slots/devices and failures surface far from the cause.
    Instead: dump every thread's stack (faulthandler) and raise."""
    deadline = time.monotonic() + timeout_s
    for t in threads:
        t.join(timeout=max(0.0, deadline - time.monotonic()))
    leaked = [t for t in threads if t.is_alive()]
    if leaked:
        names = ", ".join(t.name for t in leaked)
        faulthandler.dump_traceback(file=sys.stderr)
        raise RuntimeError(
            f"runtime thread(s) still alive {timeout_s:.0f}s after stop: "
            f"{names} — all thread stacks dumped to stderr")


@dataclass
class StagePolicy:
    """Restart budget of one supervised stage."""
    max_restarts: int = 8          # consecutive restarts before escalation
    backoff_base_s: float = 0.02   # first-restart delay
    backoff_max_s: float = 2.0     # backoff ceiling; also the healthy
                                   # streak-reset horizon


class _Stage:
    __slots__ = ("name", "healthy", "recover", "restart", "policy",
                 "escalate", "streak", "total_restarts", "last_restart_at",
                 "next_restart_at")

    def __init__(self, name, healthy, recover, restart, policy, escalate):
        self.name = name
        self.healthy = healthy
        self.recover = recover
        self.restart = restart
        self.policy = policy
        self.escalate = escalate
        self.streak = 0
        self.total_restarts = 0
        self.last_restart_at = 0.0
        self.next_restart_at = 0.0


class StageSupervisor:
    """Liveness/heartbeat supervision of worker-pool stages.

    Thread contract: ``register`` at construction time, then ``tick`` from
    ONE thread only (the engine step loop). The registered callables run
    on that thread; ``healthy``/``recover``/``restart`` must therefore be
    safe to call from it (the stage modules already lock internally)."""

    def __init__(self, clock: Callable[[], float] = time.monotonic,
                 tracer=None):
        self.clock = clock
        self.tracer = tracer
        self._stages: Dict[str, _Stage] = {}
        self.counters: Dict[str, int] = {}   # tick-thread only

    def register(self, name: str, *, healthy: Callable[[], bool],
                 restart: Callable[[], None],
                 recover: Optional[Callable[[], int]] = None,
                 policy: Optional[StagePolicy] = None,
                 escalate: Optional[Callable[[str], None]] = None):
        self._stages[name] = _Stage(name, healthy, recover, restart,
                                    policy or StagePolicy(), escalate)

    def _count(self, name: str, n: int = 1):
        self.counters[name] = self.counters.get(name, 0) + n

    def tick(self, now: Optional[float] = None) -> bool:
        """One supervision pass; True if any stage restarted. Recovery
        runs BEFORE restart so re-queued work is visible the moment fresh
        workers start popping."""
        now = self.clock() if now is None else now
        acted = False
        for st in self._stages.values():
            if st.healthy():
                # a stage that stayed healthy past the backoff ceiling has
                # genuinely recovered: forgive the streak so a much-later
                # isolated death doesn't escalate
                if st.streak and now - st.last_restart_at \
                        > st.policy.backoff_max_s:
                    st.streak = 0
                continue
            if now < st.next_restart_at:
                continue
            if st.streak >= st.policy.max_restarts:
                msg = (f"stage {st.name!r} died {st.streak} times within "
                       f"its backoff window — restart budget exhausted, "
                       f"escalating to checkpoint-restart")
                self._count(f"{st.name}_escalations")
                if st.escalate is not None:
                    st.escalate(msg)
                    continue
                raise RuntimeError(msg)
            recovered = st.recover() if st.recover is not None else 0
            st.restart()
            st.streak += 1
            st.total_restarts += 1
            st.last_restart_at = now
            backoff = min(st.policy.backoff_max_s,
                          st.policy.backoff_base_s * (2 ** (st.streak - 1)))
            st.next_restart_at = now + backoff
            self._count(f"{st.name}_restarts")
            if recovered:
                self._count(f"{st.name}_jobs_recovered", recovered)
            if self.tracer is not None:
                self.tracer.instant(("supervisor", st.name), "restart", now)
            acted = True
        return acted


# -- per-tenant circuit breaker ------------------------------------------

CLOSED, OPEN, HALF_OPEN, ABANDONED = ("closed", "open", "half_open",
                                      "abandoned")


class _Tenant:
    __slots__ = ("state", "fails", "trips", "opened_at")

    def __init__(self):
        self.state = CLOSED
        self.fails = 0          # consecutive failures while closed/half-open
        self.trips = 0
        self.opened_at = 0.0


class TenantBreaker:
    """Closed -> open after ``fail_threshold`` consecutive episode
    failures; open -> half_open after ``cooldown_s`` (probe); half_open ->
    closed on a clean probe, -> open again on failure, -> abandoned once
    trips exceed ``max_trips``. Thread-safe: record_* may run on the
    rollout thread while ``poll`` advances cooldowns; transitions queue
    internally and ``poll`` hands them to the single applying thread."""

    def __init__(self, *, fail_threshold: int = 5, cooldown_s: float = 2.0,
                 max_trips: int = 3,
                 clock: Callable[[], float] = time.monotonic):
        self.fail_threshold = fail_threshold
        self.cooldown_s = cooldown_s
        self.max_trips = max_trips
        self.clock = clock
        self._lock = threading.Lock()   # guards: _tenants/_transitions
        self._tenants: Dict[str, _Tenant] = {}
        self._transitions: List[Tuple[str, str]] = []

    def _get(self, tid: str) -> _Tenant:   # held: _lock
        t = self._tenants.get(tid)
        if t is None:
            t = self._tenants[tid] = _Tenant()
        return t

    def _trip(self, tid: str, t: _Tenant):   # held: _lock
        t.trips += 1
        t.fails = 0
        if t.trips > self.max_trips:
            t.state = ABANDONED
            self._transitions.append((tid, ABANDONED))
        else:
            t.state = OPEN
            t.opened_at = self.clock()
            self._transitions.append((tid, OPEN))

    def record_failure(self, tid: str):
        """One failed episode (permanent tool error / failed round)."""
        with self._lock:
            t = self._get(tid)
            if t.state == CLOSED:
                t.fails += 1
                if t.fails >= self.fail_threshold:
                    self._trip(tid, t)
            elif t.state == HALF_OPEN:
                # the probe failed: re-trip (or abandon past the budget)
                self._trip(tid, t)
            # open/abandoned: in-flight stragglers of the tripped tenant
            # still land here — they must not double-trip

    def record_success(self, tid: str):
        with self._lock:
            t = self._tenants.get(tid)
            if t is None:
                return
            if t.state == HALF_OPEN:
                t.state = CLOSED
                t.fails = 0
                t.trips = 0          # a clean probe is a full recovery
                self._transitions.append((tid, CLOSED))
            elif t.state == CLOSED:
                t.fails = 0

    def poll(self, now: Optional[float] = None) -> List[Tuple[str, str]]:
        """Advance open->half_open cooldowns, then return (and clear) the
        queued transitions for the applying thread."""
        now = self.clock() if now is None else now
        with self._lock:
            for tid, t in self._tenants.items():
                if t.state == OPEN and now - t.opened_at >= self.cooldown_s:
                    t.state = HALF_OPEN
                    self._transitions.append((tid, HALF_OPEN))
            out = self._transitions
            self._transitions = []
            return out

    def state(self, tid: str) -> str:
        with self._lock:
            t = self._tenants.get(tid)
            return t.state if t is not None else CLOSED

    def snapshot(self) -> Dict[str, str]:
        """Non-closed tenants only (closed == no entry == healthy)."""
        with self._lock:
            return {tid: t.state for tid, t in self._tenants.items()
                    if t.state != CLOSED}
