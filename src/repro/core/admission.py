"""KV-cache-aware admission control (paper §4.3).

A task is admitted while the estimated aggregate rollout-state footprint of
all admitted tasks stays below the rollout engine's memory budget. The
estimator generalizes the paper's KV formula to every assigned family
(DESIGN.md §5): attention archs pay per-token KV bytes, SSM archs pay a
fixed recurrent-state cost, hybrids pay both.

As in the paper, this is a soft constraint: `strict=False` lets one task
over-subscribe (it queues in the engine) — modelled in the simulator as a
throughput knee, matching the paper's observation that over-admission
raises per-step latency with marginal throughput gain.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

from repro.configs import ModelConfig
from .manager import TaskSpec


@dataclass
class AdmissionConfig:
    memory_budget_bytes: float = 8e9     # rollout-pool HBM left for KV
    kv_dtype_bytes: int = 2
    strict: bool = True
    paged: bool = False                  # paged KV engine (ISSUE 5): charge
                                         # page-granular estimates instead of
                                         # worst-case max_len reservations
    page_size: int = 16                  # engine kv_page_size (paged only)
    prefix_shared: bool = False          # COW prefix cache (ISSUE 8): a GRPO
                                         # group's full prompt pages are
                                         # physically shared, so charge them
                                         # once per group, not once per row


def task_state_bytes(cfg: ModelConfig, spec: TaskSpec,
                     prompt_len: int = 64, dtype_bytes: int = 2) -> int:
    """Estimated rollout-state bytes for one task's in-flight batch:
    rows × (max_len × per-token KV + fixed SSM state)."""
    rows = spec.rows_per_batch
    max_len = prompt_len + spec.max_new_tokens
    per_tok = cfg.state_bytes_per_token(dtype_bytes)
    fixed = cfg.state_bytes_fixed(dtype_bytes)
    return rows * (max_len * per_tok + fixed)


def task_state_bytes_remaining(cfg: ModelConfig, spec: TaskSpec,
                               prompt_len: int = 64, dtype_bytes: int = 2,
                               sampled_mean: float = 0.0) -> int:
    """Remaining-budget-aware re-estimate for a PREEMPTED task (ROADMAP
    open item): its rows carry `sampled_mean` already-generated tokens on
    average, so the modelled KV headroom charged at readmission shrinks by
    that share — readmission packs tighter than the original admission.

    Modelling note (soft, like the rest of the controller): a replayed
    row's prefix KV is re-materialized at replay, so the true peak matches
    the original estimate; but the prefix re-decode phase is brief and the
    controller's budget is a knee model, not an allocator — charging only
    the remaining growth is the paper's intent for re-admission packing."""
    rows = spec.rows_per_batch
    done = max(0.0, min(float(sampled_mean), float(spec.max_new_tokens)))
    rem_len = prompt_len + spec.max_new_tokens - done
    per_tok = cfg.state_bytes_per_token(dtype_bytes)
    fixed = cfg.state_bytes_fixed(dtype_bytes)
    return int(rows * (rem_len * per_tok + fixed))


def task_state_bytes_paged(cfg: ModelConfig, spec: TaskSpec,
                           prompt_len: int = 64, dtype_bytes: int = 2,
                           page_size: int = 16,
                           expected_new_tokens: Optional[float] = None
                           ) -> int:
    """Page-granular estimate for the PAGED KV engine (ISSUE 5): rows ×
    (pages(prompt + expected generation) × page tokens + fixed state).

    The dense estimator had no choice but to charge ``max_len`` per row —
    the engine physically reserved it. The page pool only ever holds
    ``ceil(len/page)`` pages per row, so the controller can charge what
    rows are EXPECTED to use: ``expected_new_tokens`` defaults to the full
    ``spec.max_new_tokens`` (cold tenant, pessimistic), and callers with a
    length predictor (the engine's per-tenant EMA) pass the expected
    completion length — mixed-length tenant sets then pack substantially
    more resident rows into the same HBM budget (the bench gate)."""
    rows = spec.rows_per_batch
    gen = (spec.max_new_tokens if expected_new_tokens is None
           else min(float(expected_new_tokens), float(spec.max_new_tokens)))
    total = int(prompt_len + gen + 0.999)
    pages = -(-total // page_size)
    per_tok = cfg.state_bytes_per_token(dtype_bytes)
    fixed = cfg.state_bytes_fixed(dtype_bytes)
    return int(rows * (pages * page_size * per_tok + fixed))


def task_state_bytes_shared(cfg: ModelConfig, spec: TaskSpec,
                            prompt_len: int = 64, dtype_bytes: int = 2,
                            page_size: int = 16,
                            expected_new_tokens: Optional[float] = None
                            ) -> int:
    """Group-shared estimate for the COW prefix cache (ISSUE 8): the
    ``group_size`` rows of a GRPO group run the SAME prompt, and the engine
    maps their block tables onto one retained page set — full prompt pages
    exist once per group physically, so the controller charges them once
    per group too. Each row then pays only its private growth: the shared
    partial tail page forks on first decode write (one COW page) plus the
    pages its generated suffix spills into, plus fixed recurrent state.

    This is what lets admission pack strictly more resident rows under the
    same HBM budget than the private-pages estimator — the bench gate's
    ≥1.3x admitted-rows ratio reads directly off this charge."""
    gen = (spec.max_new_tokens if expected_new_tokens is None
           else min(float(expected_new_tokens), float(spec.max_new_tokens)))
    gen = int(gen + 0.999)
    full_prompt_pages = prompt_len // page_size
    rem = prompt_len - full_prompt_pages * page_size
    # per-row private pages: the forked tail (holding `rem` prompt tokens)
    # grows with the generation; page-aligned prompts fork nothing and the
    # first decode write allocates a fresh page
    row_pages = -(-(rem + gen) // page_size) if (rem + gen) else 0
    per_tok = cfg.state_bytes_per_token(dtype_bytes)
    fixed = cfg.state_bytes_fixed(dtype_bytes)
    page_bytes = page_size * per_tok
    shared = spec.num_groups * full_prompt_pages * page_bytes
    private = spec.rows_per_batch * (row_pages * page_bytes + fixed)
    return int(shared + private)


class AdmissionController:
    """Byte-budget admission with preemption accounting.

    Lifecycle: try_admit → (preempt ↔ try_readmit)* → release. `preempt`
    releases a still-running task's bytes back to the budget while
    remembering the charge, so a higher-priority newcomer can admit;
    `try_readmit` re-charges the same estimate once budget frees. Without
    this, preempted tasks kept their reservation forever and preemption
    could never create capacity (the bug this accounting fixes — bytes
    were only released at task finish).

    Soft, like the rest of the controller (paper §4.3): a preempted
    task's evicted rows hold no state while queued, but they prefix-
    replay into decode slots as they free, so the modelled budget can be
    transiently exceeded while victim and newcomer rows coexist. The
    engine's actual KV pool is a fixed preallocation (max_slots ×
    max_len), so this over-subscription shows up as queueing, never as
    allocation beyond the pool."""

    def __init__(self, cfg: ModelConfig, acfg: AdmissionConfig):
        self.cfg = cfg
        self.acfg = acfg
        self._admitted: Dict[str, int] = {}
        self._preempted: Dict[str, int] = {}

    @property
    def used_bytes(self) -> int:
        return sum(self._admitted.values())

    def try_admit(self, spec: TaskSpec, prompt_len: int = 64,
                  expected_new_tokens: Optional[float] = None) -> bool:
        if self.acfg.paged and self.acfg.prefix_shared:
            # COW prefix cache: full prompt pages charged once per GRPO
            # group (physically shared), private growth per row
            need = task_state_bytes_shared(self.cfg, spec, prompt_len,
                                           self.acfg.kv_dtype_bytes,
                                           self.acfg.page_size,
                                           expected_new_tokens)
        elif self.acfg.paged:
            # page-granular charge (actual pool consumption), optionally
            # tightened by the caller's expected completion length
            need = task_state_bytes_paged(self.cfg, spec, prompt_len,
                                          self.acfg.kv_dtype_bytes,
                                          self.acfg.page_size,
                                          expected_new_tokens)
        else:
            need = task_state_bytes(self.cfg, spec, prompt_len,
                                    self.acfg.kv_dtype_bytes)
        return self.try_admit_bytes(spec.task_id, need)

    def try_admit_bytes(self, task_id: str, need: int) -> bool:
        """Admission on a precomputed estimate (the simulator derives it from
        the workload model rather than the TaskSpec defaults).

        An empty system always admits one task (the paper's constraint is
        soft — a lone over-budget task queues inside the engine rather than
        deadlocking the service)."""
        if not self._admitted:
            self._admitted[task_id] = need
            return True
        if (self.acfg.strict
                and self.used_bytes + need > self.acfg.memory_budget_bytes):
            return False
        self._admitted[task_id] = need
        return True

    def workload_bytes(self, rows: int, total_len: int,
                       dtype_bytes: int = None) -> int:
        db = dtype_bytes or self.acfg.kv_dtype_bytes
        return rows * (total_len * self.cfg.state_bytes_per_token(db)
                       + self.cfg.state_bytes_fixed(db))

    def preempt(self, task_id: str) -> int:
        """Release an admitted task's bytes while it is preempted; the
        charge is remembered for `try_readmit`. Returns the bytes freed."""
        need = self._admitted.pop(task_id, None)
        if need is None:
            return 0
        self._preempted[task_id] = need
        return need

    def reestimate_preempted(self, task_id: str, spec: TaskSpec,
                             sampled_mean: float,
                             prompt_len: int = 64) -> Optional[int]:
        """Tighten a preempted task's parked reservation to the
        remaining-budget-aware estimate (never raises it — the original
        charge is the ceiling). Returns the new estimate, or None if the
        task is not in the preempted set."""
        old = self._preempted.get(task_id)
        if old is None:
            return None
        new = task_state_bytes_remaining(self.cfg, spec, prompt_len,
                                         self.acfg.kv_dtype_bytes,
                                         sampled_mean)
        self._preempted[task_id] = min(old, new)
        return self._preempted[task_id]

    def reestimate_preempted_bytes(self, task_id: str,
                                   need: int) -> Optional[int]:
        """Tighten a preempted task's parked reservation to an ACTUAL byte
        count (paged engine: snapshot page counts + page-rounded replay
        prefixes reported by ``engine.queued_state_bytes``) instead of a
        model-derived estimate. Never raises the charge."""
        old = self._preempted.get(task_id)
        if old is None:
            return None
        self._preempted[task_id] = min(old, int(need))
        return self._preempted[task_id]

    def try_readmit(self, task_id: str) -> bool:
        """Re-charge a preempted task's remembered estimate if it fits (the
        empty-system soft rule of try_admit_bytes applies). The estimate
        may have been tightened by `reestimate_preempted` since preemption
        (rows already partially decoded need less KV headroom)."""
        need = self._preempted.get(task_id)
        if need is None:
            return False
        if self.try_admit_bytes(task_id, need):
            del self._preempted[task_id]
            return True
        return False

    # -- quarantine (per-tenant circuit breaker, ISSUE 10) -----------------
    def quarantine(self, task_id: str) -> int:
        """Pause a quarantined tenant's admission: its bytes free up for
        the healthy tenants (reusing the preemption accounting — the
        charge parks, it is not forgotten) until the half-open probe
        readmits it. Returns the bytes freed."""
        return self.preempt(task_id)

    def try_unquarantine(self, task_id: str) -> bool:
        """Re-charge a quarantined tenant's parked reservation for its
        half-open probe round. Soft like try_readmit: False means the
        budget is currently full — the caller retries next tick (the probe
        itself is not blocked; this is the accounting side)."""
        return self.try_readmit(task_id)

    def release(self, task_id: str):
        """Finished (or cancelled) task: drop its reservation wherever it
        is — admitted or parked in the preempted set."""
        self._admitted.pop(task_id, None)
        self._preempted.pop(task_id, None)

    def admitted(self) -> List[str]:
        return list(self._admitted)

    def admitted_bytes(self, task_id: str) -> int:
        """Current reservation charged to an admitted task (0 if absent)."""
        return self._admitted.get(task_id, 0)

    def preempted(self) -> List[str]:
        return list(self._preempted)
