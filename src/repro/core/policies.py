"""The four scheduling regimes evaluated in the paper (§5 Baselines), driving
the shared MultiTaskManager + Simulator:

  single_disagg   — tasks one at a time, exclusive disaggregated pools
  single_colloc   — tasks one at a time, idealized shared pool (instant
                    switching — paper's optimistic upper bound)
  multilora_sync  — concurrent multi-LoRA rollout, global barrier, then
                    sequential training, per round
  marlaas         — full MARLaaS: async, event-driven, admission-controlled
                    (Algorithm 1)

Each run returns (manager, recorder) for metrics.summarize().
"""
from __future__ import annotations

import numpy as np
from typing import Dict, List, Optional

from repro.configs import ModelConfig
from repro.rl.types import TrajectoryBatch
from .admission import AdmissionConfig, AdmissionController
from .manager import MultiTaskManager, TaskSpec
from .metrics import MetricsRecorder
from .simulator import HardwareModel, Simulator, WorkloadModel

POLICIES = ("single_disagg", "single_colloc", "multilora_sync", "marlaas")
# ablation variant (paper Table 4): async scheduling WITHOUT fused
# multi-LoRA decode — every tenant pays its own weight reads
ABLATIONS = ("marlaas_nomlora",)


def _fake_batch(task_id: str, version: int) -> TrajectoryBatch:
    z = np.zeros((1, 2), np.float32)
    return TrajectoryBatch(task_id=task_id, version=version,
                           tokens=z.astype(np.int32),
                           prompt_lens=np.ones(1, np.int32),
                           total_lens=np.full(1, 2, np.int32),
                           rewards=np.zeros(1, np.float32), group_size=1)


def run_sim(policy: str, cfg: ModelConfig, hw: HardwareModel,
            specs: List[TaskSpec], workloads: Dict[str, WorkloadModel],
            admission: Optional[AdmissionConfig] = None, seed: int = 0):
    sim = Simulator(cfg, hw, seed=seed)
    mgr = MultiTaskManager(clock=sim.clock)
    for s in specs:
        mgr.submit(s)

    if policy == "marlaas":
        _drive_marlaas(sim, mgr, specs, workloads, admission
                       or AdmissionConfig())
    elif policy == "marlaas_nomlora":
        _drive_marlaas(sim, mgr, specs, workloads, admission
                       or AdmissionConfig(), multi_lora=False)
    elif policy == "multilora_sync":
        _drive_sync(sim, mgr, specs, workloads)
    elif policy in ("single_disagg", "single_colloc"):
        _drive_single(sim, mgr, specs, workloads,
                      collocated=(policy == "single_colloc"))
    else:
        raise ValueError(policy)

    sim.run(stop=mgr.all_done)
    return mgr, sim.rec


# ---------------------------------------------------------------------------
# MARLaaS (Algorithm 1): fully event-driven
# ---------------------------------------------------------------------------

def _drive_marlaas(sim: Simulator, mgr: MultiTaskManager,
                   specs: List[TaskSpec], workloads, acfg: AdmissionConfig,
                   multi_lora: bool = True):
    adm = AdmissionController(sim.cfg, acfg)

    def try_admit():
        # highest-priority pending tenants claim freed budget first (ties
        # keep submission order — pending_tasks preserves it)
        pending = sorted(mgr.pending_tasks(),
                         key=lambda t: -mgr.spec_for(t).priority)
        for tid in pending:
            wl = workloads[tid]
            need = adm.workload_bytes(wl.rows, wl.prompt_len + wl.gen_len)
            if adm.try_admit_bytes(tid, need):
                mgr.admit(tid)
                issue_rollout(tid)

    def issue_rollout(tid):
        np_ = mgr.next_policy(tid)
        if np_ is None:
            return
        version, _ = np_
        spec = mgr.spec_for(tid)

        def on_rollout_done(tid=tid, version=version):
            mgr.enqueue(_fake_batch(tid, version))
            drain_buffer()

        sim.submit_rollout(spec, workloads[tid], version, on_rollout_done,
                           multi_lora=multi_lora)

    def drain_buffer():
        # single-task serialized training engine (paper §4.5): the sim's
        # train server FIFO-orders submissions, so drain eagerly.
        while True:
            b = mgr.pop_batch()
            if b is None:
                return

            def on_train_done(b=b):
                mgr.commit(b.task_id, None, None, b.version)
                if mgr.state(b.task_id).done:
                    adm.release(b.task_id)
                    try_admit()
                else:
                    issue_rollout(b.task_id)

            sim.submit_train(mgr.spec_for(b.task_id),
                             workloads[b.task_id], b.version, on_train_done)

    sim.schedule(0.0, try_admit)


# ---------------------------------------------------------------------------
# Multi-LoRA synchronous: barrier rounds
# ---------------------------------------------------------------------------

def _drive_sync(sim: Simulator, mgr: MultiTaskManager, specs, workloads):
    for s in specs:
        mgr.admit(s.task_id)

    state = {"outstanding": 0}

    def start_round():
        active = mgr.active_tasks()
        if not active:
            return
        state["outstanding"] = len(active)
        for tid in active:
            np_ = mgr.next_policy(tid)
            if np_ is None:
                state["outstanding"] -= 1
                continue
            v, _ = np_

            def on_done(tid=tid, v=v):
                mgr.enqueue(_fake_batch(tid, v))
                state["outstanding"] -= 1
                if state["outstanding"] == 0:
                    train_all()          # global barrier reached

            sim.submit_rollout(mgr.spec_for(tid), workloads[tid], v, on_done)

    def train_all():
        batches = []
        while True:
            b = mgr.pop_batch()
            if b is None:
                break
            batches.append(b)
        remaining = {"n": len(batches)}
        for b in batches:
            def on_train_done(b=b):
                mgr.commit(b.task_id, None, None, b.version)
                remaining["n"] -= 1
                if remaining["n"] == 0:
                    start_round()

            sim.submit_train(mgr.spec_for(b.task_id), workloads[b.task_id],
                             b.version, on_train_done)
        if not batches:
            start_round()

    sim.schedule(0.0, start_round)


# ---------------------------------------------------------------------------
# Single-task regimes (disaggregated / collocated)
# ---------------------------------------------------------------------------

def _drive_single(sim: Simulator, mgr: MultiTaskManager, specs, workloads,
                  *, collocated: bool):
    order = [s.task_id for s in specs]
    hw = sim.hw
    if collocated:
        # idealized shared pool: all devices serve whichever phase is active
        sim.rec = MetricsRecorder({"all": hw.n_devices})
        _alias_pools(sim)
        rollout_devs = hw.n_devices
        train_devs = hw.n_devices
    else:
        rollout_devs = hw.rollout_devices
        train_devs = hw.train_devices

    idx = {"i": 0}

    def start_next_task():
        if idx["i"] >= len(order):
            return
        tid = order[idx["i"]]
        mgr.admit(tid)
        step(tid)

    def step(tid):
        np_ = mgr.next_policy(tid)
        if np_ is None:  # task finished
            idx["i"] += 1
            start_next_task()
            return
        v, _ = np_

        def on_rollout_done(tid=tid, v=v):
            mgr.enqueue(_fake_batch(tid, v))
            b = mgr.pop_batch()

            def on_train_done(b=b):
                mgr.commit(b.task_id, None, None, b.version)
                step(b.task_id)

            sim.submit_train(mgr.spec_for(b.task_id), workloads[b.task_id],
                             b.version, on_train_done,
                             pool_devices=train_devs)

        sim.submit_rollout(mgr.spec_for(tid), workloads[tid], v,
                           on_rollout_done, multi_lora=False,
                           pool_devices=rollout_devs)

    sim.schedule(0.0, start_next_task)


def _alias_pools(sim: Simulator):
    """Collocated mode: record every phase against the single shared pool,
    and let decode use the full machine's bandwidth."""
    rec = sim.rec
    orig = rec.record

    def record(pool, phase, task_id, start, end, devices=None):
        orig("all", phase, task_id, start, end, devices)

    rec.record = record
    full_bw = sim.hw.n_devices * sim.hw.hbm_bw_per_dev * sim.hw.mem_eff
    sim._pool_bw = lambda: full_bw
