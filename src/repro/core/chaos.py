"""Deterministic chaos injection (ISSUE 10 tentpole).

Generalizes the trainer-only ``FailureInjector`` (core/runtime.py) to a
fault model covering every threaded stage: prefill-worker kills,
env-worker kills, transient/permanent tool errors, snapshot drops under
(simulated) host-memory pressure, and torn checkpoints (published
snapshot, crash before the LATEST pointer moves). Each fault site draws
from its OWN seeded RNG stream keyed ``(seed, site)`` and decisions are
consumed in event order, so a given workload replays the same fault
script run-to-run as long as the per-site event order is deterministic.
Cross-site interleaving (which worker thread rolls first) does not
perturb any other site's stream — that isolation is the point of
per-site streams.

Tests drive the matrix with rates of 0.0 / 1.0 plus ``max_faults_per_site``
caps, which is exact regardless of thread scheduling ("kill the first
prefill job's worker, then never again"). Every hook site guards
``chaos is None`` (and ``fire()`` early-outs on rate 0.0), so with chaos
off the fault paths cost one attribute check and the token stream is
byte-identical to a build without this module.
"""
from __future__ import annotations

import random
import threading
import zlib
from dataclasses import dataclass
from typing import Dict


class ChaosError(RuntimeError):
    """An injected infrastructure fault (torn checkpoint, ...). Derives
    from RuntimeError so existing crash/restart paths treat it exactly
    like the real failure it simulates."""


@dataclass
class ChaosConfig:
    """Per-stage fault rates (probability per opportunity, in [0, 1]).

    A "kill" rate is rolled once per job pickup and simulates the worker
    thread dying abruptly — no cleanup, its in-flight work stranded until
    the ``StageSupervisor`` recovers it. Tool-error rates are rolled once
    per episode tool call; a transient hit fails the same call
    ``transient_fail_count`` times before letting it through (exercising
    retry-then-succeed), a permanent hit fails it forever (exercising the
    tool_error episode outcome + circuit breaker). ``snapshot_drop``
    simulates host snapshot-budget pressure on park/preempt (the row
    falls back to token replay — output is identical, only slower).
    ``torn_checkpoint`` raises after a snapshot directory is published
    but before LATEST is updated."""
    seed: int = 0
    prefill_worker_kill: float = 0.0
    env_worker_kill: float = 0.0
    tool_error_transient: float = 0.0
    tool_error_permanent: float = 0.0
    transient_fail_count: int = 2
    snapshot_drop: float = 0.0
    torn_checkpoint: float = 0.0
    max_faults_per_site: int = 0       # per-site injection cap (0 = none)

    @property
    def enabled(self) -> bool:
        return any(r > 0 for r in (
            self.prefill_worker_kill, self.env_worker_kill,
            self.tool_error_transient, self.tool_error_permanent,
            self.snapshot_drop, self.torn_checkpoint))


# the fault sites fire() accepts — each maps to its ChaosConfig rate field
SITES = ("prefill_worker_kill", "env_worker_kill", "tool_error_transient",
         "tool_error_permanent", "snapshot_drop", "torn_checkpoint")


class ChaosInjector:
    """Thread-safe fault dice shared by all stages of one runtime."""

    def __init__(self, cfg: ChaosConfig):
        self.cfg = cfg
        self._lock = threading.Lock()   # guards: _rngs/injected
        self._rngs: Dict[str, random.Random] = {}
        self.injected: Dict[str, int] = {}

    def fire(self, site: str) -> bool:
        """Roll `site`'s die: True -> inject the fault now. Counts every
        injection (``injected``) so tests and the chaos bench can assert
        faults actually happened."""
        if site not in SITES:
            raise ValueError(f"unknown chaos site {site!r}")
        rate = getattr(self.cfg, site)
        if rate <= 0:
            return False
        with self._lock:
            cap = self.cfg.max_faults_per_site
            if cap and self.injected.get(site, 0) >= cap:
                return False
            rng = self._rngs.get(site)
            if rng is None:
                # stable per-site stream: crc32, not hash() (salted per
                # process — it would de-determinize the script)
                rng = random.Random((self.cfg.seed << 32)
                                    ^ zlib.crc32(site.encode()))
                self._rngs[site] = rng
            hit = rng.random() < rate
            if hit:
                self.injected[site] = self.injected.get(site, 0) + 1
            return hit

    def counts(self) -> Dict[str, int]:
        with self._lock:
            return dict(self.injected)
