"""Occupancy-timeline metrics — the paper's evaluation quantities (§5):

  utilization %  — average accelerator AI-core utilization: device-seconds
                   busy × phase compute-intensity / total device-seconds.
                   (Profilers count core-active cycles, which is why even a
                   fully-occupied decode pool reports single-digit %; we
                   model that with per-phase intensity factors.)
  idle %         — fraction of device-seconds with NO job resident.
  steps/hr       — committed train steps per wall-clock hour.
  TTFS           — time-to-first-step per task (submission → first commit).
  TPTS           — time-per-train-step once underway.
  slot util %    — continuous-batching decode-slot occupancy: time-weighted
                   fraction of the engine's decode slots holding a live row
                   (the §4.1 quantity round-fused scheduling wastes at the
                   end-of-round barrier).
  stage busy     — per-stage (prefill / decode / splice) busy seconds of the
                   disaggregated rollout layout (Fig 5): prefill intervals
                   come from the async prefill workers, decode intervals
                   from the decode stream, splice intervals from the
                   scatter-only installs. Under the fused baseline prefill
                   intervals sit ON the decode stream (decode-stall); under
                   ``disagg_prefill`` they overlap it.
  queue depth    — step-function timeline of the prefill-stage queues
                   (waiting + in-prefill, ready-to-splice) — the Fig-5
                   hand-off depths between the two rollout stages — and of
                   the env-interaction stage's queues (waiting, executing).
  env busy/wait  — environment-interaction accounting: "env" intervals are
                   recorded per task (tool dispatch → response), never
                   counted as device-busy (PHASE_INTENSITY 0, excluded from
                   busy/idle). env wait = Σ interval durations (row-seconds
                   spent blocked on tools); env busy = their merged union
                   (wall time with ≥1 tool call outstanding).

Both runtimes (real threads and virtual-time simulator) record through this
same recorder, so benchmark tables are produced by one code path. The
recorder is thread-safe: the disaggregated prefill workers record stage
intervals concurrently with the decode and trainer threads.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

# AI-core intensity per phase: fraction of peak compute a resident phase
# actually drives (decode is HBM-bound → low; matches paper Table 3 scale).
PHASE_INTENSITY = {
    "decode": 0.08,
    "prefill": 0.45,
    "splice": 0.05,     # scatter-only cache install (HBM copy, no compute)
    "train": 0.40,
    "env": 0.0,
}


@dataclass
class Interval:
    pool: str
    phase: str
    task_id: str
    start: float
    end: float
    devices: float          # device-count occupied (can be fractional in PS)


@dataclass
class PoolSpec:
    name: str
    devices: int


class MetricsRecorder:
    def __init__(self, pools: Dict[str, int]):
        self.pools = dict(pools)
        self.intervals: List[Interval] = []
        self.slot_samples: List[Tuple[float, int, int]] = []  # (t, occ, cap)
        self.queue_samples: List[Tuple[float, int, int]] = []  # (t, pq, rq)
        self.env_samples: List[Tuple[float, int, int]] = []  # (t, wait, exec)
        # (t, used pages, total pages, fragmentation) of the paged KV pool
        self.page_samples: List[Tuple[float, int, int, float]] = []
        self.counters: Dict[str, int] = {}    # preemption/eviction/replay...
        # ONE source of truth for event counters (ISSUE 9 satellite): the
        # engine's RolloutStats (attached by the runtime) is merged into
        # counters_snapshot() alongside the explicit incr() counters, so
        # summarize() never depends on hand-mirrored incr calls staying in
        # sync with the stats fields
        self._rollout_stats = None
        # trainer hand-off accounting (async off-policy trainer, ROADMAP §2):
        # spans the trainer spent blocked in pop, and a step-function
        # timeline of the DISPATCHABLE backlog (whole micro-batches the
        # trainer could pop right now) — together they measure "trainer
        # idle while trainable work existed", the quantity the round barrier
        # wastes. Wait spans are NOT intervals: they must never count as
        # device-busy time.
        self.trainer_waits: List[Tuple[float, float]] = []
        self.backlog_samples: List[Tuple[float, int]] = []  # (t, rows)
        # (t, tenant, state) circuit-breaker transition timeline (ISSUE 10):
        # closed -> open -> half_open -> closed/abandoned per tenant
        self.breaker_samples: List[Tuple[float, str, str]] = []
        self.t0: Optional[float] = None
        self.t1: Optional[float] = None
        # prefill workers record concurrently with the decode/train threads
        self._lock = threading.Lock()   # guards: intervals/slot_samples/
                                        # queue_samples/env_samples/
                                        # page_samples/counters/trainer_waits/
                                        # backlog_samples

    def incr(self, name: str, n: int = 1):
        """Count a scheduler event (preemptions, adapter_evictions,
        adapter_installs, replays, readmissions, ...)."""
        with self._lock:
            self.counters[name] = self.counters.get(name, 0) + n

    def attach_rollout_stats(self, stats) -> None:
        """Adopt the engine's RolloutStats as a counter source: its integer
        event fields (parks, resumes, restores, prefix_hits, ...) appear in
        counters_snapshot() by field name, live — no mirroring incr()
        required and no end-of-run copy to forget."""
        with self._lock:
            self._rollout_stats = stats

    def counters_snapshot(self) -> Dict[str, int]:
        """Explicit incr() counters merged with the attached RolloutStats'
        nonzero integer fields. Explicit counters win on a name collision
        ("preemptions" counts preemption EVENTS via incr but preempted ROWS
        in the stats — the recorder's own semantics take precedence)."""
        import dataclasses
        with self._lock:
            merged = dict(self.counters)
            stats = self._rollout_stats
        if stats is not None:
            for f in dataclasses.fields(stats):
                v = getattr(stats, f.name)
                if (isinstance(v, int) and not isinstance(v, bool)
                        and v != 0 and f.name not in merged):
                    merged[f.name] = v
        return merged

    def record(self, pool: str, phase: str, task_id: str, start: float,
               end: float, devices: float = None):
        if end <= start:
            return
        devices = devices if devices is not None else self.pools.get(pool, 0)
        with self._lock:
            self.intervals.append(Interval(pool, phase, task_id, start, end,
                                           devices))
            self.t0 = start if self.t0 is None else min(self.t0, start)
            self.t1 = end if self.t1 is None else max(self.t1, end)

    def record_slot_sample(self, t: float, occupied: int, capacity: int):
        """Point sample of continuous-engine slot occupancy (step-function
        timeline: the value holds until the next sample)."""
        if capacity <= 0:
            return
        with self._lock:
            self.slot_samples.append((t, occupied, capacity))

    def record_queue_sample(self, t: float, prefill_q: int, ready_q: int):
        """Point sample of the disaggregated prefill stage's queue depths
        (waiting+in-prefill, ready-to-splice); step-function timeline like
        the slot samples."""
        with self._lock:
            self.queue_samples.append((t, prefill_q, ready_q))

    def record_env_sample(self, t: float, waiting: int, executing: int):
        """Point sample of the env-interaction stage's queue depths
        (requests waiting for a worker, tool calls executing)."""
        with self._lock:
            self.env_samples.append((t, waiting, executing))

    def record_page_sample(self, t: float, used: int, total: int,
                           frag: float):
        """Point sample of the paged KV block pool: pages in use, pool
        size, and internal fragmentation (allocated page slack beyond the
        live cache entries); step-function timeline like the others."""
        if total <= 0:
            return
        with self._lock:
            self.page_samples.append((t, used, total, frag))

    def record_breaker_sample(self, t: float, task_id: str, state: str):
        """One tenant circuit-breaker transition (quarantine story): the
        state holds until the tenant's next transition."""
        with self._lock:
            self.breaker_samples.append((t, task_id, state))

    def breaker_timeline(self, task_id: Optional[str] = None
                         ) -> List[Tuple[float, str, str]]:
        """Breaker transitions in time order, optionally one tenant's."""
        with self._lock:
            return [s for s in self.breaker_samples
                    if task_id is None or s[1] == task_id]

    def record_trainer_wait(self, start: float, end: float):
        """The trainer blocked in pop (no admissible micro-batch) over
        [start, end). Booked separately from intervals so it can never be
        mistaken for device-busy time."""
        if end <= start:
            return
        with self._lock:
            self.trainer_waits.append((start, end))

    def record_train_backlog(self, t: float, rows: int):
        """Point sample of the dispatchable train backlog — rows sitting
        in whole micro-batches the trainer could pop right now (complete
        GRPO groups in ``train_threshold`` multiples per tenant in async
        mode; assembled Q_buffer rounds in sync mode). Step-function
        timeline: sampled at every completion routing, pop, and commit,
        the points where the level can change."""
        with self._lock:
            self.backlog_samples.append((t, rows))

    def trainer_idle_stats(self) -> Dict[str, float]:
        """Trainer idle-while-work-available between the first and last
        train step: seconds the trainer sat in pop while a dispatchable
        micro-batch existed, and that as a fraction of the
        first-commit→last-commit span. Sub-threshold partial assemblies
        are not dispatchable (no trainer could legally train them), so
        trickle-in assembly time never counts against the trainer. This
        is the hand-off latency the event-driven trainer eliminates (the
        async bench gates on trainer_idle_frac ≈ 0)."""
        with self._lock:
            trains = [iv for iv in self.intervals if iv.pool == "train"]
            waits = list(self.trainer_waits)
            samples = list(self.backlog_samples)
        if not trains:
            return {}
        t0 = min(iv.start for iv in trains)
        t1 = max(iv.end for iv in trains)
        if t1 <= t0:
            return {}
        segs: List[Tuple[float, float, int]] = []   # (start, end, backlog)
        samples.sort()          # engine + trainer threads record concurrently
        level, last = 0, None
        for t, lv in samples:
            if last is not None and t > last:
                segs.append((last, t, level))
            level = lv
            last = t
        if last is not None:
            segs.append((last, float("inf"), level))
        idle = 0.0
        for ws, we in waits:
            ws, we = max(ws, t0), min(we, t1)
            if we <= ws:
                continue
            for ss, se, lv in segs:
                if lv <= 0:
                    continue
                s, e = max(ws, ss), min(we, se)
                if e > s:
                    idle += e - s
        return {"trainer_idle_with_work_s": idle,
                "trainer_span_s": t1 - t0,
                "trainer_idle_frac": idle / (t1 - t0)}

    def page_pool_stats(self) -> Dict[str, float]:
        """Time-weighted occupancy (used/total) and fragmentation of the
        paged KV pool over the run (empty dict in dense-cache mode)."""
        with self._lock:
            ps = list(self.page_samples)
        if len(ps) < 2:
            return {}
        occ_w = frag_w = total = 0.0
        for (t0, u, cap, fr), (t1, _, _, _) in zip(ps, ps[1:]):
            dt = max(0.0, t1 - t0)
            occ_w += dt * u / cap
            frag_w += dt * fr
            total += dt
        if total <= 0:
            return {}
        return {"kv_page_occupancy_mean": occ_w / total,
                "kv_page_occupancy_max": max(u / cap
                                             for _, u, cap, _ in ps),
                "kv_page_frag_mean": frag_w / total}

    @staticmethod
    def _depth_stats(samples, names) -> Dict[str, float]:
        """Time-weighted mean + max per column of a step-function
        (t, d0, d1) depth timeline."""
        if len(samples) < 2:
            return {}
        w0 = w1 = total = 0.0
        for (t0, a, b), (t1, _, _) in zip(samples, samples[1:]):
            dt = max(0.0, t1 - t0)
            w0 += dt * a
            w1 += dt * b
            total += dt
        if total <= 0:
            return {}
        return {f"{names[0]}_mean": w0 / total,
                f"{names[0]}_max": float(max(a for _, a, _ in samples)),
                f"{names[1]}_mean": w1 / total,
                f"{names[1]}_max": float(max(b for _, _, b in samples))}

    def queue_depth_stats(self) -> Dict[str, float]:
        """Time-weighted mean + max depth per stage queue over the run
        (prefill + ready queues, and the env stage's queues if sampled)."""
        with self._lock:
            qs = list(self.queue_samples)
            es = list(self.env_samples)
        out = self._depth_stats(qs, ("prefill_q", "ready_q"))
        out.update(self._depth_stats(es, ("env_q", "env_exec")))
        return out

    # -- environment-interaction accounting -----------------------------
    def env_wait_seconds(self) -> float:
        """Σ env-interval durations: row-seconds spent blocked on external
        tools/judges (the per-task split is env_wait_by_task)."""
        with self._lock:
            return sum(iv.end - iv.start for iv in self.intervals
                       if iv.phase == "env")

    def env_wait_by_task(self) -> Dict[str, float]:
        """Per-tenant env-interaction wait seconds (satellite: the global
        aggregate hid which tenant's tools were slow)."""
        out: Dict[str, float] = {}
        with self._lock:
            ivs = list(self.intervals)
        for iv in ivs:
            if iv.phase == "env":
                out[iv.task_id] = out.get(iv.task_id, 0.0) + (iv.end - iv.start)
        return out

    def env_busy_seconds(self) -> float:
        """Merged union of env intervals: wall time with at least one tool
        call outstanding (concurrent calls counted once)."""
        with self._lock:
            spans = sorted((iv.start, iv.end) for iv in self.intervals
                           if iv.phase == "env")
        busy, cur_s, cur_e = 0.0, None, None
        for s, e in spans:
            if cur_e is None or s > cur_e:
                if cur_e is not None:
                    busy += cur_e - cur_s
                cur_s, cur_e = s, e
            else:
                cur_e = max(cur_e, e)
        if cur_e is not None:
            busy += cur_e - cur_s
        return busy

    def slot_utilization_pct(self) -> float:
        """Time-weighted mean of occupied/capacity over the sampled span."""
        with self._lock:
            ss = list(self.slot_samples)
        if len(ss) < 2:
            return 0.0
        weighted = total = 0.0
        for (t0, occ, cap), (t1, _, _) in zip(ss, ss[1:]):
            dt = max(0.0, t1 - t0)
            weighted += dt * occ / cap
            total += dt
        return 100.0 * weighted / total if total > 0 else 0.0

    # ------------------------------------------------------------------
    def span(self) -> float:
        if self.t0 is None:
            return 0.0
        return self.t1 - self.t0

    def total_device_seconds(self) -> float:
        return sum(self.pools.values()) * self.span()

    def busy_device_seconds(self, pool: str = None,
                            phase: str = None) -> float:
        with self._lock:
            return sum((iv.end - iv.start) * iv.devices
                       for iv in self.intervals
                       if iv.phase != "env"
                       and (pool is None or iv.pool == pool)
                       and (phase is None or iv.phase == phase))

    def utilization_pct(self) -> float:
        """AI-core utilization (paper Table 3 definition)."""
        total = self.total_device_seconds()
        if total <= 0:
            return 0.0
        with self._lock:
            weighted = sum((iv.end - iv.start) * iv.devices
                           * PHASE_INTENSITY.get(iv.phase, 0.3)
                           for iv in self.intervals)
        return 100.0 * weighted / total

    def idle_pct(self) -> float:
        """Fraction of device-seconds with no resident job (merged per pool)."""
        total = self.total_device_seconds()
        if total <= 0:
            return 0.0
        busy = 0.0
        with self._lock:
            ivs = list(self.intervals)
        for pool, ndev in self.pools.items():
            # merge overlapping intervals weighted by occupied devices
            evs: List[Tuple[float, float]] = []
            for iv in ivs:
                if iv.pool != pool or iv.phase == "env":
                    continue
                evs.append((iv.start, min(iv.devices, ndev)))
                evs.append((iv.end, -min(iv.devices, ndev)))
            evs.sort()
            occ, last_t = 0.0, None
            for t, d in evs:
                if last_t is not None and occ > 0:
                    busy += min(occ, ndev) * (t - last_t)
                occ += d
                last_t = t
        return 100.0 * (1.0 - busy / total)


def summarize(manager, rec: MetricsRecorder) -> Dict[str, float]:
    """Standard summary across the paper's metrics."""
    span = rec.span()
    states = [st for _, st in manager.task_items()]
    steps = sum(st.steps_done for st in states)
    ttfs = [st.first_step_at - st.submitted_at
            for st in states if st.first_step_at is not None]
    tpts: List[float] = []
    for st in states:
        ts = st.step_times
        tpts += [b - a for a, b in zip(ts, ts[1:])]
    out = {
        "span_s": span,
        "total_steps": float(steps),
        "steps_per_hr": 3600.0 * steps / span if span else 0.0,
        "utilization_pct": rec.utilization_pct(),
        "idle_pct": rec.idle_pct(),
        "ttfs_mean_s": sum(ttfs) / len(ttfs) if ttfs else 0.0,
        "ttfs_max_s": max(ttfs) if ttfs else 0.0,
        "tpts_mean_s": sum(tpts) / len(tpts) if tpts else 0.0,
        "time_hrs": span / 3600.0,
        "slot_util_pct": rec.slot_utilization_pct(),
    }
    # per-stage busy time of the disaggregated rollout layout (Fig 5)
    for phase in ("prefill", "decode", "splice"):
        busy = rec.busy_device_seconds(pool="rollout", phase=phase)
        if busy > 0:
            out[f"{phase}_busy_s"] = busy
    # environment-interaction stage: wait (row-seconds blocked on tools)
    # and busy (wall time with a tool call outstanding) — never counted as
    # device time (per-task split: rec.env_wait_by_task())
    env_wait = rec.env_wait_seconds()
    if env_wait > 0:
        out["env_wait_s"] = env_wait
        out["env_busy_s"] = rec.env_busy_seconds()
    out.update(rec.queue_depth_stats())
    # trainer hand-off: idle-while-work-available between first and last
    # commit (≈0 for the event-driven trainer; the round barrier's waste)
    out.update(rec.trainer_idle_stats())
    # paged KV pool occupancy/fragmentation gauges (ISSUE 5): absent under
    # the dense cache; restore-vs-replay counts ride the counters below
    # (n_restores / n_replays / n_replay_tokens_saved / n_snapshot_drops)
    out.update(rec.page_pool_stats())
    # scheduler event counters (zero-valued keys omitted: absent == 0) —
    # the unified snapshot: explicit incr() counters merged with the
    # attached engine RolloutStats (one source of truth; ISSUE 9
    # satellite). kv_* entries are end-of-run gauges of the prefix cache
    # (shared pages, index-held pages, HBM bytes per resident row) riding
    # the counter channel — emitted without the n_ count prefix.
    for name, n in sorted(rec.counters_snapshot().items()):
        key = name if name.startswith("kv_") else f"n_{name}"
        out[key] = float(n)
    return out
