"""Occupancy-timeline metrics — the paper's evaluation quantities (§5):

  utilization %  — average accelerator AI-core utilization: device-seconds
                   busy × phase compute-intensity / total device-seconds.
                   (Profilers count core-active cycles, which is why even a
                   fully-occupied decode pool reports single-digit %; we
                   model that with per-phase intensity factors.)
  idle %         — fraction of device-seconds with NO job resident.
  steps/hr       — committed train steps per wall-clock hour.
  TTFS           — time-to-first-step per task (submission → first commit).
  TPTS           — time-per-train-step once underway.
  slot util %    — continuous-batching decode-slot occupancy: time-weighted
                   fraction of the engine's decode slots holding a live row
                   (the §4.1 quantity round-fused scheduling wastes at the
                   end-of-round barrier).
  stage busy     — per-stage (prefill / decode / splice) busy seconds of the
                   disaggregated rollout layout (Fig 5): prefill intervals
                   come from the async prefill workers, decode intervals
                   from the decode stream, splice intervals from the
                   scatter-only installs. Under the fused baseline prefill
                   intervals sit ON the decode stream (decode-stall); under
                   ``disagg_prefill`` they overlap it.
  queue depth    — step-function timeline of the prefill-stage queues
                   (waiting + in-prefill, ready-to-splice) — the Fig-5
                   hand-off depths between the two rollout stages.

Both runtimes (real threads and virtual-time simulator) record through this
same recorder, so benchmark tables are produced by one code path. The
recorder is thread-safe: the disaggregated prefill workers record stage
intervals concurrently with the decode and trainer threads.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

# AI-core intensity per phase: fraction of peak compute a resident phase
# actually drives (decode is HBM-bound → low; matches paper Table 3 scale).
PHASE_INTENSITY = {
    "decode": 0.08,
    "prefill": 0.45,
    "splice": 0.05,     # scatter-only cache install (HBM copy, no compute)
    "train": 0.40,
    "env": 0.0,
}


@dataclass
class Interval:
    pool: str
    phase: str
    task_id: str
    start: float
    end: float
    devices: float          # device-count occupied (can be fractional in PS)


@dataclass
class PoolSpec:
    name: str
    devices: int


class MetricsRecorder:
    def __init__(self, pools: Dict[str, int]):
        self.pools = dict(pools)
        self.intervals: List[Interval] = []
        self.slot_samples: List[Tuple[float, int, int]] = []  # (t, occ, cap)
        self.queue_samples: List[Tuple[float, int, int]] = []  # (t, pq, rq)
        self.counters: Dict[str, int] = {}    # preemption/eviction/replay...
        self.t0: Optional[float] = None
        self.t1: Optional[float] = None
        self._lock = threading.Lock()   # prefill workers record concurrently

    def incr(self, name: str, n: int = 1):
        """Count a scheduler event (preemptions, adapter_evictions,
        adapter_installs, replays, readmissions, ...)."""
        with self._lock:
            self.counters[name] = self.counters.get(name, 0) + n

    def record(self, pool: str, phase: str, task_id: str, start: float,
               end: float, devices: float = None):
        if end <= start:
            return
        devices = devices if devices is not None else self.pools.get(pool, 0)
        with self._lock:
            self.intervals.append(Interval(pool, phase, task_id, start, end,
                                           devices))
            self.t0 = start if self.t0 is None else min(self.t0, start)
            self.t1 = end if self.t1 is None else max(self.t1, end)

    def record_slot_sample(self, t: float, occupied: int, capacity: int):
        """Point sample of continuous-engine slot occupancy (step-function
        timeline: the value holds until the next sample)."""
        if capacity <= 0:
            return
        self.slot_samples.append((t, occupied, capacity))

    def record_queue_sample(self, t: float, prefill_q: int, ready_q: int):
        """Point sample of the disaggregated prefill stage's queue depths
        (waiting+in-prefill, ready-to-splice); step-function timeline like
        the slot samples."""
        self.queue_samples.append((t, prefill_q, ready_q))

    def queue_depth_stats(self) -> Dict[str, float]:
        """Time-weighted mean + max depth per stage queue over the run."""
        qs = self.queue_samples
        if len(qs) < 2:
            return {}
        wp = wr = total = 0.0
        for (t0, pq, rq), (t1, _, _) in zip(qs, qs[1:]):
            dt = max(0.0, t1 - t0)
            wp += dt * pq
            wr += dt * rq
            total += dt
        if total <= 0:
            return {}
        return {"prefill_q_mean": wp / total,
                "prefill_q_max": float(max(pq for _, pq, _ in qs)),
                "ready_q_mean": wr / total,
                "ready_q_max": float(max(rq for _, _, rq in qs))}

    def slot_utilization_pct(self) -> float:
        """Time-weighted mean of occupied/capacity over the sampled span."""
        ss = self.slot_samples
        if len(ss) < 2:
            return 0.0
        weighted = total = 0.0
        for (t0, occ, cap), (t1, _, _) in zip(ss, ss[1:]):
            dt = max(0.0, t1 - t0)
            weighted += dt * occ / cap
            total += dt
        return 100.0 * weighted / total if total > 0 else 0.0

    # ------------------------------------------------------------------
    def span(self) -> float:
        if self.t0 is None:
            return 0.0
        return self.t1 - self.t0

    def total_device_seconds(self) -> float:
        return sum(self.pools.values()) * self.span()

    def busy_device_seconds(self, pool: str = None,
                            phase: str = None) -> float:
        return sum((iv.end - iv.start) * iv.devices for iv in self.intervals
                   if iv.phase != "env" and (pool is None or iv.pool == pool)
                   and (phase is None or iv.phase == phase))

    def utilization_pct(self) -> float:
        """AI-core utilization (paper Table 3 definition)."""
        total = self.total_device_seconds()
        if total <= 0:
            return 0.0
        weighted = sum((iv.end - iv.start) * iv.devices
                       * PHASE_INTENSITY.get(iv.phase, 0.3)
                       for iv in self.intervals)
        return 100.0 * weighted / total

    def idle_pct(self) -> float:
        """Fraction of device-seconds with no resident job (merged per pool)."""
        total = self.total_device_seconds()
        if total <= 0:
            return 0.0
        busy = 0.0
        for pool, ndev in self.pools.items():
            # merge overlapping intervals weighted by occupied devices
            evs: List[Tuple[float, float]] = []
            for iv in self.intervals:
                if iv.pool != pool or iv.phase == "env":
                    continue
                evs.append((iv.start, min(iv.devices, ndev)))
                evs.append((iv.end, -min(iv.devices, ndev)))
            evs.sort()
            occ, last_t = 0.0, None
            for t, d in evs:
                if last_t is not None and occ > 0:
                    busy += min(occ, ndev) * (t - last_t)
                occ += d
                last_t = t
        return 100.0 * (1.0 - busy / total)


def summarize(manager, rec: MetricsRecorder) -> Dict[str, float]:
    """Standard summary across the paper's metrics."""
    span = rec.span()
    steps = sum(st.steps_done for st in manager.tasks.values())
    ttfs = [st.first_step_at - st.submitted_at
            for st in manager.tasks.values() if st.first_step_at is not None]
    tpts: List[float] = []
    for st in manager.tasks.values():
        ts = st.step_times
        tpts += [b - a for a, b in zip(ts, ts[1:])]
    out = {
        "span_s": span,
        "total_steps": float(steps),
        "steps_per_hr": 3600.0 * steps / span if span else 0.0,
        "utilization_pct": rec.utilization_pct(),
        "idle_pct": rec.idle_pct(),
        "ttfs_mean_s": sum(ttfs) / len(ttfs) if ttfs else 0.0,
        "ttfs_max_s": max(ttfs) if ttfs else 0.0,
        "tpts_mean_s": sum(tpts) / len(tpts) if tpts else 0.0,
        "time_hrs": span / 3600.0,
        "slot_util_pct": rec.slot_utilization_pct(),
    }
    # per-stage busy time of the disaggregated rollout layout (Fig 5)
    for phase in ("prefill", "decode", "splice"):
        busy = rec.busy_device_seconds(pool="rollout", phase=phase)
        if busy > 0:
            out[f"{phase}_busy_s"] = busy
    out.update(rec.queue_depth_stats())
    # scheduler event counters (zero-valued keys omitted: absent == 0)
    for name, n in sorted(rec.counters.items()):
        out[f"n_{name}"] = float(n)
    return out
