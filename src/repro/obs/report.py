"""Critical-path latency attribution over an exported episode trace.

    PYTHONPATH=src python -m repro.obs.report trace.json [--json out.json]

Consumes the Chrome trace-event JSON written by ``Tracer.dump_json`` /
``Tracer.export_chrome`` (the ``cat == "episode"`` slices the exporter
synthesizes from the lifecycle marks) and answers the question aggregate
busy-seconds cannot: *where did each episode's submission→commit latency
go, and which stage is each tenant's bottleneck?*

Per episode it recovers the additive decomposition — queue_wait,
prefill, splice_wait, restore, decode, env_queue_wait, env, resume_wait,
preempt_wait, completed_wait, train — verifies the components sum to the
end-to-end latency (they do by construction; the check catches exporter
or clock regressions), then aggregates per tenant: episode count, E2E
p50/p95/p99, mean seconds per component, and the dominant (bottleneck)
component by total time.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Optional


def percentile(xs: List[float], q: float) -> float:
    """Nearest-rank percentile (no numpy dependency needed here)."""
    if not xs:
        return 0.0
    s = sorted(xs)
    k = max(0, min(len(s) - 1, int(round(q / 100.0 * (len(s) - 1)))))
    return s[k]


def load_episodes(trace: Dict) -> List[Dict]:
    """Rebuild per-episode records from the synthesized ``episode``
    slices: ``{trace, task, t0, t1, e2e, terminal, components}`` with
    times in seconds."""
    by_trace: Dict[int, Dict] = {}
    for ev in trace.get("traceEvents", []):
        if ev.get("cat") != "episode" or ev.get("ph") != "X":
            continue
        args = ev.get("args", {})
        tr = args.get("trace")
        if tr is None:
            continue
        rec = by_trace.setdefault(tr, {
            "trace": tr, "task": args.get("task", "?"),
            "t0": None, "t1": None,
            "terminal": args.get("terminal", "?"), "components": {}})
        ts, dur = ev["ts"] / 1e6, ev["dur"] / 1e6
        rec["t0"] = ts if rec["t0"] is None else min(rec["t0"], ts)
        rec["t1"] = (ts + dur if rec["t1"] is None
                     else max(rec["t1"], ts + dur))
        comp = rec["components"]
        comp[ev["name"]] = comp.get(ev["name"], 0.0) + dur
    out = []
    for rec in by_trace.values():
        rec["e2e"] = rec["t1"] - rec["t0"]
        total = sum(rec["components"].values())
        rec["residual"] = abs(total - rec["e2e"])
        out.append(rec)
    out.sort(key=lambda r: r["trace"])
    return out


def load_faults(trace: Dict) -> Dict:
    """Fault/recovery attribution (ISSUE 10) from the supervisor's instant
    marks: per-stage restart counts and the per-tenant circuit-breaker
    transition sequence (``<tenant>:<state>`` instants on the breaker
    thread)."""
    events = trace.get("traceEvents", [])
    # stage names live in thread_name metadata, keyed (pid, tid)
    names = {(ev.get("pid"), ev.get("tid")): ev.get("args", {}).get("name")
             for ev in events
             if ev.get("ph") == "M" and ev.get("name") == "thread_name"}
    restarts: Dict[str, int] = {}
    breaker: Dict[str, List[str]] = {}
    for ev in events:
        if ev.get("cat") != "supervisor" or ev.get("ph") != "i":
            continue
        name = ev.get("name", "")
        if ":" in name:
            tid, _, state = name.rpartition(":")
            breaker.setdefault(tid, []).append(state)
        elif name == "restart":
            stage = names.get((ev.get("pid"), ev.get("tid")), "?")
            restarts[stage] = restarts.get(stage, 0) + 1
    return {"stage_restarts": restarts,
            "breaker_transitions": {t: s for t, s in sorted(breaker.items())}}


def analyze(episodes: List[Dict]) -> Dict:
    """Per-tenant aggregation + global additivity check."""
    tenants: Dict[str, Dict] = {}
    worst_residual = 0.0
    for ep in episodes:
        t = tenants.setdefault(ep["task"], {"episodes": 0, "e2e": [],
                                            "components": {},
                                            "terminals": {}})
        t["episodes"] += 1
        t["e2e"].append(ep["e2e"])
        t["terminals"][ep["terminal"]] = t["terminals"].get(
            ep["terminal"], 0) + 1
        for name, sec in ep["components"].items():
            t["components"][name] = t["components"].get(name, 0.0) + sec
        if ep["e2e"] > 0:
            worst_residual = max(worst_residual,
                                 ep["residual"] / ep["e2e"])
    out = {"tenants": {}, "episodes": len(episodes),
           "max_relative_residual": worst_residual}
    for task, t in sorted(tenants.items()):
        comp = t["components"]
        bottleneck = (max(comp, key=comp.get) if comp else "none")
        out["tenants"][task] = {
            "episodes": t["episodes"],
            "e2e_p50": percentile(t["e2e"], 50),
            "e2e_p95": percentile(t["e2e"], 95),
            "e2e_p99": percentile(t["e2e"], 99),
            "components_mean": {k: v / t["episodes"]
                                for k, v in sorted(comp.items())},
            "bottleneck": bottleneck,
            "terminals": t["terminals"],
        }
    return out


def format_report(result: Dict) -> str:
    lines = [f"episodes: {result['episodes']}   "
             f"max component-sum residual: "
             f"{100 * result['max_relative_residual']:.3f}% of E2E"]
    faults = result.get("faults")
    if faults and (faults["stage_restarts"] or faults["breaker_transitions"]):
        lines.append("faults/recovery:")
        for stage, n in sorted(faults["stage_restarts"].items()):
            lines.append(f"  {stage}: {n} restart(s)")
        for tid, seq in faults["breaker_transitions"].items():
            lines.append(f"  breaker {tid}: {' -> '.join(seq)}")
    hdr = (f"{'tenant':20s} {'eps':>4s} {'e2e p50':>9s} {'p95':>9s} "
           f"{'p99':>9s}  bottleneck (mean seconds by component)")
    lines.append(hdr)
    lines.append("-" * len(hdr))
    for task, t in result["tenants"].items():
        comps = " ".join(f"{k}={v:.3f}"
                         for k, v in t["components_mean"].items())
        lines.append(f"{task:20s} {t['episodes']:4d} {t['e2e_p50']:9.3f} "
                     f"{t['e2e_p95']:9.3f} {t['e2e_p99']:9.3f}  "
                     f"{t['bottleneck']} [{comps}]")
    return "\n".join(lines)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="critical-path latency attribution over a trace")
    ap.add_argument("trace", help="Chrome trace-event JSON from Tracer")
    ap.add_argument("--json", default=None,
                    help="also write the aggregated report as JSON")
    args = ap.parse_args(argv)
    with open(args.trace) as f:
        trace = json.load(f)
    episodes = load_episodes(trace)
    if not episodes:
        print("no episode slices in trace (was tracing enabled?)",
              file=sys.stderr)
        return 1
    result = analyze(episodes)
    result["faults"] = load_faults(trace)
    print(format_report(result))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(result, f, indent=2)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
