"""Thread-safe, low-overhead span tracer for end-to-end episode tracing.

One ``Tracer`` instance is shared by every stage of a run — engine decode
thread, prefill workers, env workers (via the engine pump), the trainer
and the manager — and by the virtual-time simulator (inject its SimClock).
It records three kinds of events into bounded ring buffers under one
lock:

  * lifecycle **marks** — ``mark(trace, state, t)``: a single timestamped
    state transition of one episode.  Per episode the marks are
    CONTIGUOUS: the interval between consecutive marks is attributed to
    the state entered at the first of the pair, so the per-stage
    components partition submission→commit exactly and sum to the
    end-to-end latency by construction (the ±1% acceptance criterion is
    a tautology of this representation, not a measurement accident).
  * **spans** — ``span(track, name, t0, t1, ...)``: a duration on a
    (process, thread) track — one track per pool / worker / slot —
    optionally carrying incoming/outgoing flow ids that become Perfetto
    flow arrows across stage hand-offs (park→env→resume, preempt→
    reinstall).
  * **instants** — point events (e.g. a staleness drop on the manager
    track).

Design constraints (the engine hot loop calls these):

  * every hook site guards with ``if tracer is not None`` — a run
    without tracing pays one pointer compare per *episode event* (not
    per token) and allocates nothing;
  * events are stored as plain tuples appended to ``deque(maxlen=...)``
    ring buffers — no objects, no dict per event; when a buffer wraps,
    the oldest events drop and ``dropped_events`` counts them;
  * timestamps come from an injectable ``clock`` (``time.monotonic`` by
    default, the simulator's virtual clock under simulation) and callers
    on hot paths pass timestamps they already read for stats bookkeeping
    — tracing adds no extra clock calls there;
  * nothing here ever runs inside a jitted region.

The canonical lifecycle states, in the order a maximally-eventful
episode visits them (loops allowed where marked):

    submitted -> queued -> [prefill -> ready?] -> (restore|decode)
        -> { parked -> env -> resume_queued -> ... back to prefill/restore
           | preempted -> ... back to prefill/restore }*
        -> completed -> train -> committed | dropped

``export_chrome()`` renders everything as Chrome trace-event JSON
(Perfetto-loadable): real tracks for pools/workers/slots, a synthesized
``episodes`` process with one thread per trace showing the per-stage
component slices, and ``s``/``f`` flow events binding hand-offs across
threads.
"""
from __future__ import annotations

import json
import threading
import time
from collections import deque
from typing import Dict, List, Optional, Tuple

# component label charged to the interval that STARTS at each state --
# the partition of an episode's submission->commit latency. Terminal
# states (committed / dropped) start no interval.
COMPONENT_OF = {
    "submitted": "admission_wait",     # built by the driver, not yet queued
    "queued": "queue_wait",            # in the scheduler queue
    "prefill": "prefill",              # prompt/prefix (re)computation
    "ready": "splice_wait",            # prefilled, waiting for a free slot
    "restore": "restore",              # snapshot/device-page splice-back
    "decode": "decode",                # resident in a decode slot
    "parked": "env_queue_wait",        # parked, waiting for an env worker
    "env": "env",                      # tool call executing
    "resume_queued": "resume_wait",    # response ready, re-queued
    "preempted": "preempt_wait",       # vacated by preemption, re-queued
    "completed": "completed_wait",     # done, waiting for the trainer
    "train": "train",                  # inside the train step
}
TERMINAL_STATES = ("committed", "dropped")


class Tracer:
    """Ring-buffered multi-thread span/mark recorder (see module doc)."""

    def __init__(self, clock=time.monotonic, capacity: int = 1_000_000):
        self._clock = clock
        self._lock = threading.Lock()
        self._marks: deque = deque(maxlen=capacity)    # (trace, state, t)
        self._spans: deque = deque(maxlen=capacity)    # (proc, thread, name,
        #                                    t0, t1, trace, flow_in, flow_out)
        self._instants: deque = deque(maxlen=capacity)  # (proc, thread,
        #                                                  name, t, trace)
        self._traces: Dict[int, str] = {}              # trace -> task_id
        self._flow_kinds: Dict[int, str] = {}          # flow id -> kind
        self._next_trace = 0
        self._next_flow = 0
        self.dropped_events = 0
        self._capacity = capacity

    # -- recording (any thread) ------------------------------------------
    def now(self) -> float:
        return self._clock()

    def new_trace(self, task_id: str) -> int:
        with self._lock:
            tr = self._next_trace
            self._next_trace += 1
            self._traces[tr] = task_id
        return tr

    def next_flow(self, kind: str) -> int:
        """Allocate a flow id for one hand-off arrow; ``kind`` names the
        hand-off (park / resume / preempt) for structure comparisons."""
        with self._lock:
            fid = self._next_flow = self._next_flow + 1
            self._flow_kinds[fid] = kind
        return fid

    def _count_drop(self, buf) -> None:   # held: _lock
        if len(buf) >= self._capacity:
            self.dropped_events += 1

    def mark(self, trace: Optional[int], state: str,
             t: Optional[float] = None) -> None:
        if trace is None:
            return
        if t is None:
            t = self._clock()
        with self._lock:
            self._count_drop(self._marks)
            self._marks.append((trace, state, t))

    def span(self, track: Tuple[str, str], name: str, t0: float, t1: float,
             trace: Optional[int] = None, flow_in: int = 0,
             flow_out: int = 0) -> None:
        with self._lock:
            self._count_drop(self._spans)
            self._spans.append((track[0], track[1], name, t0, t1,
                                -1 if trace is None else trace,
                                flow_in, flow_out))

    def instant(self, track: Tuple[str, str], name: str,
                t: Optional[float] = None,
                trace: Optional[int] = None) -> None:
        if t is None:
            t = self._clock()
        with self._lock:
            self._count_drop(self._instants)
            self._instants.append((track[0], track[1], name, t,
                                   -1 if trace is None else trace))

    # -- snapshots (analysis / tests) ------------------------------------
    def task_of(self, trace: int) -> str:
        with self._lock:
            return self._traces.get(trace, "?")

    def flow_kind(self, fid: int) -> str:
        with self._lock:
            return self._flow_kinds.get(fid, "?")

    def marks(self) -> Dict[int, List[Tuple[float, str]]]:
        """Per-trace time-ordered ``[(t, state), ...]`` lists."""
        with self._lock:
            items = list(self._marks)
        out: Dict[int, List[Tuple[float, str]]] = {}
        for trace, state, t in items:
            out.setdefault(trace, []).append((t, state))
        for seq in out.values():
            seq.sort(key=lambda p: p[0])
        return out

    def spans(self) -> List[Tuple]:
        with self._lock:
            return list(self._spans)

    def state_sequence(self, trace: int) -> List[str]:
        """The episode's time-ordered lifecycle states (parity tests)."""
        return [s for _, s in self.marks().get(trace, [])]

    def flow_kinds_of(self, trace: int) -> List[str]:
        """Outgoing hand-off kinds of one episode, in time order."""
        out = []
        for proc, thread, name, t0, t1, tr, fin, fout in self.spans():
            if tr == trace and fout:
                out.append((t1, self.flow_kind(fout)))
        return [k for _, k in sorted(out, key=lambda p: p[0])]

    # -- export ----------------------------------------------------------
    def components(self) -> Dict[int, Dict]:
        """Per-trace additive latency decomposition, computed from the
        lifecycle marks: ``{trace: {task, t0, t1, terminal,
        components: {label: seconds}}}``. Consecutive marks partition the
        timeline, so ``sum(components.values()) == t1 - t0`` exactly."""
        out: Dict[int, Dict] = {}
        for trace, seq in self.marks().items():
            if len(seq) < 2:
                continue
            comps: Dict[str, float] = {}
            for (ta, sa), (tb, _sb) in zip(seq, seq[1:]):
                label = COMPONENT_OF.get(sa)
                if label is None:      # terminal mid-sequence: stop here
                    break
                comps[label] = comps.get(label, 0.0) + (tb - ta)
            out[trace] = {
                "task": self.task_of(trace),
                "t0": seq[0][0], "t1": seq[-1][0],
                "terminal": seq[-1][1],
                "components": comps,
            }
        return out

    def export_chrome(self) -> Dict:
        """Chrome trace-event JSON (dict) — open in https://ui.perfetto.dev.

        Layout: one process per stage group (rollout / prefill / env /
        manager / train), one thread per track (slot, worker, queue); a
        synthesized ``episodes`` process holds one thread per trace with
        the per-stage component slices; flow ``s``/``f`` pairs draw the
        park→env→resume (and preempt→reinstall) arrows."""
        with self._lock:
            spans = list(self._spans)
            instants = list(self._instants)
            traces = dict(self._traces)
            flow_kinds = dict(self._flow_kinds)
        comp = self.components()
        # common time base: trace ts are µs from the earliest event
        t_min = None
        for _, _, _, t0, _, _, _, _ in spans:
            t_min = t0 if t_min is None else min(t_min, t0)
        for info in comp.values():
            t_min = info["t0"] if t_min is None else min(t_min, info["t0"])
        for _, _, _, t, _ in instants:
            t_min = t if t_min is None else min(t_min, t)
        if t_min is None:
            t_min = 0.0

        def us(t: float) -> float:
            return round((t - t_min) * 1e6, 3)

        events: List[Dict] = []
        pids: Dict[str, int] = {}
        tids: Dict[Tuple[str, str], int] = {}

        def pid_of(proc: str) -> int:
            if proc not in pids:
                pids[proc] = len(pids) + 1
                events.append({"ph": "M", "name": "process_name",
                               "pid": pids[proc],
                               "args": {"name": proc}})
                events.append({"ph": "M", "name": "process_sort_index",
                               "pid": pids[proc],
                               "args": {"sort_index": len(pids)}})
            return pids[proc]

        def tid_of(proc: str, thread: str) -> Tuple[int, int]:
            pid = pid_of(proc)
            key = (proc, thread)
            if key not in tids:
                tids[key] = len([k for k in tids if k[0] == proc]) + 1
                events.append({"ph": "M", "name": "thread_name", "pid": pid,
                               "tid": tids[key], "args": {"name": thread}})
            return pid, tids[key]

        for proc, thread, name, t0, t1, trace, fin, fout in spans:
            pid, tid = tid_of(proc, thread)
            args = {} if trace < 0 else {"trace": trace,
                                         "task": traces.get(trace, "?")}
            events.append({"ph": "X", "cat": proc, "name": name,
                           "pid": pid, "tid": tid, "ts": us(t0),
                           "dur": max(0.001, us(t1) - us(t0)),
                           "args": args})
            if fin:
                events.append({"ph": "f", "bp": "e", "cat": "handoff",
                               "name": flow_kinds.get(fin, "flow"),
                               "id": fin, "pid": pid, "tid": tid,
                               "ts": us(t0)})
            if fout:
                events.append({"ph": "s", "cat": "handoff",
                               "name": flow_kinds.get(fout, "flow"),
                               "id": fout, "pid": pid, "tid": tid,
                               "ts": us(t1)})
        for proc, thread, name, t, trace in instants:
            pid, tid = tid_of(proc, thread)
            args = {} if trace < 0 else {"trace": trace,
                                         "task": traces.get(trace, "?")}
            events.append({"ph": "i", "cat": proc, "name": name, "pid": pid,
                           "tid": tid, "ts": us(t), "s": "t", "args": args})
        # synthesized per-episode component slices (what report.py reads)
        marks_by_trace = self.marks()
        for trace in sorted(comp):
            info = comp[trace]
            pid, tid = tid_of("episodes", f"{info['task']}#{trace}")
            seq = marks_by_trace.get(trace, [])
            for (ta, sa), (tb, _sb) in zip(seq, seq[1:]):
                label = COMPONENT_OF.get(sa)
                if label is None:
                    break
                events.append({"ph": "X", "cat": "episode", "name": label,
                               "pid": pid, "tid": tid, "ts": us(ta),
                               "dur": max(0.001, us(tb) - us(ta)),
                               "args": {"trace": trace, "task": info["task"],
                                        "state": sa,
                                        "terminal": info["terminal"]}})
        return {"traceEvents": events, "displayTimeUnit": "ms",
                "otherData": {"dropped_events": self.dropped_events,
                              "traces": len(traces)}}

    def dump_json(self, path: str) -> Dict:
        """Write the Chrome trace to ``path``; returns the exported dict."""
        doc = self.export_chrome()
        with open(path, "w") as f:
            json.dump(doc, f)
        return doc
