"""End-to-end episode observability: span tracer + Perfetto export +
critical-path latency attribution (ISSUE 9).

``Tracer`` records per-episode lifecycle marks and per-track spans from
every disaggregated stage; ``export_chrome`` renders Perfetto-loadable
JSON; ``repro.obs.report`` decomposes each episode's submission→commit
latency into additive per-stage components and names each tenant's
bottleneck stage. See ``README.md`` in this package."""
from .tracer import COMPONENT_OF, TERMINAL_STATES, Tracer

__all__ = ["Tracer", "COMPONENT_OF", "TERMINAL_STATES"]
