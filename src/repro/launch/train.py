"""Multi-tenant training service CLI.

    PYTHONPATH=src python -m repro.launch.train \
        --arch granite-3-2b --reduced --tasks 4 --steps 5 \
        --policy marlaas [--checkpoint-dir /tmp/ck] [--resume]

--reduced runs the arch's family-faithful tiny config on this host; the
full config is the production target (dry-run proven via launch.dryrun).
"""
import argparse
import dataclasses
import random

import jax

from repro.checkpoint.store import latest_checkpoint, load_checkpoint
from repro.configs import get_config, reduced
from repro.core.manager import TaskSpec
from repro.core.metrics import summarize
from repro.core.runtime import MARLaaSRuntime, RuntimeConfig
from repro.data import tokenizer as tok
from repro.envs.tasks import make_env
from repro.models import init_params

ENVS = ["gsm8k", "amc12", "search"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--tasks", type=int, default=2)
    ap.add_argument("--steps", type=int, default=3)
    ap.add_argument("--policy", default="marlaas")
    ap.add_argument("--use-kernel", action="store_true")
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=5)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--timeout", type=float, default=1800.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = dataclasses.replace(reduced(cfg, dtype="float32"),
                                  vocab_size=tok.VOCAB_SIZE)
    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    rt = MARLaaSRuntime(cfg, params, RuntimeConfig(
        policy=args.policy, max_len=64, seed=args.seed,
        use_kernel=args.use_kernel, checkpoint_dir=args.checkpoint_dir,
        checkpoint_every=(args.checkpoint_every
                          if args.checkpoint_dir else 0)))

    if args.resume and args.checkpoint_dir:
        snap = latest_checkpoint(args.checkpoint_dir)
        if snap:
            print(f"resuming from {snap}")
            load_checkpoint(snap, rt.mgr)
            for tid, st in rt.mgr.task_items():
                rt.envs[tid] = make_env(st.spec.env_name)
                rt.datagens[tid] = random.Random(args.seed + hash(tid) % 97)
    if not rt.mgr.task_items():
        for i in range(args.tasks):   # noqa: RA102 — argparse Namespace
                                      # attr, not the manager's tasks dict
            env = ENVS[i % len(ENVS)]
            rt.submit_task(TaskSpec(
                f"{env}-{i}", env, group_size=4, num_groups=1,
                max_new_tokens=6 if env != "search" else 12,
                target_steps=args.steps))

    rt.run(timeout_s=args.timeout)
    print("tasks:", {t: f"v{s.version} r={s.reward_history[-1:]}"
                     for t, s in rt.mgr.task_items()})
    print("metrics:", {k: round(v, 3)
                       for k, v in summarize(rt.mgr, rt.rec).items()})


if __name__ == "__main__":
    main()
