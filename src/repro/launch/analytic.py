"""Analytic roofline model — exact algorithmic FLOPs / HBM bytes /
collective bytes per (arch × shape × mesh) step.

WHY ANALYTIC: XLA's HloCostAnalysis visits `while` bodies ONCE — measured
on this box: a 40-layer lax.scan model reports the same flops as a 4-layer
one (experiment recorded in EXPERIMENTS.md §Roofline). Our production
stacks scan over layers, microbatches, query chunks and vocab chunks, so
raw cost_analysis() under-counts train cells by 1–2 orders of magnitude.
The roofline terms are therefore derived from the model/sharding structure
(known exactly); the compiled artifact remains the source for:
proof-of-compile, memory_analysis(), the collective op inventory, and
cross-validation on small unrolled variants where cost_analysis is exact.

Conventions:
- 2 FLOPs per MAC (consistent with MODEL_FLOPS = 6·N·D).
- collective_bytes is Σ over chips of bytes moved through each chip
  (ring algorithms): AR = 2·T_local·(g−1)/g, AG/RS/A2A = T_local·(g−1)/g,
  where T_local is the per-chip shard. The roofline then divides by
  (chips × link_bw), i.e. per-chip traffic / per-chip link bandwidth.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs import ModelConfig, ShapeConfig

BF16 = 2
F32 = 4


# ---------------------------------------------------------------------------
# forward FLOPs per token (model structure, exact)
# ---------------------------------------------------------------------------

def _layer_matmul_params(cfg: ModelConfig, kind: str) -> float:
    """Active matmul weights touched per token in one layer of `kind`."""
    d = cfg.d_model
    attn = d * cfg.q_dim + 2 * d * cfg.kv_dim + cfg.q_dim * d
    n_mats = 3 if cfg.mlp_act == "swiglu" else 2
    if kind == "dense":
        return attn + n_mats * d * cfg.d_ff
    if kind == "moe":
        m = cfg.moe
        act = (m.top_k + m.num_shared) * n_mats * d * m.expert_d_ff
        return attn + act + d * m.num_experts            # + router
    if kind in ("mamba", "mamba+attn"):
        s = cfg.ssm
        d_in = s.d_inner(d)
        nh = s.num_heads(d)
        base = d * (2 * d_in + 2 * s.n_groups * s.state_dim + nh) + d_in * d
        if kind == "mamba+attn":
            base += attn + n_mats * d * cfg.d_ff         # shared block
        return base
    raise ValueError(kind)


def fwd_flops_per_token(cfg: ModelConfig, ctx_len: float,
                        seq_mode: bool) -> float:
    """Forward FLOPs per token at (average) context ctx_len.
    seq_mode=True → sequence processing (train/prefill, SSD chunked);
    False → single-token decode (recurrent SSM step)."""
    total = 0.0
    for i in range(cfg.num_layers):
        kind = cfg.layer_kind(i)
        total += 2.0 * _layer_matmul_params(cfg, kind)
        if kind in ("dense", "moe") and cfg.num_heads:
            eff = ctx_len
            if cfg.sliding_window and not cfg.is_global_attn_layer(i):
                eff = min(ctx_len, cfg.sliding_window)
            total += 4.0 * eff * cfg.num_heads * cfg.head_dim
        elif kind == "mamba+attn":
            total += 4.0 * ctx_len * cfg.num_heads * cfg.head_dim
        if kind in ("mamba", "mamba+attn"):
            s = cfg.ssm
            nh = s.num_heads(cfg.d_model)
            state = 6.0 * nh * s.head_dim * s.state_dim   # update + output
            intra = (4.0 * s.chunk_size * nh * s.head_dim
                     if seq_mode else 0.0)                # SSD diag block
            total += state + intra
    if cfg.family == "encdec":
        # encoder over S/4 frames amortized per decoder token + cross-attn
        enc_per_tok = 0.25 * cfg.encoder_layers * (
            2.0 * _layer_matmul_params(cfg, "dense")
            + 4.0 * (ctx_len * 0.25) * cfg.num_heads * cfg.head_dim)
        xattn = cfg.num_layers * (
            2.0 * 2 * cfg.d_model * (cfg.q_dim + cfg.kv_dim)
            + 4.0 * (ctx_len * 0.25) * cfg.num_heads * cfg.head_dim)
        total += enc_per_tok + xattn
    total += 2.0 * cfg.d_model * cfg.vocab_size           # logits
    return total


# ---------------------------------------------------------------------------
# per-step analytic terms
# ---------------------------------------------------------------------------

@dataclass
class AnalyticTerms:
    flops: float
    hbm_bytes: float
    collective_bytes: float
    notes: str = ""

    def as_dict(self):
        return {"analytic_flops": self.flops,
                "analytic_hbm_bytes": self.hbm_bytes,
                "analytic_collective_bytes": self.collective_bytes,
                "analytic_notes": self.notes}


def _n_attn_layers(cfg: ModelConfig) -> int:
    return cfg._num_attn_layers()


def analytic_terms(cfg: ModelConfig, shape: ShapeConfig, chips: int,
                   dp: int, tp: int, accum: int = 1,
                   vocab_parallel_loss: bool = False) -> AnalyticTerms:
    """Terms for the *implemented* schedule (see shardings.py)."""
    B, S = shape.global_batch, shape.seq_len
    P = cfg.param_count() * BF16
    d, V = cfg.d_model, cfg.vocab_size
    kv = B * (S * cfg.state_bytes_per_token(BF16)
              + cfg.state_bytes_fixed(BF16))

    def ar_per_chip(t_local, g):
        return 2.0 * t_local * (g - 1) / g if g > 1 else 0.0

    def ag_per_chip(t_local_out, g):
        return t_local_out * (g - 1) / g if g > 1 else 0.0

    if shape.kind == "decode":
        tokens = float(B)
        f = fwd_flops_per_token(cfg, S, seq_mode=False) * tokens
        hbm = P + kv + tokens * d * BF16 * 8 * cfg.num_layers / 8
        # TP activation reductions: 2 per layer over the (tiny) token batch
        t_local = max(tokens / dp, 1) * d * BF16
        per_chip = 2 * cfg.num_layers * ar_per_chip(t_local, tp)
        # seq-sharded KV decode (kv_heads % tp != 0): partial-softmax combine
        if cfg.num_kv_heads and cfg.num_kv_heads % tp != 0:
            per_chip += _n_attn_layers(cfg) * ar_per_chip(
                max(tokens / dp, 1) * cfg.q_dim * F32, tp)
        coll = per_chip * chips
        return AnalyticTerms(f, hbm, coll,
                             "decode: HBM = params + KV; one step")

    if shape.kind == "prefill":
        tokens = float(B) * S
        f = fwd_flops_per_token(cfg, S / 2, seq_mode=True) * tokens
        act = tokens * d * BF16 * 8 * cfg.num_layers / 8
        hbm = P + kv + act
        t_local = tokens / dp * d * BF16
        per_chip = 2 * cfg.num_layers * ar_per_chip(t_local, tp)
        coll = per_chip * chips
        return AnalyticTerms(f, hbm, coll, "prefill: avg ctx S/2")

    # train (LoRA GRPO): fwd + remat-refwd + dgrad; frozen-base wgrads skipped
    tokens = float(B) * S
    f = 3.0 * fwd_flops_per_token(cfg, S / 2, seq_mode=True) * tokens
    act = tokens * d * BF16 * (2 + 10) * cfg.num_layers / 8
    hbm = 3.0 * P * accum + act          # weights stream 3× per microbatch
    t_local = tokens / dp * d * BF16
    per_chip = 2 * cfg.num_layers * ar_per_chip(t_local, tp) * 2   # fwd+bwd
    # FSDP all-gather of tp-sharded weights per microbatch, fwd + bwd
    per_chip += 2 * accum * ag_per_chip(P / tp, dp)
    # loss-side vocab matmul. UNTIED archs are structurally vocab-parallel
    # (lm_head V-sharded: LSE/target psums are [tokens]-sized). TIED archs
    # reuse embed.T, which is d-sharded → baseline all-gathers the vocab
    # matrix per microbatch; the vocab-parallel iteration (§Perf B1)
    # reshards it once per micro (all-to-all, ~P_vocab/tp per chip).
    if not cfg.tie_embeddings or vocab_parallel_loss:
        per_chip += ar_per_chip(tokens / dp * F32, tp) * 2
        if vocab_parallel_loss and cfg.tie_embeddings:
            per_chip += accum * (d * V * BF16 / tp) * (tp - 1) / tp  # a2a
    else:
        per_chip += accum * ag_per_chip(d * V * BF16 / tp * (tp - 1), tp)
    # LoRA grad all-reduce over dp (adapters are tiny)
    lora_bytes = 4e6 * F32
    per_chip += ar_per_chip(lora_bytes, dp)
    coll = per_chip * chips
    return AnalyticTerms(f, hbm, coll,
                         "train: 3×fwd (fwd+remat+dgrad); LoRA-only wgrads")
