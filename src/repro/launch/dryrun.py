import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()
# ^ MUST precede any jax import: jax locks the device count at first init.

"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture × input-shape × mesh) cell, prove the sharding config is
coherent, and extract roofline terms (deliverable g).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch granite-3-2b \
      --shape train_4k --mesh single            # one cell
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh both]  # sweep

Results append to benchmarks/results/dryrun.jsonl (one JSON per cell);
existing (arch, shape, mesh, tag) cells are skipped → resumable.
"""
import argparse
import json
import time
import traceback
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import REGISTRY, get_config, shapes_for
from repro.launch import shardings as sh
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import (model_flops_for, parse_collectives,
                                   roofline)
from repro.launch import specs as sp
from repro.train.sharding import mesh_context

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "benchmarks", "results", "dryrun.jsonl")


def _done_cells(path: str):
    done = set()
    if os.path.exists(path):
        with open(path) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    if r.get("ok"):
                        done.add((r["arch"], r["shape"], r["mesh"],
                                  r.get("tag", "base")))
                except json.JSONDecodeError:
                    pass
    return done


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             tag: str = "base", extra_env: Optional[dict] = None) -> dict:
    cfg = get_config(arch)
    shape = {s.name: s for s in shapes_for(cfg)}[shape_name]
    mesh_kind = "serve" if (tag and "servemesh" in tag) else "train"
    mesh = make_production_mesh(multi_pod=multi_pod, kind=mesh_kind)
    chips = mesh.devices.size
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "multipod" if multi_pod else "single", "chips": chips,
           "tag": tag, "ok": False}
    t0 = time.time()
    with mesh_context(mesh):
        shapes = sp.eval_shapes(cfg)
        pspec = sh.param_specs(cfg, shapes["params"], mesh)
        params_in = sh.with_shardings(shapes["params"], pspec, mesh)

        if shape.kind == "train":
            lspec = sh.lora_specs(cfg, shapes["lora"], mesh)
            ospec = sh.opt_specs(lspec)
            batch = sp.train_batch_specs(cfg, shape)
            wide = cfg.family in ("ssm", "hybrid")   # tp-replicated weights
            bspec = sh.batch_specs(batch, mesh, shape.global_batch, wide=wide)
            fn = sp.build_train_step(cfg, shape)
            args = (params_in,
                    sh.with_shardings(shapes["lora"], lspec, mesh),
                    sh.with_shardings(shapes["opt"], ospec, mesh),
                    sh.with_shardings(batch, bspec, mesh))
            lowered = jax.jit(fn, donate_argnums=(1, 2)).lower(*args)
        else:
            lsspec = sh.lora_specs(cfg, shapes["lora_stacked"], mesh,
                                   batched=True)
            serve = sp.serve_specs(cfg, shape)
            cspec = sh.cache_specs(cfg, serve["cache"], mesh,
                                   shape.global_batch)
            bsp = sh.batch_specs(
                {k: v for k, v in serve.items() if k != "cache"},
                mesh, shape.global_batch)
            adapters_in = sh.with_shardings(shapes["lora_stacked"], lsspec,
                                            mesh)
            cache_in = sh.with_shardings(serve["cache"], cspec, mesh)
            rest = sh.with_shardings(
                {k: v for k, v in serve.items() if k != "cache"}, bsp, mesh)
            if shape.kind == "prefill":
                fn = sp.build_prefill_step(cfg)
                args = [params_in, adapters_in, rest["row_ids"],
                        rest["tokens"], rest["prompt_lens"], cache_in]
                if cfg.family == "encdec":
                    args.append(rest["enc_embeds"])
                lowered = jax.jit(fn, donate_argnums=(5,)).lower(*args)
            else:
                fn = sp.build_decode_step(cfg)
                lowered = jax.jit(fn, donate_argnums=(4,)).lower(
                    params_in, adapters_in, rest["row_ids"],
                    rest["cur_tokens"], cache_in)
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)

        mem = compiled.memory_analysis()
        if mem is not None:
            for k in ("generated_code_size_in_bytes",
                      "argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "alias_size_in_bytes"):
                v = getattr(mem, k, None)
                if v is not None:
                    rec[k] = int(v)
            args_b = rec.get("argument_size_in_bytes", 0)
            temp_b = rec.get("temp_size_in_bytes", 0)
            rec["bytes_per_device"] = args_b + temp_b
        cost = compiled.cost_analysis()
        cost = cost[0] if isinstance(cost, (list, tuple)) else cost
        hlo = compiled.as_text()
        coll = parse_collectives(hlo, default_group=chips)
        rt = roofline(cost, coll, chips, model_flops_for(cfg, shape))
        rec.update({f"hlo_{k}": v for k, v in rt.as_dict().items()})
        rec["collectives"] = {k: [coll.count[k], round(v, 1)]
                              for k, v in coll.per_op.items()}

        # primary roofline: analytic terms (cost_analysis counts while
        # bodies once — see launch/analytic.py; hlo_* kept as cross-check)
        from repro.launch.analytic import analytic_terms
        from repro.launch.roofline import HBM_BW, LINK_BW, PEAK_FLOPS
        dp = chips // mesh.shape["model"]
        tp = mesh.shape["model"]
        at = analytic_terms(cfg, shape, chips, dp, tp,
                            accum=(sp.accum_steps(cfg, shape)
                                   if shape.kind == "train" else 1),
                            vocab_parallel_loss=(tag.startswith("vp")))
        rec.update(at.as_dict())
        rec["compute_s"] = at.flops / (chips * PEAK_FLOPS)
        rec["memory_s"] = at.hbm_bytes / (chips * HBM_BW)
        rec["collective_s"] = at.collective_bytes / (chips * LINK_BW)
        terms = {"compute": rec["compute_s"], "memory": rec["memory_s"],
                 "collective": rec["collective_s"]}
        rec["dominant"] = max(terms, key=terms.get)
        rec["model_flops"] = model_flops_for(cfg, shape)
        rec["useful_ratio"] = rec["model_flops"] / at.flops if at.flops else 0
        rec["roofline_frac"] = (rec["compute_s"] /
                                max(max(terms.values()), 1e-30))
        rec["ok"] = True
        rec["total_s"] = round(time.time() - t0, 1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--tag", default="base")
    ap.add_argument("--out", default=None)
    ap.add_argument("--include-paper-models", action="store_true")
    args = ap.parse_args()

    out_path = args.out or os.path.normpath(RESULTS)
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    done = _done_cells(out_path)

    cells = []
    meshes = (["single", "multipod"] if args.mesh == "both" else [args.mesh])
    if args.all:
        from repro.configs import ASSIGNED, PAPER_MODELS
        pool = ASSIGNED + (PAPER_MODELS if args.include_paper_models else ())
        for cfg in pool:
            for s in shapes_for(cfg):
                for m in meshes:
                    cells.append((cfg.name, s.name, m))
    else:
        for m in meshes:
            cells.append((args.arch, args.shape, m))

    for arch, shape, m in cells:
        key = (arch, shape, m, args.tag)
        if key in done:
            print(f"SKIP {key} (done)")
            continue
        print(f"RUN  {arch} × {shape} × {m} [{args.tag}] ...", flush=True)
        try:
            rec = run_cell(arch, shape, m == "multipod", tag=args.tag)
            print(f"  ok: compile={rec['compile_s']}s "
                  f"compute={rec['compute_s']:.3e}s mem={rec['memory_s']:.3e}s "
                  f"coll={rec['collective_s']:.3e}s dom={rec['dominant']} "
                  f"roofline_frac={rec['roofline_frac']:.2f} "
                  f"bytes/dev={rec.get('bytes_per_device', 0)/1e9:.2f}GB",
                  flush=True)
        except Exception as e:
            rec = {"arch": arch, "shape": shape, "mesh": m, "tag": args.tag,
                   "ok": False, "error": f"{type(e).__name__}: {e}",
                   "trace": traceback.format_exc()[-2000:]}
            print(f"  FAIL: {rec['error']}", flush=True)
        with open(out_path, "a") as f:
            f.write(json.dumps(rec) + "\n")


if __name__ == "__main__":
    main()
