"""input_specs + step builders for every (arch × shape) dry-run cell.

Everything here is ShapeDtypeStruct-only — no device allocation. Params,
LoRA adapters, optimizer state and caches come from jax.eval_shape over the
real init functions, so the dry-run lowers exactly the production code.

Serving cells (prefill/decode) are multi-LoRA with NUM_TENANTS adapters and
per-row task ids — the paper's §4.5 rollout configuration. The train cell is
the paper-faithful LoRA GRPO PolicyUpdate (single task, frozen base).

Modality frontends are stubs per the assignment: seamless (audio) cells take
precomputed frame embeddings [B, S_enc, d]; chameleon (vlm) consumes VQ
image tokens as ordinary ids.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig, ShapeConfig
from repro.lora.adapters import batched_ctx, init_lora, single_ctx
from repro.models import decode_step, forward_seq, init_cache, init_params, lm_logits
from repro.models.common import dtype_of
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.train_step import TrainConfig, make_train_step

NUM_TENANTS = 8          # multi-LoRA tenants in serving cells
GROUP_SIZE = 8           # GRPO group size in the train cell

# per-arch gradient-accumulation (microbatch) so remat-stored layer inputs
# fit HBM at train_4k; key: rows per microbatch. Values < 32 under-fill the
# multipod dp=32 axis (padded) — recorded in EXPERIMENTS.md §Dry-run.
MICRO_ROWS = {
    "nemotron-4-340b": 8, "qwen1.5-110b": 16, "dbrx-132b": 16,
    "chameleon-34b": 16, "gemma2-27b": 16, "qwen3-32b": 16, "qwen3-14b": 32,
    "deepseek-moe-16b": 32,
}
DEFAULT_MICRO_ROWS = 32


def _key_spec():
    return jax.ShapeDtypeStruct((2,), jnp.uint32)


def eval_shapes(cfg: ModelConfig) -> Dict[str, Any]:
    """Shape trees for params / single-task LoRA / stacked multi-LoRA / opt."""
    params = jax.eval_shape(functools.partial(init_params, cfg=cfg),
                            _key_spec())
    lora = jax.eval_shape(functools.partial(init_lora, cfg=cfg), _key_spec())

    def stacked_init(k):
        trees = [init_lora(k, cfg) for _ in range(NUM_TENANTS)]
        from repro.lora.adapters import stack_adapters
        return stack_adapters(trees)

    lora_stacked = jax.eval_shape(stacked_init, _key_spec())
    opt = jax.eval_shape(adamw_init, lora)
    return {"params": params, "lora": lora, "lora_stacked": lora_stacked,
            "opt": opt}


def accum_steps(cfg: ModelConfig, shape: ShapeConfig) -> int:
    import os
    rows = int(os.environ.get("REPRO_MICRO_ROWS", 0)) or \
        MICRO_ROWS.get(cfg.name, DEFAULT_MICRO_ROWS)
    return max(1, shape.global_batch // rows)


def maybe_remat_block(cfg: ModelConfig) -> ModelConfig:
    """Apply the REPRO_REMAT_BLOCK experiment knob (§Perf B2)."""
    import dataclasses, os
    blk = int(os.environ.get("REPRO_REMAT_BLOCK", 0))
    return dataclasses.replace(cfg, remat_block=blk) if blk else cfg


def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    R, S = shape.global_batch, shape.seq_len
    b = {
        "tokens": jax.ShapeDtypeStruct((R, S), jnp.int32),
        "prompt_lens": jax.ShapeDtypeStruct((R,), jnp.int32),
        "total_lens": jax.ShapeDtypeStruct((R,), jnp.int32),
        "rewards": jax.ShapeDtypeStruct((R,), jnp.float32),
    }
    if cfg.family == "encdec":
        b["enc_embeds"] = jax.ShapeDtypeStruct((R, S // 4, cfg.d_model),
                                               dtype_of(cfg.dtype))
    return b


def serve_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    enc_len = S // 4 if cfg.family == "encdec" else 0
    cache = jax.eval_shape(functools.partial(
        init_cache, cfg, B, S, enc_len=enc_len))
    out = {
        "cache": cache,
        "row_ids": jax.ShapeDtypeStruct((B,), jnp.int32),
    }
    if shape.kind == "prefill":
        out["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        out["prompt_lens"] = jax.ShapeDtypeStruct((B,), jnp.int32)
        if cfg.family == "encdec":
            out["enc_embeds"] = jax.ShapeDtypeStruct((B, enc_len, cfg.d_model),
                                                     dtype_of(cfg.dtype))
    else:
        out["cur_tokens"] = jax.ShapeDtypeStruct((B,), jnp.int32)
    return out


# ---------------------------------------------------------------------------
# step functions (lowered by the dry-run; same code the runtime jits)
# ---------------------------------------------------------------------------

def build_train_step(cfg: ModelConfig, shape: ShapeConfig):
    cfg = maybe_remat_block(cfg)
    tc = TrainConfig(group_size=GROUP_SIZE,
                     accum_steps=accum_steps(cfg, shape),
                     adamw=AdamWConfig())
    return make_train_step(cfg, tc)


def build_prefill_step(cfg: ModelConfig):
    def prefill_step(params, adapters, row_ids, tokens, prompt_lens, cache,
                     enc_embeds=None):
        lora = batched_ctx(adapters, row_ids, cfg)
        h, cache, _ = forward_seq(params, tokens, cfg, lora, cache,
                                  enc_embeds=enc_embeds)
        cache = dict(cache, pos=prompt_lens)
        last = jnp.take_along_axis(
            h, (prompt_lens - 1)[:, None, None].astype(jnp.int32), axis=1)[:, 0]
        return lm_logits(last, params, cfg), cache
    return prefill_step


def build_decode_step(cfg: ModelConfig):
    def serve_step(params, adapters, row_ids, cur_tokens, cache):
        lora = batched_ctx(adapters, row_ids, cfg)
        logits, cache = decode_step(params, cur_tokens, cache, cfg, lora)
        return logits, cache
    return serve_step
