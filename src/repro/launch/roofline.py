"""Roofline-term extraction from compiled dry-run artifacts (deliverable g).

  compute term    = HLO_FLOPs / (chips × peak_FLOP/s)
  memory term     = HLO_bytes / (chips × HBM_bw)
  collective term = collective_bytes / (chips × link_bw)

HLO_FLOPs / HLO_bytes come from compiled.cost_analysis(). collective_bytes
is parsed from the post-SPMD HLO text: for every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute we take the result shape,
derive the replica-group size g, apply the ring-algorithm traffic factor,
and multiply per-chip traffic by the chip count:

  all-gather       result_bytes · (g-1)/g          per chip
  reduce-scatter   input_bytes  · (g-1)/g  = result·(g-1)
  all-reduce       2 · bytes · (g-1)/g             (RS + AG)
  all-to-all       bytes · (g-1)/g
  collective-permute  bytes

TPU v5e constants: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  %all-gather.5 = bf16[2,16,512]{2,1,0} all-gather(...)
_OP_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([0-9,]*)\][^=]*?\s"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{(\{[^}]*\})")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


@dataclass
class CollectiveStats:
    per_op: Dict[str, float] = field(default_factory=dict)   # kind -> bytes/chip
    count: Dict[str, int] = field(default_factory=dict)
    total_per_chip: float = 0.0


def _shape_bytes(dtype: str, dims: str) -> float:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collectives(hlo_text: str, default_group: int) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        if "-done(" in line:
            continue                       # count async pairs once (at start)
        dtype, dims, kind = m.group(1), m.group(2), m.group(3)
        bytes_ = _shape_bytes(dtype, dims)
        g = default_group
        gm = _GROUPS_IOTA_RE.search(line)
        if gm:
            g = int(gm.group(2))
        else:
            gm2 = _GROUPS_RE.search(line)
            if gm2:
                g = max(1, gm2.group(1).count(",") + 1)
        if g <= 1:
            continue
        ring = (g - 1) / g
        if kind == "all-gather":
            traffic = bytes_ * ring                 # bytes_ = result (full)
        elif kind == "reduce-scatter":
            traffic = bytes_ * (g - 1)              # bytes_ = result (shard)
        elif kind == "all-reduce":
            traffic = 2 * bytes_ * ring
        elif kind == "all-to-all":
            traffic = bytes_ * ring
        else:                                       # collective-permute
            traffic = bytes_
        stats.per_op[kind] = stats.per_op.get(kind, 0.0) + traffic
        stats.count[kind] = stats.count.get(kind, 0) + 1
        stats.total_per_chip += traffic
    return stats


@dataclass
class RooflineTerms:
    flops: float
    bytes_accessed: float
    collective_bytes: float          # total across chips (per formula)
    chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    useful_ratio: float

    def as_dict(self) -> Dict:
        return dict(flops=self.flops, bytes_accessed=self.bytes_accessed,
                    collective_bytes=self.collective_bytes, chips=self.chips,
                    compute_s=self.compute_s, memory_s=self.memory_s,
                    collective_s=self.collective_s, dominant=self.dominant,
                    model_flops=self.model_flops,
                    useful_ratio=self.useful_ratio)


def roofline(cost: Dict, coll: CollectiveStats, chips: int,
             model_flops: float) -> RooflineTerms:
    # cost_analysis() reports the post-SPMD per-device module; scale to
    # global so the terms divide back by `chips` uniformly.
    flops = float(cost.get("flops", 0.0)) * chips
    bytes_accessed = float(cost.get("bytes accessed", 0.0)) * chips
    coll_total = coll.total_per_chip * chips
    compute_s = flops / (chips * PEAK_FLOPS)
    memory_s = bytes_accessed / (chips * HBM_BW)
    collective_s = coll_total / (chips * LINK_BW)
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    return RooflineTerms(
        flops=flops, bytes_accessed=bytes_accessed,
        collective_bytes=coll_total, chips=chips, compute_s=compute_s,
        memory_s=memory_s, collective_s=collective_s, dominant=dominant,
        model_flops=model_flops,
        useful_ratio=(model_flops / flops) if flops else 0.0)


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N·D (train) / 2·N·D (inference), N = active params."""
    N = cfg.active_param_count()
    if shape.kind == "train":
        D = shape.global_batch * shape.seq_len
        return 6.0 * N * D
    if shape.kind == "prefill":
        D = shape.global_batch * shape.seq_len
        return 2.0 * N * D
    D = shape.global_batch                     # decode: one token per row
    return 2.0 * N * D
