"""Parameter/cache/batch PartitionSpecs for the production meshes.

Rules (DESIGN.md §6), expressed over logical axes dp=('pod','data') and
tp='model' via repro.train.sharding.resolve:

  base weights   — FSDP over dp on the embed/input dim, TP over tp on the
                   heads/ff/expert dim (MaxText-style 2D sharding);
  MoE experts    — expert axis over tp (EP), d over dp;
  Mamba blocks   — FSDP only (these archs are ≤1.2B; TP of the fused
                   in_proj would split z/x/B/C/dt across shards for no win);
  LoRA adapters  — A FSDP on d_in, B TP on d_out (matches the base matmul
                   output sharding so the delta needs no extra resharding);
  KV cache       — batch over dp; kv_heads over tp when divisible, else the
                   *sequence* dim over tp (flash-decode style);
  batch arrays   — leading (row) dim over dp unless batch==1 (long-decode).

Specs are matched by tree path suffix; anything unmatched is replicated.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ModelConfig
from repro.train.sharding import resolve


def _dp(mesh: Mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _divisible(n: int, mesh: Mesh, axes) -> bool:
    if axes is None:
        return True
    axes = (axes,) if isinstance(axes, str) else axes
    k = int(np.prod([mesh.shape[a] for a in axes]))
    return n % k == 0


def _maybe(mesh: Mesh, dim_size: int, axes):
    """Use `axes` for this dim only if it divides evenly (GSPMD padding of
    uneven shards wastes memory — avoid silently)."""
    return axes if _divisible(dim_size, mesh, axes) else None


def param_specs(cfg: ModelConfig, params_shapes, mesh: Mesh):
    """PartitionSpec tree matching the params pytree (by path)."""
    dp = _dp(mesh)
    tp = "model"

    def spec_for(path: str, shape) -> P:
        nd = len(shape.shape)
        dims = shape.shape

        def mk(*axes):
            axes = axes + (None,) * (nd - len(axes))
            fixed = [_maybe(mesh, dims[i], a) for i, a in enumerate(axes)]
            return P(*fixed)

        def lead():
            """Stacked per-layer weights carry a leading L axis (nd is one
            higher); that axis is never sharded."""
            return (None,) if nd in (3, 4) else ()

        if path.endswith("embed"):
            # d-sharded: the token lookup gathers over the unsharded vocab
            # dim (GSPMD-trivial). The tied-loss contraction then all-reduces
            # per vocab chunk — revisited in §Perf for the tied archs.
            return mk(None, tp)
        if path.endswith("lm_head"):
            return mk(None, tp)
        if ("attn/" in path) or ("xattn/" in path):
            if path.endswith(("wq", "wk", "wv")):
                return mk(*lead(), dp, tp)
            if path.endswith("wo"):
                return mk(*lead(), tp, dp)
            if path.endswith(("bq", "bk", "bv")):
                return mk(*(None,) * (nd - 1), tp)
            return P()                                   # q/k norms
        if "moe/" in path and "shared/" not in path:
            if path.endswith("router"):
                return mk(None, dp, None)
            if path.endswith("w_in"):                    # [L, E, d, ff]
                return mk(None, tp, dp, None)
            if path.endswith("w_out"):                   # [L, E, ff, d]
                return mk(None, tp, None, dp)
        if path.endswith("w_in"):                        # dense/shared MLP
            return mk(*lead(), dp, tp)
        if path.endswith("w_out"):
            return mk(*lead(), tp, dp)
        if "mamba/" in path:
            if path.endswith(("in_proj", "out_proj", "conv_w")):
                return mk(*lead(), dp, None)
            return P()                                    # small vectors
        return P()                                        # norms etc.

    flat = _flatten_with_paths(params_shapes)
    spec_flat = {k: spec_for(k, v) for k, v in flat.items()}
    return _unflatten_like(params_shapes, spec_flat)


def lora_specs(cfg: ModelConfig, lora_shapes, mesh: Mesh, *,
               batched: bool = False):
    """A: FSDP on d_in; B: TP on d_out. Batched trees carry the task dim on
    axis 1 (never sharded — adapters are tiny)."""
    dp = _dp(mesh)
    tp = "model"
    off = 2 if batched else 1          # leading L (+T) axes unsharded

    def spec_for(path: str, shape) -> P:
        dims = shape.shape
        lead = (None,) * off
        if path.endswith("/a"):
            ax = _maybe(mesh, dims[off], dp)
            return P(*lead, ax, None)
        if path.endswith("/b"):
            ax = _maybe(mesh, dims[off + 1], tp)
            # ssm_in/ssm_out outputs stay replicated (mamba is FSDP-only)
            if "ssm" in path:
                ax = None
            return P(*lead, None, ax)
        return P()

    flat = _flatten_with_paths(lora_shapes)
    return _unflatten_like(lora_shapes, {k: spec_for(k, v)
                                         for k, v in flat.items()})


def cache_specs(cfg: ModelConfig, cache_shapes, mesh: Mesh, batch: int):
    dp = _dp(mesh) if batch > 1 else None
    tp = "model"

    def spec_for(path: str, shape) -> P:
        dims = shape.shape
        base = path.rsplit("/", 1)[-1]
        if base in ("k", "v", "xk", "xv"):
            # [L, B, S, KVH, hd]
            b_ax = _maybe(mesh, dims[1], dp) if dp else None
            kv_ax = _maybe(mesh, dims[3], tp)
            if kv_ax is not None:
                return P(None, b_ax, None, kv_ax, None)
            s_ax = _maybe(mesh, dims[2], tp)       # seq-sharded fallback
            return P(None, b_ax, s_ax, None, None)
        if base == "ssm":                           # [L, B, H, N, P]
            b_ax = _maybe(mesh, dims[1], dp) if dp else None
            h_ax = _maybe(mesh, dims[2], tp)
            return P(None, b_ax, h_ax, None, None)
        if base == "conv":                          # [L, B, conv_dim, W-1]
            b_ax = _maybe(mesh, dims[1], dp) if dp else None
            c_ax = _maybe(mesh, dims[2], tp)
            return P(None, b_ax, c_ax, None)
        if base == "pos":
            b_ax = _maybe(mesh, dims[0], dp) if dp else None
            return P(b_ax)
        return P()

    flat = _flatten_with_paths(cache_shapes)
    return _unflatten_like(cache_shapes, {k: spec_for(k, v)
                                          for k, v in flat.items()})


def batch_specs(batch_shapes, mesh: Mesh, batch: int, *,
                wide: bool = False):
    """wide=True shards the row dim over ALL mesh axes — used by SSM/hybrid
    archs whose block weights are FSDP-only (tp-replicated): without it,
    every tp slice redundantly computes the same tokens (§Perf C2)."""
    if wide:
        dp = tuple(mesh.axis_names) if batch > 1 else None
    else:
        dp = _dp(mesh) if batch > 1 else None

    def spec_for(path: str, shape) -> P:
        dims = shape.shape
        if not dims:
            return P()
        ax = _maybe(mesh, dims[0], dp) if dp else None
        return P(ax, *([None] * (len(dims) - 1)))

    flat = _flatten_with_paths(batch_shapes)
    return _unflatten_like(batch_shapes, {k: spec_for(k, v)
                                          for k, v in flat.items()})


def opt_specs(param_spec_tree):
    """Optimizer m/v mirror the param specs; step is replicated."""
    return {"m": param_spec_tree, "v": param_spec_tree, "step": P()}


# ---------------------------------------------------------------------------

def _flatten_with_paths(tree, prefix="") -> Dict[str, Any]:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten_with_paths(v, f"{prefix}{k}/"))
    elif hasattr(tree, "_fields"):                    # NamedTuple
        for k in tree._fields:
            v = getattr(tree, k)
            if v is not None:
                out.update(_flatten_with_paths(v, f"{prefix}{k}/"))
    elif tree is None:
        pass
    else:
        out[prefix.rstrip("/")] = tree
    return out


def _unflatten_like(tree, flat: Dict[str, Any], prefix=""):
    if isinstance(tree, dict):
        return {k: _unflatten_like(v, flat, f"{prefix}{k}/")
                for k, v in tree.items()}
    if hasattr(tree, "_fields"):
        vals = {}
        for k in tree._fields:
            v = getattr(tree, k)
            vals[k] = (None if v is None
                       else _unflatten_like(v, flat, f"{prefix}{k}/"))
        return type(tree)(**vals)
    if tree is None:
        return None
    return flat[prefix.rstrip("/")]


def to_named(tree_specs, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        tree_specs, is_leaf=lambda x: isinstance(x, P))


def with_shardings(shapes_tree, specs_tree, mesh: Mesh):
    """Attach NamedShardings to a ShapeDtypeStruct tree (for .lower)."""
    def attach(sds, spec):
        if sds is None:
            return None
        return jax.ShapeDtypeStruct(sds.shape, sds.dtype,
                                    sharding=NamedSharding(mesh, spec))
    return jax.tree.map(attach, shapes_tree, specs_tree,
                        is_leaf=lambda x: x is None or isinstance(
                            x, jax.ShapeDtypeStruct))
