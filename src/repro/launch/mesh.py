"""Production meshes. Single pod: 16×16 = 256 chips (data, model).
Multi-pod: 2×16×16 = 512 chips (pod, data, model) — the 'pod' axis carries
only hierarchical data parallelism (reduce-scatter intra-pod, cross-pod
all-reduce on scattered shards; DCN-friendly).

Defined as functions, never module-level constants: importing this module
must not touch jax device state (the dry-run pins a 512-device host platform
before any jax import).

Version compat: `jax.sharding.AxisType` (and the `axis_types=` kwarg on
`jax.make_mesh`/`AbstractMesh`) only exists in newer JAX; on the pinned
0.4.37 every axis is implicitly Auto. `make_mesh`/`make_abstract_mesh`
feature-detect and fall back, so callers never touch `AxisType` directly."""
from __future__ import annotations

import jax


def _auto_axis_types(n: int):
    """(AxisType.Auto,) * n on JAX >= 0.5, else None (0.4.x is always Auto)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return None
    return (axis_type.Auto,) * n


def make_mesh(shape, axes):
    """`jax.make_mesh` with explicit Auto axis_types where supported."""
    auto = _auto_axis_types(len(axes))
    if auto is None:
        return jax.make_mesh(tuple(shape), tuple(axes))
    return jax.make_mesh(tuple(shape), tuple(axes), axis_types=auto)


def make_abstract_mesh(shape, axes):
    """Device-less AbstractMesh across the 0.4.x / 0.5.x signature change:
    new JAX takes (axis_sizes, axis_names, axis_types=...), 0.4.37 takes a
    ((name, size), ...) shape tuple."""
    auto = _auto_axis_types(len(axes))
    if auto is None:
        return jax.sharding.AbstractMesh(tuple(zip(axes, shape)))
    return jax.sharding.AbstractMesh(tuple(shape), tuple(axes),
                                     axis_types=auto)


def make_production_mesh(*, multi_pod: bool = False, kind: str = "train"):
    """kind="train": 16×16 (balanced FSDP×TP). kind="serve": 32×8 — tp=8
    divides every assigned arch's kv_heads, so decode caches shard on the
    kv-head dim and per-row cache writes stay shard-local and in-place
    (EXPERIMENTS.md §Perf iter A3). Same 256 chips/pod either way."""
    if kind == "serve":
        shape = (2, 32, 8) if multi_pod else (32, 8)
    else:
        shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host actually has — used by tests/examples (1 device)."""
    n = len(jax.devices())
    return make_mesh((n, 1), ("data", "model"))
