"""Production meshes. Single pod: 16×16 = 256 chips (data, model).
Multi-pod: 2×16×16 = 512 chips (pod, data, model) — the 'pod' axis carries
only hierarchical data parallelism (reduce-scatter intra-pod, cross-pod
all-reduce on scattered shards; DCN-friendly).

Defined as functions, never module-level constants: importing this module
must not touch jax device state (the dry-run pins a 512-device host platform
before any jax import)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False, kind: str = "train"):
    """kind="train": 16×16 (balanced FSDP×TP). kind="serve": 32×8 — tp=8
    divides every assigned arch's kv_heads, so decode caches shard on the
    kv-head dim and per-row cache writes stay shard-local and in-place
    (EXPERIMENTS.md §Perf iter A3). Same 256 chips/pod either way."""
    if kind == "serve":
        shape = (2, 32, 8) if multi_pod else (32, 8)
    else:
        shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    auto = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, axis_types=auto)


def make_host_mesh():
    """Whatever this host actually has — used by tests/examples (1 device)."""
    n = len(jax.devices())
    auto = (jax.sharding.AxisType.Auto,) * 2
    return jax.make_mesh((n, 1), ("data", "model"), axis_types=auto)
