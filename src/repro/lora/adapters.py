"""Per-task LoRA adapters (paper §4.2: θ_t^(v)).

Tree layout (uniform across families):
  {"layers": {target: {"a": [L, d_in, r], "b": [L, r, d_out]}},
   "shared": {target: {"a": [n_inv, d_in, r], ...}}}   # hybrid only

`a` is gaussian-initialized, `b` zero-initialized → adapters start as the
identity (policy v0 == base model), which is what makes the base model the
natural KL reference policy for GRPO.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import LoRAConfig, ModelConfig
from repro.models.common import LoraCtx, dtype_of

# projection in/out dims per target name
def target_dims(cfg: ModelConfig, target: str) -> Tuple[int, int]:
    d = cfg.d_model
    if target == "attn_q":
        return d, cfg.q_dim
    if target == "attn_k" or target == "attn_v":
        return d, cfg.kv_dim
    if target == "attn_o":
        return cfg.q_dim, d
    if target == "mlp_in":
        ff = cfg.d_ff
        if cfg.moe is not None and cfg.moe.num_shared:
            ff = cfg.moe.num_shared * cfg.moe.expert_d_ff
        cols = 2 * ff if cfg.mlp_act == "swiglu" else ff
        return d, cols
    if target == "mlp_out":
        ff = cfg.d_ff
        if cfg.moe is not None and cfg.moe.num_shared:
            ff = cfg.moe.num_shared * cfg.moe.expert_d_ff
        return ff, d
    if target == "ssm_in":
        s = cfg.ssm
        d_in = s.d_inner(d)
        return d, 2 * d_in + 2 * s.n_groups * s.state_dim + s.num_heads(d)
    if target == "ssm_out":
        return cfg.ssm.d_inner(d), d
    raise ValueError(target)


def applicable_targets(cfg: ModelConfig) -> Dict[str, Tuple[str, ...]]:
    """Which configured targets apply, split by layers/shared subtree."""
    t = cfg.lora.targets
    if cfg.family == "ssm":
        layers = tuple(x for x in t if x.startswith("ssm"))
        return {"layers": layers or ("ssm_in", "ssm_out"), "shared": ()}
    if cfg.family == "hybrid":
        layers = tuple(x for x in t if x.startswith("ssm")) or ("ssm_in", "ssm_out")
        shared = tuple(x for x in t if x.startswith(("attn", "mlp")))
        return {"layers": layers, "shared": shared}
    if cfg.moe is not None:
        # adapters on attention (+ shared-expert MLP if present)
        layers = tuple(x for x in t if x.startswith("attn")
                       or (x.startswith("mlp") and cfg.moe.num_shared))
        return {"layers": layers, "shared": ()}
    layers = tuple(x for x in t if x.startswith(("attn", "mlp")))
    return {"layers": layers, "shared": ()}


def init_lora(key, cfg: ModelConfig) -> Dict[str, Any]:
    lc = cfg.lora
    dt = dtype_of(lc.dtype)
    tmap = applicable_targets(cfg)
    tree: Dict[str, Any] = {}

    def make(key, n_stack: int, target: str):
        d_in, d_out = target_dims(cfg, target)
        a = (jax.random.normal(key, (n_stack, d_in, lc.rank), jnp.float32)
             * (1.0 / np.sqrt(d_in))).astype(dt)
        b = jnp.zeros((n_stack, lc.rank, d_out), dt)
        return {"a": a, "b": b}

    if tmap["layers"]:
        tree["layers"] = {}
        for i, tgt in enumerate(tmap["layers"]):
            tree["layers"][tgt] = make(jax.random.fold_in(key, i),
                                       cfg.num_layers, tgt)
    if tmap["shared"]:
        n_inv = cfg.num_layers // cfg.hybrid_attn_every
        tree["shared"] = {}
        for i, tgt in enumerate(tmap["shared"]):
            tree["shared"][tgt] = make(jax.random.fold_in(key, 100 + i),
                                       n_inv, tgt)
    return tree


def lora_param_count(cfg: ModelConfig) -> int:
    tree = jax.eval_shape(lambda k: init_lora(k, cfg),
                          jax.ShapeDtypeStruct((2,), jnp.uint32))
    return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(tree))


def single_ctx(tree, cfg: ModelConfig) -> LoraCtx:
    return LoraCtx("single", tree, scaling=cfg.lora.scaling)


def batched_ctx(stacked_tree, row_task_ids, cfg: ModelConfig,
                use_kernel: bool = False) -> LoraCtx:
    """stacked_tree: task-stacked adapters [T, L, ...] (jnp.stack of trees)."""
    return LoraCtx("batched", stacked_tree, row_task_ids,
                   scaling=cfg.lora.scaling, use_kernel=use_kernel)


def stack_adapters(trees):
    """[{...}, {...}] -> one tree with the task dim on axis 1: leaves become
    [L, T, d, r] so per-layer slicing `leaf[i]` works identically for
    single-task ([L, d, r] -> [d, r]) and batched ([L, T, d, r] -> [T, d, r])
    modes (the model's scan body never needs to know)."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=1), *trees)


def init_stacked_buffer(tree, capacity: int):
    """Zeroed fixed-capacity stacked-LoRA buffer shaped like
    ``stack_adapters([tree] * capacity)``: leaves [L, capacity, ...].

    Zero is the identity adapter (b == 0 ⇒ delta == 0), so unoccupied /
    evicted slots are inert — a row routed at a freshly-evicted slot sees
    the base model, and a buffer rebuilt from scratch from the surviving
    tenants is bit-identical to one that reached the same occupancy through
    any install/evict interleaving (the LRU-consistency property test)."""
    return jax.tree.map(
        lambda l: jnp.zeros((l.shape[0], capacity) + l.shape[1:], l.dtype),
        tree)
