"""Batched multi-LoRA application (paper §4.5): one forward pass serves rows
belonging to *different* tenants, each with its own adapter.

`multi_lora_delta` computes   y[i] += s · (x[i] @ A[g_i]) @ B[g_i]
for per-row task ids g. Two code paths:

- reference (pure jnp): masked accumulation over tasks — O(T) dense matmuls,
  exact, used as the oracle and for tiny CPU runs.
- kernel: the Pallas SGMV grouped matmul (kernels/sgmv) — rows are sorted by
  task id outside the kernel; MXU-aligned block-diagonal compute inside.

`AdapterResidency` is the LRU map from tenants onto the fixed-capacity
stacked buffer those paths read: tenant counts ≫ slot capacity stream
through by evicting the least-recently-used *idle* tenant's adapter and
installing the newcomer in its slot (paper §4.2's shared-base +
per-tenant-LoRA model at service scale).
"""
from __future__ import annotations

from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp


def multi_lora_delta(x, a, b, row_task_ids, scaling: float,
                     use_kernel: bool = False):
    """x: [B, d] or [B, S, d]; a: [T, d, r]; b: [T, r, dout]; ids: [B]."""
    if use_kernel:
        from repro.kernels.ops import sgmv
        squeeze = False
        if x.ndim == 2:
            x3 = x[:, None, :]
            squeeze = True
        else:
            x3 = x
        B, S, d = x3.shape
        rows = x3.reshape(B * S, d)
        ids = jnp.repeat(row_task_ids, S)
        out = sgmv(rows, a, b, ids)
        out = out.reshape(B, S, -1) * scaling
        return (out[:, 0] if squeeze else out).astype(x.dtype)
    return multi_lora_delta_ref(x, a, b, row_task_ids, scaling)


def multi_lora_delta_ref(x, a, b, row_task_ids, scaling: float):
    """Masked-accumulation oracle. Exact; O(T) matmuls."""
    T = a.shape[0]
    xf = x.astype(jnp.float32)
    out = None
    for t in range(T):
        h = (xf @ a[t].astype(jnp.float32)) @ b[t].astype(jnp.float32)
        mask = (row_task_ids == t).astype(jnp.float32)
        mask = mask.reshape(mask.shape + (1,) * (x.ndim - 1))
        contrib = h * mask
        out = contrib if out is None else out + contrib
    return (out * scaling).astype(x.dtype)


class AdapterResidency:
    """LRU tenant→slot map over a fixed-capacity stacked-LoRA buffer.

    The buffer itself lives wherever `install_fn(slot, tree)` writes it
    (the continuous engine's `set_adapters`, a raw jnp buffer in tests).
    `acquire` returns the tenant's slot, installing on miss — evicting the
    least-recently-used tenant for which `in_use(tenant)` is False when the
    buffer is full. Tenants with rows resident or queued in the engine must
    be reported in-use by the caller, so queued requests never decode under
    a foreign adapter. Returns None when every slot is pinned (caller backs
    off and retries as rows complete)."""

    def __init__(self, capacity: int,
                 install_fn: Callable[[int, object], None],
                 on_evict: Optional[Callable[[str, int], None]] = None):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self.install_fn = install_fn
        self.on_evict = on_evict
        self._slot_of: Dict[str, int] = {}
        self._last_use: Dict[str, int] = {}     # tenant -> logical use time
        self._free = list(range(capacity))
        self._tick = 0
        self.installs = 0
        self.evictions = 0
        self.hits = 0

    def __contains__(self, tenant: str) -> bool:
        return tenant in self._slot_of

    def slot_of(self, tenant: str) -> Optional[int]:
        return self._slot_of.get(tenant)

    def resident(self) -> Dict[str, int]:
        return dict(self._slot_of)

    def touch(self, tenant: str):
        if tenant in self._slot_of:
            self._tick += 1
            self._last_use[tenant] = self._tick

    def evict(self, tenant: str) -> Optional[int]:
        """Explicitly drop a tenant (e.g. task finished); returns its slot."""
        slot = self._slot_of.pop(tenant, None)
        if slot is None:
            return None
        self._last_use.pop(tenant, None)
        self._free.append(slot)
        self.evictions += 1
        if self.on_evict:
            self.on_evict(tenant, slot)
        return slot

    def acquire(self, tenant: str, tree,
                in_use: Callable[[str], bool] = lambda t: False
                ) -> Optional[int]:
        if tenant in self._slot_of:
            self.hits += 1
            self.touch(tenant)
            return self._slot_of[tenant]
        if self._free:
            slot = self._free.pop(0)
        else:
            # LRU among evictable tenants; tie-break on name (deterministic)
            victims = sorted(
                (t for t in self._slot_of if not in_use(t)),
                key=lambda t: (self._last_use.get(t, 0), t))
            if not victims:
                return None
            slot = self.evict(victims[0])
            self._free.remove(slot)
        self._slot_of[tenant] = slot
        self.touch(tenant)
        self.install_fn(slot, tree)
        self.installs += 1
        return slot


def sort_rows_by_task(row_task_ids, num_tasks: int):
    """Host/device helper for the kernel path: stable sort order + per-task
    group offsets (rows of task t occupy [offsets[t], offsets[t+1]))."""
    order = jnp.argsort(row_task_ids, stable=True)
    counts = jnp.bincount(row_task_ids, length=num_tasks)
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                               jnp.cumsum(counts).astype(jnp.int32)])
    return order, offsets
