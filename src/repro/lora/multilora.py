"""Batched multi-LoRA application (paper §4.5): one forward pass serves rows
belonging to *different* tenants, each with its own adapter.

`multi_lora_delta` computes   y[i] += s · (x[i] @ A[g_i]) @ B[g_i]
for per-row task ids g. Two code paths:

- reference (pure jnp): masked accumulation over tasks — O(T) dense matmuls,
  exact, used as the oracle and for tiny CPU runs.
- kernel: the Pallas SGMV grouped matmul (kernels/sgmv) — rows are sorted by
  task id outside the kernel; MXU-aligned block-diagonal compute inside.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def multi_lora_delta(x, a, b, row_task_ids, scaling: float,
                     use_kernel: bool = False):
    """x: [B, d] or [B, S, d]; a: [T, d, r]; b: [T, r, dout]; ids: [B]."""
    if use_kernel:
        from repro.kernels.ops import sgmv
        squeeze = False
        if x.ndim == 2:
            x3 = x[:, None, :]
            squeeze = True
        else:
            x3 = x
        B, S, d = x3.shape
        rows = x3.reshape(B * S, d)
        ids = jnp.repeat(row_task_ids, S)
        out = sgmv(rows, a, b, ids)
        out = out.reshape(B, S, -1) * scaling
        return (out[:, 0] if squeeze else out).astype(x.dtype)
    return multi_lora_delta_ref(x, a, b, row_task_ids, scaling)


def multi_lora_delta_ref(x, a, b, row_task_ids, scaling: float):
    """Masked-accumulation oracle. Exact; O(T) matmuls."""
    T = a.shape[0]
    xf = x.astype(jnp.float32)
    out = None
    for t in range(T):
        h = (xf @ a[t].astype(jnp.float32)) @ b[t].astype(jnp.float32)
        mask = (row_task_ids == t).astype(jnp.float32)
        mask = mask.reshape(mask.shape + (1,) * (x.ndim - 1))
        contrib = h * mask
        out = contrib if out is None else out + contrib
    return (out * scaling).astype(x.dtype)


def sort_rows_by_task(row_task_ids, num_tasks: int):
    """Host/device helper for the kernel path: stable sort order + per-task
    group offsets (rows of task t occupy [offsets[t], offsets[t+1]))."""
    order = jnp.argsort(row_task_ids, stable=True)
    counts = jnp.bincount(row_task_ids, length=num_tasks)
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                               jnp.cumsum(counts).astype(jnp.int32)])
    return order, offsets
