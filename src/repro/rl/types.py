"""Trajectory containers flowing through Q_buffer (paper §4.2/§4.4).

A ``TrajectoryBatch`` is one task's rollout batch: prompts + generated
completions, per-token logprobs sampled under policy version ``version``,
and verifiable rewards from the environment. GRPO groups are contiguous:
rows [g*G, (g+1)*G) share a prompt.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, Optional

import numpy as np


@dataclass
class TrajectoryBatch:
    task_id: str
    version: int                 # policy version v that generated these rows
    tokens: np.ndarray           # [R, S] int32 — prompt + completion, padded
    prompt_lens: np.ndarray      # [R] int32
    total_lens: np.ndarray       # [R] int32 (prompt + completion)
    rewards: np.ndarray          # [R] float32 (verifier output)
    group_size: int              # G — rows per GRPO group
    behavior_logprobs: Optional[np.ndarray] = None  # [R, S] under π_v
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def num_rows(self) -> int:
        return int(self.tokens.shape[0])

    @property
    def num_groups(self) -> int:
        return self.num_rows // self.group_size

    def completion_mask(self) -> np.ndarray:
        """[R, S] 1.0 where position is a *generated* token (loss positions).

        Loss sits on positions predicting tokens [prompt_len, total_len):
        position j predicts token j+1, so mask[j] = prompt_len-1 <= j < total-1.
        """
        R, S = self.tokens.shape
        idx = np.arange(S)[None, :]
        lo = (self.prompt_lens - 1)[:, None]
        hi = (self.total_lens - 1)[:, None]
        return ((idx >= lo) & (idx < hi)).astype(np.float32)
