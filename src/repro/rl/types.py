"""Trajectory containers flowing through Q_buffer (paper §4.2/§4.4).

A ``TrajectoryBatch`` is one task's rollout batch: prompts + generated
completions, per-token logprobs sampled under policy version ``version``,
and verifiable rewards from the environment. GRPO groups are contiguous:
rows [g*G, (g+1)*G) share a prompt.

``RolloutCompletion`` is the unit the continuous-batching engine emits:
one finished request with its slot/timing metadata, streamed back to the
scheduler as soon as the row evicts (no round barrier). A task's round of
completions is packed into a ``TrajectoryBatch`` once all its rows arrive.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import numpy as np


@dataclass
class RolloutCompletion:
    """One finished rollout request, as evicted from a decode slot."""
    task_id: str
    prompt_len: int
    tokens: List[int]                 # prompt + completion
    gen_logprobs: List[float]         # per generated token, under π_v
    gen_loss_mask: List[float]        # 0.0 on force-fed (tool-response) tokens
    truth: Any
    env: Any
    finish_reason: str = ""           # eos|budget|capacity|turn_limit|
                                      # tool_timeout|tool_error|straggler|
                                      # aborted
    slot: int = -1                    # decode slot the row occupied
    version: int = -1                 # adapter version that generated the
                                      # row (stamped from submit meta, so it
                                      # survives park/preempt/resume) — the
                                      # behaviour version for the trainer's
                                      # staleness admission check
    sampled_tokens: int = 0           # tokens charged to max_new_tokens
    forced_tokens: int = 0            # force-fed tokens (budget-exempt)
    submit_index: int = -1            # engine-global submission order
    submitted_at: float = 0.0
    started_at: float = 0.0           # prefill/splice time (slot acquired)
    finished_at: float = 0.0          # eviction time
    finished_step: int = 0            # engine decode-step counter at eviction
    meta: Dict[str, Any] = field(default_factory=dict)

    def to_result(self) -> Dict[str, Any]:
        """The legacy per-request result dict `generate()` returns."""
        return {
            "task_id": self.task_id,
            "prompt_len": self.prompt_len,
            "tokens": list(self.tokens),
            "gen_logprobs": list(self.gen_logprobs),
            "gen_loss_mask": list(self.gen_loss_mask),
            "truth": self.truth,
            "env": self.env,
            "finish_reason": self.finish_reason,
        }


@dataclass
class TrajectoryBatch:
    task_id: str
    version: int                 # policy version v that generated these rows
    tokens: np.ndarray           # [R, S] int32 — prompt + completion, padded
    prompt_lens: np.ndarray      # [R] int32
    total_lens: np.ndarray       # [R] int32 (prompt + completion)
    rewards: np.ndarray          # [R] float32 (verifier output)
    group_size: int              # G — rows per GRPO group
    behavior_logprobs: Optional[np.ndarray] = None  # [R, S] under π_v
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def num_rows(self) -> int:
        return int(self.tokens.shape[0])

    @property
    def num_groups(self) -> int:
        return self.num_rows // self.group_size

    def completion_mask(self) -> np.ndarray:
        """[R, S] 1.0 where position is a *generated* token (loss positions).

        Loss sits on positions predicting tokens [prompt_len, total_len):
        position j predicts token j+1, so mask[j] = prompt_len-1 <= j < total-1.
        """
        R, S = self.tokens.shape
        idx = np.arange(S)[None, :]
        lo = (self.prompt_lens - 1)[:, None]
        hi = (self.total_lens - 1)[:, None]
        return ((idx >= lo) & (idx < hi)).astype(np.float32)
