"""GRPO (group-relative policy optimization) — the paper's training
algorithm (§5 "Training Algorithm"): critic-free PPO-clip with advantages
normalized within each G-sample group of the same prompt.

The loss operates on token logprobs produced by the model's training
forward; logits→logprob extraction is vocab-chunked (and has a fused Pallas
kernel, kernels/token_logprob) so the [B, S, V] softmax is never
materialized in fp32 at large vocab.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


def group_advantages(rewards, group_size: int, eps: float = 1e-4):
    """rewards: [R] with contiguous groups of `group_size`.
    A_i = (r_i - mean_group) / (std_group + eps)."""
    R = rewards.shape[0]
    g = rewards.reshape(R // group_size, group_size)
    mean = jnp.mean(g, axis=1, keepdims=True)
    std = jnp.std(g, axis=1, keepdims=True)
    return ((g - mean) / (std + eps)).reshape(R)


def token_logprobs_chunked(hidden, vocab_w, targets, logit_softcap: float = 0.0,
                           chunk: int = 1024, use_kernel: bool = False):
    """log p(targets | hidden) without materializing [B, S, V] in fp32.

    hidden: [B, S, d]; vocab_w: [d, V]; targets: [B, S] (next-token ids,
    i.e. tokens shifted left). Returns [B, S] float32 logprobs + entropy.
    """
    if use_kernel:
        from repro.kernels.ops import token_logprob
        return token_logprob(hidden, vocab_w, targets, logit_softcap)
    B, S, d = hidden.shape
    nchunks = max(1, S // chunk)
    assert S % nchunks == 0
    hs = hidden.reshape(B, nchunks, S // nchunks, d).transpose(1, 0, 2, 3)
    ts = targets.reshape(B, nchunks, S // nchunks).transpose(1, 0, 2)

    @jax.checkpoint
    def body(_, inp):
        # remat: without this the scan saves every [B, chunk, V] fp32 logits
        # tile for the backward pass (tens of GB/device at 150k+ vocabs);
        # recomputing the tile is one extra [chunk,d]×[d,V] matmul.
        h, t = inp
        logits = (h @ vocab_w.astype(h.dtype)).astype(jnp.float32)
        if logit_softcap:
            logits = jnp.tanh(logits / logit_softcap) * logit_softcap
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, t[..., None], axis=-1)[..., 0]
        p = jax.nn.softmax(logits, axis=-1)
        ent = lse - jnp.sum(p * logits, axis=-1)
        return None, (tgt - lse, ent)

    _, (lp, ent) = jax.lax.scan(body, None, (hs, ts))
    return (lp.transpose(1, 0, 2).reshape(B, S),
            ent.transpose(1, 0, 2).reshape(B, S))


class GRPOOut(NamedTuple):
    loss: jax.Array
    pg_loss: jax.Array
    kl: jax.Array
    entropy: jax.Array
    ratio_mean: jax.Array
    clip_frac: jax.Array


def grpo_loss(new_logprobs, old_logprobs, advantages, mask,
              ref_logprobs=None, *, clip_eps: float = 0.2,
              kl_coef: float = 0.0, entropy: Optional[jax.Array] = None,
              ent_coef: float = 0.0) -> GRPOOut:
    """PPO-clip objective with per-group advantages.

    new/old_logprobs: [R, S] token logprobs; advantages: [R] (broadcast over
    tokens, GRPO-style); mask: [R, S] completion mask. ref_logprobs enables
    the k3 KL penalty to the base policy (= adapter-off forward).
    """
    adv = advantages[:, None]
    log_ratio = new_logprobs - old_logprobs
    ratio = jnp.exp(log_ratio)
    unclipped = ratio * adv
    clipped = jnp.clip(ratio, 1.0 - clip_eps, 1.0 + clip_eps) * adv
    obj = jnp.minimum(unclipped, clipped)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    pg = -jnp.sum(obj * mask) / denom

    kl = jnp.zeros((), jnp.float32)
    if ref_logprobs is not None and kl_coef:
        # k3 estimator: exp(ref-new) - (ref-new) - 1  (nonnegative, unbiased)
        d = ref_logprobs - new_logprobs
        kl = jnp.sum((jnp.exp(d) - d - 1.0) * mask) / denom
    ent = (jnp.sum(entropy * mask) / denom if entropy is not None
           else jnp.zeros((), jnp.float32))
    loss = pg + kl_coef * kl - ent_coef * ent
    clip_frac = jnp.sum((jnp.abs(ratio - 1.0) > clip_eps) * mask) / denom
    return GRPOOut(loss=loss, pg_loss=pg, kl=kl, entropy=ent,
                   ratio_mean=jnp.sum(ratio * mask) / denom,
                   clip_frac=clip_frac)
