"""Rollout engine (paper §4.1/§4.4/§4.5): cross-task multi-LoRA batched
generation with agentic tool-call force-feeding.

vLLM's role in the paper, adapted to XLA's static shapes (DESIGN.md §3):
rows from *different tenants* are batched into fixed-width slots with a
per-row adapter id; decode is one jitted step; rows awaiting an external
tool response are frozen (advance=0) while the rest of the batch keeps
decoding — the intra-batch form of the paper's rollout/environment overlap.

The engine is synchronous at its API (`generate(requests)`); asynchrony
across tasks is the scheduler's job (repro.core). Tool calls are executed
through a caller-provided executor so the real runtime can run them on a
thread pool while decode proceeds.
"""
from __future__ import annotations

import dataclasses
import time
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ModelConfig
from repro.data import tokenizer as tok
from repro.envs.base import Env
from repro.lora.adapters import batched_ctx, stack_adapters
from repro.models import decode_step, forward_seq, init_cache, lm_logits
from repro.rl.types import TrajectoryBatch


@dataclass
class RolloutRequest:
    task_id: str
    adapter_index: int            # row id into the stacked adapter tree
    prompt: List[int]
    truth: object
    env: Env
    max_new_tokens: int
    temperature: float = 1.0


@dataclass
class RolloutStats:
    decode_steps: int = 0
    prefill_tokens: int = 0
    decode_seconds: float = 0.0
    env_wait_seconds: float = 0.0
    wall_seconds: float = 0.0


class RolloutEngine:
    def __init__(self, cfg: ModelConfig, base_params, *, max_len: int = 128,
                 use_kernel: bool = False, seed: int = 0):
        self.cfg = cfg
        self.base_params = base_params
        self.max_len = max_len
        self.use_kernel = use_kernel
        self._key = jax.random.PRNGKey(seed)
        self._step_fn = None
        self._prefill_fn = None

    # -- jitted kernels --------------------------------------------------
    def _build(self, num_adapters: int):
        cfg = self.cfg

        def prefill(params, adapters, row_ids, tokens, prompt_lens, cache):
            lora = batched_ctx(adapters, row_ids, cfg, self.use_kernel)
            h, cache, _ = forward_seq(params, tokens, cfg, lora, cache)
            cache = dict(cache, pos=prompt_lens)
            last = jnp.take_along_axis(
                h, (prompt_lens - 1)[:, None, None].astype(jnp.int32), axis=1)[:, 0]
            logits = lm_logits(last, params, cfg)
            return logits, cache

        def step(params, adapters, row_ids, cur_tokens, cache, key, temps,
                 forced, forced_mask, advance):
            lora = batched_ctx(adapters, row_ids, cfg, self.use_kernel)
            logits, cache = decode_step(params, cur_tokens, cache, cfg, lora,
                                        advance=advance)
            logp_all = jax.nn.log_softmax(logits, axis=-1)
            scaled = logits / jnp.maximum(temps[:, None], 1e-4)
            sampled = jax.random.categorical(key, scaled, axis=-1)
            nxt = jnp.where(forced_mask > 0, forced, sampled).astype(jnp.int32)
            lp = jnp.take_along_axis(logp_all, nxt[:, None], axis=-1)[:, 0]
            return nxt, lp, cache

        self._prefill_fn = jax.jit(prefill, donate_argnums=(5,))
        self._step_fn = jax.jit(step, donate_argnums=(4,))

    # -- main API ---------------------------------------------------------
    def generate(self, requests: Sequence[RolloutRequest], adapter_trees,
                 *, tool_executor: Optional[ThreadPoolExecutor] = None,
                 sim_latency: bool = False) -> (List[Dict], RolloutStats):
        """Run a batch of cross-task requests to completion.

        adapter_trees: list of per-task adapter trees; request.adapter_index
        selects. Returns per-request dicts (tokens/logprobs/loss_mask/...)
        and engine stats.
        """
        t_start = time.monotonic()
        cfg = self.cfg
        B = len(requests)
        if self._step_fn is None:
            self._build(len(adapter_trees))
        stacked = stack_adapters(adapter_trees)
        row_ids = jnp.asarray([r.adapter_index for r in requests], jnp.int32)
        temps = jnp.asarray([r.temperature for r in requests], jnp.float32)

        prompt_lens = np.array([len(r.prompt) for r in requests], np.int32)
        S_p = int(max(8, -(-int(prompt_lens.max()) // 8) * 8))
        tokens = np.zeros((B, S_p), np.int32)
        for i, r in enumerate(requests):
            tokens[i, :len(r.prompt)] = r.prompt

        cache = init_cache(cfg, B, self.max_len,
                           enc_len=8 if cfg.family == "encdec" else 0)
        stats = RolloutStats(prefill_tokens=int(prompt_lens.sum()))
        t0 = time.monotonic()
        logits, cache = self._prefill_fn(self.base_params, stacked, row_ids,
                                         jnp.asarray(tokens),
                                         jnp.asarray(prompt_lens), cache)
        jax.block_until_ready(logits)
        stats.decode_seconds += time.monotonic() - t0

        # host-side per-row state
        gen: List[List[int]] = [[] for _ in range(B)]
        lps: List[List[float]] = [[] for _ in range(B)]
        lmask: List[List[float]] = [[] for _ in range(B)]
        status = ["active"] * B                       # active|calling|done
        forced_q: List[List[int]] = [[] for _ in range(B)]
        pending: Dict[int, Future] = {}
        pending_t0: Dict[int, float] = {}
        own_pool = tool_executor is None
        pool = tool_executor or ThreadPoolExecutor(max_workers=4)
        rng = np.random.RandomState(int(self._key[1]) % (2**31))

        # sample the first token from prefill logits
        self._key, sk = jax.random.split(self._key)
        first = jax.random.categorical(
            sk, logits / jnp.maximum(temps[:, None], 1e-4), axis=-1)
        first_lp = jnp.take_along_axis(jax.nn.log_softmax(logits, -1),
                                       first[:, None], axis=-1)[:, 0]
        first = np.asarray(first)
        first_lp = np.asarray(first_lp)
        cur = np.zeros((B,), np.int32)
        for i, r in enumerate(requests):
            self._accept_token(i, int(first[i]), float(first_lp[i]), 1.0,
                               requests, gen, lps, lmask, status, forced_q,
                               pending, pending_t0, pool, tokens, rng,
                               sim_latency, stats)
            cur[i] = int(first[i])

        max_steps = max(r.max_new_tokens for r in requests) + 48
        steps_done = 0
        wall_deadline = time.monotonic() + 120.0
        while steps_done < max_steps and time.monotonic() < wall_deadline:
            if all(s == "done" for s in status):
                break
            # resolve finished tool calls
            for i in list(pending):
                if pending[i].done():
                    resp = pending[i].result()
                    stats.env_wait_seconds += time.monotonic() - pending_t0[i]
                    forced_q[i] = [tok.RESP] + list(resp) + [tok.ENDRESP]
                    status[i] = "active"
                    del pending[i], pending_t0[i]
            advance = np.array([1 if status[i] in ("active",) else 0
                                for i in range(B)], np.int32)
            if advance.sum() == 0:
                # waiting only on external tools — does not consume the
                # decode-step budget (straggler guard is the wall deadline)
                time.sleep(0.001)
                continue
            steps_done += 1
            forced = np.zeros((B,), np.int32)
            fmask = np.zeros((B,), np.int32)
            for i in range(B):
                if status[i] == "active" and forced_q[i]:
                    forced[i] = forced_q[i][0]
                    fmask[i] = 1
            self._key, sk = jax.random.split(self._key)
            t0 = time.monotonic()
            nxt, lp, cache = self._step_fn(
                self.base_params, stacked, row_ids, jnp.asarray(cur), cache,
                sk, temps, jnp.asarray(forced), jnp.asarray(fmask),
                jnp.asarray(advance))
            nxt = np.asarray(nxt)
            lp = np.asarray(lp)
            stats.decode_seconds += time.monotonic() - t0
            stats.decode_steps += 1
            for i in range(B):
                if status[i] != "active" or advance[i] == 0:
                    continue
                was_forced = fmask[i] == 1
                if was_forced:
                    forced_q[i].pop(0)
                self._accept_token(i, int(nxt[i]), float(lp[i]),
                                   0.0 if was_forced else 1.0,
                                   requests, gen, lps, lmask, status,
                                   forced_q, pending, pending_t0, pool,
                                   tokens, rng, sim_latency, stats)
                cur[i] = int(nxt[i])

        # timed-out tool calls: cancel
        for i in pending:
            status[i] = "done"
        if own_pool:
            pool.shutdown(wait=False)

        results = []
        for i, r in enumerate(requests):
            results.append({
                "task_id": r.task_id,
                "prompt_len": int(prompt_lens[i]),
                "tokens": list(tokens[i, :prompt_lens[i]]) + gen[i],
                "gen_logprobs": lps[i],
                "gen_loss_mask": lmask[i],
                "truth": r.truth,
                "env": r.env,
            })
        stats.wall_seconds = time.monotonic() - t_start
        return results, stats

    # ------------------------------------------------------------------
    def _accept_token(self, i, token, lp, mask, requests, gen, lps, lmask,
                      status, forced_q, pending, pending_t0, pool, tokens,
                      rng, sim_latency, stats):
        r = requests[i]
        gen[i].append(token)
        lps[i].append(lp)
        lmask[i].append(mask)
        if token == tok.EOS or len(gen[i]) >= r.max_new_tokens + 32:
            status[i] = "done"
            return
        if token == tok.CALL and r.env.is_agentic and mask == 1.0:
            status[i] = "calling"
            query = list(tokens[i, :len(r.prompt)]) + gen[i]
            latency = r.env.sample_env_latency(
                _RandomShim(rng)) if not sim_latency else 0.0

            def run_tool(q=query, env=r.env, lat=latency, truth=r.truth):
                if lat > 0:
                    time.sleep(lat)
                return env.tool_call(q, truth)

            pending[i] = pool.submit(run_tool)
            pending_t0[i] = time.monotonic()
        elif len(gen[i]) >= r.max_new_tokens and not forced_q[i]:
            status[i] = "done"


class _RandomShim:
    """random.Random-compatible gauss() over a numpy RandomState."""
    def __init__(self, rs):
        self.rs = rs

    def gauss(self, mu, sigma):
        return float(self.rs.normal(mu, sigma))


def to_trajectory_batch(results: List[Dict], task_id: str, version: int,
                        group_size: int, pad_to: int = None) -> TrajectoryBatch:
    """Pack engine results for ONE task into a padded TrajectoryBatch and
    verify rewards."""
    rows = [r for r in results if r["task_id"] == task_id]
    S = max(len(r["tokens"]) for r in rows)
    if pad_to:
        S = max(S, pad_to)
    S = -(-S // 8) * 8
    R = len(rows)
    tokens = np.zeros((R, S), np.int32)
    loss_mask = np.ones((R, S), np.float32)
    behavior = np.zeros((R, S), np.float32)
    p_lens = np.zeros((R,), np.int32)
    t_lens = np.zeros((R,), np.int32)
    rewards = np.zeros((R,), np.float32)
    for j, r in enumerate(rows):
        n = len(r["tokens"])
        tokens[j, :n] = r["tokens"]
        p_lens[j] = r["prompt_len"]
        t_lens[j] = n
        gen_len = n - r["prompt_len"]
        # behavior logprobs/losses sit at positions predicting each gen token
        for k in range(gen_len):
            pos = r["prompt_len"] - 1 + k
            behavior[j, pos] = r["gen_logprobs"][k]
            loss_mask[j, pos] = r["gen_loss_mask"][k]
        comp = r["tokens"][r["prompt_len"]:]
        rewards[j] = r["env"].verify(r["truth"], comp)
    return TrajectoryBatch(task_id=task_id, version=version, tokens=tokens,
                           prompt_lens=p_lens, total_lens=t_lens,
                           rewards=rewards, group_size=group_size,
                           behavior_logprobs=behavior[:, :S - 1],
                           meta={"loss_mask": loss_mask})
