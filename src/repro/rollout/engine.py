"""Rollout engines (paper §4.1/§4.4/§4.5): cross-task multi-LoRA batched
generation with agentic tool-call force-feeding.

Two engines share one set of jitted kernels and one per-row sampling rule:

``RolloutEngine.generate()`` — the round-fused baseline. One fixed batch
runs to completion; every row waits for the slowest before the next round
can start. This is the barrier MARLaaS measures against (§4.1).

``ContinuousRolloutEngine`` — the slot model. A persistent pool of
``max_slots`` decode slots holds rows from *any* tenant, each tagged with a
per-slot adapter id into a fixed-capacity stacked-LoRA buffer. Decode is
one jitted step over the pool and never drains: the moment a row finishes
(EOS / sampled budget / cache capacity) it is evicted, its
``RolloutCompletion`` streams back to the scheduler, and freed slots are
filled from a cross-task request queue. Two fill paths (Fig 5):

  fused (default)       — prefill of the incoming rows runs as its own
    jitted call ON THE DECODE STREAM (batched over every slot freed that
    step) whose KV/SSM state and sampled first tokens are spliced into the
    running pool. A long prompt stalls decode for every resident tenant —
    this stall is booked as ``stats.decode_stall_seconds``.
  disaggregated (``disagg_prefill=True``) — ``prefill_workers`` async
    worker threads (rollout/prefill.py) pop the SAME scheduler-ordered
    queue, run (optionally ``prefill_chunk``-chunked) prefill on their own
    caches, and emit ready row states; the decode stream installs them
    with a scatter-only jitted splice (``_build_splice_fn``). Decode never
    executes a prefill graph: ``decode_stall_seconds`` stays 0 while
    prefills are in flight, and outputs are bit-identical to the fused
    path (same forward math, same per-row sampling rule).

Agentic rows and the environment-interaction stage (two modes):

  freeze-in-slot (default baseline) — a row that emits ``tok.CALL`` keeps
    its decode slot with advance=0 for the whole env latency while the
    rest of the pool decodes; every such frozen step is booked as
    ``stats.tool_wait_slot_steps`` (the dead weight Fig 5 is about).
  env stage (``env_stage=True``) — the row is PARKED instead: its
    generated prefix is already host-side (the preemption snapshot), so
    the slot is vacated and instantly refilled from the scheduler queue
    while an EnvWorker (rollout/env_stage.py) runs the tool call. The
    response turns into a resume job: the row re-enters the scheduler
    queue with its force-feed queue pre-loaded and flows through the
    ordinary (fused or disaggregated) prefill path — prefix replay plus a
    FORCED first token (the RESP opener) — then splices back. No decode
    slot is ever occupied by an I/O-waiting row
    (``tool_wait_slot_steps == 0`` by construction), and the token stream
    is bit-identical to freeze-in-slot given the same tool responses.

Multi-turn episodes: each agentic row owns one stateful ``ToolSession``
(created at its first call, carried across park/preempt/replay) and a turn
budget (``request.max_turns``, default ``env.max_turns``; 0 = unlimited).
A CALL sampled with the budget spent ends the episode
(``finish_reason="turn_limit"``).

Paged KV cache + snapshot/restore resume (``paged_kv=True``, ISSUE 5):
attention K/V lives in a SHARED block pool of ``kv_pool_pages`` fixed-size
pages (``kv_page_size`` tokens each; rollout/kvcache.py owns the free
list, ``models.init_paged_cache`` lays out the device side, and decode
reads pages through per-slot block tables — the Pallas
``kernels/paged_decode.py`` kernel under ``use_kernel``). A slot holds
``ceil(len/page)`` pages instead of a ``max_len`` reservation, growing
one page at a time as it decodes; a row the pool cannot serve finishes
via cache-capacity eviction (never a crash). Park (env stage) and
preemption SNAPSHOT the row's live pages + SSM/conv state to host
(``resume_restore``), and resume SPLICES them back — no prefill replay, so
an N-turn agentic episode stops paying O(N·len) recomputation
(``stats.replay_tokens_saved``; ``stats.restores`` vs ``stats.replays``).
A snapshot dropped under ``snapshot_budget_bytes`` pressure falls back to
the RETAINED token-replay path — output is token-for-token identical
either way (property-tested across attention/SSM/hybrid, both fill
paths, preempt-at-any-turn).

Copy-on-write prefix cache (``prefix_cache=True``, default with paged
KV; ISSUE 8). Pages are shared at three levels over the ref-counted
pool, pure-attention families only (SSM/hybrid degrade to the private
behaviour above):

  GRPO-group sharing — same-``(tenant, prompt)`` rows are recognized in
    the queue: the leader prefills privately and publishes its prompt
    pages (full pages + the exact-remainder tail) to the per-tenant
    ``PrefixIndex``; siblings install via ``_radix_fill_rows`` with ZERO
    prompt writes — every page retained, the final chunk recomputed only
    for the first-token logits. The first decode write past the shared
    boundary hits a page with refcount > 1 and ``_ensure_decode_pages``
    COW-forks just that page (``stats.cow_forks``); earlier pages stay
    shared for the group's lifetime.
  device-resident snapshots — park/preempt of an in-pool row moves page
    OWNERSHIP from the slot to the row (pure retain, zero host bytes for
    attention; hybrid recurrent rows still snapshot) and resume is a
    block-table splice (``stats.device_resident_resumes``). The host
    ``KVSnapshot`` arena is demoted to a spill tier: under pool pressure
    ``_alloc_pages`` evicts cold radix entries, then spills the oldest
    device-parked row to host (or token replay).
  radix prefix reuse — any new request or tool-turn resume matches its
    longest cached page-aligned prefix and prefills only the suffix
    (``stats.prefix_hits`` / ``prefix_hit_tokens``; ``prefill_tokens``
    drops by exactly the matched length).

Response-prefill fusion (paged mode): a replay-path resume folds its
forced RESP…ENDRESP block into the one (re)prefill call — forced
logprobs gathered from the prefill logits, ``stats.fused_forced_tokens``
— instead of force-feeding one decode step per token; restore-mode
resumes never prefill at all, which subsumes it. Token streams are
bit-identical on every path (``tests/test_prefix_cache.py``), and
``check_page_invariants`` asserts exact refcount conservation across
slots, device-parked rows, and radix nodes.

Determinism: sampling is per-row — each request carries a base PRNG key
(``fold_in(master, request.seed or submit-index)``) folded with the row's
own generated-token count. A row's tokens therefore depend only on its own
(key, prefix), never on batch layout, so continuous-mode output matches
one-shot ``generate()`` token-for-token for families without cross-row
coupling (dense/hybrid; dropping-MoE capacity is batch-global).

Budget: only *sampled* tokens (loss_mask == 1) count against
``max_new_tokens``; force-fed tool-response tokens are budget-exempt, so a
long tool response cannot terminate a row before it samples its answer.

Preemption protocol (admission-driven, paper §4.3): ``preempt_slots`` /
``preempt_tenant`` evict *resident* rows mid-decode. A victim's generated
prefix lives entirely on the host (``_Row.gen``/``lps``/``lmask``), so
preemption is free of device copies: the slot is simply marked empty and
the row re-queued. When the scheduler later pops it, the refill call
prefill-replays ``prompt + gen`` as one sequence and samples the *next*
token with counter ``len(gen)`` — exactly the (key, counter) the
uninterrupted run would have used — so a row preempted at any decode step
finishes with bit-identical tokens/logprobs. Rows awaiting a tool response
or mid force-feed are not preemptible (a replayed first token is always
sampled, never forced); they keep their slot until the forced queue
drains. Queue pop order is pluggable (``scheduler=``):
shortest-predicted-remaining with priority tiers and a starvation bound
(default), or FIFO — see ``rollout/scheduler.py``.
"""
from __future__ import annotations

import time
from collections import deque
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional, Sequence, Tuple

import threading

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ModelConfig
from repro.data import tokenizer as tok
from repro.core.supervisor import StageSupervisor
from repro.envs.base import CancelToken, Env, ToolError, call_session
from repro.lora.adapters import batched_ctx, init_stacked_buffer, stack_adapters
from repro.models import (decode_step, forward_prefill_chunk, forward_seq,
                          init_cache, init_paged_cache, lm_logits)
from repro.rl.types import RolloutCompletion, TrajectoryBatch
from repro.rollout.env_stage import EnvStage
from repro.rollout.kvcache import (KVSnapshot, PagePool, PrefixIndex,
                                   SnapshotStore, pages_for)
from repro.rollout.prefill import (PrefillKernels, PrefillWorker, ReadyRow,
                                   _bucket_len, _sample_rows, effective_chunk)
from repro.rollout.scheduler import LengthPredictor, SlotScheduler


@dataclass
class RolloutRequest:
    task_id: str
    adapter_index: int            # row id into the stacked adapter tree
    prompt: List[int]
    truth: object
    env: Env
    max_new_tokens: int
    temperature: float = 1.0
    seed: Optional[int] = None    # per-row key = fold_in(master, seed)
                                  # (defaults to batch/submission index)
    priority: int = 0             # scheduler tier: higher pops first and is
                                  # never chosen as a preemption victim over
                                  # a lower tier
    max_turns: Optional[int] = None   # tool-turn budget for this episode
                                      # (None -> env.max_turns; 0 = unlimited)


@dataclass
class RolloutStats:
    decode_steps: int = 0
    prefill_tokens: int = 0
    decode_seconds: float = 0.0     # decode-stage device time ONLY (the
                                    # per-stage split is load-bearing for the
                                    # Fig-5 utilization metrics)
    prefill_seconds: float = 0.0    # prefill-stage device time (fused refill
                                    # OR async prefill-worker calls)
    env_wait_seconds: float = 0.0
    wall_seconds: float = 0.0
    # continuous-engine extras (zero for round-fused generate())
    prefills: int = 0
    refills: int = 0
    completions: int = 0
    tokens_generated: int = 0
    sampled_tokens: int = 0
    occupied_row_steps: int = 0    # Σ over decode steps of advanced rows
    capacity_row_steps: int = 0    # decode_steps × max_slots
    preemptions: int = 0           # rows evicted mid-decode and re-queued
    replays: int = 0               # preempted rows re-prefilled into a slot
    replay_tokens: int = 0         # prompt+prefix tokens re-processed
    # disaggregated-prefill extras
    splices: int = 0               # ready rows scatter-installed into slots
    splice_seconds: float = 0.0    # decode-side scatter time (≪ prefill)
    splice_wait_seconds: float = 0.0    # Σ (install time - prefill-ready
                                        # time): hand-off latency between
                                        # the two stages (slot availability)
    prefill_chunks: int = 0        # prefill device calls (≥ rows prefilled)
    decode_stall_seconds: float = 0.0   # prefill-stage work executed ON the
                                        # decode stream (fused refill); 0 by
                                        # construction when disaggregated
    # environment-interaction stage extras
    parks: int = 0                 # rows vacated from their slot on CALL
    resumes: int = 0               # tool responses turned into resume jobs
    tool_errors: int = 0           # episodes finished by a permanent tool
                                   # failure / exhausted retry budget
                                   # (finish_reason "tool_error")
    # paged-KV / snapshot-restore extras (rollout/kvcache.py)
    restores: int = 0              # rows resumed by splicing saved KV pages
                                   # back (NO prefill replay ran)
    replay_tokens_saved: int = 0   # prompt+prefix tokens a replay would
                                   # have re-prefilled but restore skipped
    snapshots: int = 0             # park/preempt snapshots taken to host
    snapshot_drops: int = 0        # snapshots rejected under host memory
                                   # pressure (row fell back to replay)
    pool_exhausted: int = 0        # rows finished by cache-capacity
                                   # eviction when the page pool ran dry
    # prefix-cache extras (ISSUE 8: COW page sharing, rollout/kvcache.py)
    prefix_hits: int = 0           # rows installed off a radix/trie match
                                   # (retained prefix pages, suffix-only
                                   # prefill)
    prefix_hit_tokens: int = 0     # prefix tokens those hits did NOT
                                   # re-prefill (prefill_tokens drops by
                                   # exactly this much)
    cow_forks: int = 0             # shared pages privatized on first
                                   # decode write (alloc + 1-page copy)
    device_resident_resumes: int = 0   # park/preempt resumes whose KV
                                       # pages never left the pool (pure
                                       # retain; zero host snapshot bytes)
    fused_forced_tokens: int = 0   # forced RESP…ENDRESP tokens folded into
                                   # a resume's prefill call instead of one
                                   # decode step each (response fusion)
    tool_wait_slot_steps: int = 0  # Σ over decode steps of resident rows
                                   # frozen on a tool wait — the slot dead
                                   # weight env_stage drives to 0 by
                                   # construction
    env_wait_by_task: Dict[str, float] = field(default_factory=dict)
                                   # per-tenant env-interaction wait seconds

    def add_env_wait(self, task_id: str, wait: float):
        """Book one resolved tool call's wait (global + per-tenant)."""
        self.env_wait_seconds += wait
        self.env_wait_by_task[task_id] = (
            self.env_wait_by_task.get(task_id, 0.0) + wait)

    def slot_utilization(self) -> float:
        if self.capacity_row_steps <= 0:
            return 0.0
        return self.occupied_row_steps / self.capacity_row_steps


def _decode_sample_core(cfg, use_kernel, params, adapters, row_ids,
                        cur_tokens, cache, keys, counters, temps, forced,
                        forced_mask, advance):
    """The one decode-step body BOTH engines jit — identical math is what
    keeps continuous output token-for-token equal to one-shot output."""
    lora = batched_ctx(adapters, row_ids, cfg, use_kernel)
    logits, cache = decode_step(params, cur_tokens, cache, cfg, lora,
                                advance=advance, use_kernel=use_kernel)
    logp_all = jax.nn.log_softmax(logits, axis=-1)
    sampled = _sample_rows(logits, keys, counters, temps)
    nxt = jnp.where(forced_mask > 0, forced, sampled).astype(jnp.int32)
    lp = jnp.take_along_axis(logp_all, nxt[:, None], axis=-1)[:, 0]
    return nxt, lp, cache


def _build_fns(cfg: ModelConfig, use_kernel: bool):
    """The three jitted kernels of the round-fused engine."""

    def prefill(params, adapters, row_ids, tokens, prompt_lens, cache):
        lora = batched_ctx(adapters, row_ids, cfg, use_kernel)
        h, cache, _ = forward_seq(params, tokens, cfg, lora, cache,
                                  seq_lens=prompt_lens)
        cache = dict(cache, pos=prompt_lens)
        last = jnp.take_along_axis(
            h, (prompt_lens - 1)[:, None, None].astype(jnp.int32), axis=1)[:, 0]
        logits = lm_logits(last, params, cfg)
        return logits, cache

    def first(logits, keys, counters, temps):
        sampled = _sample_rows(logits, keys, counters, temps)
        lp = jnp.take_along_axis(jax.nn.log_softmax(logits, -1),
                                 sampled[:, None], axis=-1)[:, 0]
        return sampled.astype(jnp.int32), lp

    def step(params, adapters, row_ids, cur_tokens, cache, keys, counters,
             temps, forced, forced_mask, advance):
        return _decode_sample_core(cfg, use_kernel, params, adapters,
                                   row_ids, cur_tokens, cache, keys,
                                   counters, temps, forced, forced_mask,
                                   advance)

    return (jax.jit(prefill, donate_argnums=(5,)), jax.jit(first),
            jax.jit(step, donate_argnums=(4,)))


def _build_cont_step_fn(cfg: ModelConfig, use_kernel: bool):
    """Continuous-engine decode step with device-resident row state: cur
    tokens and per-row counters are carried through the call (frozen/empty
    lanes keep their previous token), so the host uploads nothing per step
    beyond the occasionally-changing advance/forced masks."""

    def step(params, adapters, row_ids, cur_tokens, cache, keys, counters,
             temps, forced, forced_mask, advance):
        nxt, lp, cache = _decode_sample_core(cfg, use_kernel, params,
                                             adapters, row_ids, cur_tokens,
                                             cache, keys, counters, temps,
                                             forced, forced_mask, advance)
        nxt = jnp.where(advance > 0, nxt, cur_tokens)
        return nxt, lp, cache, counters + advance

    return jax.jit(step, donate_argnums=(3, 4, 6))


def _build_refill_fn(cfg: ModelConfig, use_kernel: bool, max_len: int):
    """ONE jitted call that prefills a batch of incoming rows on a fresh
    width-k cache, samples their first tokens (counter 0), and splices every
    row's KV/SSM state into the persistent pool at its target slot.

    Ghost rows (queue shorter than the padded width) carry slot index ==
    pool size: their scatters are out of bounds and XLA drops them, so the
    call has a single static shape per (width, prompt-bucket) and the refill
    path costs one dispatch regardless of how many slots freed this step.
    The pool's device-resident row state (cur/counters/keys/temps/row_ids)
    is updated in the same call.

    `init_counters` is the per-row sampling counter for the token sampled
    off the prefill logits: 0 for fresh rows, `len(gen)` for
    preemption-replayed rows (whose `tokens` are prompt + generated prefix)
    — the replayed row's next token therefore uses the identical
    fold_in(key, counter) an uninterrupted run would have.

    `forced`/`forced_mask` override the sampled first token for env-stage
    resume rows: the prefix ends in CALL, so the installed token is the
    forced RESP opener with its logprob read off the same final-position
    logits — exactly what the freeze-in-slot baseline records when it
    feeds CALL through a decode step."""

    def refill(params, adapters, tokens, prompt_lens, init_counters, slots,
               new_row_ids, new_keys, new_temps, forced, forced_mask, cache,
               cur, counters, keys, temps, row_ids):
        pcache = init_cache(cfg, tokens.shape[0], max_len,
                            enc_len=8 if cfg.family == "encdec" else 0)
        lora = batched_ctx(adapters, new_row_ids, cfg, use_kernel)
        h, pcache, _ = forward_seq(params, tokens, cfg, lora, pcache,
                                   seq_lens=prompt_lens)
        pcache = dict(pcache, pos=prompt_lens)
        last = jnp.take_along_axis(
            h, (prompt_lens - 1)[:, None, None].astype(jnp.int32), axis=1)[:, 0]
        logits = lm_logits(last, params, cfg)
        sampled = _sample_rows(logits, new_keys, init_counters, new_temps)
        first = jnp.where(forced_mask > 0, forced, sampled).astype(jnp.int32)
        lp = jnp.take_along_axis(jax.nn.log_softmax(logits, -1),
                                 first[:, None], axis=-1)[:, 0]
        out = {}
        for name in cache:
            if cache[name].ndim == 1:              # "pos": [B]
                out[name] = cache[name].at[slots].set(pcache[name])
            else:                                   # [L, B, ...]
                out[name] = cache[name].at[:, slots].set(pcache[name])
        state = (cur.at[slots].set(first),
                 counters.at[slots].set(init_counters + 1),
                 keys.at[slots].set(new_keys),
                 temps.at[slots].set(new_temps),
                 row_ids.at[slots].set(new_row_ids))
        return first, lp, out, state

    return jax.jit(refill, donate_argnums=(11, 12, 13, 14, 15, 16))


def _build_splice_fn(cfg: ModelConfig):
    """Scatter-ONLY install of one prefilled row into the persistent pool
    (the decode half of the disaggregated split): copies every cache leaf of
    the ready row's width-1 prefill cache into the pool at `slot` and
    updates the device-resident row state. No forward pass, no prefill
    graph — the decode stream pays one cheap scatter per incoming row
    instead of the whole prompt."""

    def splice(cache, pcache, slot, seq_len, first, init_counter, key, temp,
               row_id, cur, counters, keys, temps, row_ids):
        out = {}
        for name in cache:
            if cache[name].ndim == 1:              # "pos": [B]
                out[name] = cache[name].at[slot].set(seq_len)
            else:                                   # [L, B, ...]
                out[name] = cache[name].at[:, slot].set(pcache[name][:, 0])
        state = (cur.at[slot].set(first),
                 counters.at[slot].set(init_counter + 1),
                 keys.at[slot].set(key),
                 temps.at[slot].set(temp),
                 row_ids.at[slot].set(row_id))
        return out, state

    return jax.jit(splice, donate_argnums=(0, 9, 10, 11, 12, 13))


def _paged_scatter(cfg: ModelConfig, cache, pcache_k, pcache_v, dest_pages,
                   page: int):
    """Scatter a dense prefill scratch cache's K/V ([L, W, S, KVH, hd],
    S % page == 0) into the shared page pool at the physical pages named
    by ``dest_pages`` [W, S//page] (sentinel entries land on the scratch
    page and are effectively dropped). Returns (kp', vp')."""
    L, W, S, KVH, hd = pcache_k.shape
    n_chunks = S // page
    src_k = pcache_k.reshape(L, W * n_chunks, page, KVH, hd)
    src_v = pcache_v.reshape(L, W * n_chunks, page, KVH, hd)
    dest = dest_pages.reshape(W * n_chunks)
    return (cache["kp"].at[:, dest].set(src_k.astype(cache["kp"].dtype)),
            cache["vp"].at[:, dest].set(src_v.astype(cache["vp"].dtype)))


def _build_refill_fn_paged(cfg: ModelConfig, use_kernel: bool, max_len: int,
                           page: int):
    """Paged twin of ``_build_refill_fn``: the batched prefill still runs
    on a dense width-k SCRATCH cache (prefill is contiguous by nature),
    but the splice writes page-granular — each incoming row's K/V
    scatters into the physical pages the host allocator handed it
    (`dest_pages`), its block-table row is mirrored host-side by the
    engine, and only ``ceil(seq_len/page)`` pages are consumed instead of
    a ``max_len`` reservation. Recurrent SSM/conv state is per-row and
    dense, spliced exactly as before.

    Response-prefill fusion: an env-stage resume's forced RESP…ENDRESP
    block is part of ``tokens`` (the host appends it to prompt+prefix), so
    the whole response prefills in THIS call instead of force-feeding one
    decode step per token. ``fpos``/``ftoks`` [W, F_B] name the positions
    whose logits predict each forced token and the tokens themselves;
    ``flp`` returns their logprobs — bit-equal to what the step-wise path
    records, because prefill logits at a position are identical to the
    decode step's logits there."""

    def refill(params, adapters, tokens, prompt_lens, init_counters, slots,
               dest_pages, new_row_ids, new_keys, new_temps, forced,
               forced_mask, fpos, ftoks, cache, cur, counters, keys, temps,
               row_ids):
        pcache = init_cache(cfg, tokens.shape[0], max_len)
        lora = batched_ctx(adapters, new_row_ids, cfg, use_kernel)
        h, pcache, _ = forward_seq(params, tokens, cfg, lora, pcache,
                                   seq_lens=prompt_lens)
        last = jnp.take_along_axis(
            h, (prompt_lens - 1)[:, None, None].astype(jnp.int32), axis=1)[:, 0]
        logits = lm_logits(last, params, cfg)
        sampled = _sample_rows(logits, new_keys, init_counters, new_temps)
        first = jnp.where(forced_mask > 0, forced, sampled).astype(jnp.int32)
        lp = jnp.take_along_axis(jax.nn.log_softmax(logits, -1),
                                 first[:, None], axis=-1)[:, 0]
        fh = jnp.take_along_axis(
            h, fpos[:, :, None].astype(jnp.int32), axis=1)
        flogits = lm_logits(fh, params, cfg)
        flp = jnp.take_along_axis(jax.nn.log_softmax(flogits, -1),
                                  ftoks[:, :, None], axis=-1)[:, :, 0]
        out = dict(cache)
        if "kp" in cache:
            out["kp"], out["vp"] = _paged_scatter(
                cfg, cache, pcache["k"], pcache["v"], dest_pages, page)
        if "ssm" in cache:
            out["ssm"] = cache["ssm"].at[:, slots].set(pcache["ssm"])
            out["conv"] = cache["conv"].at[:, slots].set(pcache["conv"])
        out["pos"] = cache["pos"].at[slots].set(prompt_lens)
        state = (cur.at[slots].set(first),
                 counters.at[slots].set(init_counters + 1),
                 keys.at[slots].set(new_keys),
                 temps.at[slots].set(new_temps),
                 row_ids.at[slots].set(new_row_ids))
        return first, lp, flp, out, state

    return jax.jit(refill, donate_argnums=(14, 15, 16, 17, 18, 19))


def _build_splice_fn_paged(cfg: ModelConfig, page: int):
    """Paged twin of ``_build_splice_fn``: installs one async-prefilled
    row (width-1 dense worker cache) by scattering its K/V into the pool
    pages the allocator assigned the row. Still scatter-only — no prefill
    graph touches the decode stream."""

    def splice(cache, pcache, slot, dest_pages, seq_len, first, init_counter,
               key, temp, row_id, cur, counters, keys, temps, row_ids):
        out = dict(cache)
        if "kp" in cache:
            out["kp"], out["vp"] = _paged_scatter(
                cfg, cache, pcache["k"], pcache["v"], dest_pages[None], page)
        if "ssm" in cache:
            out["ssm"] = cache["ssm"].at[:, slot].set(pcache["ssm"][:, 0])
            out["conv"] = cache["conv"].at[:, slot].set(pcache["conv"][:, 0])
        out["pos"] = cache["pos"].at[slot].set(seq_len)
        state = (cur.at[slot].set(first),
                 counters.at[slot].set(init_counter + 1),
                 keys.at[slot].set(key),
                 temps.at[slot].set(temp),
                 row_ids.at[slot].set(row_id))
        return out, state

    return jax.jit(splice, donate_argnums=(0, 10, 11, 12, 13, 14))


def _build_snap_fn(cfg: ModelConfig):
    """Gather one resident row's cache state for a host snapshot: its live
    KV pages (padded page list — sentinel entries gather the scratch page
    and are trimmed host-side) and its SSM/conv rows. Read-only: nothing
    is donated."""

    def snap(cache, pages, slot):
        out = {}
        if "kp" in cache:
            out["kp"] = jnp.take(cache["kp"], pages, axis=1)
            out["vp"] = jnp.take(cache["vp"], pages, axis=1)
        if "ssm" in cache:
            out["ssm"] = cache["ssm"][:, slot]
            out["conv"] = cache["conv"][:, slot]
        return out

    return jax.jit(snap)


def _build_restore_fn(cfg: ModelConfig):
    """Splice a host snapshot back into the pool: KV pages into freshly
    allocated physical pages, SSM/conv rows into the slot, `pos` to the
    snapshot position, and the device row state to (pending token,
    counter) — the next ordinary decode step then continues the row with
    the exact logits/sample an uninterrupted run would produce. NO
    prefill graph runs: this is the call that kills O(prefix) replay."""

    def restore(cache, kpages, vpages, dest_pages, slot, pos_val, ssm_row,
                conv_row, cur_tok, counter, key, temp, row_id, cur,
                counters, keys, temps, row_ids):
        out = dict(cache)
        if "kp" in cache:
            out["kp"] = cache["kp"].at[:, dest_pages].set(
                kpages.astype(cache["kp"].dtype))
            out["vp"] = cache["vp"].at[:, dest_pages].set(
                vpages.astype(cache["vp"].dtype))
        if "ssm" in cache:
            out["ssm"] = cache["ssm"].at[:, slot].set(ssm_row)
            out["conv"] = cache["conv"].at[:, slot].set(conv_row)
        out["pos"] = cache["pos"].at[slot].set(pos_val)
        state = (cur.at[slot].set(cur_tok),
                 counters.at[slot].set(counter),
                 keys.at[slot].set(key),
                 temps.at[slot].set(temp),
                 row_ids.at[slot].set(row_id))
        return out, state

    return jax.jit(restore, donate_argnums=(0, 13, 14, 15, 16, 17))


def _build_cow_fn(cfg: ModelConfig):
    """Copy-on-write fork of ONE page: duplicate physical page `src` into
    freshly allocated page `dst` (all attention layers). Runs when a row is
    about to decode-write into a page with refcount > 1 — the writer gets a
    private copy of just that page; every earlier shared page stays shared.
    src/dst are traced scalars, so one compiled variant serves every
    fork."""

    def cow(cache, src, dst):
        out = dict(cache)
        out["kp"] = cache["kp"].at[:, dst].set(cache["kp"][:, src])
        out["vp"] = cache["vp"].at[:, dst].set(cache["vp"][:, src])
        return out

    return jax.jit(cow, donate_argnums=(0,))


def _build_suffix_fn(cfg: ModelConfig, use_kernel: bool, max_len: int,
                     page: int):
    """Radix-hit install: the row's longest indexed prefix (`start` tokens,
    static — ``start // page`` retained pool pages) is GATHERED into a
    width-1 dense scratch, only the suffix runs through
    ``forward_prefill_chunk`` at offset `start` (attending over the gathered
    prefix — the same chunked-prefill decomposition the async workers use,
    exact for pure-attention stacks at any offset), and only the suffix
    chunks scatter back into fresh pool pages (`dest_pages` names the
    matched chunks as sentinel). First token sampling/forcing is identical
    to the whole-prompt refill: same final-position logits, same
    fold_in(key, init_counter) — so a radix hit is bit-equal to a full
    prefill, minus ``start`` tokens of compute."""

    def suffix(start, params, adapters, row_id, prefix_pages, tokens,
               seq_len, init_counter, key, temp, forced, forced_mask,
               cache, dest_pages, slot, cur, counters, keys, temps,
               row_ids):
        pcache = init_cache(cfg, 1, max_len)
        pk = jnp.take(cache["kp"], prefix_pages, axis=1)
        pv = jnp.take(cache["vp"], prefix_pages, axis=1)
        L, _, _, KVH, hd = pk.shape
        pcache = dict(
            pcache,
            k=pcache["k"].at[:, :, :start].set(
                pk.reshape(L, 1, start, KVH, hd).astype(pcache["k"].dtype)),
            v=pcache["v"].at[:, :, :start].set(
                pv.reshape(L, 1, start, KVH, hd).astype(pcache["v"].dtype)))
        lora = batched_ctx(adapters, row_id, cfg, use_kernel)
        h, pcache = forward_prefill_chunk(params, tokens, cfg, lora,
                                          pcache, start=start,
                                          seq_lens=seq_len - start)
        last = jnp.take_along_axis(
            h, (seq_len - 1 - start)[:, None, None].astype(jnp.int32),
            axis=1)[:, 0]
        logits = lm_logits(last, params, cfg)
        sampled = _sample_rows(logits, key, init_counter, temp)
        first = jnp.where(forced_mask > 0, forced, sampled).astype(jnp.int32)
        lp = jnp.take_along_axis(jax.nn.log_softmax(logits, -1),
                                 first[:, None], axis=-1)[:, 0]
        out = dict(cache)
        out["kp"], out["vp"] = _paged_scatter(
            cfg, cache, pcache["k"], pcache["v"], dest_pages[None], page)
        out["pos"] = cache["pos"].at[slot].set(seq_len[0])
        state = (cur.at[slot].set(first[0]),
                 counters.at[slot].set(init_counter[0] + 1),
                 keys.at[slot].set(key[0]),
                 temps.at[slot].set(temp[0]),
                 row_ids.at[slot].set(row_id[0]))
        return first, lp, out, state

    return jax.jit(suffix, static_argnums=(0,),
                   donate_argnums=(12, 15, 16, 17, 18, 19))


class _Row:
    """Host-side per-episode state machine (one slot / one batch lane when
    resident; parked rows hold no slot at all)."""
    __slots__ = ("req", "prompt_len", "gen", "lps", "lmask", "sampled",
                 "forced", "status", "forced_q", "finish_reason", "key",
                 "submit_index", "meta", "submitted_at", "started_at",
                 "replays", "session", "turns", "snap", "dev_pages",
                 "dev_pos", "tool_retries")

    def __init__(self, req: RolloutRequest, key, submit_index: int,
                 meta=None, submitted_at: float = 0.0):
        self.req = req
        self.prompt_len = len(req.prompt)
        self.gen: List[int] = []
        self.lps: List[float] = []
        self.lmask: List[float] = []
        self.sampled = 0
        self.forced = 0
        self.status = "active"            # active|calling|done
        self.forced_q: List[int] = []
        self.finish_reason = ""
        self.key = key                    # [2] uint32 base key
        self.submit_index = submit_index
        self.meta = meta or {}
        self.submitted_at = submitted_at
        self.started_at = 0.0
        self.replays = 0              # times preempted and re-queued
        self.tool_retries = 0         # transient tool-error retries spent
                                      # (per-episode retry cap accounting)
        self.session = None           # per-episode ToolSession (lazy; kept
                                      # across park/preempt/replay)
        self.turns = 0                # tool calls dispatched this episode
        self.snap = None              # host KVSnapshot while parked/queued
                                      # (paged engine, resume_restore mode);
                                      # None -> the row replays from tokens
        self.dev_pages = None         # KV pages kept IN-POOL while parked
                                      # (prefix cache: zero-copy park; the
                                      # row owns one refcount per page)
        self.dev_pos = 0              # cache entries those pages hold

    def turn_limit(self) -> int:
        """Effective tool-turn budget (0 = unlimited)."""
        if self.req.max_turns is not None:
            return self.req.max_turns
        return getattr(self.req.env, "max_turns", 0)

    def ensure_session(self):
        if self.session is None:
            self.session = self.req.env.open_session(self.req.truth)
        return self.session

    def accept(self, token: int, lp: float, mask: float, max_total: int) -> str:
        """Record one token; returns "continue" | "done" | "call".

        Only sampled tokens (mask==1) are charged to max_new_tokens; the
        length cap is the KV-cache capacity, not the sampling budget. A
        CALL sampled with the turn budget spent ends the episode instead
        of dispatching (finish_reason "turn_limit").
        """
        self.gen.append(token)
        self.lps.append(lp)
        self.lmask.append(mask)
        if mask == 1.0:
            self.sampled += 1
        else:
            self.forced += 1
        if token == tok.EOS:
            self.status, self.finish_reason = "done", "eos"
            return "done"
        if self.prompt_len + len(self.gen) >= max_total:
            self.status, self.finish_reason = "done", "capacity"
            return "done"
        if token == tok.CALL and self.req.env.is_agentic and mask == 1.0:
            limit = self.turn_limit()
            if limit and self.turns >= limit:
                self.status, self.finish_reason = "done", "turn_limit"
                return "done"
            self.turns += 1
            self.status = "calling"
            return "call"
        if self.sampled >= self.req.max_new_tokens and not self.forced_q:
            self.status, self.finish_reason = "done", "budget"
            return "done"
        return "continue"

    def result(self, prompt_tokens) -> Dict:
        return {
            "task_id": self.req.task_id,
            "prompt_len": self.prompt_len,
            "tokens": list(prompt_tokens) + self.gen,
            "gen_logprobs": self.lps,
            "gen_loss_mask": self.lmask,
            "truth": self.req.truth,
            "env": self.req.env,
            "finish_reason": self.finish_reason,
        }


def _submit_tool_call(row: "_Row", prompt_tokens, pool, rng,
                      sim_latency: bool) -> Tuple[Future, CancelToken]:
    """Dispatch a row's agentic tool call on the shared pool (freeze-in-slot
    path of both engines): sample the env-interaction latency, then run the
    episode's stateful session call while the rest of the batch decodes.

    Returns (future, cancel token). Cancelling the token makes an
    already-RUNNING call return early — ``Future.cancel()`` alone only
    helps before the pool picks the job up; the token interrupts the
    latency sleep and is passed into ``ToolSession.call`` for cooperative
    mid-call checks, so a timed-out/evicted call frees its pool thread
    immediately instead of running to completion discarded."""
    query = list(prompt_tokens) + row.gen
    latency = row.req.env.sample_env_latency(
        _RandomShim(rng)) if not sim_latency else 0.0
    session = row.ensure_session()
    token = CancelToken()

    def run_tool(q=query, sess=session, lat=latency):
        if lat > 0 and token.wait(lat):
            return []                    # cancelled during the latency sleep
        if token.cancelled:
            return []
        return call_session(sess, q, token)

    return pool.submit(run_tool), token


class RolloutEngine:
    """Round-fused baseline: one fixed batch, barrier until the last row."""

    def __init__(self, cfg: ModelConfig, base_params, *, max_len: int = 128,
                 use_kernel: bool = False, seed: int = 0):
        self.cfg = cfg
        self.base_params = base_params
        self.max_len = max_len
        self.use_kernel = use_kernel
        self._master = jax.random.PRNGKey(seed)
        self._n_issued = 0        # cumulative rows served (key freshness
                                  # across rounds; mirrors the continuous
                                  # engine's submission counter)
        self._step_fn = None
        self._first_fn = None
        self._prefill_fn = None

    # -- jitted kernels --------------------------------------------------
    def _build(self, num_adapters: int):
        self._prefill_fn, self._first_fn, self._step_fn = _build_fns(
            self.cfg, self.use_kernel)

    def _row_keys(self, requests: Sequence[RolloutRequest]) -> np.ndarray:
        """Per-row base keys: explicit request.seed, else the engine-global
        issue counter — consecutive generate() rounds get fresh keys (and
        match a continuous engine fed the same requests in the same order)."""
        keys = [jax.random.fold_in(
                    self._master,
                    r.seed if r.seed is not None else self._n_issued + i)
                for i, r in enumerate(requests)]
        self._n_issued += len(requests)
        return np.stack([np.asarray(k, np.uint32) for k in keys])

    # -- main API ---------------------------------------------------------
    def generate(self, requests: Sequence[RolloutRequest], adapter_trees,
                 *, tool_executor: Optional[ThreadPoolExecutor] = None,
                 sim_latency: bool = False,
                 deadline_s: float = 120.0) -> Tuple[List[Dict], RolloutStats]:
        """Run a batch of cross-task requests to completion (one round).

        adapter_trees: list of per-task adapter trees; request.adapter_index
        selects. Returns per-request dicts (tokens/logprobs/loss_mask/...)
        and engine stats.
        """
        t_start = time.monotonic()
        cfg = self.cfg
        B = len(requests)
        if self._step_fn is None:
            self._build(len(adapter_trees))
        stacked = stack_adapters(adapter_trees)
        row_ids = jnp.asarray([r.adapter_index for r in requests], jnp.int32)
        temps = jnp.asarray([r.temperature for r in requests], jnp.float32)
        keys = jnp.asarray(self._row_keys(requests))

        prompt_lens = np.array([len(r.prompt) for r in requests], np.int32)
        S_p = _bucket_len(prompt_lens.max())
        tokens = np.zeros((B, S_p), np.int32)
        for i, r in enumerate(requests):
            tokens[i, :len(r.prompt)] = r.prompt

        cache = init_cache(cfg, B, self.max_len,
                           enc_len=8 if cfg.family == "encdec" else 0)
        stats = RolloutStats(prefill_tokens=int(prompt_lens.sum()),
                             prefills=B)
        t0 = time.monotonic()
        logits, cache = self._prefill_fn(self.base_params, stacked, row_ids,
                                         jnp.asarray(tokens),
                                         jnp.asarray(prompt_lens), cache)
        jax.block_until_ready(logits)
        stats.prefill_seconds += time.monotonic() - t0

        rows = [_Row(r, keys[i], i) for i, r in enumerate(requests)]
        pending: Dict[int, Future] = {}
        pending_t0: Dict[int, float] = {}
        pending_tok: Dict[int, CancelToken] = {}
        own_pool = tool_executor is None
        pool = tool_executor or ThreadPoolExecutor(max_workers=4)
        rng = np.random.RandomState(
            (int(np.asarray(self._master)[1]) + self._n_issued) % (2**31))

        # sample the first token from prefill logits (counter = 0 per row)
        counters = np.zeros((B,), np.int32)
        first, first_lp = self._first_fn(logits, keys, jnp.asarray(counters),
                                         temps)
        first = np.asarray(first)
        first_lp = np.asarray(first_lp)
        cur = np.zeros((B,), np.int32)
        for i in range(B):
            action = rows[i].accept(int(first[i]), float(first_lp[i]), 1.0,
                                    self.max_len)
            stats.tokens_generated += 1
            stats.sampled_tokens += 1
            if action == "call":
                self._dispatch_tool(i, rows[i], tokens[i], pending,
                                    pending_t0, pending_tok, pool, rng,
                                    sim_latency)
            cur[i] = int(first[i])

        # forced feeds are budget-exempt, so the step bound must cover
        # budget + worst-case tool-response lengths (one response per tool
        # turn; an unlimited turn budget gets a 4-turn allowance — the wall
        # deadline is the actual straggler guard, and rows it cuts short
        # are tagged "straggler" below).
        worst_turns = max(
            (r.max_turns if r.max_turns is not None
             else getattr(r.env, "max_turns", 0)) or 4
            for r in requests)
        max_steps = (max(r.max_new_tokens for r in requests)
                     + 96 * max(1, worst_turns))
        steps_done = 0
        wall_deadline = time.monotonic() + deadline_s
        while steps_done < max_steps and time.monotonic() < wall_deadline:
            if all(r.status == "done" for r in rows):
                break
            # resolve finished tool calls
            for i in list(pending):
                if pending[i].done():
                    resp = pending[i].result()
                    stats.add_env_wait(rows[i].req.task_id,
                                       time.monotonic() - pending_t0[i])
                    rows[i].forced_q = [tok.RESP] + list(resp) + [tok.ENDRESP]
                    rows[i].status = "active"
                    del pending[i], pending_t0[i], pending_tok[i]
            advance = np.array([1 if rows[i].status == "active" else 0
                                for i in range(B)], np.int32)
            if advance.sum() == 0:
                # waiting only on external tools — does not consume the
                # decode-step budget (straggler guard is the wall deadline)
                time.sleep(0.001)
                continue
            steps_done += 1
            forced = np.zeros((B,), np.int32)
            fmask = np.zeros((B,), np.int32)
            for i in range(B):
                if rows[i].status == "active" and rows[i].forced_q:
                    forced[i] = rows[i].forced_q[0]
                    fmask[i] = 1
                counters[i] = len(rows[i].gen)
            t0 = time.monotonic()
            nxt, lp, cache = self._step_fn(
                self.base_params, stacked, row_ids, jnp.asarray(cur), cache,
                keys, jnp.asarray(counters), temps, jnp.asarray(forced),
                jnp.asarray(fmask), jnp.asarray(advance))
            nxt = np.asarray(nxt)
            lp = np.asarray(lp)
            stats.decode_seconds += time.monotonic() - t0
            stats.decode_steps += 1
            for i in range(B):
                if rows[i].status != "active" or advance[i] == 0:
                    continue
                was_forced = fmask[i] == 1
                if was_forced:
                    rows[i].forced_q.pop(0)
                action = rows[i].accept(int(nxt[i]), float(lp[i]),
                                        0.0 if was_forced else 1.0,
                                        self.max_len)
                if action == "call":
                    self._dispatch_tool(i, rows[i], tokens[i], pending,
                                        pending_t0, pending_tok, pool, rng,
                                        sim_latency)
                cur[i] = int(nxt[i])
                stats.tokens_generated += 1
                if not was_forced:
                    stats.sampled_tokens += 1

        # timed-out tool calls: cancel the Future (drops jobs still queued
        # on the SHARED pool) AND the cooperative token (makes an
        # already-executing call return early instead of running to
        # completion discarded — satellite, ISSUE 5)
        for i in pending:
            pending[i].cancel()
            pending_tok[i].cancel()
            rows[i].status = "done"
            rows[i].finish_reason = rows[i].finish_reason or "tool_timeout"
        for row in rows:
            # rows the step bound / wall deadline cut short return partial
            # (graded reward on what exists) with an explicit reason
            if row.status != "done":
                row.status = "done"
                row.finish_reason = row.finish_reason or "straggler"
        if own_pool:
            pool.shutdown(wait=False)

        results = [rows[i].result(tokens[i, :prompt_lens[i]])
                   for i in range(B)]
        stats.wall_seconds = time.monotonic() - t_start
        return results, stats

    # ------------------------------------------------------------------
    def _dispatch_tool(self, i, row: _Row, token_row, pending, pending_t0,
                       pending_tok, pool, rng, sim_latency):
        pending[i], pending_tok[i] = _submit_tool_call(
            row, token_row[:row.prompt_len], pool, rng, sim_latency)
        pending_t0[i] = time.monotonic()


class ContinuousRolloutEngine:
    """Persistent slot-pool engine: decode never drains between tenants.

    Usage: ``set_adapters(slot, tree)`` to (re)install a tenant's LoRA in
    the fixed-capacity stacked buffer, ``submit(request)`` any number of
    requests (request.adapter_index names the adapter slot), then call
    ``step()`` from the scheduler loop — or ``drain()`` to run to empty.
    Finished rows stream out of ``drain_completions()`` the moment they
    evict.

    The request queue pops in ``scheduler`` order ("srpt": priority tiers,
    then shortest predicted remaining budget via a per-tenant EMA length
    predictor, with a ``starvation_k``-refill progress bound; "fifo":
    PR-1 arrival order). ``preempt_tenant``/``preempt_slots`` implement the
    admission-driven preemption protocol documented in the module
    docstring; preempted rows replay token-for-token — under
    ``disagg_prefill=True`` the replay prefill runs asynchronously on the
    prefill workers and splices back with the row's original per-row
    counter, so replay parity is preserved across both fill paths.

    ``env_stage=True`` activates the disaggregated environment-interaction
    stage (rollout/env_stage.py, ``env_workers`` threads,
    ``env_inflight_per_tenant`` fairness cap): rows that sample CALL are
    parked instead of freezing in their slot, and resume through the
    prefill path once their tool response lands — works with either fill
    path, preserving token-for-token parity with the freeze-in-slot
    baseline.
    """

    def __init__(self, cfg: ModelConfig, base_params, *, max_slots: int = 8,
                 max_adapters: int = 8, max_len: int = 128,
                 use_kernel: bool = False, seed: int = 0,
                 tool_executor: Optional[ThreadPoolExecutor] = None,
                 sim_latency: bool = False, tool_timeout_s: float = 60.0,
                 scheduler: str = "srpt", starvation_k: int = 8,
                 predictor: Optional[LengthPredictor] = None,
                 disagg_prefill: bool = False, prefill_chunk: int = 0,
                 prefill_workers: int = 1, env_stage: bool = False,
                 env_workers: int = 2, env_inflight_per_tenant: int = 0,
                 paged_kv: bool = False, kv_page_size: int = 16,
                 kv_pool_pages: int = 0, resume_restore: bool = True,
                 snapshot_budget_bytes: int = 0, prefix_cache: bool = True,
                 on_stage=None, tracer=None, chaos=None,
                 tool_retry_max: int = 3, tool_retry_base_s: float = 0.05,
                 tool_retry_max_s: float = 2.0,
                 tool_retry_episode_cap: int = 0,
                 supervise_wedge_s: float = 0.0):
        self.cfg = cfg
        self.base_params = base_params
        self.max_slots = max_slots
        self.max_adapters = max_adapters
        self.max_len = max_len
        self.use_kernel = use_kernel
        self.tool_timeout_s = tool_timeout_s
        # -- paged KV-cache block pool (ISSUE 5) ---------------------------
        self.paged_kv = paged_kv
        self.kv_page_size = kv_page_size
        self.resume_restore = paged_kv and resume_restore
        if paged_kv:
            if cfg.family == "encdec":
                raise ValueError("paged_kv unsupported for encdec")
            if max_len % kv_page_size != 0:
                raise ValueError(f"max_len {max_len} must be a multiple of "
                                 f"kv_page_size {kv_page_size}")
            self._max_pg = max_len // kv_page_size
            # default pool: dense-equivalent capacity (every slot could run
            # to max_len); size it DOWN to realize the HBM saving, at the
            # cost of cache-capacity evictions if every row runs long
            self.kv_pool_pages = kv_pool_pages or max_slots * self._max_pg
            self._pages = PagePool(self.kv_pool_pages, kv_page_size)
            self._slot_pages: List[List[int]] = [[] for _ in range(max_slots)]
            self._slot_pos = [0] * max_slots      # device cache["pos"] mirror
            self._tbl_host = np.full((max_slots, self._max_pg),
                                     self._pages.sentinel, np.int32)
            self._tbl_dirty = False
            self._snap_store = SnapshotStore(snapshot_budget_bytes)
        else:
            self.kv_pool_pages = 0
            self._pages = None
            self._snap_store = None
        # -- global COW prefix cache (ISSUE 8) -----------------------------
        # three sharing levels over the page pool: GRPO-group prompt pages
        # (siblings radix-hit the representative's pages), device-resident
        # park/preempt (pages stay in-pool; host snapshot demoted to a
        # spill tier), and cross-request radix reuse of common prefixes.
        # prefix_cache=False reproduces the PR-5 private-pages engine.
        self.prefix_cache = bool(paged_kv and prefix_cache)
        self._prefix_idx = (PrefixIndex(kv_page_size)
                            if self.prefix_cache else None)
        self._dev_parked: List[_Row] = []   # rows whose dev_pages are live
                                            # (engine-thread-only registry:
                                            # spill victims + invariants)
        self._cow_fn = None
        self._suffix_fn = None
        self._snap_fn = None
        self._restore_fn = None
        self.sim_latency = sim_latency
        self.disagg_prefill = disagg_prefill
        self.prefill_workers = max(1, prefill_workers)
        self.env_stage = env_stage
        self._chaos = chaos          # ChaosInjector or None (fault drills)
        self._env: Optional[EnvStage] = EnvStage(
            max(1, env_workers),
            max_inflight_per_tenant=env_inflight_per_tenant,
            sim_latency=sim_latency, retry_max=tool_retry_max,
            retry_episode_cap=tool_retry_episode_cap,
            retry_base_s=tool_retry_base_s, retry_max_s=tool_retry_max_s,
            seed=seed, chaos=chaos) if env_stage else None
        self._prefill_chunk_eff = effective_chunk(cfg, prefill_chunk)
        self.on_stage = on_stage    # optional (phase, task_id, t0, t1) hook
                                    # (called from worker threads too)
        # episode tracer (repro.obs): None by default — every hook site
        # below guards on it, so an untraced run pays one pointer compare
        # per episode EVENT (install/park/evict), never per token
        self._tracer = tracer
        self._slot_tr_t0 = [0.0] * max_slots    # residency span starts
        self._slot_tr_flow = [0] * max_slots    # incoming hand-off arrows
        self._master = jax.random.PRNGKey(seed)
        self._rng = np.random.RandomState(seed + 7919)
        self._own_pool = tool_executor is None
        self._pool = tool_executor or ThreadPoolExecutor(max_workers=4)

        self._step_fn = None
        self._refill_fn = None
        self._write_adapter_fn = None
        self._stacked = None                     # [L, T, ...] LoRA buffer
        self._cache = None                       # batch = max_slots

        N = max_slots
        self._rows: List[Optional[_Row]] = [None] * N
        self._prompts: List[Optional[List[int]]] = [None] * N
        # device-resident row state (updated inside the jitted calls; the
        # host only uploads the advance/forced masks, and only when they
        # change — see _masks())
        self._d_cur = None          # [N] int32   current token per lane
        self._d_counters = None     # [N] int32   == len(gen) per lane
        self._d_keys = None         # [N,2] uint32 per-row base PRNG keys
        self._d_temps = None        # [N] float32
        self._d_row_ids = None      # [N] int32   adapter slot per lane
        self._mask_sig = None       # last uploaded (advance,forced,fmask)
        self._d_masks = None
        self._pending: Dict[int, Future] = {}
        self._pending_t0: Dict[int, float] = {}
        self._pending_tok: Dict[int, CancelToken] = {}
        self.predictor = predictor or LengthPredictor()
        self._sched = SlotScheduler(policy=scheduler,
                                    predictor=self.predictor,
                                    starvation_k=starvation_k)
        self._completed: Deque[RolloutCompletion] = deque()
        self._n_submitted = 0
        self.stats = RolloutStats()
        # -- disaggregated prefill stage (workers <-> decode thread) -------
        self._stage_lock = threading.Lock()   # guards: _sched/_ready/
                                              # _stage_inflight
        self._ready: Deque[ReadyRow] = deque()
        self._stage_inflight: List[_Row] = []  # popped by a worker, not yet
                                               # ready (host refs only)
        self._stage_stop = threading.Event()
        self._stage_error: Optional[BaseException] = None
        self._workers: List[PrefillWorker] = []
        self._next_pwid = 0     # unique prefill-worker ids across respawns
        self._pkernels: Optional[PrefillKernels] = None
        self._splice_fn = None
        # -- stage supervision (ISSUE 10) ----------------------------------
        # dead/wedged workers are detected on the step() tick, their
        # stranded work recovered, and the pool restarted to complement
        # under bounded exponential backoff; past the restart budget the
        # supervisor raises on the engine thread (-> runtime.error ->
        # checkpoint-restart)
        self.supervise_wedge_s = supervise_wedge_s   # 0 = liveness only
        self.supervisor = StageSupervisor(tracer=tracer)
        if env_stage:
            self.supervisor.register(
                "env_worker", healthy=self._env_stage_healthy,
                recover=self._env.recover_dead,
                restart=self._env._ensure_workers)
        if disagg_prefill:
            self.supervisor.register(
                "prefill_worker", healthy=self._prefill_stage_healthy,
                recover=self._recover_prefill_claims,
                restart=self._ensure_stage)

    # -- build ----------------------------------------------------------
    def _ensure_built(self):
        if self._step_fn is None:
            self._step_fn = _build_cont_step_fn(self.cfg, self.use_kernel)
            if self.paged_kv:
                self._refill_fn = _build_refill_fn_paged(
                    self.cfg, self.use_kernel, self.max_len,
                    self.kv_page_size)
                self._snap_fn = _build_snap_fn(self.cfg)
                self._restore_fn = _build_restore_fn(self.cfg)
                if self.prefix_cache:
                    self._cow_fn = _build_cow_fn(self.cfg)
                    self._suffix_fn = _build_suffix_fn(
                        self.cfg, self.use_kernel, self.max_len,
                        self.kv_page_size)
            else:
                self._refill_fn = _build_refill_fn(self.cfg, self.use_kernel,
                                                   self.max_len)
            # disaggregated mode: the write must NOT donate the old buffer —
            # a prefill worker's in-flight call may still be reading it (the
            # old immutable tree stays valid until its last reader drops it)
            self._write_adapter_fn = jax.jit(
                lambda buf, tree, i: jax.tree.map(
                    lambda b, l: b.at[:, i].set(l), buf, tree),
                donate_argnums=() if self.disagg_prefill else (0,))
            if self.disagg_prefill:
                self._splice_fn = (_build_splice_fn_paged(self.cfg,
                                                          self.kv_page_size)
                                   if self.paged_kv else
                                   _build_splice_fn(self.cfg))
                self._pkernels = PrefillKernels(self.cfg, self.use_kernel,
                                                self.max_len)
        if self._cache is None:
            N = self.max_slots
            if self.paged_kv:
                self._cache = init_paged_cache(
                    self.cfg, N, pool_pages=self.kv_pool_pages,
                    page_size=self.kv_page_size,
                    max_pages_per_row=self._max_pg)
            else:
                self._cache = init_cache(
                    self.cfg, N, self.max_len,
                    enc_len=8 if self.cfg.family == "encdec" else 0)
            self._d_cur = jnp.zeros((N,), jnp.int32)
            self._d_counters = jnp.zeros((N,), jnp.int32)
            self._d_keys = jnp.zeros((N, 2), jnp.uint32)
            self._d_temps = jnp.ones((N,), jnp.float32)
            self._d_row_ids = jnp.zeros((N,), jnp.int32)

    # -- adapters --------------------------------------------------------
    def set_adapters(self, index: int, tree):
        """Install/replace the LoRA tree at adapter slot `index` in the
        fixed-capacity stacked buffer (shape-stable: no recompiles)."""
        if not 0 <= index < self.max_adapters:
            raise ValueError(f"adapter slot {index} out of range "
                             f"[0, {self.max_adapters})")
        self._ensure_built()
        if self._stacked is None:
            self._stacked = init_stacked_buffer(tree, self.max_adapters)
        self._stacked = self._write_adapter_fn(self._stacked, tree,
                                               jnp.int32(index))
        if self._prefix_idx is not None:
            # cached K/V was produced under the OLD adapter weights — a
            # match against it would be silently wrong for the new ones
            stale = self._prefix_idx.invalidate(index)
            if stale:
                self._pages.release(stale)

    # -- submission ------------------------------------------------------
    def submit(self, req: RolloutRequest, meta=None):
        if len(req.prompt) + 1 >= self.max_len:
            raise ValueError("prompt does not fit decode cache")
        key = np.asarray(jax.random.fold_in(
            self._master,
            req.seed if req.seed is not None else self._n_submitted),
            np.uint32)
        row = _Row(req, key, self._n_submitted, meta=meta,
                   submitted_at=time.monotonic())
        self._n_submitted += 1
        if self._tracer is not None:
            # the episode's trace id rides row.meta — the one piece of
            # host state that provably survives park, preemption and
            # snapshot/replay resume (it already carries the behaviour
            # version for the same reason)
            if not isinstance(row.meta, dict):
                row.meta = {}           # engine-direct callers pass no meta
            trace = row.meta.get("trace_id")
            if trace is None:
                trace = self._tracer.new_trace(req.task_id)
                row.meta["trace_id"] = trace
            self._tracer.mark(trace, "queued", row.submitted_at)
        with self._stage_lock:
            self._sched.push(row, self.stats.refills)
        return row.submit_index

    # -- episode tracing helpers (all no-ops when tracer is None) ---------
    def _trace_of(self, row: _Row):
        m = row.meta
        return m.get("trace_id") if isinstance(m, dict) else None

    def _tr_install(self, slot: int, row: _Row, t_now: float,
                    t_pre: float = None, pre_state: str = None):
        """Row entered a decode slot: open its residency span, consume any
        pending hand-off arrow (env resume / preempt reinstall), and mark
        the lifecycle transition(s)."""
        tr = self._tracer
        if tr is None:
            return
        trace = self._trace_of(row)
        if t_pre is not None and pre_state is not None:
            tr.mark(trace, pre_state, t_pre)
        tr.mark(trace, "decode", t_now)
        self._slot_tr_t0[slot] = t_now
        m = row.meta
        self._slot_tr_flow[slot] = (m.pop("_flow_in", 0)
                                    if isinstance(m, dict) else 0)

    def _tr_vacate(self, slot: int, row: _Row, t_now: float,
                   flow_out: int = 0):
        """Row left its slot (evict/park/preempt): emit the residency span
        on the slot's track, with flow arrows binding it to the hand-off
        source/destination across threads."""
        tr = self._tracer
        if tr is None:
            return
        tr.span(("rollout", f"slot-{slot}"), row.req.task_id,
                self._slot_tr_t0[slot], t_now, trace=self._trace_of(row),
                flow_in=self._slot_tr_flow[slot], flow_out=flow_out)
        self._slot_tr_flow[slot] = 0

    # -- prefill stage lifecycle ------------------------------------------
    def _ensure_stage(self):
        """Spawn the async prefill workers — the full complement after a
        halt, or just replacements for workers that died on an error
        (survivors keep running; total parallelism stays at
        `prefill_workers`). A no-op until the first adapter install —
        workers have nothing to prefill against before then (requests may
        already be queued; they keep until the buffer exists)."""
        if not self.disagg_prefill or self._stacked is None:
            return
        self._ensure_built()
        alive = [w for w in self._workers if w.is_alive()]
        if len(alive) >= self.prefill_workers:
            return
        self._stage_stop.clear()
        fresh = []
        for _ in range(self.prefill_workers - len(alive)):
            # unique ids across respawns: a replacement must not shadow a
            # dead worker's claimed-row ownership (supervisor recovery)
            fresh.append(PrefillWorker(self, self._next_pwid))
            self._next_pwid += 1
        self._workers = alive + fresh
        for w in fresh:
            w.start()

    def _halt_stage(self):
        """Stop the prefill workers; their unfinished rows return to the
        queue (worker teardown pushes them back under the stage lock)."""
        self._stage_stop.set()
        for w in self._workers:
            w.join(timeout=30)
        self._recover_prefill_claims()   # chaos-killed workers strand rows
        self._workers = []

    # -- stage supervision callables (engine thread only) -----------------
    def _env_stage_healthy(self) -> bool:
        if self.supervise_wedge_s > 0:
            self._env.mark_wedged(self.supervise_wedge_s)
        return self._env.healthy()

    def _prefill_stage_healthy(self) -> bool:
        if self._stacked is None or not self._workers:
            return True          # stage not started (or halted): nothing
                                 # to supervise — step() does first start
        if self._stage_error is not None:
            return True          # a REAL worker error is about to raise on
                                 # the engine thread (fatal) — restarting
                                 # first would just mask the cause
        alive = [w for w in self._workers if w.is_alive()]
        return len(alive) >= self.prefill_workers

    def _recover_prefill_claims(self) -> int:
        """Requeue rows a dead prefill worker stranded mid-prefill: still
        in ``_stage_inflight`` (so not aborted) but never emitted and with
        no live owner — they re-enter the scheduler queue and prefill
        again from scratch (prefill is deterministic; the re-run is
        token-identical)."""
        n = 0
        with self._stage_lock:
            for w in self._workers:
                if w.is_alive():
                    continue
                for row in list(w.claimed):
                    w.claimed.remove(row)
                    if row in self._stage_inflight:
                        self._stage_inflight.remove(row)
                        self._sched.push(row, self.stats.refills)
                        n += 1
        return n

    def _raise_stage_error(self):
        if self._stage_error is not None:
            err, self._stage_error = self._stage_error, None
            raise err

    # -- introspection ---------------------------------------------------
    def occupancy(self) -> Tuple[int, int]:
        return sum(r is not None for r in self._rows), self.max_slots

    def occupant_tasks(self) -> frozenset:
        return frozenset(r.req.task_id for r in self._rows if r is not None)

    def queued(self) -> int:
        with self._stage_lock:
            n = (len(self._sched) + len(self._stage_inflight)
                 + len(self._ready))
        if self._env is not None:
            n += self._env.count()      # parked rows hold no slot but are
                                        # still in flight (env stage)
        return n

    def queue_depths(self) -> Tuple[int, int]:
        """(prefill queue + in-prefill, ready-to-splice) — the two stage
        queues of the disaggregated layout (Fig 5)."""
        with self._stage_lock:
            return (len(self._sched) + len(self._stage_inflight),
                    len(self._ready))

    def env_depths(self) -> Tuple[int, int]:
        """(queued, executing) depths of the env-interaction stage."""
        return self._env.depths() if self._env is not None else (0, 0)

    def idle(self) -> bool:
        return self.queued() == 0 and all(r is None for r in self._rows)

    def active_tenants(self) -> frozenset:
        """Tenants with rows resident in slots OR anywhere in the pipeline
        (queued, mid-prefill, ready-to-splice, parked in the env stage,
        incl. preempted rows awaiting replay) — i.e. whose adapter slot
        must stay resident."""
        with self._stage_lock:
            stage = (frozenset(r.req.task_id for r in self._stage_inflight)
                     | frozenset(rr.row.req.task_id for rr in self._ready)
                     | self._sched.tenants())
        if self._env is not None:
            stage = stage | self._env.tenants()
        return self.occupant_tasks() | stage

    def queued_progress(self, task_id: str) -> Tuple[int, float]:
        """(row count, mean sampled tokens) over a tenant's not-yet-resident
        rows (queued / mid-prefill / ready / parked). Preempted rows carry
        their generated prefix, so this feeds the admission controller's
        remaining-budget re-estimate (readmission packs tighter)."""
        with self._stage_lock:
            rows = self._sched.rows_for(task_id)
            rows += [r for r in self._stage_inflight
                     if r.req.task_id == task_id]
            rows += [rr.row for rr in self._ready
                     if rr.row.req.task_id == task_id]
        if self._env is not None:
            rows += self._env.rows_for(task_id)
        if not rows:
            return 0, 0.0
        return len(rows), float(sum(r.sampled for r in rows)) / len(rows)

    def drain_completions(self) -> List[RolloutCompletion]:
        out = []
        while self._completed:
            out.append(self._completed.popleft())
        return out

    # -- slot lifecycle --------------------------------------------------
    def _completion(self, row: _Row, prompt, slot: int) -> RolloutCompletion:
        """One finished episode → completion record (shared by slot
        eviction, parked-row timeout, and the drain abort paths). The
        behaviour version is stamped per-row from the submit meta — which
        lives on the row object itself, so the stamp survives park,
        preemption, and snapshot/replay resume."""
        meta = row.meta if isinstance(row.meta, dict) else {}
        finished_at = time.monotonic()
        if self._tracer is not None:
            self._tracer.mark(self._trace_of(row), "completed", finished_at)
        return RolloutCompletion(
            task_id=row.req.task_id, prompt_len=row.prompt_len,
            tokens=list(prompt) + row.gen, gen_logprobs=row.lps,
            gen_loss_mask=row.lmask, truth=row.req.truth, env=row.req.env,
            finish_reason=row.finish_reason, slot=slot,
            version=int(meta.get("version", -1)),
            sampled_tokens=row.sampled, forced_tokens=row.forced,
            submit_index=row.submit_index, submitted_at=row.submitted_at,
            started_at=row.started_at, finished_at=finished_at,
            finished_step=self.stats.decode_steps, meta=row.meta)

    def _evict(self, slot: int):
        row = self._rows[slot]
        if self._tracer is not None:
            self._tr_vacate(slot, row, time.monotonic())
        self._completed.append(self._completion(row, self._prompts[slot],
                                                slot))
        self.stats.completions += 1
        if row.finish_reason in ("eos", "budget", "capacity", "turn_limit"):
            # natural finishes only: a tool_timeout/aborted row's partial
            # sampled count would bias the tenant's length EMA low
            self.predictor.observe(row.req.task_id, row.sampled)
        self._rows[slot] = None
        self._prompts[slot] = None
        if self.paged_kv:
            self._free_slot_pages(slot)
        # cancel, don't just drop, a pending tool Future: abandoned
        # env.tool_call work left queued would keep burning the shared
        # thread-pool and starve other tenants' tool calls — and a late
        # response must never reach the slot's next occupant. The token
        # additionally makes an already-RUNNING call return early, freeing
        # its pool thread immediately (cooperative cancellation).
        fut = self._pending.pop(slot, None)
        if fut is not None:
            fut.cancel()
        tok_ = self._pending_tok.pop(slot, None)
        if tok_ is not None:
            tok_.cancel()
        self._pending_t0.pop(slot, None)

    def _complete_parked(self, row: _Row):
        """Finish an episode that holds NO slot (parked in the env stage:
        tool timeout or abort)."""
        self._drop_snap(row)          # a dead row's snapshot frees its arena
        self._release_dev(row)        # ... and its in-pool parked pages
        self._completed.append(self._completion(row, row.req.prompt, -1))
        self.stats.completions += 1

    # -- paged KV page + snapshot lifecycle (rollout/kvcache.py) ----------
    def _row_pages_needed(self, tokens: int) -> int:
        """Pool pages holding `tokens` cache entries for one row (0 for
        pure-SSM models: recurrent state is fixed-size and never paged)."""
        if self.cfg.family == "ssm":
            return 0
        return pages_for(tokens, self.kv_page_size)

    def _assign_slot_pages(self, slot: int, pages: List[int], pos: int):
        """Install a slot's host-side page list + block-table mirror."""
        self._slot_pages[slot] = list(pages)
        self._slot_pos[slot] = pos
        self._tbl_host[slot, :] = self._pages.sentinel
        self._tbl_host[slot, :len(pages)] = pages
        self._tbl_dirty = True

    def _free_slot_pages(self, slot: int):
        """Vacating a slot returns its pages to the pool and neutralizes
        its block-table row — stale entries would let the empty lane's
        (garbage) decode writes corrupt pages re-allocated to other rows."""
        if self._slot_pages[slot]:
            self._pages.release(self._slot_pages[slot])
        self._slot_pages[slot] = []
        self._tbl_host[slot, :] = self._pages.sentinel
        self._tbl_dirty = True

    def _padded_pages(self, pages: List[int]) -> np.ndarray:
        out = np.full((self._max_pg,), self._pages.sentinel, np.int32)
        out[:len(pages)] = pages
        return out

    def _snapshot_row(self, slot: int, row: _Row):
        """Copy a row's cache state to HOST before vacating its slot (park
        or preemption): only the ``ceil(pos/page)`` live pages plus the
        fixed SSM/conv rows — never the max_len worst case. Under host
        memory pressure the snapshot is dropped and the row replays from
        tokens instead (identical output, recomputed)."""
        if not self.resume_restore:
            return
        if self._chaos is not None and self._chaos.fire("snapshot_drop"):
            # simulated host-memory pressure: the row falls back to token
            # replay — identical output, recomputed prefix
            row.snap = None
            self.stats.snapshot_drops += 1
            return
        pos = self._slot_pos[slot]
        n_pg = self._row_pages_needed(pos)
        # the slot may hold one extra pre-allocated page for the pending
        # write (pos % page == 0); it contains no valid entries — skip it
        outs = self._snap_fn(self._cache,
                             jnp.asarray(self._padded_pages(
                                 self._slot_pages[slot][:n_pg])),
                             jnp.int32(slot))
        # device-side slice BEFORE the host transfer: the jitted gather is
        # shape-stable at _max_pg pages, but only the n_pg live ones cross
        # the host boundary — the snapshot copy is O(live), not O(max_len)
        snap = KVSnapshot(
            pos=pos, cur=row.gen[-1],
            kpages=(np.asarray(outs["kp"][:, :n_pg])
                    if "kp" in outs else None),
            vpages=(np.asarray(outs["vp"][:, :n_pg])
                    if "vp" in outs else None),
            ssm=np.asarray(outs["ssm"]).copy() if "ssm" in outs else None,
            conv=np.asarray(outs["conv"]).copy() if "conv" in outs else None)
        if self._snap_store.try_add(snap):
            row.snap = snap
            self.stats.snapshots += 1
        else:
            row.snap = None
            self.stats.snapshot_drops += 1

    def _drop_snap(self, row: _Row):
        if getattr(row, "snap", None) is not None:
            self._snap_store.remove(row.snap)
            row.snap = None

    def _release_dev(self, row: _Row):
        """Drop a row's device-resident parked pages (death paths: abort,
        timeout, capacity finish) — the counterpart of ``_drop_snap`` for
        the in-pool tier."""
        if getattr(row, "dev_pages", None) is not None:
            self._pages.release(row.dev_pages)
            row.dev_pages, row.dev_pos = None, 0
            if row in self._dev_parked:
                self._dev_parked.remove(row)

    # -- prefix cache: allocation relief + device-resident parking ---------
    def _alloc_pages(self, n: int, *, spill: bool = True
                     ) -> Optional[List[int]]:
        """Pool allocation with prefix-cache pressure relief: on failure,
        evict cold radix entries (LRU leaves), then spill the oldest
        device-parked row's pages to the host snapshot tier, then retry.
        ``spill=False`` keeps the call host-only (no device gather) — use
        it under ``_stage_lock``."""
        if n == 0:
            return []
        pages = self._pages.alloc(n)
        while pages is None:
            if self._prefix_idx is not None:
                dropped = self._prefix_idx.pop_lru(
                    max(1, n - self._pages.free_pages))
                if dropped:
                    self._pages.release(dropped)
                    pages = self._pages.alloc(n)
                    continue
            if not (spill and self._spill_dev_parked()):
                return None
            pages = self._pages.alloc(n)
        return pages

    def _spill_dev_parked(self) -> bool:
        """Spill tier: demote the oldest device-parked row to a host
        snapshot (gather its pages off-device, merge with its recurrent
        -state snapshot if any) so the pool pages free up. If the snapshot
        store rejects the bytes, the row falls back to token replay —
        either way its pages return to the pool. Returns True if a row was
        spilled."""
        if not self._dev_parked:
            return False
        row = self._dev_parked.pop(0)
        n_pg = len(row.dev_pages)
        outs = self._snap_fn(self._cache,
                             jnp.asarray(self._padded_pages(row.dev_pages)),
                             jnp.int32(0))
        old = row.snap            # hybrid park: recurrent-only snapshot
        snap = KVSnapshot(
            pos=row.dev_pos, cur=row.gen[-1],
            kpages=np.asarray(outs["kp"][:, :n_pg]),
            vpages=np.asarray(outs["vp"][:, :n_pg]),
            ssm=old.ssm if old is not None else None,
            conv=old.conv if old is not None else None)
        if old is not None:
            self._snap_store.remove(old)
            row.snap = None
        self._pages.release(row.dev_pages)
        row.dev_pages, row.dev_pos = None, 0
        if self._snap_store.try_add(snap):
            row.snap = snap
            self.stats.snapshots += 1
        else:
            self.stats.snapshot_drops += 1   # token-replay fallback
        return True

    def _dev_park_row(self, slot: int, row: _Row) -> bool:
        """Device-resident park/preempt (prefix cache): the row KEEPS its
        pool pages — ownership moves from the slot to the row, resume is a
        block-table splice, and ZERO bytes cross the host boundary for the
        attention family. Recurrent state (hybrid) has no paged
        representation and still snapshots to host; if the store rejects
        it the row falls back to token replay (pages released). Returns
        True when the slot was vacated (device-resident or replay)."""
        if not (self.prefix_cache and self.resume_restore):
            return False
        if self.cfg.family == "ssm" or not self._slot_pages[slot]:
            return False                 # no attention pages to keep
        pos = self._slot_pos[slot]
        n_pg = self._row_pages_needed(pos)
        pages = self._slot_pages[slot]
        if "ssm" in self._cache:         # hybrid: recurrent part to host
            outs = self._snap_fn(self._cache,
                                 jnp.asarray(self._padded_pages([])),
                                 jnp.int32(slot))
            snap = KVSnapshot(pos=pos, cur=row.gen[-1],
                              ssm=np.asarray(outs["ssm"]).copy(),
                              conv=np.asarray(outs["conv"]).copy())
            if not self._snap_store.try_add(snap):
                self.stats.snapshot_drops += 1
                self._free_slot_pages(slot)      # replay fallback
                return True
            row.snap = snap
            self.stats.snapshots += 1
        # the slot may hold one slack page pre-allocated for the pending
        # write (pos % page == 0): it has no valid entries — drop it
        if n_pg < len(pages):
            self._pages.release(pages[n_pg:])
        row.dev_pages = pages[:n_pg]
        row.dev_pos = pos
        self._dev_parked.append(row)
        # hand-off WITHOUT release: the row now owns the refcounts
        self._slot_pages[slot] = []
        self._tbl_host[slot, :] = self._pages.sentinel
        self._tbl_dirty = True
        return True

    def _park_or_snap(self, slot: int, row: _Row):
        """Vacate a slot preserving resume state: device-resident when the
        prefix cache is on (pure retain, no host round-trip), host
        snapshot otherwise; both fall back to token replay under memory
        pressure."""
        if self._dev_park_row(slot, row):
            return
        self._snapshot_row(slot, row)
        self._free_slot_pages(slot)

    def _finish_capacity(self, row: _Row):
        """Cache-capacity eviction: the page pool cannot serve this row
        even when otherwise idle, so the episode finishes with what it has
        instead of deadlocking the queue."""
        self._drop_snap(row)
        row.status, row.finish_reason = "done", "capacity"
        self.stats.pool_exhausted += 1
        self._complete_parked(row)

    def _restore_rows(self) -> bool:
        """Decode-thread install of snapshot-carrying queued rows (the
        resume path that kills O(prefix) replay): splice the saved KV
        pages into freshly allocated pool pages, the SSM/conv rows into
        the slot, and resume with the pending token — the next decode step
        produces the exact logits an uninterrupted run would have. No
        token is accepted at install (the pending one was accepted before
        the park/preempt), so bookkeeping differs from refill: only the
        device state moves."""
        if not self.resume_restore or self._cache is None:
            return False
        free = [s for s in range(self.max_slots) if self._rows[s] is None]
        did = False
        while free:
            with self._stage_lock:
                # pop_if, not pop(where=): a snapshot row restores only
                # when it is genuinely next in scheduler order — it must
                # not jump a higher-priority tenant's fresh rows (e.g. the
                # newcomer its own preemption just made room for)
                row = self._sched.pop_if(
                    self.stats.refills,
                    lambda r: r.snap is not None or r.dev_pages is not None)
            if row is None:
                break
            if row.dev_pages is not None:
                # device-resident resume: the pages never left the pool —
                # reattach them to the slot's block table and reset the
                # device row state. Zero KV bytes cross the host boundary
                # (the restore call's page writes land on the scratch
                # page); only the hybrid recurrent rows come back up.
                slot = free.pop(0)
                t0 = time.monotonic()
                kz = vz = jnp.zeros(
                    (self._cache["kp"].shape[0], self._max_pg,
                     self.kv_page_size, self.cfg.num_kv_heads,
                     self.cfg.head_dim), self._cache["kp"].dtype)
                zssm = self._cache.get("ssm")
                ssm_row = (jnp.asarray(row.snap.ssm)
                           if row.snap is not None and row.snap.ssm is not None
                           else (zssm[:, 0] if zssm is not None
                                 else jnp.zeros((1,))))
                zconv = self._cache.get("conv")
                conv_row = (jnp.asarray(row.snap.conv)
                            if row.snap is not None and row.snap.conv is not None
                            else (zconv[:, 0] if zconv is not None
                                  else jnp.zeros((1,))))
                self._cache, state = self._restore_fn(
                    self._cache, kz, vz,
                    jnp.asarray(self._padded_pages([])), jnp.int32(slot),
                    jnp.int32(row.dev_pos), ssm_row, conv_row,
                    jnp.int32(row.gen[-1]), jnp.int32(len(row.gen)),
                    jnp.asarray(row.key, jnp.uint32),
                    jnp.float32(row.req.temperature),
                    jnp.int32(row.req.adapter_index), self._d_cur,
                    self._d_counters, self._d_keys, self._d_temps,
                    self._d_row_ids)
                (self._d_cur, self._d_counters, self._d_keys,
                 self._d_temps, self._d_row_ids) = state
                self._mask_sig = None
                now = time.monotonic()
                self._rows[slot] = row
                self._prompts[slot] = list(row.req.prompt)
                self._tr_install(slot, row, now, t0, "restore")
                # ownership transfer back: slot adopts the row's refcounts
                self._assign_slot_pages(slot, row.dev_pages, row.dev_pos)
                self._dev_parked.remove(row)
                row.dev_pages, row.dev_pos = None, 0
                self._drop_snap(row)
                self.stats.restores += 1
                self.stats.device_resident_resumes += 1
                self.stats.replay_tokens_saved += (row.prompt_len
                                                   + len(row.gen))
                self.stats.splice_seconds += now - t0
                if self.on_stage is not None:
                    self.on_stage("splice", row.req.task_id, t0, now)
                did = True
                continue
            snap = row.snap
            pages = self._alloc_pages(snap.n_pages)
            if pages is None:
                if (self._pages.used_pages == 0
                        and snap.n_pages > self._pages.n_pages):
                    self._finish_capacity(row)      # can never fit
                    continue
                with self._stage_lock:              # pool pressure: retry
                    self._sched.push(row, self.stats.refills)
                break
            slot = free.pop(0)
            t0 = time.monotonic()
            L_attn = 1 if snap.kpages is None else snap.kpages.shape[0]
            pad = self._max_pg - snap.n_pages
            kpages = vpages = jnp.zeros(
                (L_attn, self._max_pg, self.kv_page_size,
                 self.cfg.num_kv_heads, self.cfg.head_dim), jnp.float32)
            if snap.kpages is not None:
                kpages = jnp.asarray(np.pad(
                    snap.kpages, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0))))
                vpages = jnp.asarray(np.pad(
                    snap.vpages, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0))))
            zssm = self._cache.get("ssm")
            ssm_row = (jnp.asarray(snap.ssm) if snap.ssm is not None
                       else (zssm[:, 0] if zssm is not None else jnp.zeros((1,))))
            zconv = self._cache.get("conv")
            conv_row = (jnp.asarray(snap.conv) if snap.conv is not None
                        else (zconv[:, 0] if zconv is not None else jnp.zeros((1,))))
            self._cache, state = self._restore_fn(
                self._cache, kpages, vpages,
                jnp.asarray(self._padded_pages(pages)), jnp.int32(slot),
                jnp.int32(snap.pos), ssm_row, conv_row,
                jnp.int32(snap.cur), jnp.int32(len(row.gen)),
                jnp.asarray(row.key, jnp.uint32),
                jnp.float32(row.req.temperature),
                jnp.int32(row.req.adapter_index), self._d_cur,
                self._d_counters, self._d_keys, self._d_temps,
                self._d_row_ids)
            (self._d_cur, self._d_counters, self._d_keys, self._d_temps,
             self._d_row_ids) = state
            self._mask_sig = None
            now = time.monotonic()
            self._rows[slot] = row
            self._prompts[slot] = list(row.req.prompt)
            self._tr_install(slot, row, now, t0, "restore")
            self._assign_slot_pages(slot, pages, snap.pos)
            self._drop_snap(row)
            self.stats.restores += 1
            self.stats.replay_tokens_saved += row.prompt_len + len(row.gen)
            self.stats.splice_seconds += now - t0
            if self.on_stage is not None:
                self.on_stage("splice", row.req.task_id, t0, now)
            did = True
        if did:
            self.stats.refills += 1     # one refill event (starvation aging)
        return did

    def _ensure_decode_pages(self):
        """Pre-step growth: every resident ACTIVE row is about to write
        its K/V at cache position ``_slot_pos`` — allocate the covering
        page when the row crosses a page boundary. A row the pool cannot
        serve finishes via cache-capacity eviction (pool exhaustion is a
        scheduling condition, not a crash)."""
        for slot, r in enumerate(self._rows):
            if r is None or r.status != "active":
                continue
            if self.cfg.family == "ssm":
                continue
            need_idx = self._slot_pos[slot] // self.kv_page_size
            if need_idx >= self._max_pg:
                continue            # accept() finishes the row at max_len
            if need_idx < len(self._slot_pages[slot]):
                page = self._slot_pages[slot][need_idx]
                if (self.prefix_cache
                        and self._pages.refcount(page) > 1):
                    # copy-on-write fork: the row is about to decode-write
                    # into a SHARED page — privatize just this page (alloc
                    # + one-page copy); every earlier shared page stays
                    # shared. The last sibling standing sees rc==1 and
                    # writes in place.
                    pg = self._alloc_pages(1)
                    if pg is None:
                        r.status, r.finish_reason = "done", "capacity"
                        self.stats.pool_exhausted += 1
                        self._evict(slot)
                        continue
                    self._cache = self._cow_fn(self._cache, jnp.int32(page),
                                               jnp.int32(pg[0]))
                    self._pages.release([page])
                    self._slot_pages[slot][need_idx] = pg[0]
                    self._tbl_host[slot, need_idx] = pg[0]
                    self._tbl_dirty = True
                    self.stats.cow_forks += 1
                continue
            pg = (self._alloc_pages(1) if self.prefix_cache
                  else self._pages.alloc(1))
            if pg is None:
                r.status, r.finish_reason = "done", "capacity"
                self.stats.pool_exhausted += 1
                self._evict(slot)
                continue
            self._slot_pages[slot].extend(pg)
            self._tbl_host[slot, need_idx] = pg[0]
            self._tbl_dirty = True

    def page_stats(self) -> Dict[str, float]:
        """Pool occupancy/fragmentation gauges: used/total pages, the
        high-water mark, internal fragmentation (allocated page slack
        beyond the live cache entries), and the prefix-cache sharing
        gauges (shared pages, index-held pages, HBM bytes per resident
        row)."""
        if self._pages is None:
            return {}
        used = self._pages.used_pages
        cap_tokens = used * self.kv_page_size
        live = sum(min(self._slot_pos[s],
                       len(self._slot_pages[s]) * self.kv_page_size)
                   for s in range(self.max_slots)
                   if self._rows[s] is not None)
        frag = 1.0 - live / cap_tokens if cap_tokens else 0.0
        resident = sum(1 for r in self._rows if r is not None)
        resident += len(self._dev_parked)      # in-pool parked rows count:
                                               # their pages are HBM too
        dtype_bytes = (self._cache["kp"].dtype.itemsize
                       if self._cache is not None and "kp" in self._cache
                       else 2)
        hbm = cap_tokens * self.cfg.state_bytes_per_token(dtype_bytes)
        return {"kv_pages_used": float(used),
                "kv_pages_total": float(self._pages.n_pages),
                "kv_pages_peak": float(self._pages.peak_used),
                "kv_page_frag": float(frag),
                "kv_shared_pages": float(self._pages.shared_pages),
                "kv_prefix_pages": float(
                    self._prefix_idx.held_pages
                    if self._prefix_idx is not None else 0),
                "kv_hbm_bytes_per_row": float(hbm / max(1, resident)),
                "snapshot_bytes": float(
                    self._snap_store.bytes_used if self._snap_store else 0)}

    def check_page_invariants(self):
        """Debug assertion the test suite runs after every drive loop:
        allocator-level conservation (``PagePool.check_invariants``) PLUS
        exact refcount accounting — every page's rc must equal its owner
        count across resident slots, device-parked rows, and radix-index
        nodes, and the host block-table mirror must name exactly the
        slots' pages. Catches COW leaks and double-frees at the step they
        happen instead of as end-of-run drift."""
        if self._pages is None:
            return
        self._pages.check_invariants()
        owners = np.zeros((self._pages.n_pages,), np.int64)
        for s in range(self.max_slots):
            for p in self._slot_pages[s]:
                owners[p] += 1
            want = np.full((self._max_pg,), self._pages.sentinel, np.int32)
            want[:len(self._slot_pages[s])] = self._slot_pages[s]
            assert (self._tbl_host[s] == want).all(), \
                f"slot {s}: block-table mirror out of sync"
        for row in self._dev_parked:
            assert row.dev_pages is not None
            for p in row.dev_pages:
                owners[p] += 1
        if self._prefix_idx is not None:
            for p, n in self._prefix_idx.refcounts().items():
                owners[p] += n
        for p in range(self._pages.n_pages):
            assert self._pages.refcount(p) == owners[p], (
                f"page {p}: rc={self._pages.refcount(p)} but "
                f"{owners[p]} owners (slots+parked+index)")

    def queued_state_bytes(self, task_id: str,
                           dtype_bytes: int = 2) -> Optional[int]:
        """ACTUAL byte need of a tenant's queued/parked rows (paged mode):
        snapshot page counts for restore rows (exact — what restore will
        allocate), page-rounded prompt+prefix for replay rows, plus the
        fixed recurrent state. Feeds the admission controller's
        readmission re-estimate, replacing the worst-case ``max_len``
        charge. None in dense mode (caller falls back to the estimator)."""
        if not self.paged_kv:
            return None
        with self._stage_lock:
            rows = self._sched.rows_for(task_id)
            rows += [r for r in self._stage_inflight
                     if r.req.task_id == task_id]
            rows += [rr.row for rr in self._ready
                     if rr.row.req.task_id == task_id]
        if self._env is not None:
            rows += self._env.rows_for(task_id)
        per_tok = self.cfg.state_bytes_per_token(dtype_bytes)
        fixed = self.cfg.state_bytes_fixed(dtype_bytes)
        total = 0
        for r in rows:
            if getattr(r, "dev_pages", None) is not None:
                # device-parked: its pages are ALREADY in the pool — the
                # resume allocates nothing, only the fixed state returns
                total += fixed
                continue
            n_pg = (r.snap.n_pages if getattr(r, "snap", None) is not None
                    else self._row_pages_needed(r.prompt_len + len(r.gen)))
            total += n_pg * self.kv_page_size * per_tok + fixed
        return int(total)

    # -- preemption -------------------------------------------------------
    def _preemptible(self, slot: int, protect=()) -> bool:
        r = self._rows[slot]
        return (r is not None and r.status == "active" and not r.forced_q
                and slot not in self._pending
                and r.req.task_id not in protect)

    def _preempt_slot(self, slot: int):
        """Free one slot: snapshot is implicit (the generated prefix already
        lives host-side in the _Row), so just vacate and re-queue. The
        re-queued row flows through the SAME path as a fresh one — in
        disaggregated mode a prefill worker replays prompt+prefix
        asynchronously and the row splices back with its original per-row
        counter, preserving token-for-token replay parity.

        Paged engine with ``resume_restore``: the row's KV pages + SSM
        state snapshot to host first, so the later resume SPLICES state
        back instead of re-prefilling — unless the snapshot was dropped
        under memory pressure, in which case the retained replay path
        runs (identical output either way)."""
        row = self._rows[slot]
        row.replays += 1
        if self.paged_kv:
            self._park_or_snap(slot, row)
        if self._tracer is not None:
            fid = self._tracer.next_flow("preempt")
            now = time.monotonic()
            self._tr_vacate(slot, row, now, flow_out=fid)
            self._tracer.mark(self._trace_of(row), "preempted", now)
            if isinstance(row.meta, dict):
                row.meta["_flow_in"] = fid    # consumed at reinstall
        self._rows[slot] = None
        self._prompts[slot] = None
        self.stats.preemptions += 1
        with self._stage_lock:
            self._sched.push(row, self.stats.refills)

    def preempt_tenant(self, task_id: str, max_rows: Optional[int] = None
                       ) -> int:
        """Preempt up to `max_rows` (default: all) of a tenant's resident
        rows; returns the number preempted. Rows mid tool-call or mid
        force-feed keep their slots (replay always samples its first
        token). The freed KV needs no save: replay re-prefills the prefix."""
        n = 0
        for slot in range(self.max_slots):
            if max_rows is not None and n >= max_rows:
                break
            r = self._rows[slot]
            if (r is not None and r.req.task_id == task_id
                    and self._preemptible(slot)):
                self._preempt_slot(slot)
                n += 1
        return n

    def preempt_slots(self, n: int, protect=()) -> int:
        """Free up to `n` slots for an incoming tenant by preempting the
        lowest-priority / longest-remaining-budget resident rows (tenants in
        `protect` are never victims). Returns the number actually freed."""
        victims = [s for s in range(self.max_slots)
                   if self._preemptible(s, protect)]
        victims.sort(key=lambda s: (self._rows[s].req.priority,
                                    -(self._rows[s].req.max_new_tokens
                                      - self._rows[s].sampled),
                                    -self._rows[s].submit_index))
        freed = 0
        for slot in victims[:n]:
            self._preempt_slot(slot)
            freed += 1
        return freed

    def abort_tenant(self, task_id: str, reason: str = "quarantined") -> int:
        """Abort EVERY in-flight episode of one tenant — resident rows,
        queued / mid-prefill / ready-to-splice rows, and env-parked jobs —
        each yielding exactly one completion with ``reason`` as its
        finish_reason (the runtime counts them as quarantine drops).
        Other tenants' rows and scheduling order are untouched."""
        n = 0
        if self._env is not None:
            for job in self._env.cancel_tenant(task_id):
                row = job.row
                if row.status == "done":
                    continue     # expired earlier; already completed
                row.status, row.finish_reason = "done", reason
                self._complete_parked(row)
                n += 1
        for slot, r in enumerate(self._rows):
            if r is not None and r.req.task_id == task_id:
                r.status, r.finish_reason = "done", reason
                self._evict(slot)    # cancels a pending tool future too
                n += 1
        with self._stage_lock:
            drained: List[_Row] = []
            while True:
                row = self._sched.pop(
                    self.stats.refills,
                    where=lambda r: r.req.task_id == task_id)
                if row is None:
                    break
                drained.append(row)
            # mid-prefill rows: removing them from _stage_inflight makes
            # the owning worker's eventual _emit a no-op (abort idiom the
            # drain() path established)
            for row in list(self._stage_inflight):
                if row.req.task_id == task_id:
                    self._stage_inflight.remove(row)
                    drained.append(row)
            keep: Deque[ReadyRow] = deque()
            for rr in self._ready:
                if rr.row.req.task_id == task_id:
                    drained.append(rr.row)
                else:
                    keep.append(rr)
            self._ready = keep
        for row in drained:
            row.status, row.finish_reason = "done", reason
            self._complete_parked(row)
            n += 1
        return n

    # -- radix prefix reuse + GRPO-group sharing ---------------------------
    def _radix_on(self) -> bool:
        """Radix/group page sharing applies to pure-attention stacks only:
        suffix prefill at an arbitrary page offset is exact for attention
        (the same chunked-prefill decomposition the async workers use) but
        not for SSD recurrences mid-chunk, and SSM/hybrid rows carry
        recurrent state that has no shareable paged form."""
        return (self._prefix_idx is not None and self._cache is not None
                and "kp" in self._cache and "ssm" not in self._cache)

    def _group_key(self, r: _Row):
        return (r.req.adapter_index, tuple(r.req.prompt))

    def _radix_candidate(self, r: _Row):
        """Shared-install plan for a queued row whose prefix is in-pool:
        ``(shared_pages, start, L)`` or None. ``shared_pages`` are the
        pool pages the row will reference (NOT yet retained) and ``start``
        the page-aligned offset its suffix prefill resumes from. An exact
        whole-sequence hit (a GRPO-group sibling, or an unmodified
        re-submit) shares EVERY page including the partial tail: nothing
        is written at install — the final chunk recomputes only for the
        first-token logits — and the first decode write past the shared
        boundary COW-forks the tail page."""
        if r.snap is not None or r.dev_pages is not None:
            return None
        seq = list(r.req.prompt) + r.gen
        L = len(seq)
        adapter = r.req.adapter_index
        hit = self._prefix_idx.match_full(adapter, seq)
        if hit is not None:
            pages, tail = hit
            shared = pages + ([tail] if tail is not None else [])
            start = (len(pages) - (1 if tail is None else 0)) \
                * self.kv_page_size
            if start + _bucket_len(L - start) <= self.max_len:
                return (shared, start, L)
        pages = self._prefix_idx.match(adapter, seq, max_tokens=L - 1)
        if not pages:
            return None
        start = len(pages) * self.kv_page_size
        if start + _bucket_len(L - start) > self.max_len:
            return None                  # suffix bucket would overflow
        return (pages, start, L)

    def _index_prompt(self, row: _Row, row_pages: List[int]):
        """Publish a freshly installed row's prompt pages (full pages +
        partial tail) to the per-tenant radix index so later same-prefix
        rows share them. The index holds its own refcount per page
        (retained here); entries outlive the row and drop via LRU eviction
        under pool pressure or adapter-swap invalidation. Valid on EVERY
        install path — prompt-position K/V depends only on prompt tokens,
        so even a replayed row's pages hold the exact prompt prefix."""
        if not self._radix_on():
            return
        n_full = row.prompt_len // self.kv_page_size
        if n_full < 1:
            return
        rem = row.prompt_len % self.kv_page_size
        tail = (int(row_pages[n_full])
                if rem and len(row_pages) > n_full else None)
        newly = self._prefix_idx.insert(
            row.req.adapter_index, row.req.prompt,
            [int(p) for p in row_pages[:n_full]], tail_page=tail)
        if newly:
            self._pages.retain(newly)

    def _radix_fill_rows(self) -> bool:
        """Decode-thread install of queued rows whose prefix is already
        in-pool (radix hit / GRPO sibling): retain the shared pages,
        prefill ONLY the suffix (`_suffix_fn`, one width-1 call per row;
        jit caches one variant per (start, suffix-bucket) pair), and book
        only the suffix as prefill work. Runs before the private fill
        paths each step, and pops with ``pop_if`` so a sharable row never
        jumps a higher-priority tenant."""
        if not self._radix_on():
            return False
        free = [s for s in range(self.max_slots) if self._rows[s] is None]
        if not free:
            return False
        self._ensure_built()
        if self._stacked is None:
            return False          # the fill paths raise the proper error
        installed = 0
        while free:
            with self._stage_lock:
                row = self._sched.pop_if(
                    self.stats.refills,
                    lambda r: self._radix_candidate(r) is not None)
            if row is None:
                break
            plan = self._radix_candidate(row)
            if plan is None:      # index mutated between pop and here
                with self._stage_lock:
                    self._sched.push(row, self.stats.refills)
                break
            shared, start, L = plan
            self._pages.retain(shared)
            fresh = self._alloc_pages(self._row_pages_needed(L)
                                      - len(shared))
            if fresh is None:     # pool pressure: retry next step
                self._pages.release(shared)
                with self._stage_lock:
                    self._sched.push(row, self.stats.refills)
                break
            slot = free.pop(0)
            t0 = time.monotonic()
            seq = list(row.req.prompt) + row.gen
            S_b = _bucket_len(L - start)
            toks = np.zeros((1, S_b), np.int32)
            toks[0, :L - start] = seq[start:]
            n_chunks = self.max_len // self.kv_page_size
            dest = np.full((n_chunks,), self._pages.sentinel, np.int32)
            dest[len(shared):len(shared) + len(fresh)] = fresh
            was_forced = bool(row.forced_q)
            first, lp, self._cache, state = self._suffix_fn(
                start, self.base_params, self._stacked,
                jnp.asarray([row.req.adapter_index], jnp.int32),
                jnp.asarray(shared[:start // self.kv_page_size], jnp.int32),
                jnp.asarray(toks), jnp.asarray([L], jnp.int32),
                jnp.asarray([len(row.gen)], jnp.int32),
                jnp.asarray(row.key[None], jnp.uint32),
                jnp.asarray([row.req.temperature], jnp.float32),
                jnp.asarray([row.forced_q[0] if was_forced else 0],
                            jnp.int32),
                jnp.asarray([1 if was_forced else 0], jnp.int32),
                self._cache, jnp.asarray(dest), jnp.int32(slot),
                self._d_cur, self._d_counters, self._d_keys, self._d_temps,
                self._d_row_ids)
            (self._d_cur, self._d_counters, self._d_keys, self._d_temps,
             self._d_row_ids) = state
            self._mask_sig = None
            now = time.monotonic()
            installed += 1
            self._rows[slot] = row
            self._prompts[slot] = list(row.req.prompt)
            self._tr_install(slot, row, now, t0, "prefill")
            self._assign_slot_pages(slot, shared + fresh, L)
            self._index_prompt(row, shared + fresh)
            self.stats.prefix_hits += 1
            self.stats.prefix_hit_tokens += start
            self.stats.prefill_tokens += L - start      # suffix only
            self.stats.prefill_seconds += now - t0
            self.stats.decode_stall_seconds += now - t0
            if was_forced:                    # env-stage resume splice
                row.forced_q.pop(0)
                if row.gen:
                    self.stats.replays += 1
                    self.stats.replay_tokens += L - start
            elif row.gen:                     # preemption replay
                self.stats.replays += 1
                self.stats.replay_tokens += L - start
            else:                             # fresh row (GRPO sibling)
                self.stats.prefills += 1
                row.started_at = now
            self.stats.tokens_generated += 1
            if not was_forced:
                self.stats.sampled_tokens += 1
            if self.on_stage is not None:
                self.on_stage("prefill", row.req.task_id, t0, now)
            action = row.accept(int(np.asarray(first)[0]),
                                float(np.asarray(lp)[0]),
                                0.0 if was_forced else 1.0, self.max_len)
            if action == "call":
                self._on_call(slot)
            elif action == "done":
                self._evict(slot)
        if installed:
            self.stats.refills += 1    # one refill event (starvation aging)
        return installed > 0

    def _fusable_forced(self, row: _Row) -> bool:
        """Response-prefill fusion guard: a forced RESP…ENDRESP block can
        fold into the row's (re)prefill call only when replaying it
        step-wise would provably not terminate or branch mid-block —
        forced tokens never dispatch CALLs (mask 0), so the only early
        exits are a forced EOS, the max_len capacity trip, or the
        sampling budget firing at the block's last token."""
        q = row.forced_q
        if len(q) <= 1:
            return False            # single opener: already one call
        if tok.EOS in q:
            return False
        if row.prompt_len + len(row.gen) + len(q) >= self.max_len:
            return False
        if row.sampled >= row.req.max_new_tokens:
            return False
        return True

    def _refill_free_slots(self) -> bool:
        """Fill every freed slot from the queue with ONE fused jitted call:
        batch-prefill the incoming rows, splice their KV/SSM state into the
        pool, and sample their first tokens. Ghost lanes (fewer queued rows
        than the padded width) scatter out of bounds and are dropped, so the
        call shape depends only on (width, prompt bucket).

        The queue pops in scheduler order (priority / predicted-remaining /
        starvation tier). A preemption-replayed row prefills its prompt +
        generated prefix in one sequence and samples token `len(gen)` with
        counter `len(gen)` — bit-identical continuation.

        Prefix cache interplay: snapshot/device-parked rows restore on the
        decode thread and radix-sharable rows install via
        ``_radix_fill_rows`` — neither pops here. A GRPO sibling of a row
        popped THIS round defers one step (``seen_keys``) so the leader's
        pages reach the index first and the sibling lands as an
        exact-match share instead of a private prefill."""
        free = [s for s in range(self.max_slots) if self._rows[s] is None]
        with self._stage_lock:
            has_queued = bool(self._sched)
        if not free or not has_queued:
            return False
        self._ensure_built()
        if self._stacked is None:
            raise RuntimeError("no adapters installed — call set_adapters()")
        t0 = time.monotonic()
        incoming: List[Tuple[int, _Row]] = []
        pages_of: List[List[int]] = []
        seen_keys = set()
        radix = self._radix_on()
        where = None
        if self.resume_restore or radix:
            def where(r):
                # snapshot/device-parked rows restore on the decode
                # thread (no prefill at all); radix candidates install
                # through the suffix-only path
                if r.snap is not None or r.dev_pages is not None:
                    return False
                if radix and self._radix_candidate(r) is not None:
                    return False
                if radix and len(r.req.prompt) >= self.kv_page_size \
                        and self._group_key(r) in seen_keys:
                    return False        # sibling: wait for the leader
                return True
        pressure = False
        with self._stage_lock:
            while free and self._sched:
                row = self._sched.pop(self.stats.refills, where=where)
                if row is None:
                    break
                if self.paged_kv:
                    n_pg = self._row_pages_needed(
                        len(row.req.prompt) + len(row.gen))
                    # spill=False: dev-parked spilling gathers device
                    # state (host sync) — never under _stage_lock; cold
                    # radix entries still evict (pure host bookkeeping)
                    pages = self._alloc_pages(n_pg, spill=False)
                    if pages is None:
                        if self._pages.used_pages == 0:
                            self._finish_capacity(row)   # can never fit
                            continue
                        # pool pressure: resident rows will free pages
                        self._sched.push(row, self.stats.refills)
                        pressure = True
                        break
                    pages_of.append(pages)
                incoming.append((free.pop(0), row))
                if radix:
                    seen_keys.add(self._group_key(row))
        if not incoming:
            if pressure and self._dev_parked:
                # nothing installable and nothing resident to free pages:
                # demote the oldest device-parked row to the host tier
                # (outside the lock) so the next step's alloc succeeds
                self._spill_dev_parked()
            return False
        k = len(incoming)
        W = 1                                    # next-pow2 width bucket
        while W < k:
            W *= 2
        # response-prefill fusion (paged path): a resume's whole forced
        # RESP…ENDRESP block joins the prefilled sequence — its tokens are
        # known — instead of force-feeding one decode step each
        fused = [self.paged_kv and self._fusable_forced(row)
                 for _, row in incoming]
        seqs = [list(row.req.prompt) + row.gen
                + (row.forced_q if fused[j] else [])
                for j, (_, row) in enumerate(incoming)]
        F_B = max([1] + [_bucket_len(len(r.forced_q))
                         for j, (_, r) in enumerate(incoming) if fused[j]])
        S_p = _bucket_len(max(len(s) for s in seqs))
        tokens = np.zeros((W, S_p), np.int32)
        prompt_lens = np.ones((W,), np.int32)    # ghosts: len-1 dummy prompt
        init_counters = np.zeros((W,), np.int32)
        row_ids = np.zeros((W,), np.int32)
        slots = np.full((W,), self.max_slots, np.int32)   # ghosts: OOB → drop
        keys = np.zeros((W, 2), np.uint32)
        temps = np.ones((W,), np.float32)
        forced = np.zeros((W,), np.int32)        # env-stage resumes install
        fmask = np.zeros((W,), np.int32)         # a forced RESP opener
        fpos = np.zeros((W, F_B), np.int32)      # fusion: positions whose
        ftoks = np.zeros((W, F_B), np.int32)     # logits predict each
                                                 # forced token
        for j, (slot, row) in enumerate(incoming):
            tokens[j, :len(seqs[j])] = seqs[j]
            prompt_lens[j] = len(seqs[j])
            init_counters[j] = len(row.gen)
            row_ids[j] = row.req.adapter_index
            slots[j] = slot
            keys[j] = row.key
            temps[j] = row.req.temperature
            if fused[j]:
                L0 = len(row.req.prompt) + len(row.gen)
                Fj = len(row.forced_q)
                init_counters[j] = len(row.gen) + Fj
                fpos[j, :Fj] = np.arange(L0 - 1, L0 - 1 + Fj)
                ftoks[j, :Fj] = row.forced_q
            elif row.forced_q:
                forced[j] = row.forced_q[0]
                fmask[j] = 1
        if self.paged_kv:
            # physical destination pages per (row, chunk); ghost rows and
            # chunks past a row's page count point at the scratch page
            n_chunks = self.max_len // self.kv_page_size
            dest = np.full((W, n_chunks), self._pages.sentinel, np.int32)
            for j, pages in enumerate(pages_of):
                dest[j, :len(pages)] = pages
            first, lp, flp, self._cache, state = self._refill_fn(
                self.base_params, self._stacked, jnp.asarray(tokens),
                jnp.asarray(prompt_lens), jnp.asarray(init_counters),
                jnp.asarray(slots), jnp.asarray(dest), jnp.asarray(row_ids),
                jnp.asarray(keys), jnp.asarray(temps), jnp.asarray(forced),
                jnp.asarray(fmask), jnp.asarray(fpos), jnp.asarray(ftoks),
                self._cache, self._d_cur, self._d_counters, self._d_keys,
                self._d_temps, self._d_row_ids)
            flp = np.asarray(flp)
        else:
            first, lp, self._cache, state = self._refill_fn(
                self.base_params, self._stacked, jnp.asarray(tokens),
                jnp.asarray(prompt_lens), jnp.asarray(init_counters),
                jnp.asarray(slots), jnp.asarray(row_ids), jnp.asarray(keys),
                jnp.asarray(temps), jnp.asarray(forced), jnp.asarray(fmask),
                self._cache, self._d_cur, self._d_counters,
                self._d_keys, self._d_temps, self._d_row_ids)
        (self._d_cur, self._d_counters, self._d_keys, self._d_temps,
         self._d_row_ids) = state
        first = np.asarray(first)
        lp = np.asarray(lp)
        now = time.monotonic()
        self.stats.refills += 1
        # stage attribution (pre-existing bug: this was booked as decode
        # time): the fused refill is PREFILL-stage work, and because it runs
        # on the decode stream it is also decode-stall time — the quantity
        # the disaggregated path drives to zero.
        self.stats.prefill_seconds += now - t0
        self.stats.decode_stall_seconds += now - t0
        if self.on_stage is not None:
            self.on_stage("prefill",
                          "+".join(sorted({r.req.task_id
                                           for _, r in incoming})), t0, now)
        if self._tracer is not None:
            self._tracer.span(("prefill", "fused"),
                              "+".join(sorted({r.req.task_id
                                               for _, r in incoming})),
                              t0, now)
        for j, (slot, row) in enumerate(incoming):
            self._rows[slot] = row
            self._prompts[slot] = list(row.req.prompt)
            self._tr_install(slot, row, now, t0, "prefill")
            if self.paged_kv:
                self._assign_slot_pages(slot, pages_of[j], len(seqs[j]))
                self._index_prompt(row, pages_of[j])
            was_forced = fmask[j] == 1
            L_replay = len(row.req.prompt) + len(row.gen)
            if was_forced or fused[j]:            # env-stage resume splice
                if row.gen:   # the resume re-prefilled prompt+prefix: the
                    self.stats.replays += 1       # per-turn recomputation
                    self.stats.replay_tokens += L_replay  # restore kills
            elif row.gen:                         # preemption replay
                self.stats.replays += 1
                self.stats.replay_tokens += L_replay
            else:                                 # fresh row
                self.stats.prefills += 1
                row.started_at = now
            self.stats.prefill_tokens += len(seqs[j])
            if fused[j]:
                Fj = len(row.forced_q)
                self.stats.fused_forced_tokens += Fj
                self.stats.tokens_generated += Fj
                for t in range(Fj):
                    tk = row.forced_q.pop(0)   # pop BEFORE accept, like the
                    # step-wise path: accept's budget check reads forced_q
                    action = row.accept(tk, float(flp[j, t]), 0.0,
                                        self.max_len)
                    assert action == "continue", \
                        "fusion guard admitted a terminating forced block"
            elif was_forced:
                row.forced_q.pop(0)
            self.stats.tokens_generated += 1
            if not was_forced:
                self.stats.sampled_tokens += 1
            action = row.accept(int(first[j]), float(lp[j]),
                                0.0 if was_forced else 1.0, self.max_len)
            if action == "call":
                self._on_call(slot)
            elif action == "done":
                self._evict(slot)
        return True

    def _splice_ready_rows(self) -> bool:
        """Decode-side half of the disaggregated split: install rows the
        async prefill stage finished into freed slots with one scatter-only
        jitted call each. No prefill graph runs on the decode stream — the
        splice is O(cache row copy), so decode never stalls on a prompt."""
        free = [s for s in range(self.max_slots) if self._rows[s] is None]
        if not free:
            return False
        ready: List[ReadyRow] = []
        with self._stage_lock:
            while free and self._ready:
                ready.append(self._ready.popleft())
                free.pop(0)
        if not ready:
            return False
        free = [s for s in range(self.max_slots) if self._rows[s] is None]
        t0 = time.monotonic()
        installed = 0
        for i_rr, rr in enumerate(ready):
            row = rr.row
            pages: List[int] = []
            if self.paged_kv:
                alloc = self._alloc_pages(self._row_pages_needed(rr.seq_len))
                if alloc is None:
                    if self._pages.used_pages == 0:
                        self._finish_capacity(row)      # can never fit
                        continue
                    with self._stage_lock:    # pool pressure: retry later
                        for back in reversed(ready[i_rr:]):
                            self._ready.appendleft(back)
                    break
                pages = alloc
            slot = free.pop(0)
            if self.paged_kv:
                self._cache, state = self._splice_fn(
                    self._cache, rr.pcache, jnp.int32(slot),
                    jnp.asarray(self._padded_pages(pages)),
                    jnp.int32(rr.seq_len), jnp.int32(rr.first),
                    jnp.int32(rr.init_counter),
                    jnp.asarray(row.key, jnp.uint32),
                    jnp.float32(row.req.temperature),
                    jnp.int32(row.req.adapter_index), self._d_cur,
                    self._d_counters, self._d_keys, self._d_temps,
                    self._d_row_ids)
            else:
                self._cache, state = self._splice_fn(
                    self._cache, rr.pcache, jnp.int32(slot),
                    jnp.int32(rr.seq_len), jnp.int32(rr.first),
                    jnp.int32(rr.init_counter),
                    jnp.asarray(row.key, jnp.uint32),
                    jnp.float32(row.req.temperature),
                    jnp.int32(row.req.adapter_index), self._d_cur,
                    self._d_counters, self._d_keys, self._d_temps,
                    self._d_row_ids)
            (self._d_cur, self._d_counters, self._d_keys, self._d_temps,
             self._d_row_ids) = state
            self._mask_sig = None      # slot contents changed
            now = time.monotonic()
            installed += 1
            self._rows[slot] = row
            self._prompts[slot] = list(row.req.prompt)
            self._tr_install(slot, row, now)
            if self.paged_kv:
                self._assign_slot_pages(slot, pages, rr.seq_len)
                self._index_prompt(row, pages)
            n_fused = len(rr.forced_lps)
            if rr.forced_first or n_fused:        # env-stage resume splice
                if rr.forced_first:
                    row.forced_q.pop(0)
                if row.gen:                       # resume re-prefilled the
                    self.stats.replays += 1       # whole prefix async
                    self.stats.replay_tokens += rr.seq_len - n_fused
            elif row.gen:                         # preemption replay
                self.stats.replays += 1
                self.stats.replay_tokens += rr.seq_len
            else:                                 # fresh row
                self.stats.prefills += 1
                row.started_at = now
            self.stats.splices += 1
            self.stats.splice_wait_seconds += max(0.0, now - rr.ready_at)
            if n_fused:
                # response-prefill fusion: the worker prefilled the whole
                # forced block — book its tokens here with the prefill
                # logprobs (bit-equal to the step-wise force-feed)
                self.stats.fused_forced_tokens += n_fused
                self.stats.tokens_generated += n_fused
                for t in range(n_fused):
                    tk = row.forced_q.pop(0)
                    action = row.accept(tk, rr.forced_lps[t], 0.0,
                                        self.max_len)
                    assert action == "continue", \
                        "fusion guard admitted a terminating forced block"
            self.stats.tokens_generated += 1
            if not rr.forced_first:
                self.stats.sampled_tokens += 1
            action = row.accept(rr.first, rr.lp,
                                0.0 if rr.forced_first else 1.0,
                                self.max_len)
            if action == "call":
                self._on_call(slot)
            elif action == "done":
                self._evict(slot)
        if installed == 0:
            return False
        now = time.monotonic()
        self.stats.refills += 1        # one refill event (starvation aging)
        self.stats.splice_seconds += now - t0
        if self.on_stage is not None:
            self.on_stage("splice",
                          "+".join(sorted({rr.row.req.task_id
                                           for rr in ready})), t0, now)
        if self._tracer is not None:
            self._tracer.span(("rollout", "splice"),
                              "+".join(sorted({rr.row.req.task_id
                                               for rr in ready})), t0, now)
        return True

    def _on_call(self, slot: int):
        """Route a freshly sampled CALL: park the row in the env stage
        (env_stage mode) or freeze it in its slot (baseline)."""
        if self._env is not None:
            self._park(slot)
        else:
            self._dispatch_tool(slot)

    def _dispatch_tool(self, slot: int):
        self._pending[slot], self._pending_tok[slot] = _submit_tool_call(
            self._rows[slot], self._prompts[slot], self._pool, self._rng,
            self.sim_latency)
        self._pending_t0[slot] = time.monotonic()
        if self._tracer is not None:
            # freeze-in-slot baseline: the row stays resident, so the
            # env window is a lifecycle state only (no park hand-off)
            self._tracer.mark(self._trace_of(self._rows[slot]), "env",
                              self._pending_t0[slot])

    def _park(self, slot: int):
        """Env-stage path: vacate the slot the moment the row samples CALL.
        The generated prefix already lives host-side (the same snapshot
        preemption relies on), so parking is free of device copies — the
        slot is immediately refillable from the scheduler queue while an
        EnvWorker runs the tool call."""
        row = self._rows[slot]
        row.ensure_session()
        query = list(self._prompts[slot]) + row.gen
        latency = row.req.env.sample_env_latency(
            _RandomShim(self._rng)) if not self.sim_latency else 0.0
        if self.paged_kv:
            # resume_restore: the row's resume state is preserved — pages
            # stay IN-POOL under the prefix cache (pure retain, zero host
            # bytes) or snapshot to host otherwise; the tool-response
            # resume splices them back instead of replaying prompt+prefix
            self._park_or_snap(slot, row)
        fid = 0
        if self._tracer is not None:
            fid = self._tracer.next_flow("park")
            now = time.monotonic()
            self._tr_vacate(slot, row, now, flow_out=fid)
            self._tracer.mark(self._trace_of(row), "parked", now)
        self._rows[slot] = None
        self._prompts[slot] = None
        self.stats.parks += 1
        job = self._env.submit(row, query, row.req.task_id, latency)
        job.flow = fid

    def _pump_env_stage(self):
        """Resolve the env stage's response queue: expire timed-out jobs
        (their rows finish with tool_timeout — they hold no slot), and turn
        each response into a resume job — the row re-enters the scheduler
        queue with its force-feed queue pre-loaded; the (fused or
        disaggregated) prefill path replays prompt+prefix and installs the
        forced RESP opener."""
        now = time.monotonic()
        for job in self._env.expire(now, self.tool_timeout_s):
            row = job.row
            row.status, row.finish_reason = "done", "tool_timeout"
            self._complete_parked(row)
        # drain_resolved() pops the WHOLE resolved batch: process every job
        # before surfacing an error, else the siblings' rows would vanish
        # from all engine accounting (no slot, no queue, no completion)
        first_error: Optional[BaseException] = None
        for job in self._env.drain_resolved():
            row = job.row
            if job.error is not None:
                # ToolError (permanent / retries exhausted) is an expected
                # EPISODE outcome: the row finishes with finish_reason
                # tool_error — counted, never trained, feeding the tenant
                # breaker. Anything else is a bug in our stack and stays
                # fatal, so chaos-off behaviour is unchanged.
                row.status, row.finish_reason = "done", "tool_error"
                self.stats.tool_errors += 1
                if self._tracer is not None:
                    self._tracer.mark(self._trace_of(row), "tool_error")
                self._complete_parked(row)
                if not isinstance(job.error, ToolError):
                    first_error = first_error or job.error
                continue
            tid = row.req.task_id
            self.stats.add_env_wait(tid, job.resolved_at - job.submitted_at)
            if self.on_stage is not None:
                self.on_stage("env", tid, job.submitted_at, job.resolved_at)
            if self._tracer is not None:
                # env worker span + the two hand-off arrows: park→env
                # (job.flow, opened at _park) and env→resume (opened
                # here, consumed when the row reinstalls into a slot)
                trace = self._trace_of(row)
                fid = self._tracer.next_flow("resume")
                self._tracer.span(("env", f"worker-{job.worker}"), tid,
                                  job.started_at, job.resolved_at,
                                  trace=trace, flow_in=job.flow,
                                  flow_out=fid)
                self._tracer.mark(trace, "env", job.started_at)
                self._tracer.mark(trace, "resume_queued", job.resolved_at)
                if isinstance(row.meta, dict):
                    row.meta["_flow_in"] = fid
            row.forced_q = [tok.RESP] + list(job.response) + [tok.ENDRESP]
            row.status = "active"
            self.stats.resumes += 1
            with self._stage_lock:
                self._sched.push(row, self.stats.refills)
        if first_error is not None:      # surface like fut.result() does
            raise first_error

    # -- scheduler interface ---------------------------------------------
    def step(self) -> bool:
        """One engine iteration: resolve tools, fill freed slots (fused
        refill, or splice of async-prefilled rows in disaggregated mode),
        one decode step over the pool, evict finished rows. Returns True if
        any device work happened (refill/splice or decode)."""
        now = time.monotonic()
        progressed = False
        # stage supervision: detect dead/wedged workers, recover their
        # stranded work, respawn to complement under backoff (no-op while
        # every pool is at complement — one healthy() call per stage)
        self.supervisor.tick(now)
        # env-interaction stage: expire + resume parked rows (env_stage
        # mode); the baseline freeze-in-slot path resolves futures below
        if self._env is not None:
            self._pump_env_stage()
        # resolve / time out pending tool calls (freeze-in-slot baseline)
        for slot in list(self._pending):
            fut = self._pending[slot]
            row = self._rows[slot]
            if fut.done():
                resp = fut.result()
                t0w = self._pending_t0[slot]
                tid = row.req.task_id
                self.stats.add_env_wait(tid, now - t0w)
                if self.on_stage is not None:
                    self.on_stage("env", tid, t0w, now)
                if self._tracer is not None:
                    trace = self._trace_of(row)
                    self._tracer.span(("env", "pool"), tid, t0w, now,
                                      trace=trace)
                    self._tracer.mark(trace, "decode", now)
                row.forced_q = [tok.RESP] + list(resp) + [tok.ENDRESP]
                row.status = "active"
                del self._pending[slot], self._pending_t0[slot]
            elif now - self._pending_t0[slot] > self.tool_timeout_s:
                row.status, row.finish_reason = "done", "tool_timeout"
                self._evict(slot)
        # snapshot-restore resume (paged engine): queued rows carrying a
        # host snapshot splice their saved pages back on the decode thread
        # — no prefill graph, no replay — before the fill paths run
        if self.resume_restore and self._restore_rows():
            progressed = True
        # radix/GRPO shared installs (prefix cache): rows whose prefix is
        # already in-pool retain it and prefill only their suffix — runs
        # on the decode thread before the private fill paths in BOTH
        # fused and disaggregated modes
        if self.paged_kv and self._stacked is not None \
                and self._radix_fill_rows():
            progressed = True
        # fill freed slots from the cross-task queue: disaggregated mode
        # splices asynchronously-prefilled rows (decode never runs a prefill
        # graph); fused mode runs the baseline one-call refill
        if self.disagg_prefill:
            self._raise_stage_error()
            if self._stacked is None:
                with self._stage_lock:
                    has_queued = len(self._sched) > 0
                if has_queued:      # same fail-fast as the fused refill
                    raise RuntimeError(
                        "no adapters installed — call set_adapters()")
            else:
                if not self._workers:
                    self._ensure_stage()  # first start / post-halt only;
                                          # replacements are the
                                          # supervisor's (backoff-gated)
                if self._splice_ready_rows():
                    progressed = True
        elif self._refill_free_slots():
            progressed = True
        if self._env is not None:
            # the engine invariant of the disaggregated env stage: a
            # tool-waiting row NEVER occupies a decode slot (it parks)
            assert all(r is None or r.status != "calling"
                       for r in self._rows), \
                "env-stage invariant violated: tool-waiting row resident"
        if self.paged_kv:
            # pre-step growth: allocate the page each active row's next
            # K/V write lands in (cache-capacity eviction on exhaustion),
            # then upload the block table if the topology changed
            self._ensure_decode_pages()
            if self._tbl_dirty and "tbl" in self._cache:
                self._cache = dict(self._cache,
                                   tbl=jnp.asarray(self._tbl_host))
                self._tbl_dirty = False
        advance = np.array(
            [1 if (r is not None and r.status == "active") else 0
             for r in self._rows], np.int32)
        if advance.sum() == 0:
            return progressed
        forced = np.zeros((self.max_slots,), np.int32)
        fmask = np.zeros((self.max_slots,), np.int32)
        for i, r in enumerate(self._rows):
            if r is not None and r.status == "active" and r.forced_q:
                forced[i] = r.forced_q[0]
                fmask[i] = 1
        # upload the masks only when they changed (steady decode between
        # evictions re-uses the device copies — zero uploads per step)
        sig = advance.tobytes() + forced.tobytes() + fmask.tobytes()
        if sig != self._mask_sig:
            self._d_masks = (jnp.asarray(forced), jnp.asarray(fmask),
                             jnp.asarray(advance))
            self._mask_sig = sig
        d_forced, d_fmask, d_advance = self._d_masks
        t0 = time.monotonic()
        nxt, lp, self._cache, self._d_counters = self._step_fn(
            self.base_params, self._stacked, self._d_row_ids, self._d_cur,
            self._cache, self._d_keys, self._d_counters, self._d_temps,
            d_forced, d_fmask, d_advance)
        self._d_cur = nxt
        nxt = np.asarray(nxt)
        lp = np.asarray(lp)
        self.stats.decode_seconds += time.monotonic() - t0
        self.stats.decode_steps += 1
        self.stats.occupied_row_steps += int(advance.sum())
        self.stats.capacity_row_steps += self.max_slots
        # slot dead weight of the freeze-in-slot baseline: resident rows
        # that spent this decode step waiting on a tool (0 by construction
        # under env_stage — the invariant above)
        self.stats.tool_wait_slot_steps += sum(
            1 for r in self._rows if r is not None and r.status == "calling")
        for slot, r in enumerate(self._rows):
            if r is None or r.status != "active" or advance[slot] == 0:
                continue
            if self.paged_kv:
                self._slot_pos[slot] += 1     # device cache["pos"] mirror
            was_forced = fmask[slot] == 1
            if was_forced:
                r.forced_q.pop(0)
            action = r.accept(int(nxt[slot]), float(lp[slot]),
                              0.0 if was_forced else 1.0, self.max_len)
            self.stats.tokens_generated += 1
            if not was_forced:
                self.stats.sampled_tokens += 1
            if action == "call":
                self._on_call(slot)
            elif action == "done":
                self._evict(slot)
        return True

    def drain(self, deadline_s: float = 300.0,
              stop: Optional[Callable[[], bool]] = None
              ) -> List[RolloutCompletion]:
        """Run until queue and pool are empty (or deadline); returns all
        completions produced during the drain."""
        out: List[RolloutCompletion] = []
        deadline = time.monotonic() + deadline_s
        while not self.idle() and time.monotonic() < deadline:
            if stop is not None and stop():
                break
            progressed = self.step()
            out.extend(self.drain_completions())
            if not progressed:
                time.sleep(0.001)     # waiting only on external tools
        # deadline: abort whatever is still resident OR anywhere in the
        # prefill pipeline, so every submitted request yields exactly one
        # completion. Workers are halted first: their unfinished rows return
        # to the queue, ready-but-unspliced rows abort like queued ones. A
        # worker stuck past the join timeout (e.g. mid cold-compile) still
        # can't lose rows: its in-flight rows are swept into the queue here,
        # and the worker's late emit/teardown drops rows it no longer owns.
        if self.queued() > 0 and self.disagg_prefill:
            self._halt_stage()
            with self._stage_lock:
                for rr in self._ready:
                    self._sched.push(rr.row, self.stats.refills)
                self._ready.clear()
                for row in self._stage_inflight:
                    self._sched.push(row, self.stats.refills)
                self._stage_inflight.clear()
        if self._env is not None and self._env.count() > 0:
            # parked episodes abort like queued ones; late worker results
            # are dropped by the cancelled flag. cancel_all also returns
            # already-cancelled executing jobs whose rows expire() finished
            # earlier — those must not complete twice.
            for job in self._env.cancel_all():
                row = job.row
                if row.status == "done":
                    continue
                row.status = "done"
                row.finish_reason = row.finish_reason or "aborted"
                self._complete_parked(row)
        for slot, r in enumerate(self._rows):
            if r is not None:
                r.status = "done"
                r.finish_reason = r.finish_reason or "aborted"
                self._evict(slot)
        with self._stage_lock:
            leftovers = self._sched.pop_all()
        for row in leftovers:
            # a preempted/resumed-then-aborted row keeps its generated prefix
            row.status, row.finish_reason = "done", "aborted"
            self._complete_parked(row)
        out.extend(self.drain_completions())
        return out

    def run_requests(self, requests: Sequence[RolloutRequest], adapter_trees,
                     deadline_s: float = 300.0
                     ) -> Tuple[List[Dict], RolloutStats]:
        """Convenience: submit a request list, drain, return results in
        submission order — drop-in comparable with `generate()`."""
        t0 = time.monotonic()
        for i, tree in enumerate(adapter_trees):
            self.set_adapters(i, tree)
        idx = {}
        for i, r in enumerate(requests):
            # unseeded requests default to the advancing submission counter
            # inside submit() — matching generate()'s _n_issued behaviour
            idx[self.submit(r)] = i
        comps = self.drain(deadline_s)
        results: List[Optional[Dict]] = [None] * len(requests)
        for c in comps:
            if c.submit_index in idx:     # skip strays from an earlier call
                results[idx[c.submit_index]] = c.to_result()
        self.stats.wall_seconds += time.monotonic() - t0
        return results, self.stats

    def shutdown(self):
        if self._workers:
            self._halt_stage()
        if self._env is not None:
            self._env.halt()
        if self._own_pool:
            self._pool.shutdown(wait=False)


class _RandomShim:
    """random.Random-compatible gauss() over a numpy RandomState."""
    def __init__(self, rs):
        self.rs = rs

    def gauss(self, mu, sigma):
        return float(self.rs.normal(mu, sigma))


def to_trajectory_batch(results: List, task_id: str, version: int,
                        group_size: int, pad_to: int = None) -> TrajectoryBatch:
    """Pack engine results for ONE task into a padded TrajectoryBatch and
    verify rewards. Accepts `generate()` result dicts or
    `RolloutCompletion`s (continuous engine)."""
    results = [r.to_result() if isinstance(r, RolloutCompletion) else r
               for r in results]
    rows = [r for r in results if r["task_id"] == task_id]
    S = max(len(r["tokens"]) for r in rows)
    if pad_to:
        S = max(S, pad_to)
    S = -(-S // 8) * 8
    R = len(rows)
    tokens = np.zeros((R, S), np.int32)
    loss_mask = np.ones((R, S), np.float32)
    behavior = np.zeros((R, S), np.float32)
    p_lens = np.zeros((R,), np.int32)
    t_lens = np.zeros((R,), np.int32)
    rewards = np.zeros((R,), np.float32)
    for j, r in enumerate(rows):
        n = len(r["tokens"])
        tokens[j, :n] = r["tokens"]
        p_lens[j] = r["prompt_len"]
        t_lens[j] = n
        gen_len = n - r["prompt_len"]
        # behavior logprobs/losses sit at positions predicting each gen token
        for k in range(gen_len):
            pos = r["prompt_len"] - 1 + k
            behavior[j, pos] = r["gen_logprobs"][k]
            loss_mask[j, pos] = r["gen_loss_mask"][k]
        comp = r["tokens"][r["prompt_len"]:]
        rewards[j] = r["env"].verify(r["truth"], comp)
    meta = {"loss_mask": loss_mask}
    if any("finish_reason" in r for r in rows):
        meta["finish_reasons"] = [r.get("finish_reason", "") for r in rows]
    return TrajectoryBatch(task_id=task_id, version=version, tokens=tokens,
                           prompt_lens=p_lens, total_lens=t_lens,
                           rewards=rewards, group_size=group_size,
                           behavior_logprobs=behavior[:, :S - 1],
                           meta=meta)
