"""Disaggregated environment-interaction stage (ISSUE 4 tentpole).

The paper's architecture disaggregates THREE stages — rollout generation,
environment interaction, and policy training. PR 1–3 disaggregated the
first; this module is the second: before it, a row that emitted a tool
call FROZE in its decode slot (``advance=0``) for the entire env latency,
turning decode slots into dead weight exactly when external tool/judge
latency dominates (the idle time Fig 5 is about).

``EnvStage`` — an event-driven request/response pipeline between the
decode stream and a pool of ``EnvWorker`` threads:

  decode stream ──park──> request queue ──pop──> EnvWorker pool
       ▲                  (FIFO, per-tenant        latency sleep +
       │                   in-flight caps)         session.call()
       └──resume job <── response queue <──emit────────┘

When a resident row samples ``tok.CALL`` under ``env_stage=True`` the
engine PARKS it: the generated prefix already lives host-side (the same
snapshot the preemption machinery relies on), so the slot is simply
vacated and instantly refilled from the scheduler queue. The parked row
becomes an ``EnvJob``; an ``EnvWorker`` applies the sampled env latency,
runs the episode's stateful ``ToolSession`` call, and pushes the response
back. The engine's pump turns each response into a *resume job*: the row
re-enters the scheduler queue with its force-feed queue pre-loaded
(``RESP … ENDRESP``) and flows through the ordinary (fused or
disaggregated) prefill path — prefix replay plus a FORCED first token —
then splices back into a slot. Decode slots are therefore never occupied
by I/O-waiting rows, and the token stream is bit-identical to the
freeze-in-slot baseline given the same tool responses (same forward math,
same per-row (key, counter) sampling, same forced tokens).

Per-episode state machine (host-side, one ``_Row`` per episode):

  active ──CALL (turn < budget)──> parked ──response──> resuming(queued)
    ▲                                │                        │
    └────────── splice-back ─────────┼────────────────────────┘
  done  <──CALL (budget spent) / EOS / token budget / timeout / abort
            / tool_error (permanent failure or retry budget spent)

Fairness: ``max_inflight_per_tenant`` caps how many of one tenant's tool
calls may execute concurrently — a tenant with pathologically slow tools
cannot monopolize the worker pool (queued jobs from other tenants are
popped around it). Timeouts are engine-driven: ``expire()`` cancels
queued jobs outright and flags executing ones so their late responses are
discarded — a late tool response can never be force-fed into a row that
already timed out (or into the slot's next occupant; parked rows hold no
slot at all).

Fault tolerance (ISSUE 10): a ``TransientToolError`` from the session is
retried with exponential backoff + jitter — the backoff runs QUEUE-side
(``EnvJob.not_before``), so the worker is immediately free for other
tenants' calls, and the retried job keeps its cancel token (timeout /
abort still discards late duplicates). ``PermanentToolError`` — or a
spent retry budget — surfaces as ``job.error`` and the engine finishes
the row with ``finish_reason="tool_error"``. Dead or wedged workers are
the supervisor's problem: ``healthy()``/``mark_wedged()`` feed its
liveness check, ``recover_dead()`` re-queues the jobs they stranded
(clones — a wedged worker's eventual late ``_finish`` is untracked and
dropped), and ``_ensure_workers`` respawns the pool to complement.
"""
from __future__ import annotations

import random
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Tuple

from repro.core.supervisor import join_or_raise
from repro.envs.base import (CancelToken, PermanentToolError, ToolError,
                             TransientToolError, call_session)


@dataclass
class EnvJob:
    """One parked episode's in-flight environment interaction."""
    row: object                  # engine _Row (host-side episode state)
    query: List[int]             # prompt + generated prefix (ends in CALL)
    task_id: str
    latency: float               # sampled env-interaction latency (seconds)
    submitted_at: float
    started_at: float = 0.0      # worker pickup time
    resolved_at: float = 0.0
    response: Optional[List[int]] = None
    error: Optional[BaseException] = None
    # timeout/abort: the late result is discarded AND the token wakes the
    # executing worker immediately (interruptible latency sleep +
    # cooperative mid-call checks) instead of letting the call run to
    # completion for nothing (ISSUE 5 satellite)
    cancel: CancelToken = field(default_factory=CancelToken)
    state: str = "queued"        # queued | executing | done
    worker: int = -1             # executing worker's id (tracer track)
    flow: int = 0                # park→env hand-off arrow (repro.obs)
    attempts: int = 0            # tries so far (retry accounting)
    not_before: float = 0.0      # retry backoff: ineligible until then
    chaos_transient_left: int = 0  # injected consecutive transient fails
    chaos_permanent: bool = False  # injected permanent endpoint failure

    @property
    def cancelled(self) -> bool:
        return self.cancel.cancelled


class EnvWorker(threading.Thread):
    """Env-interaction worker: pops eligible jobs (FIFO within the
    per-tenant cap), applies the sampled external latency, runs the
    episode's stateful session call, and emits the response."""

    def __init__(self, stage: "EnvStage", worker_id: int = 0):
        super().__init__(daemon=True, name=f"env-worker-{worker_id}")
        self.stage = stage
        self.worker_id = worker_id
        self.last_beat = time.monotonic()   # liveness heartbeat (supervisor)
        self.poisoned = False    # marked wedged: excluded from the pool
                                 # complement, its job already recovered
        self.chaos_killed = False

    def run(self):
        stage = self.stage
        chaos = stage.chaos
        while True:
            self.last_beat = time.monotonic()
            job = stage._pop_eligible(worker=self)
            if job is None:
                if stage._stop.is_set():
                    return
                continue
            if chaos is not None and chaos.fire("env_worker_kill"):
                # simulated abrupt death: no _finish, no cleanup — the job
                # stays stranded in _executing (inflight count held) until
                # the supervisor's recover_dead() re-queues it
                self.chaos_killed = True
                return
            if job.latency > 0 and job.attempts == 0 \
                    and not stage.sim_latency:
                # interruptible: a timeout/abort wakes the worker NOW
                # (retries skip the latency — the backoff already ran)
                job.cancel.wait(job.latency)
            resp: List[int] = []
            try:
                if not job.cancelled:
                    stage._chaos_tool_fault(job)
                    resp = list(call_session(job.row.session, job.query,
                                             job.cancel))
            except ToolError as e:
                job.attempts += 1
                if (isinstance(e, TransientToolError)
                        and stage._schedule_retry(job)):
                    continue
                job.error = e
                stage._finish(job, [])
                continue
            except BaseException as e:      # surfaced on the engine thread
                job.error = e
            stage._finish(job, resp)


class EnvStage:
    """Event-driven env-interaction stage shared by one engine.

    Thread contract: ``submit`` / ``drain_resolved`` / ``expire`` /
    ``cancel_all`` / ``recover_dead`` / ``mark_wedged`` are called from
    the engine (decode) thread; workers only touch the queues under the
    stage condition. All host state — no device work happens here, which
    is the point: env I/O never rides the decode stream."""

    def __init__(self, n_workers: int = 2, *,
                 max_inflight_per_tenant: int = 0,
                 sim_latency: bool = False,
                 retry_max: int = 3, retry_episode_cap: int = 0,
                 retry_base_s: float = 0.05, retry_max_s: float = 2.0,
                 seed: int = 0, chaos=None):
        if n_workers < 1:
            raise ValueError("env stage needs at least one worker")
        self.n_workers = n_workers
        self.max_inflight_per_tenant = max_inflight_per_tenant  # 0 = off
        self.sim_latency = sim_latency
        self.retry_max = retry_max              # retries per tool call
        self.retry_episode_cap = retry_episode_cap  # per episode (0 = off)
        self.retry_base_s = retry_base_s
        self.retry_max_s = retry_max_s
        self.chaos = chaos                      # ChaosInjector or None
        self._rng = random.Random(seed)         # retry jitter only — never
                                                # touches token sampling
        self._cond = threading.Condition()  # guards: _queue/_executing/
                                            # _done/_inflight
        self._queue: Deque[EnvJob] = deque()      # FIFO request queue
        self._executing: Dict[int, EnvJob] = {}   # id(job) -> job
        self._done: Deque[EnvJob] = deque()       # response queue
        self._inflight: Dict[str, int] = {}       # tenant -> executing count
        self._stop = threading.Event()
        self._workers: List[EnvWorker] = []
        self._next_wid = 0      # unique worker ids across respawns: a
                                # replacement must not shadow a dead
                                # worker's stranded-job ownership
        self.calls = 0                            # jobs handed to workers
        self.timeouts = 0
        self.retries = 0                          # transient-error retries
        self.recovered = 0                        # jobs re-queued after a
                                                  # worker death/wedge
        self.wedged = 0                           # workers marked wedged

    # -- lifecycle --------------------------------------------------------
    def _ensure_workers(self):
        live = [w for w in self._workers if w.is_alive()]
        ok = [w for w in live if not w.poisoned]
        if len(ok) >= self.n_workers:
            self._workers = live
            return
        self._stop.clear()
        fresh = []
        for _ in range(self.n_workers - len(ok)):
            fresh.append(EnvWorker(self, self._next_wid))
            self._next_wid += 1
        # poisoned-but-alive zombies stay tracked: halt()'s join_or_raise
        # must surface them loudly rather than leak them silently
        self._workers = live + fresh
        for w in fresh:
            w.start()

    def healthy(self) -> bool:
        """Supervisor liveness check: full complement of alive,
        non-wedged workers (a halted/never-started pool is healthy —
        there is nothing to supervise)."""
        if not self._workers:
            return True
        ok = [w for w in self._workers if w.is_alive() and not w.poisoned]
        return len(ok) >= self.n_workers

    def mark_wedged(self, timeout_s: float,
                    now: Optional[float] = None) -> int:
        """Heartbeat check: poison workers stuck in one tool call longer
        than `timeout_s` (0 disables — legitimate long calls are the
        engine timeout's business, not ours). A poisoned worker leaves
        the complement; its job is recovered by ``recover_dead`` and its
        eventual late ``_finish`` is untracked and dropped."""
        if timeout_s <= 0:
            return 0
        now = time.monotonic() if now is None else now
        n = 0
        with self._cond:
            by_worker = {j.worker: j for j in self._executing.values()}
            for w in self._workers:
                if not w.is_alive() or w.poisoned:
                    continue
                job = by_worker.get(w.worker_id)
                if (job is not None and job.started_at
                        and now - job.started_at > timeout_s
                        and now - w.last_beat > timeout_s):
                    w.poisoned = True
                    n += 1
        self.wedged += n
        return n

    def recover_dead(self) -> int:
        """Re-queue (at the FRONT) every job stranded in _executing by a
        dead or poisoned worker. The stranded job's cancel token fires —
        a wedged worker's eventual result is a late duplicate — and a
        CLONE carries the row forward, so the orphan object's untracked
        ``_finish`` can never decrement counts twice or double-deliver."""
        with self._cond:
            gone = {w.worker_id for w in self._workers
                    if not w.is_alive() or w.poisoned}
            stranded = [j for j in self._executing.values()
                        if j.worker in gone]
            for job in stranded:
                self._executing.pop(id(job), None)
                n = self._inflight.get(job.task_id, 0) - 1
                if n > 0:
                    self._inflight[job.task_id] = n
                else:
                    self._inflight.pop(job.task_id, None)
                cancelled = job.cancelled
                job.cancel.cancel()
                if cancelled:
                    continue     # row already finished (timeout/abort)
                clone = EnvJob(row=job.row, query=job.query,
                               task_id=job.task_id, latency=0.0,
                               submitted_at=job.submitted_at,
                               attempts=job.attempts, flow=job.flow,
                               chaos_transient_left=job.chaos_transient_left,
                               chaos_permanent=job.chaos_permanent)
                self._queue.appendleft(clone)
                self.recovered += 1
            self._cond.notify_all()
            return len(stranded)

    def halt(self, timeout_s: float = 30.0):
        """Stop the workers. Queued jobs are cancelled outright — without
        this, workers would drain the whole backlog (latency sleeps
        included) for discarded results before noticing the stop flag,
        stalling the caller's join for the queue's worth of env latency.
        The join goes through ``join_or_raise``: a wedged worker dumps
        every thread's stack and raises instead of silently leaking."""
        self._stop.set()
        with self._cond:
            for job in self._queue:
                job.cancel.cancel()
            self._queue.clear()
            # wake executing workers out of their latency sleeps too —
            # their results were going to be discarded anyway
            for job in self._executing.values():
                job.cancel.cancel()
            self._cond.notify_all()
        join_or_raise([w for w in self._workers if w.is_alive()],
                      timeout_s=timeout_s)
        self._workers = []

    # -- engine side ------------------------------------------------------
    def submit(self, row, query: List[int], task_id: str,
               latency: float) -> EnvJob:
        """Park one episode: enqueue its tool call for the worker pool."""
        job = EnvJob(row=row, query=query, task_id=task_id, latency=latency,
                     submitted_at=time.monotonic())
        if not self._workers:
            self._ensure_workers()   # lazy first start / post-halt restart;
                                     # mid-run respawns are the supervisor's
                                     # (backoff-gated, work recovered first)
        with self._cond:
            self._queue.append(job)
            self._cond.notify()
        return job

    def drain_resolved(self) -> List[EnvJob]:
        """Pop every completed (non-cancelled) response."""
        out: List[EnvJob] = []
        with self._cond:
            while self._done:
                out.append(self._done.popleft())
        return out

    def expire(self, now: float, timeout_s: float) -> List[EnvJob]:
        """Time out jobs older than `timeout_s`: queued jobs are cancelled
        outright (they never burn a worker); executing jobs are flagged so
        the worker's late result is discarded. Returns the expired jobs —
        the engine evicts their rows with finish_reason tool_timeout."""
        expired: List[EnvJob] = []
        with self._cond:
            keep: Deque[EnvJob] = deque()
            for job in self._queue:
                if now - job.submitted_at > timeout_s:
                    job.cancel.cancel()
                    expired.append(job)
                else:
                    keep.append(job)
            self._queue = keep
            for job in self._executing.values():
                if not job.cancelled and now - job.submitted_at > timeout_s:
                    job.cancel.cancel()
                    expired.append(job)
        self.timeouts += len(expired)
        return expired

    def cancel_tenant(self, task_id: str) -> List[EnvJob]:
        """Quarantine/abort one tenant: cancel its queued + executing jobs
        and return them — the engine completes their rows (aborted), and
        executing workers' late results drop on the cancelled flag."""
        out: List[EnvJob] = []
        with self._cond:
            keep: Deque[EnvJob] = deque()
            for job in self._queue:
                if job.task_id == task_id:
                    job.cancel.cancel()
                    out.append(job)
                else:
                    keep.append(job)
            self._queue = keep
            for job in self._executing.values():
                if job.task_id == task_id and not job.cancelled:
                    job.cancel.cancel()
                    out.append(job)
            keep_done: Deque[EnvJob] = deque()
            for job in self._done:
                if job.task_id == task_id:
                    job.cancel.cancel()
                    out.append(job)
                else:
                    keep_done.append(job)
            self._done = keep_done
        return out

    def cancel_all(self) -> List[EnvJob]:
        """Abort path (engine drain deadline / shutdown): cancel every
        queued and executing job; returns them for abort accounting."""
        with self._cond:
            out = [j for j in self._queue]
            out += list(self._executing.values())
            for j in out:
                j.cancel.cancel()
            self._queue.clear()
            # late worker results are dropped by the cancelled flag;
            # already-resolved-but-undrained responses abort too
            while self._done:
                j = self._done.popleft()
                j.cancel.cancel()
                out.append(j)
        return out

    # -- worker side ------------------------------------------------------
    def _chaos_tool_fault(self, job: EnvJob):
        """Injected tool failures (worker thread). One decision per job at
        its first attempt: permanent beats transient; a transient hit
        fails ``transient_fail_count`` consecutive attempts then lets the
        real call through (retry-then-succeed, bit-identical stream)."""
        chaos = self.chaos
        if chaos is None:
            return
        if (job.attempts == 0 and not job.chaos_permanent
                and job.chaos_transient_left == 0):
            if chaos.fire("tool_error_permanent"):
                job.chaos_permanent = True
            elif chaos.fire("tool_error_transient"):
                job.chaos_transient_left = chaos.cfg.transient_fail_count
        if job.chaos_permanent:
            raise PermanentToolError(
                f"chaos: tool endpoint down for {job.task_id}")
        if job.chaos_transient_left > 0:
            job.chaos_transient_left -= 1
            raise TransientToolError("chaos: transient tool failure")

    def _schedule_retry(self, job: EnvJob) -> bool:
        """Queue-side retry with exponential backoff + jitter. False once
        the per-call (``retry_max``) or per-episode
        (``retry_episode_cap``) budget is spent or the job is cancelled —
        the caller then fails the row. The executing slot is released
        immediately: the backoff costs no worker time."""
        if job.cancelled or job.attempts > self.retry_max:
            return False
        row = job.row
        used = getattr(row, "tool_retries", 0)
        if self.retry_episode_cap and used >= self.retry_episode_cap:
            return False
        backoff = min(self.retry_max_s,
                      self.retry_base_s * (2 ** (job.attempts - 1)))
        with self._cond:
            try:
                row.tool_retries = used + 1
            except AttributeError:
                pass      # non-engine row objects (unit tests) without the
                          # slot: per-call cap still bounds the retries
            self.retries += 1
            self._executing.pop(id(job), None)
            n = self._inflight.get(job.task_id, 0) - 1
            if n > 0:
                self._inflight[job.task_id] = n
            else:
                self._inflight.pop(job.task_id, None)
            job.state = "queued"
            job.not_before = time.monotonic() + backoff * (
                1.0 + 0.25 * self._rng.random())
            self._queue.append(job)
            self._cond.notify_all()
        return True

    def _pop_eligible(self, worker: Optional[EnvWorker] = None
                      ) -> Optional[EnvJob]:
        """Oldest queued job whose tenant is under the in-flight cap,
        not cancelled, and past its retry backoff. Blocks on the stage
        condition until work or stop. The worker's id lands on the job
        INSIDE the lock — ownership is never observable half-assigned
        (recover_dead keys stranded jobs by it)."""
        with self._cond:
            while True:
                if self._stop.is_set():
                    return None
                cap = self.max_inflight_per_tenant
                now = time.monotonic()
                for i, job in enumerate(self._queue):
                    if job.not_before and now < job.not_before:
                        continue
                    if cap and self._inflight.get(job.task_id, 0) >= cap:
                        continue
                    del self._queue[i]
                    job.state = "executing"
                    job.started_at = now
                    if worker is not None:
                        job.worker = worker.worker_id
                    self._executing[id(job)] = job
                    self._inflight[job.task_id] = (
                        self._inflight.get(job.task_id, 0) + 1)
                    self.calls += 1
                    return job
                if self._stop.is_set():
                    return None
                self._cond.wait(timeout=0.05)

    def _finish(self, job: EnvJob, response: List[int]):
        with self._cond:
            # a job recover_dead already re-queued (as a clone) is
            # UNTRACKED here: a wedged worker limping in late must not
            # decrement counts twice or deliver a duplicate response
            tracked = self._executing.pop(id(job), None) is not None
            if tracked:
                n = self._inflight.get(job.task_id, 0) - 1
                if n > 0:
                    self._inflight[job.task_id] = n
                else:
                    self._inflight.pop(job.task_id, None)
            job.state = "done"
            job.resolved_at = time.monotonic()
            job.response = response
            if tracked and not job.cancelled:
                self._done.append(job)
            # a freed tenant cap slot may unblock a queued sibling
            self._cond.notify_all()

    # -- introspection ----------------------------------------------------
    def _live_executing(self) -> List[EnvJob]:  # held: _cond
        """Executing jobs whose row is still in flight. A cancelled job's
        row already completed (tool_timeout/abort) — the worker is merely
        riding out an uninterruptible call whose result will be discarded,
        so it must not keep the engine non-idle or pin the tenant."""
        return [j for j in self._executing.values() if not j.cancelled]

    def depths(self) -> Tuple[int, int]:
        """(queued, executing) — the env stage's two queue depths."""
        with self._cond:
            return len(self._queue), len(self._live_executing())

    def count(self) -> int:
        """Rows anywhere in the stage (queued + executing + resolved but
        not yet drained) — feeds the engine's queued()/idle() accounting."""
        with self._cond:
            return (len(self._queue) + len(self._live_executing())
                    + len(self._done))

    def tenants(self) -> frozenset:
        with self._cond:
            return (frozenset(j.task_id for j in self._queue)
                    | frozenset(j.task_id for j in self._live_executing())
                    | frozenset(j.task_id for j in self._done))

    def rows_for(self, task_id: str) -> List[object]:
        with self._cond:
            jobs = ([j for j in self._queue if j.task_id == task_id]
                    + [j for j in self._live_executing()
                       if j.task_id == task_id]
                    + [j for j in self._done if j.task_id == task_id])
        return [j.row for j in jobs]
