"""Disaggregated environment-interaction stage (ISSUE 4 tentpole).

The paper's architecture disaggregates THREE stages — rollout generation,
environment interaction, and policy training. PR 1–3 disaggregated the
first; this module is the second: before it, a row that emitted a tool
call FROZE in its decode slot (``advance=0``) for the entire env latency,
turning decode slots into dead weight exactly when external tool/judge
latency dominates (the idle time Fig 5 is about).

``EnvStage`` — an event-driven request/response pipeline between the
decode stream and a pool of ``EnvWorker`` threads:

  decode stream ──park──> request queue ──pop──> EnvWorker pool
       ▲                  (FIFO, per-tenant        latency sleep +
       │                   in-flight caps)         session.call()
       └──resume job <── response queue <──emit────────┘

When a resident row samples ``tok.CALL`` under ``env_stage=True`` the
engine PARKS it: the generated prefix already lives host-side (the same
snapshot the preemption machinery relies on), so the slot is simply
vacated and instantly refilled from the scheduler queue. The parked row
becomes an ``EnvJob``; an ``EnvWorker`` applies the sampled env latency,
runs the episode's stateful ``ToolSession`` call, and pushes the response
back. The engine's pump turns each response into a *resume job*: the row
re-enters the scheduler queue with its force-feed queue pre-loaded
(``RESP … ENDRESP``) and flows through the ordinary (fused or
disaggregated) prefill path — prefix replay plus a FORCED first token —
then splices back into a slot. Decode slots are therefore never occupied
by I/O-waiting rows, and the token stream is bit-identical to the
freeze-in-slot baseline given the same tool responses (same forward math,
same per-row (key, counter) sampling, same forced tokens).

Per-episode state machine (host-side, one ``_Row`` per episode):

  active ──CALL (turn < budget)──> parked ──response──> resuming(queued)
    ▲                                │                        │
    └────────── splice-back ─────────┼────────────────────────┘
  done  <──CALL (budget spent) / EOS / token budget / timeout / abort

Fairness: ``max_inflight_per_tenant`` caps how many of one tenant's tool
calls may execute concurrently — a tenant with pathologically slow tools
cannot monopolize the worker pool (queued jobs from other tenants are
popped around it). Timeouts are engine-driven: ``expire()`` cancels
queued jobs outright and flags executing ones so their late responses are
discarded — a late tool response can never be force-fed into a row that
already timed out (or into the slot's next occupant; parked rows hold no
slot at all).
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Tuple

from repro.envs.base import CancelToken, call_session


@dataclass
class EnvJob:
    """One parked episode's in-flight environment interaction."""
    row: object                  # engine _Row (host-side episode state)
    query: List[int]             # prompt + generated prefix (ends in CALL)
    task_id: str
    latency: float               # sampled env-interaction latency (seconds)
    submitted_at: float
    started_at: float = 0.0      # worker pickup time
    resolved_at: float = 0.0
    response: Optional[List[int]] = None
    error: Optional[BaseException] = None
    # timeout/abort: the late result is discarded AND the token wakes the
    # executing worker immediately (interruptible latency sleep +
    # cooperative mid-call checks) instead of letting the call run to
    # completion for nothing (ISSUE 5 satellite)
    cancel: CancelToken = field(default_factory=CancelToken)
    state: str = "queued"        # queued | executing | done
    worker: int = -1             # executing worker's id (tracer track)
    flow: int = 0                # park→env hand-off arrow (repro.obs)

    @property
    def cancelled(self) -> bool:
        return self.cancel.cancelled


class EnvWorker(threading.Thread):
    """Env-interaction worker: pops eligible jobs (FIFO within the
    per-tenant cap), applies the sampled external latency, runs the
    episode's stateful session call, and emits the response."""

    def __init__(self, stage: "EnvStage", worker_id: int = 0):
        super().__init__(daemon=True, name=f"env-worker-{worker_id}")
        self.stage = stage
        self.worker_id = worker_id

    def run(self):
        stage = self.stage
        while True:
            job = stage._pop_eligible()
            if job is None:
                if stage._stop.is_set():
                    return
                continue
            job.worker = self.worker_id
            if job.latency > 0 and not stage.sim_latency:
                # interruptible: a timeout/abort wakes the worker NOW
                job.cancel.wait(job.latency)
            resp: List[int] = []
            try:
                if not job.cancelled:
                    resp = list(call_session(job.row.session, job.query,
                                             job.cancel))
            except BaseException as e:      # surfaced on the engine thread
                job.error = e
            stage._finish(job, resp)


class EnvStage:
    """Event-driven env-interaction stage shared by one engine.

    Thread contract: ``submit`` / ``drain_resolved`` / ``expire`` /
    ``cancel_all`` are called from the engine (decode) thread; workers only
    touch the queues under the stage condition. All host state — no device
    work happens here, which is the point: env I/O never rides the decode
    stream."""

    def __init__(self, n_workers: int = 2, *,
                 max_inflight_per_tenant: int = 0,
                 sim_latency: bool = False):
        if n_workers < 1:
            raise ValueError("env stage needs at least one worker")
        self.n_workers = n_workers
        self.max_inflight_per_tenant = max_inflight_per_tenant  # 0 = off
        self.sim_latency = sim_latency
        self._cond = threading.Condition()  # guards: _queue/_executing/
                                            # _done/_inflight
        self._queue: Deque[EnvJob] = deque()      # FIFO request queue
        self._executing: Dict[int, EnvJob] = {}   # id(job) -> job
        self._done: Deque[EnvJob] = deque()       # response queue
        self._inflight: Dict[str, int] = {}       # tenant -> executing count
        self._stop = threading.Event()
        self._workers: List[EnvWorker] = []
        self.calls = 0                            # jobs handed to workers
        self.timeouts = 0

    # -- lifecycle --------------------------------------------------------
    def _ensure_workers(self):
        alive = [w for w in self._workers if w.is_alive()]
        if len(alive) >= self.n_workers:
            return
        self._stop.clear()
        fresh = [EnvWorker(self, i)
                 for i in range(len(alive), self.n_workers)]
        self._workers = alive + fresh
        for w in fresh:
            w.start()

    def halt(self):
        """Stop the workers. Queued jobs are cancelled outright — without
        this, workers would drain the whole backlog (latency sleeps
        included) for discarded results before noticing the stop flag,
        stalling the caller's join for the queue's worth of env latency."""
        self._stop.set()
        with self._cond:
            for job in self._queue:
                job.cancel.cancel()
            self._queue.clear()
            # wake executing workers out of their latency sleeps too —
            # their results were going to be discarded anyway
            for job in self._executing.values():
                job.cancel.cancel()
            self._cond.notify_all()
        for w in self._workers:
            w.join(timeout=30)
        self._workers = []

    # -- engine side ------------------------------------------------------
    def submit(self, row, query: List[int], task_id: str,
               latency: float) -> EnvJob:
        """Park one episode: enqueue its tool call for the worker pool."""
        job = EnvJob(row=row, query=query, task_id=task_id, latency=latency,
                     submitted_at=time.monotonic())
        self._ensure_workers()
        with self._cond:
            self._queue.append(job)
            self._cond.notify()
        return job

    def drain_resolved(self) -> List[EnvJob]:
        """Pop every completed (non-cancelled) response."""
        out: List[EnvJob] = []
        with self._cond:
            while self._done:
                out.append(self._done.popleft())
        return out

    def expire(self, now: float, timeout_s: float) -> List[EnvJob]:
        """Time out jobs older than `timeout_s`: queued jobs are cancelled
        outright (they never burn a worker); executing jobs are flagged so
        the worker's late result is discarded. Returns the expired jobs —
        the engine evicts their rows with finish_reason tool_timeout."""
        expired: List[EnvJob] = []
        with self._cond:
            keep: Deque[EnvJob] = deque()
            for job in self._queue:
                if now - job.submitted_at > timeout_s:
                    job.cancel.cancel()
                    expired.append(job)
                else:
                    keep.append(job)
            self._queue = keep
            for job in self._executing.values():
                if not job.cancelled and now - job.submitted_at > timeout_s:
                    job.cancel.cancel()
                    expired.append(job)
        self.timeouts += len(expired)
        return expired

    def cancel_all(self) -> List[EnvJob]:
        """Abort path (engine drain deadline / shutdown): cancel every
        queued and executing job; returns them for abort accounting."""
        with self._cond:
            out = [j for j in self._queue]
            out += list(self._executing.values())
            for j in out:
                j.cancel.cancel()
            self._queue.clear()
            # late worker results are dropped by the cancelled flag;
            # already-resolved-but-undrained responses abort too
            while self._done:
                j = self._done.popleft()
                j.cancel.cancel()
                out.append(j)
        return out

    # -- worker side ------------------------------------------------------
    def _pop_eligible(self) -> Optional[EnvJob]:
        """Oldest queued job whose tenant is under the in-flight cap (and
        not cancelled). Blocks on the stage condition until work or stop."""
        with self._cond:
            while True:
                if self._stop.is_set():
                    return None
                cap = self.max_inflight_per_tenant
                for i, job in enumerate(self._queue):
                    if cap and self._inflight.get(job.task_id, 0) >= cap:
                        continue
                    del self._queue[i]
                    job.state = "executing"
                    job.started_at = time.monotonic()
                    self._executing[id(job)] = job
                    self._inflight[job.task_id] = (
                        self._inflight.get(job.task_id, 0) + 1)
                    self.calls += 1
                    return job
                if self._stop.is_set():
                    return None
                self._cond.wait(timeout=0.05)

    def _finish(self, job: EnvJob, response: List[int]):
        with self._cond:
            self._executing.pop(id(job), None)
            n = self._inflight.get(job.task_id, 0) - 1
            if n > 0:
                self._inflight[job.task_id] = n
            else:
                self._inflight.pop(job.task_id, None)
            job.state = "done"
            job.resolved_at = time.monotonic()
            job.response = response
            if not job.cancelled:
                self._done.append(job)
            # a freed tenant cap slot may unblock a queued sibling
            self._cond.notify_all()

    # -- introspection ----------------------------------------------------
    def _live_executing(self) -> List[EnvJob]:  # held: _cond
        """Executing jobs whose row is still in flight. A cancelled job's
        row already completed (tool_timeout/abort) — the worker is merely
        riding out an uninterruptible call whose result will be discarded,
        so it must not keep the engine non-idle or pin the tenant."""
        return [j for j in self._executing.values() if not j.cancelled]

    def depths(self) -> Tuple[int, int]:
        """(queued, executing) — the env stage's two queue depths."""
        with self._cond:
            return len(self._queue), len(self._live_executing())

    def count(self) -> int:
        """Rows anywhere in the stage (queued + executing + resolved but
        not yet drained) — feeds the engine's queued()/idle() accounting."""
        with self._cond:
            return (len(self._queue) + len(self._live_executing())
                    + len(self._done))

    def tenants(self) -> frozenset:
        with self._cond:
            return (frozenset(j.task_id for j in self._queue)
                    | frozenset(j.task_id for j in self._live_executing())
                    | frozenset(j.task_id for j in self._done))

    def rows_for(self, task_id: str) -> List[object]:
        with self._cond:
            jobs = ([j for j in self._queue if j.task_id == task_id]
                    + [j for j in self._live_executing()
                       if j.task_id == task_id]
                    + [j for j in self._done if j.task_id == task_id])
        return [j.row for j in jobs]
