"""Disaggregated async prefill stage (paper §4.1, Fig 5).

The continuous engine's fused refill ran every incoming prompt's prefill as
one jitted call ON THE DECODE STREAM: a long prompt stalled decode for all
resident tenants — exactly the cross-task interference MARLaaS's
disaggregated layout eliminates. This module is the prefill side of the
split:

``PrefillWorker`` — a daemon thread (the engine spawns ``prefill_workers``
of them when ``disagg_prefill=True``). Each worker pops scheduler-ordered
rows from the engine's cross-task queue (the same ``SlotScheduler`` that
used to order the fused refill pop), prefills them on its OWN cache — never
touching the decode pool — and emits a ``ReadyRow`` (spliceable KV/SSM
state + first sampled token + logprob) into the engine's ready queue. The
decode side then installs ready rows with a scatter-only jitted splice
(see ``engine._build_splice_fn``), so decode literally never waits on a
prefill graph.

Chunked prefill: prompts longer than ``prefill_chunk`` are processed in
fixed-size chunks through ``forward_prefill_chunk`` and each worker
round-robins its in-flight jobs chunk by chunk, so one huge prompt cannot
monopolize the stage — short prompts admitted later still come out first.
The chunk size is rounded up to a multiple of 8 (shape buckets) and of
``cfg.ssm.chunk_size`` (recurrent families: external chunk boundaries then
coincide with the SSD scan's internal ones, making the chunked state
bit-equal to the whole-prompt state). Only the last chunk is padded; its
pad positions are masked out of the recurrent state (``seq_lens``) and sit
beyond ``pos`` in the KV cache, where decode overwrites them.

Determinism: the first token is sampled from the final-position prefill
logits with ``fold_in(row key, init_counter)`` — counter 0 for fresh rows,
``len(gen)`` for preemption-replayed rows — the identical rule the fused
refill applies, so disaggregated output is token-for-token equal to the
fused path (and to one-shot ``generate()``).

Env-stage resume jobs (rollout/env_stage.py) ride the same path with ONE
difference: their first token is FORCED (the tool response's ``RESP``
opener) instead of sampled — ``forced``/``forced_mask`` select it, and its
logprob is read off the same final-position logits, exactly what the
freeze-in-slot baseline records when it feeds ``CALL`` through a decode
step. Everything downstream (force-feed of the rest of the response,
budget exemption) is the ordinary decode path.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ModelConfig
from repro.lora.adapters import batched_ctx
from repro.models import (forward_prefill_chunk, forward_seq, init_cache,
                          lm_logits)


def _bucket_len(n: int) -> int:
    return int(max(8, -(-int(n) // 8) * 8))


def _sample_rows(logits, keys, counters, temps):
    """Per-row categorical: row i uses fold_in(keys[i], counters[i]).

    The sample depends only on the row's own (key, count, logits) — not on
    batch width or slot position — which is what makes continuous batching
    (and the disaggregated prefill stage) bit-reproduce one-shot generation.
    """
    scaled = logits / jnp.maximum(temps[:, None], 1e-4)

    def one(k, c, row):
        return jax.random.categorical(jax.random.fold_in(k, c), row)

    return jax.vmap(one)(keys, counters, scaled)


def effective_chunk(cfg: ModelConfig, chunk: int) -> int:
    """Round a requested prefill chunk up so chunked == whole-prompt
    bit-for-bit: multiple of 8 (shape buckets) and, for recurrent families,
    of the SSD scan chunk (aligned boundaries decompose exactly). 0 keeps
    chunking off (whole-prompt prefill calls)."""
    if chunk <= 0:
        return 0
    c = _bucket_len(chunk)
    if cfg.ssm is not None:
        s = cfg.ssm.chunk_size
        c = -(-c // s) * s
    return c


class PrefillKernels:
    """The jitted kernels of the prefill stage (shared by all workers).

    ``whole``  — one-call prefill of a full (bucketed) sequence on a fresh
                 width-1 cache; returns (first token, logprob, cache). Same
                 forward + sampling math as the fused refill, minus the
                 splice.
    ``chunk``  — one fixed-size chunk at static offset `start` through
                 ``forward_prefill_chunk`` (jit caches one variant per
                 offset); returns (hidden, cache).
    ``finish`` — final-position logits + first-token sample off the last
                 chunk's hidden states.
    """

    def __init__(self, cfg: ModelConfig, use_kernel: bool, max_len: int):
        self.cfg = cfg
        self.max_len = max_len
        enc = 8 if cfg.family == "encdec" else 0

        def whole(params, adapters, row_ids, tokens, seq_lens, init_counters,
                  keys, temps, forced, forced_mask, fpos, ftoks):
            pcache = init_cache(cfg, tokens.shape[0], max_len, enc_len=enc)
            lora = batched_ctx(adapters, row_ids, cfg, use_kernel)
            h, pcache, _ = forward_seq(params, tokens, cfg, lora, pcache,
                                       seq_lens=seq_lens)
            last = jnp.take_along_axis(
                h, (seq_lens - 1)[:, None, None].astype(jnp.int32),
                axis=1)[:, 0]
            logits = lm_logits(last, params, cfg)
            sampled = _sample_rows(logits, keys, init_counters, temps)
            first = jnp.where(forced_mask > 0, forced,
                              sampled).astype(jnp.int32)
            lp = jnp.take_along_axis(jax.nn.log_softmax(logits, -1),
                                     first[:, None], axis=-1)[:, 0]
            # response-prefill fusion: logprob of each forced token off
            # the logits at the position that predicts it (fpos) — the
            # same values the step-wise force-feed would record
            fh = jnp.take_along_axis(
                h, fpos[:, :, None].astype(jnp.int32), axis=1)
            flogits = lm_logits(fh, params, cfg)
            flp = jnp.take_along_axis(jax.nn.log_softmax(flogits, -1),
                                      ftoks[:, :, None], axis=-1)[:, :, 0]
            return first, lp, flp, pcache

        def chunk(start, params, adapters, row_ids, tokens, seq_lens, pcache):
            lora = batched_ctx(adapters, row_ids, cfg, use_kernel)
            return forward_prefill_chunk(params, tokens, cfg, lora, pcache,
                                         start=start, seq_lens=seq_lens)

        def finish(params, h, last_idx, keys, init_counters, temps, forced,
                   forced_mask):
            last = jnp.take_along_axis(
                h, last_idx[:, None, None].astype(jnp.int32), axis=1)[:, 0]
            logits = lm_logits(last, params, cfg)
            sampled = _sample_rows(logits, keys, init_counters, temps)
            first = jnp.where(forced_mask > 0, forced,
                              sampled).astype(jnp.int32)
            lp = jnp.take_along_axis(jax.nn.log_softmax(logits, -1),
                                     first[:, None], axis=-1)[:, 0]
            return first, lp

        self.whole = jax.jit(whole)
        self.chunk = jax.jit(chunk, static_argnums=(0,),
                             donate_argnums=(6,))
        self.finish = jax.jit(finish)

    def fresh_cache(self):
        return init_cache(self.cfg, 1, self.max_len,
                          enc_len=8 if self.cfg.family == "encdec" else 0)


@dataclass
class ReadyRow:
    """A prefilled row awaiting its scatter-only splice into the pool."""
    row: object              # engine _Row (host-side state)
    seq_len: int             # prompt (+ replayed prefix) length == cache pos
    first: int               # first sampled token (counter = init_counter)
    lp: float                # its logprob
    init_counter: int        # len(gen) at prefill time (0 for fresh rows)
    pcache: dict             # width-1 device cache to splice
    ready_at: float          # queue timestamp: splice latency = now - this
    forced_first: bool = False   # env-stage resume: `first` is the forced
                                 # RESP opener (loss_mask 0), not a sample
    forced_lps: List[float] = field(default_factory=list)
                             # response-prefill fusion: logprobs of the
                             # whole forced RESP…ENDRESP block, prefilled
                             # in the same call (seq_len includes them and
                             # `first` samples AFTER the block)


class _Job:
    """One in-flight prefill: host progress of a chunked row."""
    __slots__ = ("row", "seq", "L", "pcache", "done", "chunks", "spent",
                 "fused")

    def __init__(self, row, fused: int = 0):
        self.row = row
        self.seq = list(row.req.prompt) + row.gen
        self.fused = fused           # forced tokens folded into the prefill
        if fused:
            self.seq += row.forced_q[:fused]
        self.L = len(self.seq)
        self.pcache = None
        self.done = 0
        self.chunks = 0
        self.spent = 0.0


class PrefillWorker(threading.Thread):
    """Async prefill worker: pops scheduler-ordered rows from the engine's
    queue, runs (chunked) prefill on its own caches, emits ReadyRows.

    Backpressure: workers only pop while ready + in-flight rows stay under
    ``max_slots + prefill_workers`` — bounded lookahead keeps device memory
    at O(max_slots) extra caches and bounds priority inversion (a
    higher-priority late arrival waits at most the lookahead window).
    Workers round-robin their jobs one chunk at a time, so the stage stays
    responsive under a single huge prompt.
    """

    def __init__(self, engine, worker_id: int = 0):
        super().__init__(daemon=True,
                         name=f"prefill-worker-{worker_id}")
        self.eng = engine
        self.worker_id = worker_id
        self.last_beat = time.monotonic()  # liveness heartbeat (supervisor)
        self.claimed: List = []  # rows popped but not yet emitted (mutated
                                 # under eng._stage_lock) — the supervisor
                                 # requeues these if this worker dies
        self.chaos_killed = False

    # -- queue interaction (under the engine's stage lock) -----------------
    def _try_pop(self):
        eng = self.eng
        if eng._stacked is None:     # no adapter buffer yet: nothing to
            return None              # prefill against (rows keep queued)
        with eng._stage_lock:
            backlog = len(eng._ready) + len(eng._stage_inflight)
            if backlog >= eng.max_slots + eng.prefill_workers:
                return None
            if not eng._sched:
                return None
            # snapshot-carrying and device-parked rows (paged engine,
            # resume_restore / prefix cache) never prefill: the decode
            # thread splices their saved state back. Radix candidates and
            # GRPO siblings of rows already in this stage also stay queued
            # — the decode thread installs them as shared-page suffix
            # prefills (a sibling popped here would pay a full private
            # prefill the index was about to save).
            where = None
            if (getattr(eng, "resume_restore", False)
                    or getattr(eng, "prefix_cache", False)):
                radix = eng._radix_on()
                seen = set()
                if radix:
                    seen = {eng._group_key(r) for r in eng._stage_inflight}
                    seen |= {eng._group_key(rr.row) for rr in eng._ready}

                def where(r):
                    if r.snap is not None or r.dev_pages is not None:
                        return False
                    if radix and eng._radix_candidate(r) is not None:
                        return False
                    if radix and len(r.req.prompt) >= eng.kv_page_size \
                            and eng._group_key(r) in seen:
                        return False
                    return True
            row = eng._sched.pop(eng.stats.refills, where=where)
            if row is not None:
                eng._stage_inflight.append(row)
                self.claimed.append(row)
        if row is not None and eng._tracer is not None:
            eng._tracer.mark(eng._trace_of(row), "prefill")
        return row

    def _emit(self, job: _Job, first: int, lp: float,
              forced_lps: Optional[List[float]] = None):
        eng = self.eng
        ready = ReadyRow(row=job.row, seq_len=job.L, first=first, lp=lp,
                         init_counter=len(job.row.gen) + job.fused,
                         pcache=job.pcache,
                         ready_at=time.monotonic(),
                         forced_first=bool(job.row.forced_q)
                         and not job.fused,
                         forced_lps=forced_lps or [])
        if eng._tracer is not None:
            eng._tracer.mark(eng._trace_of(job.row), "ready", ready.ready_at)
        with eng._stage_lock:
            if job.row in self.claimed:
                self.claimed.remove(job.row)
            if job.row not in eng._stage_inflight:
                return    # aborted by drain() while we were prefilling
            eng._stage_inflight.remove(job.row)
            eng._ready.append(ready)
            eng.stats.prefill_seconds += job.spent
            eng.stats.prefill_tokens += job.L
            eng.stats.prefill_chunks += job.chunks

    # -- device calls ------------------------------------------------------
    def _advance(self, job: _Job) -> bool:
        """Run ONE prefill call for `job` (whole prompt, or the next chunk);
        returns True when the job is complete."""
        eng = self.eng
        ker = eng._pkernels
        cfg = eng.cfg
        params = eng.base_params
        stacked = eng._stacked           # immutable jax tree; non-donating
                                         # writes keep in-flight readers safe
        row = job.row
        row_id = jnp.asarray([row.req.adapter_index], jnp.int32)
        key = jnp.asarray(row.key[None], jnp.uint32)
        temp = jnp.asarray([row.req.temperature], jnp.float32)
        counter = jnp.asarray([len(row.gen) + job.fused], jnp.int32)
        # env-stage resume: the first spliced token is the forced RESP
        # opener (the response follows via the ordinary force-feed path) —
        # unless the job FUSED the whole forced block into its sequence,
        # in which case `first` is a true sample past the block
        forced = jnp.asarray(
            [row.forced_q[0] if row.forced_q and not job.fused else 0],
            jnp.int32)
        fmask = jnp.asarray([1 if row.forced_q and not job.fused else 0],
                            jnp.int32)
        C = eng._prefill_chunk_eff
        t0 = time.monotonic()

        def booked(done: bool) -> bool:
            now = time.monotonic()
            job.spent += now - t0
            if eng.on_stage is not None:
                eng.on_stage("prefill", row.req.task_id, t0, now)
            if eng._tracer is not None:
                # one span per (chunk or whole-prompt) device call, on
                # this worker's own track
                eng._tracer.span(
                    ("prefill", f"worker-{self.worker_id}"),
                    row.req.task_id, t0, now, trace=eng._trace_of(row))
            return done

        if C == 0 or job.L <= C or cfg.family == "encdec":
            toks = np.zeros((1, _bucket_len(job.L)), np.int32)
            toks[0, :job.L] = job.seq
            F = job.fused
            fpos = np.zeros((1, _bucket_len(F) if F else 1), np.int32)
            ftoks = np.zeros_like(fpos)
            if F:
                L0 = job.L - F
                fpos[0, :F] = np.arange(L0 - 1, L0 - 1 + F)
                ftoks[0, :F] = job.seq[L0:]
            first, lp, flp, job.pcache = ker.whole(
                params, stacked, row_id, jnp.asarray(toks),
                jnp.asarray([job.L], jnp.int32), counter, key, temp,
                forced, fmask, jnp.asarray(fpos), jnp.asarray(ftoks))
            job.chunks += 1
            first = int(np.asarray(first)[0])
            lp = float(np.asarray(lp)[0])
            flps = [float(x) for x in np.asarray(flp)[0, :F]] if F else None
            booked(True)
            self._emit(job, first, lp, flps)
            return True
        if job.pcache is None:
            job.pcache = ker.fresh_cache()
        start = job.done
        end = min(start + C, job.L)
        toks = np.zeros((1, C), np.int32)
        toks[0, :end - start] = job.seq[start:end]
        h, job.pcache = ker.chunk(start, params, stacked, row_id,
                                  jnp.asarray(toks),
                                  jnp.asarray([end - start], jnp.int32),
                                  job.pcache)
        job.done = end
        job.chunks += 1
        if end < job.L:
            return booked(False)
        first, lp = ker.finish(params, h,
                               jnp.asarray([job.L - 1 - start], jnp.int32),
                               key, counter, temp, forced, fmask)
        first = int(np.asarray(first)[0])
        lp = float(np.asarray(lp)[0])
        booked(True)
        self._emit(job, first, lp)
        return True

    # -- main loop ---------------------------------------------------------
    def run(self):
        eng = self.eng
        jobs: Deque[_Job] = deque()
        try:
            while not eng._stage_stop.is_set():
                self.last_beat = time.monotonic()
                row = self._try_pop()
                if row is not None and eng._chaos is not None \
                        and eng._chaos.fire("prefill_worker_kill"):
                    # simulated abrupt death: skip the finally requeue —
                    # the claimed rows stay stranded in _stage_inflight
                    # until the supervisor's recovery requeues them
                    self.chaos_killed = True
                    return
                if row is not None:
                    # response-prefill fusion: fold a resume's whole forced
                    # block into the prefill when the job will run as ONE
                    # whole-sequence call (per-token logprobs come off the
                    # same hidden states; chunked jobs keep the step-wise
                    # force-feed)
                    C = eng._prefill_chunk_eff
                    L_f = row.prompt_len + len(row.gen) + len(row.forced_q)
                    fuse = (getattr(eng, "paged_kv", False)
                            and eng._fusable_forced(row)
                            and (C == 0 or L_f <= C
                                 or eng.cfg.family == "encdec"))
                    jobs.append(_Job(row,
                                     fused=len(row.forced_q) if fuse else 0))
                if not jobs:
                    time.sleep(0.0005)
                    continue
                job = jobs.popleft()         # round-robin: one chunk each
                try:
                    if not self._advance(job):
                        jobs.append(job)
                except BaseException as e:   # surface to the engine thread
                    eng._stage_error = e
                    jobs.append(job)         # keep the row accounted for
                    break
        finally:
            # hand unfinished rows back so abort/drain accounting sees them
            # (rows drain() already swept out of _stage_inflight were
            # aborted there — dropping them keeps one completion each);
            # a chaos-killed worker deliberately strands its rows — the
            # supervisor's recovery path is what's under test
            if not self.chaos_killed:
                with eng._stage_lock:
                    for job in jobs:
                        if job.row in eng._stage_inflight:
                            eng._stage_inflight.remove(job.row)
                            eng._sched.push(job.row, eng.stats.refills)
                        if job.row in self.claimed:
                            self.claimed.remove(job.row)
