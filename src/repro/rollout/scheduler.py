"""Slot-scheduling policies for the continuous engine (paper §4.3/§4.5).

The continuous engine's cross-task request queue was FIFO in PR 1; at high
tenant counts a few long rollouts head-of-line block everyone else (the
skew "RL in the Wild" characterizes). This module provides the ordered pop
that replaces it:

``LengthPredictor`` — per-tenant EMA of *sampled* completion length, fed by
every evicted row. Until a tenant has history its prediction is its request
budget (``max_new_tokens``), so cold tenants are scheduled pessimistically
and converge as rows complete.

``SlotScheduler`` — the queue. Pop order under policy ``"srpt"``:

  1. starvation tier: any entry that has waited ``starvation_k`` refill
     events pops first, FIFO among the starved — every queued tenant is
     guaranteed progress within K refills no matter how many short rows
     keep arriving;
  2. priority tier: higher ``RolloutRequest.priority`` first;
  3. resume tier: env-stage resume jobs (rows re-queued with a pre-loaded
     force-feed queue, see rollout/env_stage.py) pop before fresh rows of
     the same priority — they carry live episode/session state and their
     force-fed response tokens are budget-exempt, so finishing them first
     drains in-flight episodes instead of opening new ones;
  4. shortest-predicted-remaining-budget first (predicted length minus
     tokens already sampled — replayed rows get credit for their prefix);
  5. deterministic tie-break on ``submit_index`` (unique per row).

Policy ``"fifo"`` preserves PR-1 arrival order (the benchmark baseline).
Token streams are unaffected by pop order: sampling is per-row
(key, counter), so any schedule yields the same tokens per request.

With the disaggregated prefill stage (``rollout/prefill.py``) this queue
IS the prefill queue: workers pop in the same scheduler order the fused
refill used, so SRPT/priority/starvation semantics carry over unchanged —
the pop just happens on a prefill worker instead of the decode stream.
The queue itself is not thread-safe; the engine serializes access under
its stage lock.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Dict, List, Optional

POLICIES = ("fifo", "srpt")


class LengthPredictor:
    """EMA per-tenant predictor of sampled completion length.

    Thread contract: ``observe`` runs on the rollout thread (every evicted
    row) while the driver thread calls ``predict`` from the admission
    tick's expected-generation estimate — the EMA dict is the one piece of
    scheduler state crossing threads, hence its own lock."""

    def __init__(self, alpha: float = 0.25):
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha {alpha} outside (0, 1]")
        self.alpha = alpha
        self._lock = threading.Lock()   # guards: _ema
        self._ema: Dict[str, float] = {}

    def observe(self, tenant: str, sampled_tokens: int):
        """Feed one completed row's sampled-token count."""
        x = float(sampled_tokens)
        with self._lock:
            prev = self._ema.get(tenant)
            self._ema[tenant] = x if prev is None else (
                self.alpha * x + (1.0 - self.alpha) * prev)

    def predict(self, tenant: str, budget: int) -> float:
        """Expected sampled length for a row of `tenant` with this budget.

        No history -> the full budget (pessimistic prior); with history the
        EMA, still capped by the budget (a row can never exceed it)."""
        with self._lock:
            e = self._ema.get(tenant)
        return float(budget) if e is None else min(float(budget), e)

    def remaining(self, tenant: str, budget: int, sampled: int) -> float:
        """Predicted sampled tokens still to come for a (possibly replayed)
        row that has already sampled `sampled` of its `budget`."""
        return max(1.0, self.predict(tenant, budget) - float(sampled))


@dataclass
class _Entry:
    row: object          # duck-typed: .req.{task_id,priority,max_new_tokens},
                         # .sampled, .submit_index
    seq: int             # push order (FIFO key)
    enq_refill: int      # engine refill counter at push time (starvation age)


class SlotScheduler:
    """Ordered request queue for the continuous engine's free-slot refill."""

    def __init__(self, policy: str = "srpt",
                 predictor: Optional[LengthPredictor] = None,
                 starvation_k: int = 8):
        if policy not in POLICIES:
            raise ValueError(f"unknown scheduler policy {policy!r}; "
                             f"one of {POLICIES}")
        if starvation_k < 1:
            raise ValueError("starvation_k must be >= 1")
        self.policy = policy
        self.predictor = predictor or LengthPredictor()
        self.starvation_k = starvation_k
        self._entries: List[_Entry] = []
        self._seq = 0

    def __len__(self) -> int:
        return len(self._entries)

    def push(self, row, refill_count: int = 0):
        self._entries.append(_Entry(row, self._seq, refill_count))
        self._seq += 1

    def _key(self, e: _Entry, refill_count: int):
        if self.policy == "fifo":
            return (e.seq,)
        starved = (refill_count - e.enq_refill) >= self.starvation_k
        if starved:
            # starvation tier wins outright; FIFO among the starved
            return (0, e.seq, 0, 0, 0.0, 0)
        req = e.row.req
        rem = self.predictor.remaining(req.task_id, req.max_new_tokens,
                                       e.row.sampled)
        resume = 0 if getattr(e.row, "forced_q", None) else 1
        return (1, 0, -req.priority, resume, rem, e.row.submit_index)

    def pop(self, refill_count: int = 0, where=None):
        """Remove and return the highest-ranked row, or None if empty.

        `where` (optional row predicate) restricts the pop to matching
        rows — the paged engine uses it to keep snapshot-carrying rows out
        of the prefill/replay path (they restore on the decode thread) and
        vice versa; scheduling order among eligible rows is unchanged."""
        if not self._entries:
            return None
        idxs = (range(len(self._entries)) if where is None else
                [i for i in range(len(self._entries))
                 if where(self._entries[i].row)])
        if not idxs:
            return None
        best = min(idxs,
                   key=lambda i: self._key(self._entries[i], refill_count))
        return self._entries.pop(best).row

    def pop_if(self, refill_count: int = 0, pred=None):
        """Pop the highest-ranked row ONLY if it satisfies `pred`; returns
        None otherwise (queue untouched). Unlike ``pop(where=)`` this never
        jumps a matching row over better-ranked non-matching ones — the
        paged engine's restore path uses it so a snapshot-carrying row
        resumes when (and only when) it is genuinely next in line, never
        ahead of a higher-priority tenant's fresh rows."""
        if not self._entries:
            return None
        best = min(range(len(self._entries)),
                   key=lambda i: self._key(self._entries[i], refill_count))
        if pred is not None and not pred(self._entries[best].row):
            return None
        return self._entries.pop(best).row

    def pop_all(self) -> List:
        """Drain every queued row in current pop order (abort path)."""
        out = []
        while self._entries:
            out.append(self.pop())
        return out

    def tenants(self) -> frozenset:
        return frozenset(e.row.req.task_id for e in self._entries)

    def rows_for(self, task_id: str) -> List:
        """A tenant's queued rows (admission re-estimates read `.sampled`
        off preempted rows awaiting replay)."""
        return [e.row for e in self._entries if e.row.req.task_id == task_id]
