"""Paged KV-cache block pool (ISSUE 5 tentpole).

The continuous engine's dense cache reserved ``max_len`` KV positions per
decode slot — a 10-token row paid the same HBM as a 2000-token one, and the
admission controller had to charge every tenant the worst case. This module
is the host-side half of the paged replacement (vLLM's PagedAttention
memory model, TPU-adapted — the device half is
``kernels/paged_decode.py`` + the paged write/gather paths in
``models/model.py``):

``PagePool`` — a fixed-size pool of ``n_pages`` KV pages of ``page_size``
tokens each, with a free list and per-page reference counts. Rows own
pages through per-slot block tables (the engine mirrors them host-side and
uploads a ``[slots, max_pages_per_row]`` int32 table to the device when
the topology changes). Ref counts make sharing explicit: a page is
returned to the free list only when its last owner releases it, and the
allocator invariants (no page on the free list while referenced, no page
referenced by two owners unless retained, conservation of the page count)
are property-tested in ``tests/test_paged_kv.py``.

``KVSnapshot`` — a parked/preempted row's device state copied to HOST
memory: its live KV pages (only ``ceil(pos/page_size)`` of them — never
the ``max_len`` worst case), recurrent SSM/conv states, the cache position
and the pending current token. Restoring a snapshot splices the pages back
into freshly allocated pool pages and resumes decode with the pending
token — no prefill replay, so an N-turn agentic episode stops paying
O(N·len) recomputation (``RolloutStats.replay_tokens_saved``).

``SnapshotStore`` — byte-budgeted host arena for snapshots. Under memory
pressure (``budget_bytes`` exceeded) a new snapshot is DROPPED rather than
stored; the row then falls back to the retained token-replay path, which
is token-for-token identical (property-tested), just slower. Since the
prefix cache (ISSUE 8) parks attention pages device-resident, the store
is a SPILL tier: it only sees pages when the pool itself is under
pressure, plus the recurrent SSM/conv states (which have no paged
representation and always go to host).

``PrefixIndex`` — a per-adapter radix/trie over page-aligned token
prefixes. Every fully-prefilled prompt inserts its FULL pages (each node
is one page worth of tokens; the index holds its own refcount on the
page), and a new request walks its longest indexed prefix, retains those
pages, and prefills only the suffix. Pages in the index are immutable by
construction — decode writes land at positions >= the page-aligned
prompt boundary, and the engine's copy-on-write fork covers any page
with refcount > 1 — so sharing is safe across GRPO siblings, tool-turn
resumes, and unrelated requests with a common system prefix.

The pool itself is plain host bookkeeping — device page contents live in
the engine's cache pytree (``kp``/``vp``: ``[L, n_pages+1, page, KVH,
hd]``; physical page ``n_pages`` is a scratch/pad page that sentinel block
-table entries point at, so out-of-range reads and frozen-lane writes land
somewhere harmless without any clamping in the kernels).
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np


def pages_for(tokens: int, page_size: int) -> int:
    """Pages needed to hold `tokens` cache entries."""
    if tokens <= 0:
        return 0
    return -(-int(tokens) // int(page_size))


class PagePool:
    """Fixed-size block-pool allocator with a free list and ref counts.

    Page ids are ``0 .. n_pages-1``; id ``n_pages`` is the conventional
    SENTINEL (the device-side scratch page) and is never allocated. All
    methods are host-side and O(pages touched); the engine serializes
    access (single rollout thread).
    """

    def __init__(self, n_pages: int, page_size: int):
        if n_pages < 1:
            raise ValueError("page pool needs at least one page")
        if page_size < 1:
            raise ValueError("page_size must be >= 1")
        self.n_pages = n_pages
        self.page_size = page_size
        self.sentinel = n_pages
        self._free: List[int] = list(range(n_pages - 1, -1, -1))
        self._rc = np.zeros((n_pages,), np.int32)
        # high-water mark of pages in use (occupancy gauge)
        self.peak_used = 0

    # -- introspection ---------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.n_pages - len(self._free)

    def refcount(self, page: int) -> int:
        return int(self._rc[page])

    @property
    def shared_pages(self) -> int:
        """Pages with more than one owner (COW prefix-sharing gauge)."""
        return int((self._rc > 1).sum())

    def check_invariants(self):
        """Allocator invariants (hypothesis property tests call this after
        every operation): free/used conservation, free pages unreferenced,
        used pages referenced, no duplicates on the free list."""
        assert len(set(self._free)) == len(self._free), "free-list dup"
        assert all(0 <= p < self.n_pages for p in self._free)
        free = set(self._free)
        for p in range(self.n_pages):
            if p in free:
                assert self._rc[p] == 0, f"page {p} free but referenced"
            else:
                assert self._rc[p] > 0, f"page {p} leaked (rc=0, not free)"
        assert self.used_pages + self.free_pages == self.n_pages

    # -- lifecycle -------------------------------------------------------
    def alloc(self, n: int) -> Optional[List[int]]:
        """Allocate `n` pages (rc=1 each) or None if the pool can't serve
        the whole request (all-or-nothing: a partially allocated row would
        deadlock against another partially allocated row)."""
        if n < 0:
            raise ValueError(n)
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._rc[p] = 1
        self.peak_used = max(self.peak_used, self.used_pages)
        return pages

    def retain(self, pages: List[int]):
        """Add one reference to each page (prefix sharing: a second owner
        of the same immutable prefix pages)."""
        for p in pages:
            if self._rc[p] <= 0:
                raise ValueError(f"retain of unallocated page {p}")
            self._rc[p] += 1

    def release(self, pages: List[int]):
        """Drop one reference per page; pages return to the free list at
        rc==0."""
        for p in pages:
            if self._rc[p] <= 0:
                raise ValueError(f"release of unallocated page {p}")
            self._rc[p] -= 1
            if self._rc[p] == 0:
                self._free.append(p)


@dataclass
class KVSnapshot:
    """One parked/preempted row's cache state, host-side.

    ``pos`` cache entries are materialized (the prompt + all generated
    tokens EXCEPT the pending one); ``cur`` is the last accepted token,
    not yet fed through the model — restoring installs (pages, states,
    pos, cur) and the next ordinary decode step continues the row exactly
    where an uninterrupted run would be (same logits, same
    fold_in(key, counter) sample)."""
    pos: int                           # materialized cache entries
    cur: int                           # pending token (== row.gen[-1])
    kpages: Optional[np.ndarray] = None   # [L_attn, n_pg, page, KVH, hd]
    vpages: Optional[np.ndarray] = None
    ssm: Optional[np.ndarray] = None      # [L_ssm, H, N, P] (this row)
    conv: Optional[np.ndarray] = None     # [L_ssm, conv_dim, W-1]

    @property
    def n_pages(self) -> int:
        return 0 if self.kpages is None else int(self.kpages.shape[1])

    @property
    def nbytes(self) -> int:
        return sum(int(a.nbytes) for a in
                   (self.kpages, self.vpages, self.ssm, self.conv)
                   if a is not None)


class SnapshotStore:
    """Byte-budgeted host arena for KV snapshots.

    ``budget_bytes == 0`` means unlimited. ``try_add`` REJECTS a snapshot
    that would exceed the budget (the caller falls back to token replay) —
    rejecting the newcomer rather than evicting an older snapshot keeps
    the drop deterministic and never invalidates state another queued row
    already depends on."""

    def __init__(self, budget_bytes: int = 0):
        self.budget_bytes = int(budget_bytes)
        self.bytes_used = 0
        self.drops = 0            # snapshots rejected under pressure

    def try_add(self, snap: KVSnapshot) -> bool:
        need = snap.nbytes
        if self.budget_bytes and self.bytes_used + need > self.budget_bytes:
            self.drops += 1
            return False
        self.bytes_used += need
        return True

    def remove(self, snap: KVSnapshot):
        self.bytes_used -= snap.nbytes
        assert self.bytes_used >= 0


class _TrieNode:
    __slots__ = ("children", "page", "parent", "key", "stamp")

    def __init__(self, parent: Optional["_TrieNode"] = None,
                 key=None, page: int = -1):
        self.children: Dict[tuple, "_TrieNode"] = {}
        self.page = page          # physical page id this node retains
        self.parent = parent
        self.key = key            # edge label: tuple of page_size tokens
        self.stamp = 0            # LRU clock at last touch


class PrefixIndex:
    """Per-adapter radix index over page-aligned token prefixes.

    Each trie edge is one page worth of tokens (a tuple of ``page_size``
    ints); the node at the end of the edge retains exactly one reference
    on the physical page holding that chunk's K/V. ``insert`` dedups
    against existing nodes (a sibling inserting an already-indexed prefix
    retains nothing new), ``match`` walks the longest indexed prefix, and
    ``pop_lru`` / ``invalidate`` hand back page ids for the CALLER to
    release — all ``PagePool`` mutation stays on the engine thread, which
    serializes pool access. The lock only protects trie structure so that
    prefill workers may run read-mostly ``match`` probes concurrently
    with engine inserts/evictions.
    """

    def __init__(self, page_size: int):
        if page_size < 1:
            raise ValueError("page_size must be >= 1")
        self.page_size = int(page_size)
        self._lock = threading.Lock()   # guards: _roots/_clock/_held
        self._roots: Dict[object, _TrieNode] = {}
        self._clock = 0
        self._held = 0                  # pages currently retained by nodes

    # -- introspection ---------------------------------------------------
    @property
    def held_pages(self) -> int:
        with self._lock:
            return self._held

    def refcounts(self) -> Dict[int, int]:
        """Page id -> number of index nodes retaining it (for the engine's
        page-invariant checker)."""
        out: Dict[int, int] = {}
        with self._lock:
            for root in self._roots.values():
                stack = list(root.children.values())
                while stack:
                    nd = stack.pop()
                    out[nd.page] = out.get(nd.page, 0) + 1
                    stack.extend(nd.children.values())
        return out

    # -- helpers ---------------------------------------------------------
    def _chunks(self, tokens) -> List[tuple]:
        p = self.page_size
        n = len(tokens) // p
        return [tuple(int(t) for t in tokens[i * p:(i + 1) * p])
                for i in range(n)]

    # -- lifecycle -------------------------------------------------------
    def insert(self, adapter, tokens, pages: List[int],
               tail_page: Optional[int] = None) -> List[int]:
        """Index a prompt's FULL pages (``len(pages)`` must cover the
        page-aligned prefix of ``tokens``) plus, optionally, the PARTIAL
        tail page holding the remainder — keyed by the (shorter) remainder
        tuple, so an exact-prompt sibling (GRPO group) can share the whole
        prompt including its last page and fork it copy-on-write at the
        first decode write. Returns the subset of page ids newly
        referenced by the index — the caller must ``retain`` exactly
        those (the row already owns them, so rc >= 1 holds)."""
        chunks = self._chunks(tokens)[:len(pages)]
        rem = tuple(int(t) for t in tokens[len(chunks) * self.page_size:])
        newly: List[int] = []
        with self._lock:
            self._clock += 1
            node = self._roots.setdefault(adapter, _TrieNode())
            for i, ch in enumerate(chunks):
                nxt = node.children.get(ch)
                if nxt is None:
                    nxt = _TrieNode(parent=node, key=ch,
                                    page=int(pages[i]))
                    node.children[ch] = nxt
                    newly.append(int(pages[i]))
                nxt.stamp = self._clock
                node = nxt
            if tail_page is not None and rem:
                nxt = node.children.get(rem)
                if nxt is None:
                    nxt = _TrieNode(parent=node, key=rem,
                                    page=int(tail_page))
                    node.children[rem] = nxt
                    newly.append(int(tail_page))
                nxt.stamp = self._clock
            self._held += len(newly)
        return newly

    def match_full(self, adapter, tokens):
        """Exact whole-sequence match (the GRPO-sibling fast path): every
        full chunk is indexed AND — for non-page-aligned sequences — a
        tail node holds the exact remainder. Returns ``(full_pages,
        tail_page)`` (``tail_page`` None when the sequence is page-aligned)
        or None. A hit means the sibling installs with ZERO prefill
        writes: it retains every page, recomputes only the final chunk for
        its first-token logits, and its first decode write COW-forks the
        shared tail."""
        chunks = self._chunks(tokens)
        rem = tuple(int(t) for t in tokens[len(chunks) * self.page_size:])
        with self._lock:
            node = self._roots.get(adapter)
            if node is None:
                return None
            self._clock += 1
            pages: List[int] = []
            for ch in chunks:
                nxt = node.children.get(ch)
                if nxt is None:
                    return None
                nxt.stamp = self._clock
                pages.append(nxt.page)
                node = nxt
            if not rem:
                return (pages, None) if pages else None
            tail = node.children.get(rem)
            if tail is None:
                return None
            tail.stamp = self._clock
            return (pages, tail.page)

    def match(self, adapter, tokens, max_tokens: Optional[int] = None
              ) -> List[int]:
        """Longest indexed page-aligned prefix of ``tokens``: the page
        ids along the path, NOT retained — the engine retains them under
        its own serialization before any eviction can run (evictions also
        happen only on the engine thread). ``max_tokens`` caps the match
        (e.g. to ``len(seq) - 1`` so at least one suffix token remains to
        prefill)."""
        chunks = self._chunks(tokens)
        if max_tokens is not None:
            chunks = chunks[:max(0, int(max_tokens)) // self.page_size]
        pages: List[int] = []
        with self._lock:
            node = self._roots.get(adapter)
            if node is None:
                return []
            self._clock += 1
            for ch in chunks:
                nxt = node.children.get(ch)
                if nxt is None:
                    break
                nxt.stamp = self._clock
                pages.append(nxt.page)
                node = nxt
        return pages

    def pop_lru(self, n_pages: int) -> List[int]:
        """Remove up to ``n_pages`` least-recently-touched LEAF entries
        (an emptied parent becomes eligible next round) and return their
        page ids for the caller to release."""
        out: List[int] = []
        with self._lock:
            while len(out) < n_pages:
                leaf = None
                for root in self._roots.values():
                    stack = list(root.children.values())
                    while stack:
                        nd = stack.pop()
                        if nd.children:
                            stack.extend(nd.children.values())
                        elif leaf is None or nd.stamp < leaf.stamp:
                            leaf = nd
                if leaf is None:
                    break
                del leaf.parent.children[leaf.key]
                out.append(leaf.page)
            self._held -= len(out)
        return out

    def invalidate(self, adapter=None) -> List[int]:
        """Drop one adapter's subtree (or everything when ``adapter`` is
        None — e.g. ``set_adapters`` swapped the stack) and return the
        page ids for the caller to release."""
        out: List[int] = []
        with self._lock:
            if adapter is None:
                roots = list(self._roots.values())
                self._roots.clear()
            else:
                nd = self._roots.pop(adapter, None)
                roots = [nd] if nd is not None else []
            for root in roots:
                stack = list(root.children.values())
                while stack:
                    nd = stack.pop()
                    out.append(nd.page)
                    stack.extend(nd.children.values())
            self._held -= len(out)
        return out
