"""Logical-axis sharding helpers.

Models annotate activations with *logical* axes ("dp", "tp", "sp"); the
launcher installs a mesh + logical→physical rules and annotations become
``with_sharding_constraint``. Outside a mesh context they are no-ops, so the
same model code runs single-device tests and 512-chip dry-runs unchanged.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_TLS = threading.local()

# default logical→physical rules (single-pod); launcher overrides for multi-pod
DEFAULT_RULES: Dict[str, Union[str, Tuple[str, ...], None]] = {
    "dp": ("data",),         # batch / fsdp axis
    "tp": ("model",),        # tensor / expert axis
    "sp": ("model",),        # sequence axis for sharded long-KV decode
    None: None,
}

MULTIPOD_RULES: Dict[str, Union[str, Tuple[str, ...], None]] = {
    "dp": ("pod", "data"),
    "tp": ("model",),
    "sp": ("model",),
    None: None,
}


def _state():
    if not hasattr(_TLS, "mesh"):
        _TLS.mesh = None
        _TLS.rules = DEFAULT_RULES
    return _TLS


@contextlib.contextmanager
def mesh_context(mesh: Optional[Mesh], rules=None):
    st = _state()
    prev = (st.mesh, st.rules)
    st.mesh = mesh
    st.rules = rules or (MULTIPOD_RULES if (mesh is not None and "pod" in mesh.axis_names)
                         else DEFAULT_RULES)
    try:
        if mesh is not None:
            # jax.sharding.set_mesh only exists on newer JAX; 0.4.x spells
            # the same thing as the Mesh context manager.
            set_mesh = getattr(jax.sharding, "set_mesh", None)
            ctx = set_mesh(mesh) if set_mesh is not None else mesh
            with ctx:
                yield
        else:
            yield
    finally:
        st.mesh, st.rules = prev


def current_mesh() -> Optional[Mesh]:
    return _state().mesh


def resolve(*logical) -> P:
    rules = _state().rules
    phys = []
    for ax in logical:
        if ax is None:
            phys.append(None)
        elif isinstance(ax, (tuple, list)):
            flat = []
            for a in ax:
                r = rules.get(a, None)
                if r is None:
                    continue
                flat.extend([r] if isinstance(r, str) else list(r))
            phys.append(tuple(flat) if flat else None)
        else:
            r = rules.get(ax, None)
            if r is None:
                phys.append(None)
            elif isinstance(r, str):
                phys.append(r)
            else:
                phys.append(r if len(r) > 1 else r[0])
    return P(*phys)


def constrain(x, *logical):
    """with_sharding_constraint via logical axes; no-op without a mesh."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = resolve(*logical)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(*logical) -> Optional[NamedSharding]:
    mesh = current_mesh()
    if mesh is None:
        return None
    return NamedSharding(mesh, resolve(*logical))
