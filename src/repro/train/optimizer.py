"""AdamW in pure JAX (no optax in this environment) + global-norm clipping.

Optimizer state is a plain pytree {m, v, step} mirroring the param tree —
exactly the φ_t^(v) the multi-task manager versions per tenant (paper §4.2).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 1e-4
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float = 1.0
    warmup_steps: int = 0


def adamw_init(params) -> Dict[str, Any]:
    zeros = lambda p: jax.tree.map(lambda t: jnp.zeros(t.shape, jnp.float32), p)
    return {"m": zeros(params), "v": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32)))
              for l in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(grads, max_norm: float):
    n = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (n + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), n


def adamw_update(params, grads, state, cfg: AdamWConfig):
    """Returns (new_params, new_state, grad_norm)."""
    if cfg.grad_clip:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    else:
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        gnorm = global_norm(grads)
    step = state["step"] + 1
    lr = cfg.lr
    if cfg.warmup_steps:
        lr = lr * jnp.minimum(1.0, step.astype(jnp.float32) / cfg.warmup_steps)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, gnorm
