"""Supervised warmup (SFT) — teacher-forced cross-entropy on verified
answers. RLVR assumes a pretrained base policy (the paper fine-tunes Qwen3);
on this box base models are random-init, so examples/tests warm the base up
on the task format first, then GRPO lifts the verifiable reward — the same
two-stage shape as the paper's pipeline."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig
from repro.models import forward_seq
from repro.rl.grpo import token_logprobs_chunked
from .optimizer import AdamWConfig, adamw_init, adamw_update


def make_sft_step(cfg: ModelConfig, adamw: AdamWConfig,
                  trainable: str = "full"):
    """SFT on (tokens, loss positions). trainable: full | lora."""

    def loss_fn(tree, base_params, batch):
        if trainable == "lora":
            from repro.lora.adapters import single_ctx
            params, lora = base_params, single_ctx(tree, cfg)
        else:
            params, lora = tree, None
        tokens = batch["tokens"]
        S = tokens.shape[1]
        h, _, _ = forward_seq(params, tokens, cfg, lora, None)
        w = params["lm_head"] if not cfg.tie_embeddings else params["embed"].T
        lp, _ = token_logprobs_chunked(h[:, :-1], w, tokens[:, 1:],
                                       cfg.logit_softcap)
        idx = jnp.arange(S - 1)[None, :]
        mask = ((idx >= (batch["prompt_lens"] - 1)[:, None])
                & (idx < (batch["total_lens"] - 1)[:, None])).astype(jnp.float32)
        return -jnp.sum(lp * mask) / jnp.maximum(jnp.sum(mask), 1.0)

    def sft_step(base_params, tree, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(tree, base_params, batch)
        tree, opt_state, gnorm = adamw_update(tree, grads, opt_state, adamw)
        return tree, opt_state, {"loss": loss, "grad_norm": gnorm}

    return sft_step


def sft_init(params_or_lora):
    return adamw_init(params_or_lora)
