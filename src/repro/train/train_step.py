"""PolicyUpdate (paper Algorithm 1, line 14): one GRPO update for one task.

``make_train_step`` builds the jitted update used by the training engine.
The paper-faithful mode differentiates ONLY the task's LoRA adapters
(θ_t^(v) → θ_t^(v+1)) against the frozen shared base model; optimizer state
is the task's φ_t^(v). ``trainable="full"`` exists as a baseline.

Gradient accumulation scans over microbatches (accum_steps) — at production
scale this is what lets per-microbatch reduce-scatters overlap the backward
of the next microbatch.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig
from repro.lora.adapters import single_ctx
from repro.models import forward_seq
from repro.models.common import LoraCtx
from repro.rl.grpo import (GRPOOut, group_advantages, grpo_loss,
                           token_logprobs_chunked)
from .optimizer import AdamWConfig, adamw_init, adamw_update
from .sharding import constrain


@dataclass(frozen=True)
class TrainConfig:
    group_size: int = 8
    clip_eps: float = 0.2
    kl_coef: float = 0.0
    ent_coef: float = 0.0
    accum_steps: int = 1
    recompute_old: bool = True       # recompute behavior logprobs under the
                                     # training forward (MoE-drop safe)
    is_cap: float = 0.0              # decoupled-PPO importance-weight cap
                                     # for off-policy (stale) batches:
                                     # ρ = min(exp(old_lp − behavior_lp),
                                     # is_cap) reweights the clipped
                                     # objective. 0 disables the correction
                                     # entirely — the on-policy loss is
                                     # bit-identical to before
    trainable: str = "lora"          # lora | full
    use_logprob_kernel: bool = False
    adamw: AdamWConfig = field(default_factory=AdamWConfig)


def _completion_mask(prompt_lens, total_lens, S):
    idx = jnp.arange(S)[None, :]
    lo = (prompt_lens - 1)[:, None]
    hi = (total_lens - 1)[:, None]
    return ((idx >= lo) & (idx < hi)).astype(jnp.float32)


def _policy_logprobs(params, tokens, cfg: ModelConfig, lora: Optional[LoraCtx],
                     tc: TrainConfig, enc_embeds=None):
    """Token logprobs [R, S-1] for predicting tokens[:, 1:]."""
    h, _, aux = forward_seq(params, tokens, cfg, lora, None,
                            enc_embeds=enc_embeds)
    if not cfg.tie_embeddings:
        vocab_w = params["lm_head"]      # V-sharded → vocab-parallel loss
    else:
        # tied: embed.T is d-sharded; reshard to V-sharded ONCE per
        # microbatch (one all-to-all of the table) so the LSE/gather run
        # vocab-parallel instead of all-gathering the matrix per chunk
        # (§Perf B1 — tied archs only)
        vocab_w = constrain(params["embed"].T, None, "tp")
    lp, ent = token_logprobs_chunked(h[:, :-1], vocab_w, tokens[:, 1:],
                                     cfg.logit_softcap,
                                     use_kernel=tc.use_logprob_kernel)
    return lp, ent, aux


def make_train_step(cfg: ModelConfig, tc: TrainConfig):
    """Returns train_step(base_params, lora, opt_state, batch) ->
    (new_lora, new_opt_state, metrics). batch keys:
      tokens [R, S] int32, prompt_lens [R], total_lens [R], rewards [R],
      behavior_logprobs [R, S-1] (optional), enc_embeds (encdec only).
    R = num_groups * tc.group_size; groups contiguous.
    """

    def loss_fn(trainable_tree, base_params, batch):
        if tc.trainable == "lora":
            params = base_params
            lora = single_ctx(trainable_tree, cfg)
        else:
            params = trainable_tree
            lora = None
        tokens = batch["tokens"]
        R, S = tokens.shape
        assert R % tc.group_size == 0, (R, tc.group_size)
        mask = _completion_mask(batch["prompt_lens"], batch["total_lens"], S)[:, :S - 1]
        if "loss_mask" in batch:  # env/tool-provided tokens carry no loss
            mask = mask * batch["loss_mask"][:, :S - 1]
        adv = group_advantages(batch["rewards"], tc.group_size)

        new_lp, ent, aux = _policy_logprobs(params, tokens, cfg, lora, tc,
                                            batch.get("enc_embeds"))
        if tc.recompute_old or "behavior_logprobs" not in batch:
            old_lp = jax.lax.stop_gradient(new_lp)
        else:
            old_lp = batch["behavior_logprobs"]
        ref_lp = None
        if tc.kl_coef:
            ref_lp, _, _ = _policy_logprobs(params, tokens, cfg, None, tc,
                                            batch.get("enc_embeds"))
            ref_lp = jax.lax.stop_gradient(ref_lp)
        # off-policy correction for the bounded-staleness trainer: the
        # behaviour logprobs recorded at sample time enter ONLY as the
        # truncated importance weight; the clip ratio stays anchored to the
        # recomputed (proximal) old_lp
        behavior = (batch.get("behavior_logprobs")
                    if tc.is_cap > 0 else None)
        out = grpo_loss(new_lp, old_lp, adv, mask, ref_lp,
                        clip_eps=tc.clip_eps, kl_coef=tc.kl_coef,
                        entropy=ent, ent_coef=tc.ent_coef,
                        behavior_logprobs=behavior, is_cap=tc.is_cap)
        loss = out.loss + 0.01 * aux          # MoE load-balance aux
        metrics = {"loss": out.loss, "pg_loss": out.pg_loss, "kl": out.kl,
                   "entropy": out.entropy, "ratio_mean": out.ratio_mean,
                   "clip_frac": out.clip_frac, "aux": aux,
                   "is_weight_mean": out.is_weight_mean,
                   "is_trunc_frac": out.is_trunc_frac}
        return loss, metrics

    def train_step(base_params, lora_tree, opt_state, batch):
        trainable = lora_tree if tc.trainable == "lora" else base_params
        grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

        if tc.accum_steps == 1:
            (loss, metrics), grads = grad_fn(trainable, base_params, batch)
        else:
            A = tc.accum_steps

            def micro(carry, mb):
                g_acc, m_acc = carry
                (_, m), g = grad_fn(trainable, base_params, mb)
                g_acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                     g_acc, g)
                m_acc = jax.tree.map(lambda a, b: a + b, m_acc, m)
                return (g_acc, m_acc), None

            zeros_g = jax.tree.map(lambda t: jnp.zeros(t.shape, jnp.float32),
                                   trainable)
            zeros_m = {k: jnp.zeros((), jnp.float32) for k in
                       ["loss", "pg_loss", "kl", "entropy", "ratio_mean",
                        "clip_frac", "aux", "is_weight_mean",
                        "is_trunc_frac"]}
            mbs = jax.tree.map(
                lambda t: t.reshape((A, t.shape[0] // A) + t.shape[1:]), batch)
            (grads, msum), _ = jax.lax.scan(micro, (zeros_g, zeros_m), mbs)
            grads = jax.tree.map(lambda g: g / A, grads)
            metrics = jax.tree.map(lambda m: m / A, msum)

        new_trainable, new_opt, gnorm = adamw_update(trainable, grads,
                                                     opt_state, tc.adamw)
        metrics["grad_norm"] = gnorm
        metrics["reward_mean"] = jnp.mean(batch["rewards"])
        return new_trainable, new_opt, metrics

    return train_step


def init_opt_state(cfg: ModelConfig, tc: TrainConfig, base_params, lora_tree):
    return adamw_init(lora_tree if tc.trainable == "lora" else base_params)
