"""The paper's three workload archetypes, self-contained and synthetic:

  ArithmeticEnv ("gsm8k")  — short math, no tools, short rollouts
  LongMathEnv   ("amc12")  — longer chains, higher rollout latency
  SearchEnv     ("search") — agentic: CALL → synthetic-KB lookup with
                             external latency → force-fed RESP tokens
These are deliberately heterogeneous in rollout length and env latency, the
property Table 1 / Fig 3 of the paper exploits.
"""
from __future__ import annotations

import random
from typing import List, Sequence, Tuple

from repro.data import tokenizer as tok
from .base import Env, _answer_reward


class ArithmeticEnv(Env):
    name = "gsm8k"
    is_agentic = False
    max_new_tokens = 8

    def __init__(self, max_operand: int = 20):
        self.max_operand = max_operand

    def sample_prompt(self, rng: random.Random) -> Tuple[List[int], str]:
        a = rng.randint(0, self.max_operand)
        b = rng.randint(0, self.max_operand)
        prompt = f"{a}+{b}="
        answer = str(a + b)
        return [tok.BOS] + tok.encode(prompt), answer

    def verify(self, truth: str, completion_ids: Sequence[int]) -> float:
        return _answer_reward(truth, completion_ids)


class LongMathEnv(Env):
    name = "amc12"
    is_agentic = False
    max_new_tokens = 24

    def __init__(self, n_terms: int = 4, max_operand: int = 12):
        self.n_terms = n_terms
        self.max_operand = max_operand

    def sample_prompt(self, rng: random.Random) -> Tuple[List[int], str]:
        terms = [rng.randint(1, self.max_operand) for _ in range(self.n_terms)]
        ops = [rng.choice("+-") for _ in range(self.n_terms - 1)]
        expr = str(terms[0])
        val = terms[0]
        for op, t in zip(ops, terms[1:]):
            expr += op + str(t)
            val = val + t if op == "+" else val - t
        return [tok.BOS] + tok.encode(expr + "="), str(val)

    def verify(self, truth: str, completion_ids: Sequence[int]) -> float:
        return _answer_reward(truth, completion_ids)


class SearchEnv(Env):
    """Agentic lookup against a synthetic KB (HotpotQA/wiki-search analogue).

    Prompt: "<entity>?" — the correct move is to emit <call> (the query is
    implicit: the engine passes the prompt row to tool_call), receive the
    force-fed "<resp>fact<endresp>" tokens, then answer with the fact.
    Rewards: graded match on the final answer.
    """
    name = "search"
    is_agentic = True
    max_new_tokens = 24
    env_latency_mean = 0.15      # external API latency (paper: wiki + judge)
    env_latency_std = 0.05

    def __init__(self, kb_size: int = 64, seed: int = 0):
        rng = random.Random(seed)
        entities = []
        while len(entities) < kb_size:
            e = "".join(rng.choice("abcdefghijklmnopqrstuvwxyz") for _ in range(3))
            if e not in entities:
                entities.append(e)
        self.kb = {e: str(rng.randint(10, 99)) for e in entities}
        self.entities = entities

    def sample_prompt(self, rng: random.Random) -> Tuple[List[int], str]:
        e = rng.choice(self.entities)
        return [tok.BOS] + tok.encode(e + "?"), (e, self.kb[e])

    def tool_call(self, query_ids: Sequence[int], truth=None) -> List[int]:
        text = tok.decode(query_ids)
        for e in self.entities:
            if e in text:
                return tok.encode(self.kb[e])
        return tok.encode("00")

    def verify(self, truth, completion_ids: Sequence[int]) -> float:
        _, fact = truth
        # strip the force-fed tool response; grade only post-ENDRESP answer
        ids = list(int(i) for i in completion_ids)
        if tok.ENDRESP in ids:
            ids = ids[ids.index(tok.ENDRESP) + 1:]
        return _answer_reward(fact, ids)


class CopyEnv(Env):
    """Echo task with dense per-char reward — the fastest-learning RLVR
    sanity signal (used by the learning demo / Fig-1-shape test: reward must
    visibly improve under GRPO within tens of versions at toy scale)."""
    name = "copy"
    is_agentic = False
    max_new_tokens = 6

    def __init__(self, length: int = 3, alphabet: str = "012"):
        self.length = length
        self.alphabet = alphabet

    def sample_prompt(self, rng: random.Random):
        s = "".join(rng.choice(self.alphabet) for _ in range(self.length))
        return [tok.BOS] + tok.encode(s + "="), s

    def verify(self, truth: str, completion_ids) -> float:
        ids = []
        for i in completion_ids:
            if int(i) == tok.EOS:
                break
            ids.append(int(i))
        got = tok.decode(ids)
        hits = sum(1 for a, b in zip(got, truth) if a == b)
        exact = 0.2 if got == truth else 0.0
        return 0.8 * hits / len(truth) + exact


REGISTRY = {
    "gsm8k": ArithmeticEnv,
    "amc12": LongMathEnv,
    "search": SearchEnv,
    "copy": CopyEnv,
}


def make_env(name: str, **kw) -> Env:
    return REGISTRY[name](**kw)
