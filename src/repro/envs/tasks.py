"""The paper's workload archetypes, self-contained and synthetic:

  ArithmeticEnv ("gsm8k")     — short math, no tools, short rollouts
  LongMathEnv   ("amc12")     — longer chains, higher rollout latency
  SearchEnv     ("search")    — agentic: CALL → synthetic-KB lookup with
                                external latency → force-fed RESP tokens
  MultiHopSearchEnv ("hopsearch") — multi-turn agentic: the answer sits
                                `hops` KB links away; the session tracks
                                hop progress (link hops, then a value read)
  CalculatorEnv ("calcrepl")  — multi-turn agentic: a stateful accumulator
                                REPL; each call folds the next operand into
                                the session register and echoes it
  GuessRefineEnv ("guess")    — multi-turn agentic: a guess-and-refine
                                oracle that reveals one more digit of the
                                hidden answer per call
These are deliberately heterogeneous in rollout length, env latency, AND
tool-turn structure — the scenario diversity the env-interaction stage
(rollout/env_stage.py) is benchmarked against.
"""
from __future__ import annotations

import random
from typing import List, Sequence, Tuple

from repro.data import tokenizer as tok
from .base import Env, ToolSession, _answer_after_tools, _answer_reward


class ArithmeticEnv(Env):
    name = "gsm8k"
    is_agentic = False
    max_new_tokens = 8

    def __init__(self, max_operand: int = 20):
        self.max_operand = max_operand

    def sample_prompt(self, rng: random.Random) -> Tuple[List[int], str]:
        a = rng.randint(0, self.max_operand)
        b = rng.randint(0, self.max_operand)
        prompt = f"{a}+{b}="
        answer = str(a + b)
        return [tok.BOS] + tok.encode(prompt), answer

    def verify(self, truth: str, completion_ids: Sequence[int]) -> float:
        return _answer_reward(truth, completion_ids)


class LongMathEnv(Env):
    name = "amc12"
    is_agentic = False
    max_new_tokens = 24

    def __init__(self, n_terms: int = 4, max_operand: int = 12):
        self.n_terms = n_terms
        self.max_operand = max_operand

    def sample_prompt(self, rng: random.Random) -> Tuple[List[int], str]:
        terms = [rng.randint(1, self.max_operand) for _ in range(self.n_terms)]
        ops = [rng.choice("+-") for _ in range(self.n_terms - 1)]
        expr = str(terms[0])
        val = terms[0]
        for op, t in zip(ops, terms[1:]):
            expr += op + str(t)
            val = val + t if op == "+" else val - t
        return [tok.BOS] + tok.encode(expr + "="), str(val)

    def verify(self, truth: str, completion_ids: Sequence[int]) -> float:
        return _answer_reward(truth, completion_ids)


class SearchEnv(Env):
    """Agentic lookup against a synthetic KB (HotpotQA/wiki-search analogue).

    Prompt: "<entity>?" — the correct move is to emit <call> (the query is
    implicit: the engine passes the prompt row to tool_call), receive the
    force-fed "<resp>fact<endresp>" tokens, then answer with the fact.
    Rewards: graded match on the final answer.
    """
    name = "search"
    is_agentic = True
    max_new_tokens = 24
    env_latency_mean = 0.15      # external API latency (paper: wiki + judge)
    env_latency_std = 0.05

    def __init__(self, kb_size: int = 64, seed: int = 0):
        rng = random.Random(seed)
        entities = []
        while len(entities) < kb_size:
            e = "".join(rng.choice("abcdefghijklmnopqrstuvwxyz") for _ in range(3))
            if e not in entities:
                entities.append(e)
        self.kb = {e: str(rng.randint(10, 99)) for e in entities}
        self.entities = entities

    def sample_prompt(self, rng: random.Random) -> Tuple[List[int], str]:
        e = rng.choice(self.entities)
        return [tok.BOS] + tok.encode(e + "?"), (e, self.kb[e])

    def tool_call(self, query_ids: Sequence[int], truth=None) -> List[int]:
        text = tok.decode(query_ids)
        for e in self.entities:
            if e in text:
                return tok.encode(self.kb[e])
        return tok.encode("00")

    def verify(self, truth, completion_ids: Sequence[int]) -> float:
        _, fact = truth
        # strip force-fed tool responses; grade only the final answer
        return _answer_reward(fact, _answer_after_tools(completion_ids))


def _gen_entities(rng: random.Random, n: int) -> List[str]:
    entities: List[str] = []
    while len(entities) < n:
        e = "".join(rng.choice("abcdefghijklmnopqrstuvwxyz") for _ in range(3))
        if e not in entities:
            entities.append(e)
    return entities


def _rightmost_entity(text: str, entities) -> str:
    best, pos = None, -1
    for e in entities:
        p = text.rfind(e)
        if p > pos:
            best, pos = e, p
    return best


class _HopSession(ToolSession):
    """Stateful hop tracker: the first `hops-1` calls follow KB links
    (entity → next entity), the final call reads the value at the terminal
    entity. Which lookup happens depends on per-episode state (the hop
    counter), not on the query alone."""

    def call(self, query_ids: Sequence[int],
             cancel=None) -> List[int]:
        self.turns += 1
        env: "MultiHopSearchEnv" = self.env
        e = _rightmost_entity(tok.decode(query_ids), env.entities)
        if e is None:
            e = self.truth[0]
        if self.turns < env.hops:
            return tok.encode(env.next_of[e])
        return tok.encode(env.value_of[e])


class MultiHopSearchEnv(Env):
    """Multi-hop agentic lookup (HotpotQA-style): the prompt names a start
    entity; the answer is `hops` KB reads away. Each hop is one CALL turn —
    the session force-feeds the next entity (or, on the last hop, the
    value), so one episode interleaves several RESP…ENDRESP blocks."""
    name = "hopsearch"
    is_agentic = True
    max_new_tokens = 24
    max_turns = 2                 # == hops (set in __init__)
    env_latency_mean = 0.08       # per-hop external API latency
    env_latency_std = 0.02

    def __init__(self, kb_size: int = 32, hops: int = 2, seed: int = 0):
        if hops < 1:
            raise ValueError("hops must be >= 1")
        rng = random.Random(seed)
        self.entities = _gen_entities(rng, kb_size)
        # a single cyclic chain: every start entity has a well-defined
        # `hops`-step walk ending in a value read
        self.next_of = {e: self.entities[(i + 1) % kb_size]
                        for i, e in enumerate(self.entities)}
        self.value_of = {e: str(rng.randint(10, 99)) for e in self.entities}
        self.hops = hops
        self.max_turns = hops

    def _terminal(self, start: str) -> str:
        e = start
        for _ in range(self.hops - 1):
            e = self.next_of[e]
        return e

    def sample_prompt(self, rng: random.Random) -> Tuple[List[int], tuple]:
        s = rng.choice(self.entities)
        answer = self.value_of[self._terminal(s)]
        return [tok.BOS] + tok.encode(s + "?"), (s, answer)

    def open_session(self, truth) -> ToolSession:
        return _HopSession(self, truth)

    def tool_call(self, query_ids: Sequence[int], truth=None) -> List[int]:
        # stateless fallback (single-turn callers): value at the last entity
        e = _rightmost_entity(tok.decode(query_ids), self.entities)
        return tok.encode(self.value_of[e] if e else "00")

    def verify(self, truth, completion_ids: Sequence[int]) -> float:
        _, answer = truth
        return _answer_reward(answer, _answer_after_tools(completion_ids))


class _ReplSession(ToolSession):
    """Stateful accumulator REPL: call k folds operand k into the register
    and echoes the running total. The same query issued twice returns
    DIFFERENT responses — the canonical stateful-session behaviour."""

    def __init__(self, env, truth):
        super().__init__(env, truth)
        self.register = 0
        self.idx = 0

    def call(self, query_ids: Sequence[int],
             cancel=None) -> List[int]:
        self.turns += 1
        nums = self.truth[0]
        if self.idx < len(nums):
            self.register += nums[self.idx]
            self.idx += 1
        return tok.encode(str(self.register))


class CalculatorEnv(Env):
    """Stateful calculator REPL: the prompt lists operands ("sum 3 7 2=");
    each CALL turn adds the next operand to the session register and
    force-feeds the running total; the episode answers with the final sum."""
    name = "calcrepl"
    is_agentic = True
    max_new_tokens = 16
    max_turns = 3                 # == n_terms (set in __init__)
    env_latency_mean = 0.05
    env_latency_std = 0.01

    def __init__(self, n_terms: int = 3, max_operand: int = 9):
        self.n_terms = n_terms
        self.max_operand = max_operand
        self.max_turns = n_terms

    def sample_prompt(self, rng: random.Random) -> Tuple[List[int], tuple]:
        nums = tuple(rng.randint(1, self.max_operand)
                     for _ in range(self.n_terms))
        prompt = "sum " + " ".join(str(n) for n in nums) + "="
        return [tok.BOS] + tok.encode(prompt), (nums, str(sum(nums)))

    def open_session(self, truth) -> ToolSession:
        return _ReplSession(self, truth)

    def tool_call(self, query_ids: Sequence[int], truth=None) -> List[int]:
        # stateless fallback: the full sum in one shot
        return tok.encode(truth[1] if truth else "0")

    def verify(self, truth, completion_ids: Sequence[int]) -> float:
        _, total = truth
        return _answer_reward(total, _answer_after_tools(completion_ids))


class _RevealSession(ToolSession):
    """Guess-and-refine oracle: call k reveals the first k digits of the
    hidden answer (monotone refinement, stateful reveal counter)."""

    def call(self, query_ids: Sequence[int],
             cancel=None) -> List[int]:
        self.turns += 1
        secret = self.truth
        return tok.encode(secret[:min(self.turns, len(secret))])


class GuessRefineEnv(Env):
    """Guess-and-refine game: the answer is hidden; every CALL turn the
    oracle reveals one more digit. More turns → better information → better
    final answer (the reward gradient the turn budget trades against)."""
    name = "guess"
    is_agentic = True
    max_new_tokens = 12
    max_turns = 3                 # == digits (set in __init__)
    env_latency_mean = 0.05
    env_latency_std = 0.01

    def __init__(self, digits: int = 3):
        if digits < 1:
            raise ValueError("digits must be >= 1")
        self.digits = digits
        self.max_turns = digits

    def sample_prompt(self, rng: random.Random) -> Tuple[List[int], str]:
        secret = "".join(rng.choice("0123456789") for _ in range(self.digits))
        return [tok.BOS] + tok.encode("guess?"), secret

    def open_session(self, truth) -> ToolSession:
        return _RevealSession(self, truth)

    def tool_call(self, query_ids: Sequence[int], truth=None) -> List[int]:
        # stateless fallback: first digit only
        return tok.encode(truth[:1] if truth else "0")

    def verify(self, truth, completion_ids: Sequence[int]) -> float:
        return _answer_reward(truth, _answer_after_tools(completion_ids))


class CopyEnv(Env):
    """Echo task with dense per-char reward — the fastest-learning RLVR
    sanity signal (used by the learning demo / Fig-1-shape test: reward must
    visibly improve under GRPO within tens of versions at toy scale)."""
    name = "copy"
    is_agentic = False
    max_new_tokens = 6

    def __init__(self, length: int = 3, alphabet: str = "012"):
        self.length = length
        self.alphabet = alphabet

    def sample_prompt(self, rng: random.Random):
        s = "".join(rng.choice(self.alphabet) for _ in range(self.length))
        return [tok.BOS] + tok.encode(s + "="), s

    def verify(self, truth: str, completion_ids) -> float:
        ids = []
        for i in completion_ids:
            if int(i) == tok.EOS:
                break
            ids.append(int(i))
        got = tok.decode(ids)
        hits = sum(1 for a, b in zip(got, truth) if a == b)
        exact = 0.2 if got == truth else 0.0
        return 0.8 * hits / len(truth) + exact


REGISTRY = {
    "gsm8k": ArithmeticEnv,
    "amc12": LongMathEnv,
    "search": SearchEnv,
    "hopsearch": MultiHopSearchEnv,
    "calcrepl": CalculatorEnv,
    "guess": GuessRefineEnv,
    "copy": CopyEnv,
}


def make_env(name: str, **kw) -> Env:
    return REGISTRY[name](**kw)
