"""Verifiable-reward environments (paper §5 Datasets and Tasks).

Each env provides:
  sample_prompt(rng)          -> (prompt_token_ids, truth)  — data pipeline
  verify(truth, completion)   -> float reward in [0, 1]     — RLVR verifier
  tool_call(query_ids)        -> response_token_ids          — agentic only
  open_session(truth)         -> ToolSession                 — multi-turn
  latency profile             — env-interaction latency (real: sleep;
                                 sim: virtual seconds), the paper's external
                                 tool/judge latency source.

Multi-turn episode protocol: an agentic episode may emit ``tok.CALL`` up to
``max_turns`` times (0 = unlimited). Each episode owns ONE ``ToolSession``
— a stateful per-episode tool endpoint (REPL register, progressive-reveal
oracle, hop counter, ...) created lazily at the first call and carried with
the row across preemption/parking, so sessions survive slot eviction and
replay. Sessions must be deterministic functions of their call sequence:
replay never re-executes past calls (responses already live in the
generated prefix as force-fed tokens), so determinism is what keeps
preempt-at-any-turn replay token-for-token exact.

Rewards are *graded* (fraction-correct) rather than binary so GRPO groups
have variance from step one; exact-match is reported separately.
"""
from __future__ import annotations

import abc
import inspect
import random
import threading
from typing import List, Optional, Sequence, Tuple

from repro.data import tokenizer as tok


class ToolError(RuntimeError):
    """A tool/environment endpoint failure during a session call (ISSUE
    10). Unlike an arbitrary exception — which is a BUG in our stack and
    stays fatal — a ToolError is an expected operational outcome of
    talking to external tools, and the env stage handles it as one:
    ``TransientToolError`` is retried with exponential backoff + jitter
    (capped per call and per episode), ``PermanentToolError`` (or an
    exhausted retry budget) finishes the episode with
    ``finish_reason="tool_error"`` — counted, never trained, and feeding
    the per-tenant circuit breaker."""


class TransientToolError(ToolError):
    """Retryable: rate limit, timeout, flaky endpoint — try again."""


class PermanentToolError(ToolError):
    """Non-retryable: malformed query, dead endpoint — fail the episode."""


class CancelToken:
    """Cooperative cancellation for in-flight tool calls (ISSUE 5
    satellite, ROADMAP PR-4 follow-on).

    A timed-out/evicted call used to run to completion with its result
    discarded — the worker (EnvWorker or shared-pool thread) stayed busy
    for the full env latency. The engine now hands every dispatched call a
    token: cancelling it (a) interrupts the latency sleep immediately
    (``wait`` returns True) and (b) lets long-running sessions bail out
    mid-call by checking ``cancelled`` between steps. Thread-safe; cancel
    is idempotent."""

    def __init__(self):
        self._ev = threading.Event()

    def cancel(self):
        self._ev.set()

    @property
    def cancelled(self) -> bool:
        return self._ev.is_set()

    def wait(self, timeout: Optional[float] = None) -> bool:
        """Interruptible sleep: returns True the moment the token is
        cancelled, False after the full timeout elapsed uncancelled."""
        return self._ev.wait(timeout)


def call_session(session: "ToolSession", query_ids: Sequence[int],
                 cancel: Optional[CancelToken] = None) -> List[int]:
    """Invoke a session's ``call``, forwarding the cancellation token when
    the session accepts one (user-defined sessions predating the token
    keep working unchanged)."""
    if cancel is not None:
        try:
            params = inspect.signature(session.call).parameters
        except (TypeError, ValueError):
            params = {}
        if "cancel" in params or any(p.kind == p.VAR_KEYWORD
                                     for p in params.values()):
            return session.call(query_ids, cancel=cancel)
    return session.call(query_ids)


class ToolSession:
    """One episode's stateful tool endpoint.

    The default session is a stateless adapter over ``env.tool_call`` —
    every call re-derives the response from the full query. Stateful envs
    subclass and keep per-episode state across ``call``s (`self.turns`
    counts completed calls). ``cancel`` (when provided) is a cooperative
    ``CancelToken``: long-running sessions should poll ``cancel.cancelled``
    between expensive steps and return early — the result of a cancelled
    call is discarded by the engine."""

    def __init__(self, env: "Env", truth):
        self.env = env
        self.truth = truth
        self.turns = 0

    def call(self, query_ids: Sequence[int],
             cancel: Optional[CancelToken] = None) -> List[int]:
        self.turns += 1
        if cancel is not None and cancel.cancelled:
            return []
        return self.env.tool_call(query_ids, self.truth)


class Env(abc.ABC):
    name: str = "env"
    is_agentic: bool = False
    max_new_tokens: int = 16
    max_turns: int = 0           # tool turns per episode (0 = unlimited)
    # latency model for environment interaction (seconds)
    env_latency_mean: float = 0.0
    env_latency_std: float = 0.0

    @abc.abstractmethod
    def sample_prompt(self, rng: random.Random) -> Tuple[List[int], object]:
        ...

    @abc.abstractmethod
    def verify(self, truth, completion_ids: Sequence[int]) -> float:
        ...

    def tool_call(self, query_ids: Sequence[int], truth=None) -> List[int]:
        raise NotImplementedError

    def open_session(self, truth) -> ToolSession:
        """A fresh per-episode tool session (called once per episode, at
        the first tool call). Stateful envs return their own subclass."""
        return ToolSession(self, truth)

    def sample_env_latency(self, rng: random.Random) -> float:
        if self.env_latency_mean <= 0:
            return 0.0
        return max(0.0, rng.gauss(self.env_latency_mean, self.env_latency_std))


def _answer_after_tools(completion_ids: Sequence[int]) -> List[int]:
    """The episode's final answer: tokens after the LAST force-fed tool
    response (multi-turn episodes interleave several RESP…ENDRESP blocks;
    only what the policy says after the last one is graded)."""
    ids = [int(i) for i in completion_ids]
    while tok.ENDRESP in ids:
        ids = ids[ids.index(tok.ENDRESP) + 1:]
    return ids


def _answer_reward(expected: str, completion_ids: Sequence[int]) -> float:
    """Graded reward: per-char match fraction up to EOS; exact bonus."""
    ids = []
    for i in completion_ids:
        if int(i) == tok.EOS:
            break
        ids.append(int(i))
    got = tok.decode(ids)
    if not expected:
        return 0.0
    if got == expected:
        return 1.0
    hits = sum(1 for a, b in zip(got, expected) if a == b)
    frac = hits / max(len(expected), len(got) or 1)
    return 0.8 * frac
