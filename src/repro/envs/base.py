"""Verifiable-reward environments (paper §5 Datasets and Tasks).

Each env provides:
  sample_prompt(rng)          -> (prompt_token_ids, truth)  — data pipeline
  verify(truth, completion)   -> float reward in [0, 1]     — RLVR verifier
  tool_call(query_ids)        -> response_token_ids          — agentic only
  latency profile             — env-interaction latency (real: sleep;
                                 sim: virtual seconds), the paper's external
                                 tool/judge latency source.

Rewards are *graded* (fraction-correct) rather than binary so GRPO groups
have variance from step one; exact-match is reported separately.
"""
from __future__ import annotations

import abc
import random
from typing import List, Optional, Sequence, Tuple

from repro.data import tokenizer as tok


class Env(abc.ABC):
    name: str = "env"
    is_agentic: bool = False
    max_new_tokens: int = 16
    # latency model for environment interaction (seconds)
    env_latency_mean: float = 0.0
    env_latency_std: float = 0.0

    @abc.abstractmethod
    def sample_prompt(self, rng: random.Random) -> Tuple[List[int], object]:
        ...

    @abc.abstractmethod
    def verify(self, truth, completion_ids: Sequence[int]) -> float:
        ...

    def tool_call(self, query_ids: Sequence[int], truth=None) -> List[int]:
        raise NotImplementedError

    def sample_env_latency(self, rng: random.Random) -> float:
        if self.env_latency_mean <= 0:
            return 0.0
        return max(0.0, rng.gauss(self.env_latency_mean, self.env_latency_std))


def _answer_reward(expected: str, completion_ids: Sequence[int]) -> float:
    """Graded reward: per-char match fraction up to EOS; exact bonus."""
    ids = []
    for i in completion_ids:
        if int(i) == tok.EOS:
            break
        ids.append(int(i))
    got = tok.decode(ids)
    if not expected:
        return 0.0
    if got == expected:
        return 1.0
    hits = sum(1 for a, b in zip(got, expected) if a == b)
    frac = hits / max(len(expected), len(got) or 1)
    return 0.8 * frac
