"""GQA flash-decode — single-token attention over a long KV cache, the
per-step memory-bound core of rollout decode (vLLM's PagedAttention role,
TPU-adapted: contiguous block tiles in VMEM instead of pages — DESIGN.md §3).

One grid step = one (batch row, kv head, KV block): the rep = H/KVH query
heads sharing that KV head attend to a [BS, hd] cache tile with an online
(running max / sum / weighted-acc) softmax carried in VMEM scratch across
KV blocks. Per-row valid length (`pos`) and optional sliding window are
masked inside; gemma2's score softcap is applied pre-softmax.

VMEM per step: (BS·hd + BS·hd) cache tiles + rep·hd acc ≈ 0.6 MB at
BS=512, hd=128 — double-buffered well under the v5e budget; the kernel is
HBM-bandwidth-bound by design (reads each cache byte exactly once).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BS = 512
NEG_INF = -1e30


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref,
                   m_ref, l_ref, acc_ref, *, n_s, bs, softcap, window, scale):
    b = pl.program_id(0)
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)              # [rep, hd]
    k = k_ref[0, :, 0].astype(jnp.float32)           # [BS, hd]
    v = v_ref[0, :, 0].astype(jnp.float32)           # [BS, hd]
    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale   # [rep, BS]
    if softcap:
        scores = jnp.tanh(scores / softcap) * softcap
    pos = pos_ref[b]
    idx = s * bs + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    valid = idx < pos
    if window:
        valid &= (pos - 1 - idx) < window
    scores = jnp.where(valid, scores, NEG_INF)

    m_prev = m_ref[...]                               # [rep, 1]
    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(scores - m_new)                       # [rep, BS]
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(s == n_s - 1)
    def _flush():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bs", "softcap", "window", "interpret"))
def gqa_decode(q, cache_k, cache_v, pos, *, bs=DEFAULT_BS, softcap=0.0,
               window=0, interpret=None):
    """q: [B, H, hd]; cache_k/v: [B, S, KVH, hd]; pos: [B] valid lengths
    (including the just-written token). Returns [B, H, hd]."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    B, H, hd = q.shape
    S, KVH = cache_k.shape[1], cache_k.shape[2]
    rep = H // KVH
    bs = min(bs, S)
    assert S % bs == 0, (S, bs)
    n_s = S // bs
    scale = 1.0 / (hd ** 0.5)

    qg = q.reshape(B, KVH, rep, hd)
    grid = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, KVH, n_s),
        in_specs=[
            pl.BlockSpec((1, 1, rep, hd), lambda b, g, s, p: (b, g, 0, 0)),
            pl.BlockSpec((1, bs, 1, hd), lambda b, g, s, p: (b, s, g, 0)),
            pl.BlockSpec((1, bs, 1, hd), lambda b, g, s, p: (b, s, g, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, rep, hd), lambda b, g, s, p: (b, g, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((rep, 1), jnp.float32),
            pltpu.VMEM((rep, 1), jnp.float32),
            pltpu.VMEM((rep, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_decode_kernel, n_s=n_s, bs=bs, softcap=softcap,
                          window=window, scale=scale),
        grid_spec=grid,
        out_shape=jax.ShapeDtypeStruct((B, KVH, rep, hd), q.dtype),
        interpret=interpret,
    )(pos.astype(jnp.int32), qg, cache_k, cache_v)
    return out.reshape(B, H, hd)
