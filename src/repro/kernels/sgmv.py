"""SGMV — sorted grouped multi-LoRA matmul, the rollout hot-spot of
multi-tenant serving (paper §4.5; Punica's CUDA contribution, re-designed
for TPU — DESIGN.md §3).

TPU adaptation: CUDA SGMV gathers adapter weights per warp; TPU has no
warp shuffles, so we *sort rows by task id and pad each task's rows to a
block multiple* outside the kernel. Every (BM×*) tile then belongs to
exactly one adapter, selected via a scalar-prefetched group id in the
BlockSpec index_map — the MXU sees only dense, 128-aligned tiles.

Two passes (Punica's shrink/expand split, which also minimizes VMEM):
  pass A (shrink):  h[i]  = x[i] @ A[g(i)]        grid (row_blocks, K)
  pass B (expand):  y[i]  = h[i] @ B[g(i)]        grid (row_blocks, N)
h is [rows, r] (r ≤ 64) — negligible HBM traffic between passes.

VMEM per step (pass A): bm·bk·4 + bk·r·4 + bm·r·4  ≈ 0.4 MB at
(bm, bk, r) = (128, 512, 64); pass B: bm·r·4 + r·bn·4 + bm·bn·4 ≈ 0.4 MB at
bn = 512 — comfortably within the ~16 MB v5e VMEM with double-buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BM = 128
DEFAULT_BK = 512
DEFAULT_BN = 512


def _shrink_kernel(group_of_block, x_ref, a_ref, h_ref, acc_ref, *, n_k):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)            # [BM, BK]
    a = a_ref[0].astype(jnp.float32)              # [BK, r]
    acc_ref[...] += jax.lax.dot_general(
        x, a, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _flush():
        h_ref[...] = acc_ref[...].astype(h_ref.dtype)


def _expand_kernel(group_of_block, h_ref, b_ref, y_ref):
    h = h_ref[...].astype(jnp.float32)            # [BM, r]
    b = b_ref[0].astype(jnp.float32)              # [r, BN]
    y_ref[...] = jax.lax.dot_general(
        h, b, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(y_ref.dtype)


def _pad_to(x, m):
    return -(-x // m) * m


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret"))
def sgmv_sorted(x_sorted, a, b, group_of_block, *, bm=DEFAULT_BM,
                bk=DEFAULT_BK, bn=DEFAULT_BN, interpret=None):
    """Core kernel on pre-sorted, block-aligned rows.

    x_sorted: [Rp, d] — rows grouped by task, each group padded to bm.
    a: [T, d, r]; b: [T, r, dout]; group_of_block: [Rp//bm] int32.
    Returns y: [Rp, dout] (float32).
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    Rp, d = x_sorted.shape
    T, _, r = a.shape
    dout = b.shape[2]
    bk = min(bk, d)
    bn = min(bn, dout)
    assert Rp % bm == 0 and d % bk == 0 and dout % bn == 0, (Rp, bm, d, bk, dout, bn)
    n_rows = Rp // bm
    n_k = d // bk
    n_n = dout // bn

    grid_a = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_rows, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, k, g: (i, k)),
            pl.BlockSpec((1, bk, r), lambda i, k, g: (g[i], k, 0)),
        ],
        out_specs=pl.BlockSpec((bm, r), lambda i, k, g: (i, 0)),
        scratch_shapes=[pltpu.VMEM((bm, r), jnp.float32)],
    )
    h = pl.pallas_call(
        functools.partial(_shrink_kernel, n_k=n_k),
        grid_spec=grid_a,
        out_shape=jax.ShapeDtypeStruct((Rp, r), jnp.float32),
        interpret=interpret,
    )(group_of_block, x_sorted, a)

    grid_b = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_rows, n_n),
        in_specs=[
            pl.BlockSpec((bm, r), lambda i, n, g: (i, 0)),
            pl.BlockSpec((1, r, bn), lambda i, n, g: (g[i], 0, n)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, n, g: (i, n)),
    )
    y = pl.pallas_call(
        _expand_kernel,
        grid_spec=grid_b,
        out_shape=jax.ShapeDtypeStruct((Rp, dout), jnp.float32),
        interpret=interpret,
    )(group_of_block, h, b)
    return y


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret"))
def sgmv(rows, a, b, ids, *, bm=DEFAULT_BM, bk=DEFAULT_BK, bn=DEFAULT_BN,
         interpret=None):
    """Unsorted entry point: y[i] = rows[i] @ a[ids[i]] @ b[ids[i]].

    Sorts rows by task, pads each task's span to a multiple of bm (so every
    tile is single-adapter), runs the two-pass kernel, scatters back.
    Padding waste is ≤ T·bm rows of compute; gather/scatter are memory ops.
    """
    R, d = rows.shape
    T = a.shape[0]
    dout = b.shape[2]
    # pad contraction/output dims so the block shapes divide them exactly
    d_pad = _pad_to(d, bk) if d > bk else _pad_to(d, 8)
    n_pad = _pad_to(dout, bn) if dout > bn else _pad_to(dout, 8)
    if d_pad != d:
        rows = jnp.pad(rows, ((0, 0), (0, d_pad - d)))
        a = jnp.pad(a, ((0, 0), (0, d_pad - d), (0, 0)))
    if n_pad != dout:
        b = jnp.pad(b, ((0, 0), (0, 0), (0, n_pad - dout)))
    bm_eff = min(bm, _pad_to(max(R // max(T, 1), 8), 8))
    counts = jnp.bincount(ids, length=T)
    padded = _pad_to_multiple(counts, bm_eff)                # [T]
    bases = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                             jnp.cumsum(padded)[:-1].astype(jnp.int32)])
    Rp = int(_pad_to(R, bm_eff) + T * bm_eff)                # static bound
    order = jnp.argsort(ids, stable=True)
    sorted_ids = ids[order]
    rank = jnp.arange(R) - jnp.searchsorted(sorted_ids, sorted_ids, "left")
    slots = bases[sorted_ids] + rank                          # [R] in [0, Rp)
    x_sorted = jnp.zeros((Rp, d_pad), rows.dtype).at[slots].set(rows[order])
    # group id per block: the task whose padded span covers the block start
    block_start = jnp.arange(Rp // bm_eff) * bm_eff
    ends = jnp.cumsum(padded)
    gob = jnp.searchsorted(ends, block_start, side="right").astype(jnp.int32)
    gob = jnp.minimum(gob, T - 1)
    y_sorted = sgmv_sorted(x_sorted, a, b, gob, bm=bm_eff, bk=bk, bn=bn,
                           interpret=interpret)
    y = y_sorted[slots]                                       # back to sorted
    inv = jnp.zeros((R,), jnp.int32).at[order].set(
        jnp.arange(R, dtype=jnp.int32))
    return y[inv][:, :dout]


def _pad_to_multiple(counts, m):
    return ((counts + m - 1) // m * m).astype(jnp.int32)
