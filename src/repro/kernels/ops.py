"""Public jit'd wrappers around the Pallas kernels — the API surface the
model/RL layers call (kernels auto-interpret on CPU, compile on TPU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .gqa_decode import gqa_decode as _gqa_decode
from .sgmv import sgmv as _sgmv
from .token_logprob import token_logprob_flat


def sgmv(rows, a, b, ids, **kw):
    """Multi-LoRA delta for a batch of rows: rows[i] @ a[g] @ b[g].
    rows: [R, d]; a: [T, d, r]; b: [T, r, dout]; ids: [R]. -> [R, dout]"""
    return _sgmv(rows, a, b, ids, **kw)


def gqa_decode(q, cache_k, cache_v, pos, *, softcap=0.0, window=0, **kw):
    """Flash-decode GQA attention over a KV cache (one query token/row)."""
    return _gqa_decode(q, cache_k, cache_v, pos, softcap=softcap,
                       window=window, **kw)


def token_logprob(hidden, vocab_w, targets, softcap: float = 0.0, **kw):
    """Fused logprob+entropy. hidden: [B, S, d]; vocab_w: [d, V];
    targets: [B, S]. Returns (logprob [B, S], entropy [B, S]) fp32."""
    B, S, d = hidden.shape
    lp, ent = token_logprob_flat(hidden.reshape(B * S, d), vocab_w,
                                 targets.reshape(B * S), softcap=softcap, **kw)
    return lp.reshape(B, S), ent.reshape(B, S)
