"""Paged GQA flash-decode — single-token attention over a BLOCK-POOL KV
cache (the PagedAttention role proper; ``gqa_decode`` is its contiguous
-cache sibling).

The cache is a pool of fixed-size pages ``[n_pages+1, page, KVH, hd]``
shared by every decode slot; each row names its pages through a block
table ``tbl: [B, max_pages]`` (entries are physical page ids; unused
entries point at the scratch page ``n_pages``). One grid step = one
(batch row, kv head, LOGICAL page): the block table rides the scalar
-prefetch channel, so the BlockSpec ``index_map`` resolves logical page
``s`` of row ``b`` to its physical page ``tbl[b, s]`` BEFORE the kernel
body runs — the page tile is DMA'd straight from its pooled location, no
gather materializes a contiguous cache. The rep = H/KVH query heads
sharing the kv head carry an online (running max / sum / weighted-acc)
softmax across logical pages in VMEM scratch, exactly the ``gqa_decode``
recurrence; per-row valid length (`pos`), optional sliding window and
gemma2's score softcap are applied per page.

Pages past a row's live count resolve to the scratch page (or any page —
their positions are ≥ pos and fully masked), so a short row costs the
same DMAs as dense only in grid steps, not in pool HBM: the pool holds
Σ ceil(len_i / page) pages instead of B × max_len rows, which is the
whole point (ISSUE 5: per-slot max_len reservation killed).

VMEM per step: 2·page·hd cache tile + rep·hd acc — identical budget to
``gqa_decode`` at bs == page.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(pos_ref, tbl_ref, q_ref, k_ref, v_ref, o_ref,
                  m_ref, l_ref, acc_ref, *, n_s, page, softcap, window,
                  scale):
    b = pl.program_id(0)
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)              # [rep, hd]
    k = k_ref[0, :, 0].astype(jnp.float32)           # [page, hd]
    v = v_ref[0, :, 0].astype(jnp.float32)           # [page, hd]
    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale   # [rep, page]
    if softcap:
        scores = jnp.tanh(scores / softcap) * softcap
    pos = pos_ref[b]
    # logical (pre-paging) position of each lane in this page
    idx = s * page + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    valid = idx < pos
    if window:
        valid &= (pos - 1 - idx) < window
    scores = jnp.where(valid, scores, NEG_INF)

    m_prev = m_ref[...]                               # [rep, 1]
    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(scores - m_new)                       # [rep, page]
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(s == n_s - 1)
    def _flush():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("softcap", "window", "interpret"))
def paged_gqa_decode(q, kp, vp, tbl, pos, *, softcap=0.0, window=0,
                     interpret=None):
    """q: [B, H, hd]; kp/vp: [n_pages+1, page, KVH, hd] (page pool, last
    physical page is the scratch page sentinel entries point at);
    tbl: [B, max_pages] int32 physical page ids; pos: [B] valid lengths
    (including the just-written token). Returns [B, H, hd]."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    B, H, hd = q.shape
    page, KVH = kp.shape[1], kp.shape[2]
    n_s = tbl.shape[1]
    rep = H // KVH
    scale = 1.0 / (hd ** 0.5)

    qg = q.reshape(B, KVH, rep, hd)
    grid = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,       # pos, then the block table
        grid=(B, KVH, n_s),
        in_specs=[
            pl.BlockSpec((1, 1, rep, hd), lambda b, g, s, p, t: (b, g, 0, 0)),
            pl.BlockSpec((1, page, 1, hd),
                         lambda b, g, s, p, t: (t[b, s], 0, g, 0)),
            pl.BlockSpec((1, page, 1, hd),
                         lambda b, g, s, p, t: (t[b, s], 0, g, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, rep, hd),
                               lambda b, g, s, p, t: (b, g, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((rep, 1), jnp.float32),
            pltpu.VMEM((rep, 1), jnp.float32),
            pltpu.VMEM((rep, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_paged_kernel, n_s=n_s, page=page, softcap=softcap,
                          window=window, scale=scale),
        grid_spec=grid,
        out_shape=jax.ShapeDtypeStruct((B, KVH, rep, hd), q.dtype),
        interpret=interpret,
    )(pos.astype(jnp.int32), tbl.astype(jnp.int32), qg, kp, vp)
    return out.reshape(B, H, hd)
