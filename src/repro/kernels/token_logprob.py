"""Fused token-logprob — logsumexp + target gather + entropy over the vocab,
without ever materializing [tokens, V] softmax in fp32.

This is the GRPO training-side hot-spot at 150k–256k vocabs (qwen/gemma/
nemotron): the naive path writes tokens·V fp32 logits + softmax (≈ 2 TB for
a 1M-token batch at V=256k); this kernel streams vocab tiles through VMEM
keeping only three [BM] running statistics per row:
  m  (running max),  l = Σ e^{logit−m},  s = Σ logit·e^{logit−m}
so  logprob = logit_tgt − (m + log l)   and  entropy = (m + log l) − s/l.

Grid (row_blocks, V_blocks, K_blocks): K innermost accumulates the logits
tile h·W in VMEM scratch; at the last K slice the online stats fold the
tile in, and the target gather hits at most one tile per row.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BM = 256
DEFAULT_BV = 1024
DEFAULT_BK = 512
NEG_INF = -1e30


def _logprob_kernel(tgt_ref, h_ref, w_ref, lp_ref, ent_ref,
                    logits_ref, m_ref, l_ref, s_ref, t_ref,
                    *, n_v, n_k, bv, softcap):
    i = pl.program_id(0)
    v = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when((v == 0) & (k == 0))
    def _init_row():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        s_ref[...] = jnp.zeros_like(s_ref)
        t_ref[...] = jnp.full_like(t_ref, NEG_INF)

    @pl.when(k == 0)
    def _init_tile():
        logits_ref[...] = jnp.zeros_like(logits_ref)

    h = h_ref[...].astype(jnp.float32)               # [BM, BK]
    w = w_ref[...].astype(jnp.float32)               # [BK, BV]
    logits_ref[...] += jax.lax.dot_general(
        h, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _fold():
        logits = logits_ref[...]                     # [BM, BV]
        if softcap:
            logits = jnp.tanh(logits / softcap) * softcap
        bm = logits.shape[0]
        # target gather: ids within this vocab tile
        tgt = tgt_ref[pl.ds(i * bm, bm)]             # [BM]
        local = tgt - v * bv
        cols = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
        hit = cols == local[:, None]
        t_ref[...] = jnp.maximum(
            t_ref[...],
            jnp.max(jnp.where(hit, logits, NEG_INF), axis=1, keepdims=True))
        # online lse/entropy stats
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(logits, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(logits - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        s_ref[...] = s_ref[...] * alpha + jnp.sum(p * logits, axis=1,
                                                  keepdims=True)
        m_ref[...] = m_new

        @pl.when(v == n_v - 1)
        def _flush():
            lse = m_ref[...] + jnp.log(jnp.maximum(l_ref[...], 1e-30))
            lp_ref[...] = (t_ref[...] - lse).astype(lp_ref.dtype)
            ent_ref[...] = (lse - s_ref[...] /
                            jnp.maximum(l_ref[...], 1e-30)).astype(ent_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bv", "bk", "softcap",
                                             "interpret"))
def token_logprob_flat(h, w, targets, *, bm=DEFAULT_BM, bv=DEFAULT_BV,
                       bk=DEFAULT_BK, softcap=0.0, interpret=None):
    """h: [R, d]; w: [d, V]; targets: [R] int32.
    Returns (logprob [R], entropy [R]) float32."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    R, d = h.shape
    V = w.shape[1]
    bm = min(bm, max(8, R))
    bv = min(bv, V)
    bk = min(bk, d)
    Rp = -(-R // bm) * bm
    Vp = -(-V // bv) * bv
    dp = -(-d // bk) * bk
    if Rp != R:
        h = jnp.pad(h, ((0, Rp - R), (0, 0)))
        targets = jnp.pad(targets, (0, Rp - R))
    if dp != d:
        h = jnp.pad(h, ((0, 0), (0, dp - d)))
        w = jnp.pad(w, ((0, dp - d), (0, 0)))
    if Vp != V:
        # pad vocab with NEG_INF-like columns: zero weights give logit 0,
        # which would corrupt lse — mask by giving padded cols −∞ via a
        # large negative bias row trick: instead pad W with zeros and rely
        # on masking below (cols >= V are never targets; their logit 0 can
        # distort lse). To stay exact we fold padding into the last tile
        # mask inside the kernel — cheaper: require V % bv == 0 by choosing
        # bv that divides V.
        for cand in (bv, 512, 256, 128, 64, 32, 16, 8, 4, 2, 1):
            if V % cand == 0:
                bv = cand
                break
        Vp = V
    n_v = Vp // bv
    n_k = dp // bk
    grid = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(Rp // bm, n_v, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, v, k, t: (i, k)),
            pl.BlockSpec((bk, bv), lambda i, v, k, t: (k, v)),
        ],
        out_specs=[
            pl.BlockSpec((bm, 1), lambda i, v, k, t: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i, v, k, t: (i, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((bm, bv), jnp.float32),
            pltpu.VMEM((bm, 1), jnp.float32),
            pltpu.VMEM((bm, 1), jnp.float32),
            pltpu.VMEM((bm, 1), jnp.float32),
            pltpu.VMEM((bm, 1), jnp.float32),
        ],
    )
    lp, ent = pl.pallas_call(
        functools.partial(_logprob_kernel, n_v=n_v, n_k=n_k, bv=bv,
                          softcap=softcap),
        grid_spec=grid,
        out_shape=[jax.ShapeDtypeStruct((Rp, 1), jnp.float32),
                   jax.ShapeDtypeStruct((Rp, 1), jnp.float32)],
        interpret=interpret,
    )(targets.astype(jnp.int32), h, w)
    return lp[:R, 0], ent[:R, 0]
