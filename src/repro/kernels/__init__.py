# Pallas TPU kernels for the paper's compute hot-spots (DESIGN.md §3):
#   sgmv          — multi-LoRA grouped matmul (rollout, paper §4.5)
#   gqa_decode    — flash-decode attention over contiguous KV caches
#   paged_decode  — flash-decode over the block-pool (paged) KV cache: the
#                   block table rides the scalar-prefetch channel so each
#                   logical page DMAs straight from its pooled location
#   token_logprob — fused LSE+gather+entropy over big vocabs (GRPO training)
# Each has ops.py wrappers and ref.py pure-jnp oracles; validated in
# interpret mode on CPU, targeted at TPU v5e tile sizes.
from . import ops, ref
