"""Pure-jnp oracles for every Pallas kernel (tests assert_allclose against
these across shape/dtype sweeps)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sgmv_ref(rows, a, b, ids):
    """y[i] = rows[i] @ a[ids[i]] @ b[ids[i]]  (fp32)."""
    T = a.shape[0]
    xf = rows.astype(jnp.float32)
    out = jnp.zeros((rows.shape[0], b.shape[2]), jnp.float32)
    for t in range(T):
        h = (xf @ a[t].astype(jnp.float32)) @ b[t].astype(jnp.float32)
        out = out + h * (ids == t)[:, None]
    return out


def gqa_decode_ref(q, cache_k, cache_v, pos, *, softcap=0.0, window=0):
    B, H, hd = q.shape
    Smax, KVH = cache_k.shape[1], cache_k.shape[2]
    rep = H // KVH
    k = jnp.repeat(cache_k, rep, axis=2)
    v = jnp.repeat(cache_v, rep, axis=2)
    s = jnp.einsum("bhd,bkhd->bhk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (hd ** 0.5)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    idx = jnp.arange(Smax)
    valid = idx[None, :] < pos[:, None]
    if window:
        valid &= (pos[:, None] - 1 - idx[None, :]) < window
    s = jnp.where(valid[:, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhk,bkhd->bhd", p, v.astype(jnp.float32)).astype(q.dtype)


def paged_gqa_decode_ref(q, kp, vp, tbl, pos, *, softcap=0.0, window=0):
    """Gather the block-pool pages back into a contiguous per-row cache,
    then run the contiguous oracle — the paged kernel must match this."""
    B = q.shape[0]
    page, KVH, hd = kp.shape[1], kp.shape[2], kp.shape[3]
    n_pg = tbl.shape[1]
    ck = jnp.take(kp, tbl, axis=0).reshape(B, n_pg * page, KVH, hd)
    cv = jnp.take(vp, tbl, axis=0).reshape(B, n_pg * page, KVH, hd)
    return gqa_decode_ref(q, ck, cv, pos, softcap=softcap, window=window)


def token_logprob_ref(hidden, vocab_w, targets, softcap: float = 0.0):
    """hidden: [B, S, d] (or [R, d]); returns fp32 (logprob, entropy)."""
    squeeze = hidden.ndim == 2
    if squeeze:
        hidden, targets = hidden[None], targets[None]
    logits = (hidden.astype(jnp.float32) @ vocab_w.astype(jnp.float32))
    if softcap:
        logits = jnp.tanh(logits / softcap) * softcap
    lse = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, targets[..., None], -1)[..., 0]
    p = jax.nn.softmax(logits, -1)
    ent = lse - jnp.sum(p * logits, -1)
    lp = tgt - lse
    return (lp[0], ent[0]) if squeeze else (lp, ent)
