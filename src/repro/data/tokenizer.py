"""Deterministic character-level tokenizer for the self-contained RLVR tasks.

Specials:
  PAD=0 BOS=1 EOS=2 SEP=3 CALL=4 ENDCALL=5 RESP=6 ENDRESP=7
CALL/ENDCALL bracket an agentic tool invocation; RESP/ENDRESP bracket the
environment's force-fed response tokens (excluded from the GRPO loss mask).
"""
from __future__ import annotations

from typing import List

PAD, BOS, EOS, SEP, CALL, ENDCALL, RESP, ENDRESP = range(8)
SPECIALS = ["<pad>", "<bos>", "<eos>", "<sep>", "<call>", "<endcall>",
            "<resp>", "<endresp>"]

_CHARS = "0123456789+-*/=?abcdefghijklmnopqrstuvwxyz ()."
CHAR_TO_ID = {c: i + len(SPECIALS) for i, c in enumerate(_CHARS)}
ID_TO_CHAR = {i: c for c, i in CHAR_TO_ID.items()}

VOCAB_SIZE = len(SPECIALS) + len(_CHARS)


def encode(text: str) -> List[int]:
    return [CHAR_TO_ID[c] for c in text if c in CHAR_TO_ID]


def decode(ids) -> str:
    return "".join(ID_TO_CHAR.get(int(i), "") for i in ids)


def decode_with_specials(ids) -> str:
    out = []
    for i in ids:
        i = int(i)
        if i < len(SPECIALS):
            out.append(SPECIALS[i])
        else:
            out.append(ID_TO_CHAR.get(i, ""))
    return "".join(out)
