"""Fault-tolerant checkpointing of the full multi-task manager state.

Design (DESIGN.md §6):
- one atomic snapshot = manifest.json + per-task .npz blobs, written to a
  temp dir then os.rename'd into place (crash-safe: a half-written snapshot
  is never visible);
- replacing an existing snapshot of the same tag renames the old one ASIDE
  first and deletes it only after the new payload + LATEST pointer are both
  published — there is no window where a crash leaves neither (the old
  rmtree-before-rename flow had exactly that window: die between rmtree and
  rename and LATEST dangles over nothing);
- snapshots are *mesh-agnostic* (host numpy trees keyed by tree path) → an
  elastic restart under a different device count/mesh re-shards on load;
- MARLaaS's strict on-policy invariant makes recovery exact: every task
  resumes at its last committed (θ_t^(v), φ_t^(v)); in-flight rollouts of
  uncommitted versions are simply regenerated — no stale trajectory can ever
  be trained on, so a crash never corrupts optimization state;
- trainer-visible work survives restart on BOTH paths: the sync FIFO buffer
  (committed-but-untrained trajectory batches) and the async per-tenant
  completed-episode queues serialize too, with popped-but-uncommitted
  in-flight items at their queue head (same ordering `recover_inflight`
  restores). Partially-assembled GRPO groups do NOT serialize — their
  rollout rounds are re-issued and regenerate them exactly;
- `latest_checkpoint` trusts the LATEST pointer first, but falls back to
  scanning for the newest snapshot with a parseable manifest when LATEST is
  missing, dangling, or points at a torn (manifest-less) directory — the
  recovery story after a crash mid-publish.

Trees are serialized by key path ("layers/attn_q/a"), so any nested-dict
pytree round-trips without treedef pickling.
"""
from __future__ import annotations

import dataclasses
import json
import os
import pickle
import shutil
import tempfile
import time
from typing import Any, Dict, List, Optional

import numpy as np

from repro.core.chaos import ChaosError
from repro.core.manager import (EpisodeGroup, MultiTaskManager, TaskSpec,
                                TaskState)
from repro.rl.types import TrajectoryBatch

_SEP = "/"

# per-task fault/drop counters that round-trip through the manifest (the
# conservation invariant must hold ACROSS a restart, not just within one
# incarnation)
_TASK_COUNTERS = ("rollout_rows_total", "stale_rows_dropped", "failed_rows",
                  "quarantine_dropped_rows")
_MGR_COUNTERS = ("stale_rows_dropped", "stale_groups_dropped",
                 "stale_batches_dropped", "discarded_tail_rows",
                 "failed_rows", "quarantine_dropped_rows", "rows_trained",
                 "orphaned_rows")


def tree_to_flat(tree, prefix="") -> Dict[str, np.ndarray]:
    out: Dict[str, np.ndarray] = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(tree_to_flat(v, f"{prefix}{k}{_SEP}"))
    elif tree is None:
        pass
    else:
        out[prefix.rstrip(_SEP)] = np.asarray(tree)
    return out


def flat_to_tree(flat: Dict[str, np.ndarray]):
    tree: Dict[str, Any] = {}
    for key, val in flat.items():
        parts = key.split(_SEP)
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return tree


def _strip_env(comp):
    """Episodes serialize without their env handle (envs hold RNGs/sessions
    that don't pickle); `MultiTaskManager.rebind_episode_envs` re-attaches
    live handles on load."""
    if dataclasses.is_dataclass(comp) and getattr(comp, "env", None) is not None:
        return dataclasses.replace(comp, env=None)
    return comp


def save_checkpoint(directory: str, mgr: MultiTaskManager,
                    step_tag: Optional[str] = None, *,
                    keep_last_n: int = 0, chaos=None) -> str:
    """Atomic snapshot; returns the snapshot path.

    `keep_last_n` > 0 prunes older snapshots after a successful publish
    (the one just written always survives). `chaos` is the runtime's
    ChaosInjector: the `torn_checkpoint` site simulates a crash mid-publish
    (payload landed, manifest torn, LATEST never moved)."""
    tag = step_tag or f"step_{mgr.total_steps_done():08d}"
    os.makedirs(directory, exist_ok=True)
    tmp = tempfile.mkdtemp(prefix=".ckpt_tmp_", dir=directory)
    manifest: Dict[str, Any] = {"tag": tag, "time": time.time(), "tasks": {},
                                "buffer": []}
    with mgr._lock:
        for tid, st in mgr.tasks.items():
            entry = {
                "spec": dataclasses.asdict(st.spec),
                "version": st.version,
                "steps_done": st.steps_done,
                "status": st.status,
                "abandoned": st.abandoned,
                "reward_history": st.reward_history,
                "counters": {k: getattr(st, k) for k in _TASK_COUNTERS},
                "has_adapters": st.adapters is not None,
                "has_opt": st.opt_state is not None,
            }
            if st.adapters is not None:
                np.savez(os.path.join(tmp, f"{tid}_adapters.npz"),
                         **tree_to_flat(st.adapters))
            if st.opt_state is not None:
                np.savez(os.path.join(tmp, f"{tid}_opt.npz"),
                         **tree_to_flat(st.opt_state))
            manifest["tasks"][tid] = entry
        # trainer feed, in recover_inflight order: popped-but-uncommitted
        # work first (it restores to the queue head), then the queues
        batches: List[TrajectoryBatch] = [
            item[2] for item in mgr._inflight_train if item[0] == "batch"]
        batches.extend(mgr.q_buffer)
        for i, tb in enumerate(batches):
            np.savez(os.path.join(tmp, f"buffer_{i}.npz"),
                     tokens=tb.tokens, prompt_lens=tb.prompt_lens,
                     total_lens=tb.total_lens, rewards=tb.rewards,
                     behavior=(tb.behavior_logprobs
                               if tb.behavior_logprobs is not None
                               else np.zeros((0,))),
                     loss_mask=tb.meta.get("loss_mask", np.zeros((0,))))
            manifest["buffer"].append({
                "task_id": tb.task_id, "version": tb.version,
                "group_size": tb.group_size, "idx": i,
            })
        # async feed (event-driven trainer): complete GRPO groups per
        # tenant, in-flight first. Partial groups regenerate — their round
        # re-issues on load via rollout_issued_version = version - 1.
        episodes: Dict[str, List[EpisodeGroup]] = {}
        for item in mgr._inflight_train:
            if item[0] == "episodes":
                episodes.setdefault(item[1], []).extend(item[2])
        for tid, dq in mgr.episodes.items():
            episodes.setdefault(tid, []).extend(dq)
        if episodes:
            payload = {
                tid: [EpisodeGroup(task_id=g.task_id, version=g.version,
                                   rows=[_strip_env(c) for c in g.rows],
                                   seq=g.seq)
                      for g in groups]
                for tid, groups in episodes.items()}
            with open(os.path.join(tmp, "episodes.pkl"), "wb") as f:
                pickle.dump(payload, f)
        manifest["async"] = {
            "counters": {k: getattr(mgr, k) for k in _MGR_COUNTERS},
            "ep_seq": mgr._ep_seq,
            "has_episodes": bool(episodes),
        }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    final = os.path.join(directory, tag)
    aside = None
    if os.path.exists(final):
        # rename the old snapshot ASIDE instead of rmtree-ing it: a crash
        # anywhere in the publish below still leaves one recoverable copy
        aside = final + ".replacing"
        if os.path.exists(aside):
            shutil.rmtree(aside)
        os.rename(final, aside)
    os.rename(tmp, final)                      # atomic publish
    if chaos is not None and chaos.fire("torn_checkpoint"):
        # simulate dying mid-publish: payload landed but the manifest is
        # torn and LATEST never moved — recovery must fall back to the
        # previous snapshot via the manifest scan
        os.remove(os.path.join(final, "manifest.json"))
        raise ChaosError("torn checkpoint publish (injected)")
    _write_latest(directory, tag)
    if aside is not None:
        shutil.rmtree(aside)
    if keep_last_n > 0:
        _prune(directory, keep_last_n)
    return final


def _write_latest(directory: str, tag: str):
    tmp = os.path.join(directory, ".latest_tmp")
    with open(tmp, "w") as f:
        f.write(tag)
    os.rename(tmp, os.path.join(directory, "LATEST"))


def _manifest_time(path: str) -> Optional[float]:
    """Publish time of a COMPLETE snapshot dir; None if torn/not one."""
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            return float(json.load(f)["time"])
    except (OSError, ValueError, KeyError, json.JSONDecodeError):
        return None


def _snapshots_by_age(directory: str) -> List[str]:
    """Complete snapshot dirs, newest first (tmp dirs excluded; a
    `.replacing` aside counts — it IS a valid older snapshot)."""
    out = []
    for name in os.listdir(directory):
        full = os.path.join(directory, name)
        if name.startswith(".") or not os.path.isdir(full):
            continue
        t = _manifest_time(full)
        if t is not None:
            out.append((t, full))
    out.sort(key=lambda p: -p[0])
    return [full for _, full in out]


def _prune(directory: str, keep_last_n: int):
    for full in _snapshots_by_age(directory)[keep_last_n:]:
        shutil.rmtree(full)


def latest_checkpoint(directory: str) -> Optional[str]:
    """Newest usable snapshot. The LATEST pointer is authoritative while it
    points at a complete snapshot; when it is missing, dangling, or points
    at a torn directory (crash mid-publish), fall back to scanning for the
    newest directory with a parseable manifest."""
    if not os.path.isdir(directory):
        return None
    p = os.path.join(directory, "LATEST")
    if os.path.exists(p):
        with open(p) as f:
            tag = f.read().strip()
        full = os.path.join(directory, tag)
        if _manifest_time(full) is not None:
            return full
    snaps = _snapshots_by_age(directory)
    return snaps[0] if snaps else None


def load_checkpoint(path: str, mgr: MultiTaskManager) -> MultiTaskManager:
    """Restore manager state in place (tasks + both trainer feeds). Adapters
    come back as host numpy trees; device placement/resharding happens lazily
    on first use under whatever mesh is now active (elastic restart).

    `rollout_issued_version` is reset to version-1 so the next policy
    version is re-issued for rollout — in-flight work at crash time is
    regenerated, never resumed stale. A tenant checkpointed while
    `quarantined` restores as `admitted`: the breaker state machine does not
    survive restart, and a status with no breaker driving it would never
    unquarantine (the fresh breaker re-trips it if the faults persist)."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    with mgr._lock:
        mgr.q_buffer.clear()
        mgr.episodes.clear()
        mgr._partial.clear()
        mgr._inflight_train.clear()
        mgr._failed_groups.clear()
        for tid, entry in manifest["tasks"].items():
            spec = TaskSpec(**entry["spec"])
            adapters = opt_state = None
            if entry["has_adapters"]:
                adapters = flat_to_tree(
                    dict(np.load(os.path.join(path, f"{tid}_adapters.npz"))))
            if entry["has_opt"]:
                opt_state = flat_to_tree(
                    dict(np.load(os.path.join(path, f"{tid}_opt.npz"))))
            status = entry["status"]
            if status == "quarantined":
                status = "admitted"
            st = TaskState(spec=spec, adapters=adapters, opt_state=opt_state,
                           version=entry["version"],
                           steps_done=entry["steps_done"],
                           status=status,
                           abandoned=entry.get("abandoned", False),
                           rollout_issued_version=entry["version"] - 1,
                           submitted_at=mgr.clock())
            st.reward_history = list(entry.get("reward_history", []))
            for k, v in entry.get("counters", {}).items():
                setattr(st, k, v)
            mgr.tasks[spec.task_id] = st
        for b in manifest["buffer"]:
            arrs = dict(np.load(os.path.join(path, f"buffer_{b['idx']}.npz")))
            tb = TrajectoryBatch(
                task_id=b["task_id"], version=b["version"],
                tokens=arrs["tokens"], prompt_lens=arrs["prompt_lens"],
                total_lens=arrs["total_lens"], rewards=arrs["rewards"],
                group_size=b["group_size"],
                behavior_logprobs=(arrs["behavior"]
                                   if arrs["behavior"].size else None),
                meta=({"loss_mask": arrs["loss_mask"]}
                      if arrs["loss_mask"].size else {}))
            mgr.q_buffer.append(tb)
            # this version's rollout survived in the buffer — do NOT
            # re-issue it, or the duplicate would train stale after the
            # buffered copy commits
            st = mgr.tasks[tb.task_id]
            if tb.version == st.version:
                st.rollout_issued_version = st.version
        a = manifest.get("async")
        if a:
            for k, v in a.get("counters", {}).items():
                setattr(mgr, k, v)
            mgr._ep_seq = a.get("ep_seq", 0)
            if a.get("has_episodes"):
                with open(os.path.join(path, "episodes.pkl"), "rb") as f:
                    payload = pickle.load(f)
                from collections import deque
                for tid, groups in payload.items():
                    mgr.episodes[tid] = deque(groups)
        # reconcile the restored completed-row count against what actually
        # survived: rows completed before the crash whose round had not yet
        # assembled into a serialized batch/group are gone, and their round
        # re-issues (rollout_issued_version = version - 1) — the regenerated
        # rows count `completed` a second time. Attribute the lost copies
        # to `orphaned` so the conservation invariant stays EXACT across
        # the restart instead of leaking the regenerated double-count.
        completed = sum(st.rollout_rows_total for st in mgr.tasks.values())
        in_flight = (sum(tb.num_rows for tb in mgr.q_buffer)
                     + sum(len(g.rows) for dq in mgr.episodes.values()
                           for g in dq))
        accounted = (mgr.rows_trained + mgr.stale_rows_dropped
                     + mgr.discarded_tail_rows + mgr.failed_rows
                     + mgr.quarantine_dropped_rows + in_flight
                     + mgr.orphaned_rows)   # prior restarts' orphans
        mgr.orphaned_rows += max(0, completed - accounted)
        mgr._cv.notify_all()
    return mgr
