"""Fault-tolerant checkpointing of the full multi-task manager state.

Design (DESIGN.md §6):
- one atomic snapshot = manifest.json + per-task .npz blobs, written to a
  temp dir then os.rename'd into place (crash-safe: a half-written snapshot
  is never visible);
- snapshots are *mesh-agnostic* (host numpy trees keyed by tree path) → an
  elastic restart under a different device count/mesh re-shards on load;
- MARLaaS's strict on-policy invariant makes recovery exact: every task
  resumes at its last committed (θ_t^(v), φ_t^(v)); in-flight rollouts of
  uncommitted versions are simply regenerated — no stale trajectory can ever
  be trained on, so a crash never corrupts optimization state;
- the FIFO buffer is serialized too: committed-but-untrained trajectories
  survive restart (still on-policy by the invariant above).

Trees are serialized by key path ("layers/attn_q/a"), so any nested-dict
pytree round-trips without treedef pickling.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import tempfile
import time
from typing import Any, Dict, Optional

import numpy as np

from repro.core.manager import MultiTaskManager, TaskSpec, TaskState
from repro.rl.types import TrajectoryBatch

_SEP = "/"


def tree_to_flat(tree, prefix="") -> Dict[str, np.ndarray]:
    out: Dict[str, np.ndarray] = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(tree_to_flat(v, f"{prefix}{k}{_SEP}"))
    elif tree is None:
        pass
    else:
        out[prefix.rstrip(_SEP)] = np.asarray(tree)
    return out


def flat_to_tree(flat: Dict[str, np.ndarray]):
    tree: Dict[str, Any] = {}
    for key, val in flat.items():
        parts = key.split(_SEP)
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return tree


def save_checkpoint(directory: str, mgr: MultiTaskManager,
                    step_tag: Optional[str] = None) -> str:
    """Atomic snapshot; returns the snapshot path."""
    tag = step_tag or f"step_{mgr.total_steps_done():08d}"
    os.makedirs(directory, exist_ok=True)
    tmp = tempfile.mkdtemp(prefix=".ckpt_tmp_", dir=directory)
    manifest: Dict[str, Any] = {"tag": tag, "time": time.time(), "tasks": {},
                                "buffer": []}
    with mgr._lock:
        for tid, st in mgr.tasks.items():
            entry = {
                "spec": dataclasses.asdict(st.spec),
                "version": st.version,
                "steps_done": st.steps_done,
                "status": st.status,
                "reward_history": st.reward_history,
                "has_adapters": st.adapters is not None,
                "has_opt": st.opt_state is not None,
            }
            if st.adapters is not None:
                np.savez(os.path.join(tmp, f"{tid}_adapters.npz"),
                         **tree_to_flat(st.adapters))
            if st.opt_state is not None:
                np.savez(os.path.join(tmp, f"{tid}_opt.npz"),
                         **tree_to_flat(st.opt_state))
            manifest["tasks"][tid] = entry
        for i, tb in enumerate(mgr.q_buffer):
            np.savez(os.path.join(tmp, f"buffer_{i}.npz"),
                     tokens=tb.tokens, prompt_lens=tb.prompt_lens,
                     total_lens=tb.total_lens, rewards=tb.rewards,
                     behavior=(tb.behavior_logprobs
                               if tb.behavior_logprobs is not None
                               else np.zeros((0,))),
                     loss_mask=tb.meta.get("loss_mask", np.zeros((0,))))
            manifest["buffer"].append({
                "task_id": tb.task_id, "version": tb.version,
                "group_size": tb.group_size, "idx": i,
            })
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    final = os.path.join(directory, tag)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                      # atomic publish
    _write_latest(directory, tag)
    return final


def _write_latest(directory: str, tag: str):
    tmp = os.path.join(directory, ".latest_tmp")
    with open(tmp, "w") as f:
        f.write(tag)
    os.rename(tmp, os.path.join(directory, "LATEST"))


def latest_checkpoint(directory: str) -> Optional[str]:
    p = os.path.join(directory, "LATEST")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        tag = f.read().strip()
    full = os.path.join(directory, tag)
    return full if os.path.exists(full) else None


def load_checkpoint(path: str, mgr: MultiTaskManager) -> MultiTaskManager:
    """Restore manager state in place (tasks + buffer). Adapters come back
    as host numpy trees; device placement/resharding happens lazily on first
    use under whatever mesh is now active (elastic restart).

    `rollout_issued_version` is reset to version-1 so the next policy
    version is re-issued for rollout — in-flight work at crash time is
    regenerated, never resumed stale."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    with mgr._lock:
        mgr.q_buffer.clear()
        for tid, entry in manifest["tasks"].items():
            spec = TaskSpec(**entry["spec"])
            adapters = opt_state = None
            if entry["has_adapters"]:
                adapters = flat_to_tree(
                    dict(np.load(os.path.join(path, f"{tid}_adapters.npz"))))
            if entry["has_opt"]:
                opt_state = flat_to_tree(
                    dict(np.load(os.path.join(path, f"{tid}_opt.npz"))))
            st = TaskState(spec=spec, adapters=adapters, opt_state=opt_state,
                           version=entry["version"],
                           steps_done=entry["steps_done"],
                           status=entry["status"],
                           rollout_issued_version=entry["version"] - 1,
                           submitted_at=mgr.clock())
            st.reward_history = list(entry.get("reward_history", []))
            mgr.tasks[spec.task_id] = st
        for b in manifest["buffer"]:
            arrs = dict(np.load(os.path.join(path, f"buffer_{b['idx']}.npz")))
            tb = TrajectoryBatch(
                task_id=b["task_id"], version=b["version"],
                tokens=arrs["tokens"], prompt_lens=arrs["prompt_lens"],
                total_lens=arrs["total_lens"], rewards=arrs["rewards"],
                group_size=b["group_size"],
                behavior_logprobs=(arrs["behavior"]
                                   if arrs["behavior"].size else None),
                meta=({"loss_mask": arrs["loss_mask"]}
                      if arrs["loss_mask"].size else {}))
            mgr.q_buffer.append(tb)
            # this version's rollout survived in the buffer — do NOT
            # re-issue it, or the duplicate would train stale after the
            # buffered copy commits
            st = mgr.tasks[tb.task_id]
            if tb.version == st.version:
                st.rollout_issued_version = st.version
    return mgr
