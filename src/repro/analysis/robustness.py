"""RA106 — no swallowed exceptions in stage-worker run() loops (ISSUE 10).

The threaded stages (prefill workers, env workers) are supervised: a
worker that dies is detected by liveness/heartbeat checks and restarted,
and its in-flight work is recovered. That whole story collapses if a
worker's ``run()`` swallows the exception instead of dying (or recording
it) — the supervisor sees a healthy thread spinning uselessly, nothing
restarts, and the fault surfaces as a silent throughput hole.

  RA106  in the ``run()`` method of a ``threading.Thread`` subclass: a
         bare ``except:``, or an ``except Exception/BaseException``
         handler that neither re-raises nor uses the caught exception
         (binds no name, or binds one the handler body never reads)

Using the exception means: a bare ``raise``, or any read of the bound
name (stashing it on ``self.error``, passing it to ``_finish``, logging
it). Narrow except types (``except ToolError``) are the stage's own
error taxonomy and are never flagged. Suppress a deliberate swallow with
``# noqa: RA106`` and a comment saying why.
"""
from __future__ import annotations

import ast
from typing import List

from .core import Finding, SourceFile

_BROAD = {"Exception", "BaseException"}


def _is_thread_base(base: ast.expr) -> bool:
    """True for ``threading.Thread`` / ``Thread`` base-class nodes."""
    if isinstance(base, ast.Name):
        return base.id == "Thread"
    if isinstance(base, ast.Attribute):
        return base.attr == "Thread"
    return False


def _broad_type(node: ast.expr) -> bool:
    """True if the except type catches Exception/BaseException (directly
    or anywhere in a tuple)."""
    if isinstance(node, ast.Tuple):
        return any(_broad_type(e) for e in node.elts)
    if isinstance(node, ast.Name):
        return node.id in _BROAD
    if isinstance(node, ast.Attribute):
        return node.attr in _BROAD
    return False


def _uses_exception(handler: ast.ExceptHandler) -> bool:
    """The handler re-raises or reads the caught exception."""
    for node in ast.walk(handler):
        if isinstance(node, ast.Raise):
            return True            # bare `raise` or `raise X from e`
        if (handler.name and isinstance(node, ast.Name)
                and node.id == handler.name
                and isinstance(node.ctx, ast.Load)):
            return True
    return False


def check(files: List[SourceFile]) -> List[Finding]:
    out: List[Finding] = []
    for src in files:
        for cls in ast.walk(src.tree):
            if not (isinstance(cls, ast.ClassDef)
                    and any(_is_thread_base(b) for b in cls.bases)):
                continue
            run = next((n for n in cls.body
                        if isinstance(n, (ast.FunctionDef,
                                          ast.AsyncFunctionDef))
                        and n.name == "run"), None)
            if run is None:
                continue
            for node in ast.walk(run):
                if not isinstance(node, ast.ExceptHandler):
                    continue
                if node.type is None:
                    out.append(Finding(
                        "RA106", src.rel, node.lineno,
                        f"bare except in {cls.name}.run() — the supervisor "
                        "can't see a worker that swallows its own death; "
                        "catch narrowly or record/re-raise"))
                elif _broad_type(node.type) and not _uses_exception(node):
                    out.append(Finding(
                        "RA106", src.rel, node.lineno,
                        f"except {ast.unparse(node.type)} in "
                        f"{cls.name}.run() swallows the exception — "
                        "re-raise it or record it (self.error / _finish) "
                        "so the supervisor and caller can act"))
    return out
