"""CLI: ``python -m repro.analysis [--check] [--write-baseline] PATHS``.

Default mode prints every finding. ``--check`` compares against the
committed baseline (``analysis/baseline.json``) and exits 1 only on NEW
findings — the CI gate. ``--write-baseline`` regenerates the baseline
from the current findings (review the diff before committing it).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from .core import (RULES, analyze_paths, default_baseline_path,
                   diff_against_baseline, load_baseline, write_baseline)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="MARLaaS-repro static analysis (lock discipline, "
                    "JAX trace hygiene, Pallas kernel checks)")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files or directories to analyze (default: src)")
    ap.add_argument("--check", action="store_true",
                    help="fail (exit 1) on findings NOT in the baseline")
    ap.add_argument("--write-baseline", action="store_true",
                    help="regenerate the baseline from current findings")
    ap.add_argument("--baseline", type=Path, default=None,
                    help="baseline path (default: analysis/baseline.json)")
    ap.add_argument("--report", type=Path, default=None,
                    help="write all findings as JSON to this path")
    args = ap.parse_args(argv)

    findings, _ = analyze_paths(args.paths or ["src"])

    if args.report:
        args.report.write_text(json.dumps(
            {"findings": [{"rule": f.rule, "file": f.file, "line": f.line,
                           "message": f.message} for f in findings]},
            indent=2) + "\n")

    if args.write_baseline:
        path = write_baseline(findings, args.baseline)
        print(f"wrote {len(findings)} finding(s) to {path}")
        return 0

    if args.check:
        baseline = load_baseline(args.baseline)
        new = diff_against_baseline(findings, baseline)
        known = len(findings) - len(new)
        for f in new:
            print(f.format())
        print(f"{len(new)} new finding(s); {known} baselined "
              f"({args.baseline or default_baseline_path()})")
        return 1 if new else 0

    for f in findings:
        print(f.format())
    by_rule = {}
    for f in findings:
        by_rule[f.rule] = by_rule.get(f.rule, 0) + 1
    for rule in sorted(by_rule):
        print(f"  {rule} {RULES[rule]}: {by_rule[rule]}")
    print(f"{len(findings)} finding(s)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
