"""RA105 — metrics phase-literal discipline (ISSUE 9 satellite).

Every ``rec.record(pool, phase, ...)`` call site must pass the phase as a
string literal that exists in ``repro.core.metrics.PHASE_INTENSITY``. The
recorder itself accepts any string — a typo'd or unregistered phase would
silently book intervals that ``utilization_pct`` weights with the default
intensity and the per-stage summaries never surface. Catch it statically:

  RA105  phase argument of ``.record(...)`` is not a literal, or is a
         literal missing from PHASE_INTENSITY

Receivers considered recorders: names ``rec`` / ``recorder`` / ``_rec``
and attribute chains ending in them (``self.rec``, ``sim.rec``). Call
sites that forward a *variable* phase (e.g. a validated hook parameter)
suppress with ``# noqa: RA105`` next to an explicit
``phase in PHASE_INTENSITY`` guard.
"""
from __future__ import annotations

import ast
from typing import List

from .core import Finding, SourceFile

_RECORDER_NAMES = {"rec", "recorder", "_rec"}

# keep the checker importable even if metrics grows exotic imports: the
# phase registry is the single source of truth, read at check time
from repro.core.metrics import PHASE_INTENSITY


def _is_recorder(node: ast.expr) -> bool:
    """True for ``rec`` / ``self.rec`` / ``runtime.rec``-style receivers."""
    if isinstance(node, ast.Name):
        return node.id in _RECORDER_NAMES
    if isinstance(node, ast.Attribute):
        return node.attr in _RECORDER_NAMES
    return False


def check(files: List[SourceFile]) -> List[Finding]:
    out: List[Finding] = []
    known = ", ".join(sorted(PHASE_INTENSITY))
    for src in files:
        for node in ast.walk(src.tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr == "record"
                    and _is_recorder(node.func.value)):
                continue
            if len(node.args) < 2:
                continue        # phase passed by keyword or not at all
            phase = node.args[1]
            if not (isinstance(phase, ast.Constant)
                    and isinstance(phase.value, str)):
                out.append(Finding(
                    "RA105", src.rel, node.lineno,
                    "phase argument of rec.record() is not a string "
                    "literal — pass a PHASE_INTENSITY key (or guard the "
                    "variable and suppress)"))
            elif phase.value not in PHASE_INTENSITY:
                out.append(Finding(
                    "RA105", src.rel, node.lineno,
                    f"phase {phase.value!r} is not in PHASE_INTENSITY "
                    f"(known: {known})"))
    return out
