"""RA3xx — Pallas kernel structural checks.

Parses every ``pl.pallas_call(...)`` site (``grid_spec`` /
``PrefetchScalarGridSpec`` constructed in a local variable is resolved
through the enclosing function's assignments) and validates the arity
contracts that otherwise only fail at trace time — or worse, silently
read the wrong block:

  RA301  ``index_map`` lambda arity != len(grid) + num_scalar_prefetch
  RA302  ``index_map`` returns a tuple whose length != block rank, or a
         kernel body indexes a ref with a literal out of range for its
         (literal) block shape (``None`` dims are squeezed)
  RA303  kernel positional-param count != num_scalar_prefetch +
         len(in_specs) + n_outs + len(scratch_shapes); the immediate
         invocation passes a different arg count than
         num_scalar_prefetch + len(in_specs); or an int32-cast scalar
         operand appears AFTER a non-scalar one (scalar-prefetch operands
         must come first — the ``paged_decode.py`` block-table pattern)
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from .core import Finding, SourceFile


def _callee_name(node: ast.expr) -> Optional[str]:
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        return node.attr
    return None


@dataclass
class _Spec:
    """One BlockSpec: literal block shape (None entries for non-literal
    dims, ``"squeeze"`` markers dropped) and its index_map lambda."""
    rank: Optional[int] = None
    dims: Optional[List[Optional[int]]] = None   # squeezed literal dims
    index_map: Optional[ast.Lambda] = None
    line: int = 0


def _parse_blockspec(node: ast.expr) -> Optional[_Spec]:
    if not (isinstance(node, ast.Call)
            and _callee_name(node.func) == "BlockSpec"):
        return None
    spec = _Spec(line=node.lineno)
    exprs = list(node.args) + [k.value for k in node.keywords]
    for e in exprs:
        if isinstance(e, ast.Tuple):
            spec.rank = len(e.elts)
            dims = []
            for elt in e.elts:
                if isinstance(elt, ast.Constant):
                    if elt.value is None:
                        continue            # squeezed dim
                    dims.append(elt.value
                                if isinstance(elt.value, int) else None)
                else:
                    dims.append(None)
            spec.dims = dims
        elif isinstance(e, ast.Lambda):
            spec.index_map = e
    return spec


@dataclass
class _CallInfo:
    node: ast.Call
    line: int
    nsp: int = 0
    grid_len: Optional[int] = None
    in_specs: Optional[List[_Spec]] = None
    out_specs: Optional[List[_Spec]] = None
    n_out: Optional[int] = None
    n_scratch: Optional[int] = None
    kernel: Optional[ast.FunctionDef] = None
    kernel_name: str = "<kernel>"


def _resolve(expr: ast.expr, env: Dict[str, ast.expr],
             depth: int = 0) -> ast.expr:
    while isinstance(expr, ast.Name) and expr.id in env and depth < 4:
        expr = env[expr.id]
        depth += 1
    return expr


def _spec_list(expr: ast.expr) -> Optional[List[_Spec]]:
    if isinstance(expr, (ast.List, ast.Tuple)):
        out = []
        for e in expr.elts:
            s = _parse_blockspec(e)
            if s is None:
                return None
            out.append(s)
        return out
    s = _parse_blockspec(expr)
    return [s] if s is not None else None


def _parse_call(call: ast.Call, env: Dict[str, ast.expr],
                module_defs: Dict[str, ast.FunctionDef]
                ) -> Optional[_CallInfo]:
    if _callee_name(call.func) != "pallas_call":
        return None
    info = _CallInfo(node=call, line=call.lineno)
    kwargs = {k.arg: _resolve(k.value, env) for k in call.keywords if k.arg}
    gs = kwargs.get("grid_spec")
    if isinstance(gs, ast.Call) and _callee_name(gs.func) in (
            "PrefetchScalarGridSpec", "GridSpec"):
        for k in gs.keywords:
            kwargs.setdefault(k.arg, _resolve(k.value, env))
    nsp = kwargs.get("num_scalar_prefetch")
    if isinstance(nsp, ast.Constant) and isinstance(nsp.value, int):
        info.nsp = nsp.value
    grid = kwargs.get("grid")
    if isinstance(grid, ast.Tuple):
        info.grid_len = len(grid.elts)
    elif isinstance(grid, ast.Constant):
        info.grid_len = 1
    if "in_specs" in kwargs:
        info.in_specs = _spec_list(kwargs["in_specs"])
    if "out_specs" in kwargs:
        info.out_specs = _spec_list(kwargs["out_specs"])
        if info.out_specs is not None:
            info.n_out = len(info.out_specs)
    if info.n_out is None and "out_shape" in kwargs:
        osh = kwargs["out_shape"]
        info.n_out = len(osh.elts) if isinstance(osh, (ast.List, ast.Tuple)) \
            else 1
    scratch = kwargs.get("scratch_shapes")
    if isinstance(scratch, (ast.List, ast.Tuple)):
        info.n_scratch = len(scratch.elts)
    elif "scratch_shapes" not in kwargs:
        info.n_scratch = 0
    # kernel: first positional arg, possibly partial(_kernel, ...)
    if call.args:
        k = call.args[0]
        if isinstance(k, ast.Call) and _callee_name(k.func) == "partial" \
                and k.args:
            k = k.args[0]
        name = _callee_name(k) if isinstance(k, (ast.Name,
                                                 ast.Attribute)) else None
        if name and name in module_defs:
            info.kernel = module_defs[name]
            info.kernel_name = name
    return info


def _kernel_positional_count(fn: ast.FunctionDef) -> Optional[int]:
    a = fn.args
    if a.vararg is not None:
        return None
    return len(a.posonlyargs) + len(a.args)


_INT32_MARKERS = ("int32", "int16")


def _is_scalar_marked(expr: ast.expr) -> bool:
    for n in ast.walk(expr):
        if isinstance(n, ast.Attribute) and n.attr in _INT32_MARKERS:
            return True
        if isinstance(n, ast.Name) and n.id in _INT32_MARKERS:
            return True
    return False


class _FileChecker:
    def __init__(self, src: SourceFile, findings: List[Finding]):
        self.src = src
        self.findings = findings
        self.module_defs = {n.name: n for n in ast.walk(src.tree)
                            if isinstance(n, ast.FunctionDef)}

    def _emit(self, rule: str, line: int, msg: str):
        self.findings.append(Finding(rule, self.src.rel, line, msg))

    def run(self):
        for fn in self.src.tree.body:
            if isinstance(fn, ast.FunctionDef):
                self._function(fn)

    def _function(self, fn: ast.FunctionDef):
        env: Dict[str, ast.expr] = {}
        for s in ast.walk(fn):
            if isinstance(s, ast.Assign) and len(s.targets) == 1 \
                    and isinstance(s.targets[0], ast.Name):
                env[s.targets[0].id] = s.value
        calls = [n for n in ast.walk(fn) if isinstance(n, ast.Call)]
        infos: Dict[ast.Call, _CallInfo] = {}
        for c in calls:
            info = _parse_call(c, env, self.module_defs)
            if info is not None:
                infos[c] = info
                self._check_specs(info)
                self._check_kernel(info)
        # immediate invocation: pl.pallas_call(...)(operands...)
        for c in calls:
            if isinstance(c.func, ast.Call) and c.func in infos:
                self._check_invocation(infos[c.func], c)

    def _check_specs(self, info: _CallInfo):
        if info.grid_len is None:
            return
        expect = info.grid_len + info.nsp
        all_specs = (info.in_specs or []) + (info.out_specs or [])
        for spec in all_specs:
            lam = spec.index_map
            if lam is None:
                continue
            arity = len(lam.args.posonlyargs) + len(lam.args.args)
            if lam.args.vararg is None and arity != expect:
                self._emit("RA301", spec.line,
                           f"index_map takes {arity} args; grid "
                           f"({info.grid_len}) + scalar prefetch "
                           f"({info.nsp}) needs {expect}")
            if spec.rank is not None and isinstance(lam.body, ast.Tuple) \
                    and len(lam.body.elts) != spec.rank:
                self._emit("RA302", spec.line,
                           f"index_map returns {len(lam.body.elts)} "
                           f"indices for a rank-{spec.rank} block shape")

    def _check_kernel(self, info: _CallInfo):
        if info.kernel is None or info.in_specs is None \
                or info.n_out is None or info.n_scratch is None:
            return
        got = _kernel_positional_count(info.kernel)
        if got is None:
            return
        expect = info.nsp + len(info.in_specs) + info.n_out + info.n_scratch
        if got != expect:
            self._emit("RA303", info.line,
                       f"kernel `{info.kernel_name}` has {got} positional "
                       f"params; expected {expect} (= {info.nsp} prefetch "
                       f"+ {len(info.in_specs)} in + {info.n_out} out "
                       f"+ {info.n_scratch} scratch)")
            return
        self._check_ref_bounds(info)

    def _check_ref_bounds(self, info: _CallInfo):
        """Literal subscripts on kernel refs vs literal block dims
        (None dims squeezed)."""
        kernel = info.kernel
        a = kernel.args
        params = [p.arg for p in (a.posonlyargs + a.args)]
        specs: List[Optional[_Spec]] = \
            [None] * info.nsp + list(info.in_specs) + \
            list(info.out_specs or [None] * (info.n_out or 0))
        by_param: Dict[str, _Spec] = {}
        for name, spec in zip(params, specs):
            if spec is not None and spec.dims:
                by_param[name] = spec
        for node in ast.walk(kernel):
            if not (isinstance(node, ast.Subscript)
                    and isinstance(node.value, ast.Name)
                    and node.value.id in by_param):
                continue
            dims = by_param[node.value.id].dims
            idxs = node.slice.elts if isinstance(node.slice, ast.Tuple) \
                else [node.slice]
            for d, idx in enumerate(idxs):
                if d >= len(dims) or dims[d] is None:
                    continue
                if isinstance(idx, ast.Constant) \
                        and isinstance(idx.value, int) \
                        and idx.value >= dims[d] >= 0:
                    self._emit("RA302", node.lineno,
                               f"ref `{node.value.id}` indexed at "
                               f"{idx.value} but block dim {d} has size "
                               f"{dims[d]}")

    def _check_invocation(self, info: _CallInfo, call: ast.Call):
        if info.in_specs is None:
            return
        expect = info.nsp + len(info.in_specs)
        if call.keywords or any(isinstance(x, ast.Starred)
                                for x in call.args):
            return
        if len(call.args) != expect:
            self._emit("RA303", call.lineno,
                       f"pallas_call invocation passes {len(call.args)} "
                       f"operands; expected {expect} (= {info.nsp} "
                       f"prefetch + {len(info.in_specs)} in)")
            return
        if info.nsp:
            head = call.args[:info.nsp]
            tail = call.args[info.nsp:]
            if any(not _is_scalar_marked(h) for h in head) \
                    and any(_is_scalar_marked(t) for t in tail):
                self._emit("RA303", call.lineno,
                           "scalar-prefetch operands (int32 scalars) "
                           "must be the FIRST invocation args")


def check(files: List[SourceFile]) -> List[Finding]:
    findings: List[Finding] = []
    for src in files:
        _FileChecker(src, findings).run()
    return findings
