"""RA2xx — JAX trace-hygiene checks.

Finds jitted functions (``jax.jit(f)`` / ``jax.jit(lambda ...)`` /
``@jax.jit`` / ``@partial(jax.jit, static_argnums=...)``) and taint-walks
their bodies: every non-static positional parameter is a tracer. Keyword-
only parameters are treated as static configuration (the repo binds them
via ``functools.partial`` at pallas_call/jit construction time), and
``.shape`` / ``.ndim`` / ``.dtype`` / ``len()`` stop taint — those are
Python values at trace time.

  RA201  ``if`` / ``while`` / ``assert`` / ternary on a traced value
         (needs ``jnp.where`` / ``lax.cond`` / checkify instead)
  RA202  host sync on a tracer: ``float()/int()/bool()`` of a traced
         value, ``np.*`` called on one, ``.item()`` / ``.tolist()``
  RA203  mutation of captured state inside a jitted closure
         (``self.x = ...`` / ``global``-declared names) — silently traces
         once and never updates again
  RA204  recompile hazards at jit CALL sites: an argument whose shape
         expression derives from an unbucketed ``len(...)`` — every new
         length is a fresh trace signature in the decode hot loop. Shapes
         routed through ``_bucket_len`` / ``_pad_to`` / ``pages_for`` or
         pow2 growth (``W *= 2``) are considered bucketed.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple, Union

from .core import Finding, SourceFile

_FnNode = Union[ast.FunctionDef, ast.Lambda]


@dataclass
class _Jitted:
    fn: _FnNode
    static_idx: Set[int] = field(default_factory=set)
    static_names: Set[str] = field(default_factory=set)


def _is_jit_func(f: ast.expr) -> bool:
    if isinstance(f, ast.Attribute) and f.attr == "jit" and \
            isinstance(f.value, ast.Name) and f.value.id == "jax":
        return True
    return isinstance(f, ast.Name) and f.id == "jit"


def _jit_call_of(node: ast.expr) -> Optional[ast.Call]:
    """The ``jax.jit(...)`` call inside `node`, unwrapping
    ``partial(jax.jit, ...)``."""
    if not isinstance(node, ast.Call):
        return None
    if _is_jit_func(node.func):
        return node
    f = node.func
    is_partial = (isinstance(f, ast.Name) and f.id == "partial") or \
        (isinstance(f, ast.Attribute) and f.attr == "partial")
    if is_partial and node.args and _is_jit_func(node.args[0]):
        return node
    return None


def _static_spec(call: ast.Call) -> Tuple[Set[int], Set[str]]:
    idx: Set[int] = set()
    names: Set[str] = set()
    for kw in call.keywords:
        if kw.arg == "static_argnums":
            vals = kw.value.elts if isinstance(kw.value, ast.Tuple) \
                else [kw.value]
            idx.update(v.value for v in vals
                       if isinstance(v, ast.Constant)
                       and isinstance(v.value, int))
        elif kw.arg == "static_argnames":
            vals = kw.value.elts if isinstance(kw.value, (ast.Tuple,
                                                          ast.List)) \
                else [kw.value]
            names.update(v.value for v in vals
                         if isinstance(v, ast.Constant)
                         and isinstance(v.value, str))
    return idx, names


class _JitFinder(ast.NodeVisitor):
    """Scoped resolver: `jax.jit(step)` binds to the `def step` visible in
    the enclosing scope chain (builders reuse local names like `step`)."""

    def __init__(self):
        self.scopes: List[Dict[str, ast.FunctionDef]] = [{}]
        self.found: Dict[int, _Jitted] = {}

    def _resolve(self, name: str) -> Optional[ast.FunctionDef]:
        for scope in reversed(self.scopes):
            if name in scope:
                return scope[name]
        return None

    def visit_FunctionDef(self, node: ast.FunctionDef):
        self.scopes[-1][node.name] = node
        for dec in node.decorator_list:
            if _is_jit_func(dec):
                self._add(node, set(), set())
            else:
                call = _jit_call_of(dec)
                if call is not None:
                    self._add(node, *_static_spec(call))
        self.scopes.append({})
        self.generic_visit(node)
        self.scopes.pop()

    def visit_Call(self, node: ast.Call):
        call = _jit_call_of(node)
        if call is not None:
            # target is the first non-jit positional arg
            args = [a for a in call.args if not _is_jit_func(a)]
            if args:
                target = args[0]
                if isinstance(target, ast.Lambda):
                    self._add(target, *_static_spec(call))
                elif isinstance(target, ast.Name):
                    fn = self._resolve(target.id)
                    if fn is not None:
                        self._add(fn, *_static_spec(call))
        self.generic_visit(node)

    def _add(self, fn: _FnNode, idx: Set[int], names: Set[str]):
        j = self.found.setdefault(id(fn), _Jitted(fn))
        j.static_idx |= idx
        j.static_names |= names


_TAINT_STOP_ATTRS = {"shape", "ndim", "dtype", "size"}
_UNTAINTED_CALLS = {"len", "isinstance", "type", "range", "enumerate",
                    "zip", "hasattr", "getattr"}


class _Taint:
    """Expression taintedness relative to a set of traced names."""

    def __init__(self, tainted: Set[str]):
        self.tainted = tainted

    def expr(self, node: ast.expr) -> bool:
        if isinstance(node, ast.Name):
            return node.id in self.tainted
        if isinstance(node, ast.Constant):
            return False
        if isinstance(node, ast.Attribute):
            if node.attr in _TAINT_STOP_ATTRS:
                return False
            return self.expr(node.value)
        if isinstance(node, ast.Call):
            fname = None
            if isinstance(node.func, ast.Name):
                fname = node.func.id
            elif isinstance(node.func, ast.Attribute):
                fname = node.func.attr
            if fname in _UNTAINTED_CALLS:
                return False
            args_tainted = any(self.expr(a) for a in node.args) or \
                any(self.expr(k.value) for k in node.keywords)
            if isinstance(node.func, ast.Attribute):
                return args_tainted or self.expr(node.func.value)
            return args_tainted
        if isinstance(node, ast.Compare):
            if all(isinstance(op, (ast.Is, ast.IsNot, ast.In, ast.NotIn))
                   for op in node.ops):
                return False
            return self.expr(node.left) or \
                any(self.expr(c) for c in node.comparators)
        if isinstance(node, ast.Subscript):
            return self.expr(node.value) or self.expr(node.slice)
        if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
            return any(self.expr(e) for e in node.elts)
        if isinstance(node, ast.Dict):
            return any(self.expr(v) for v in node.values if v is not None)
        if isinstance(node, ast.Lambda):
            return False
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.expr) and self.expr(child):
                return True
        return False

    def first_name(self, node: ast.expr) -> str:
        for n in ast.walk(node):
            if isinstance(n, ast.Name) and n.id in self.tainted:
                return n.id
        return "<traced>"


class _TraceChecker:
    """RA201/202/203 over one jitted function body."""

    def __init__(self, src: SourceFile, jit: _Jitted,
                 findings: List[Finding]):
        self.src = src
        self.findings = findings
        self.jit = jit
        fn = jit.fn
        self.globals_decl: Set[str] = set()
        params = self._params(fn)
        tainted = set()
        for i, name in enumerate(params):
            if name == "self" or i in jit.static_idx \
                    or name in jit.static_names:
                continue
            tainted.add(name)
        self.taint = _Taint(tainted)

    @staticmethod
    def _params(fn: _FnNode) -> List[str]:
        a = fn.args
        return [p.arg for p in (a.posonlyargs + a.args)]

    def _emit(self, rule: str, line: int, msg: str):
        self.findings.append(Finding(rule, self.src.rel, line, msg))

    def run(self):
        body = self.jit.fn.body
        if isinstance(self.jit.fn, ast.Lambda):
            self._expr_checks(body)
            return
        for stmt in body:
            self._stmt(stmt)

    def _stmt(self, stmt: ast.stmt):
        t = self.taint
        if isinstance(stmt, (ast.Global, ast.Nonlocal)):
            self.globals_decl.update(stmt.names)
        elif isinstance(stmt, (ast.If, ast.While)):
            if t.expr(stmt.test):
                kw = "if" if isinstance(stmt, ast.If) else "while"
                self._emit("RA201", stmt.lineno,
                           f"Python `{kw}` on traced value "
                           f"`{t.first_name(stmt.test)}` in jitted function")
            self._expr_checks(stmt.test)
        elif isinstance(stmt, ast.Assert):
            if t.expr(stmt.test):
                self._emit("RA201", stmt.lineno,
                           f"`assert` on traced value "
                           f"`{t.first_name(stmt.test)}` in jitted function")
            self._expr_checks(stmt.test)
        elif isinstance(stmt, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
            self._assign(stmt)
        elif isinstance(stmt, ast.For):
            if t.expr(stmt.iter):
                for n in ast.walk(stmt.target):
                    if isinstance(n, ast.Name):
                        t.tainted.add(n.id)
            self._expr_checks(stmt.iter)
        elif isinstance(stmt, ast.FunctionDef):
            # nested def (loop body for fori/scan): params are tracers too
            nested = _Jitted(stmt)
            sub = _TraceChecker(self.src, nested, self.findings)
            sub.taint.tainted |= self.taint.tainted
            sub.run()
            return
        elif isinstance(stmt, (ast.Return, ast.Expr)):
            if stmt.value is not None:
                self._expr_checks(stmt.value)
        # recurse into compound bodies
        for child in ast.iter_child_nodes(stmt):
            if isinstance(child, ast.stmt):
                self._stmt(child)
            elif isinstance(child, (ast.excepthandler,)):
                for s in child.body:
                    self._stmt(s)

    def _assign(self, stmt):
        t = self.taint
        value = stmt.value
        if value is not None:
            self._expr_checks(value)
        tainted_val = value is not None and t.expr(value)
        targets = stmt.targets if isinstance(stmt, ast.Assign) \
            else [stmt.target]
        for tgt in targets:
            if isinstance(tgt, ast.Attribute):
                root = tgt
                while isinstance(root, (ast.Attribute, ast.Subscript)):
                    root = root.value
                if isinstance(root, ast.Name) and (
                        root.id == "self"
                        or root.id in self.globals_decl):
                    self._emit("RA203", stmt.lineno,
                               f"mutation of captured `{ast.unparse(tgt)}` "
                               f"inside jitted function (traced once, "
                               f"never re-runs)")
            elif isinstance(tgt, ast.Name):
                if tgt.id in self.globals_decl:
                    self._emit("RA203", stmt.lineno,
                               f"assignment to global `{tgt.id}` inside "
                               f"jitted function (traced once, never "
                               f"re-runs)")
                elif isinstance(stmt, ast.AugAssign):
                    if tainted_val:
                        t.tainted.add(tgt.id)
                elif tainted_val:
                    t.tainted.add(tgt.id)
                else:
                    t.tainted.discard(tgt.id)
            elif isinstance(tgt, (ast.Tuple, ast.List)):
                for e in tgt.elts:
                    if isinstance(e, ast.Name):
                        if tainted_val:
                            t.tainted.add(e.id)
                        else:
                            t.tainted.discard(e.id)

    def _expr_checks(self, expr: ast.expr):
        t = self.taint
        stack: List[ast.AST] = [expr]
        while stack:
            node = stack.pop()
            if isinstance(node, ast.Lambda):
                nested = _Jitted(node)
                sub = _TraceChecker(self.src, nested, self.findings)
                sub.taint.tainted |= t.tainted
                sub._expr_checks(node.body)
                continue
            if isinstance(node, ast.IfExp) and t.expr(node.test):
                self._emit("RA201", node.lineno,
                           f"ternary on traced value "
                           f"`{t.first_name(node.test)}` in jitted function")
            elif isinstance(node, ast.Call):
                self._host_sync(node)
            stack.extend(ast.iter_child_nodes(node))

    def _host_sync(self, node: ast.Call):
        t = self.taint
        f = node.func
        if isinstance(f, ast.Name) and f.id in ("float", "int", "bool") \
                and node.args and t.expr(node.args[0]):
            self._emit("RA202", node.lineno,
                       f"`{f.id}()` on traced value "
                       f"`{t.first_name(node.args[0])}` forces host sync")
        elif isinstance(f, ast.Attribute):
            if f.attr in ("item", "tolist") and t.expr(f.value):
                self._emit("RA202", node.lineno,
                           f"`.{f.attr}()` on traced value "
                           f"`{t.first_name(f.value)}` forces host sync")
            elif isinstance(f.value, ast.Name) \
                    and f.value.id in ("np", "numpy") \
                    and any(t.expr(a) for a in node.args):
                self._emit("RA202", node.lineno,
                           f"`np.{f.attr}(...)` on traced value "
                           f"`{t.first_name(node.args[0])}` forces "
                           f"host sync")


# -- RA204: recompile hazards at jit call sites --------------------------

_BUCKET_MARKERS = ("bucket", "pad", "pages_for")
# scalar-cast callees: `jnp.int32(len(x))` is a VALUE, not a shape
_CAST_FUNCS = {"int", "float", "bool", "int8", "int16", "int32", "int64",
               "uint8", "uint16", "uint32", "uint64", "float16", "float32",
               "float64", "bfloat16", "bool_"}


def _jit_value_names(files: List[SourceFile]) -> Set[str]:
    """Names (locals and attributes) known to hold jitted callables:
    direct ``x = jax.jit(...)`` / ``self.f = jax.jit(...)`` assignments,
    plus attributes assigned from builder functions that return jitted
    callables (``self._step_fn = _build_cont_step_fn(...)``)."""
    names: Set[str] = set()
    builders: Set[str] = set()
    for src in files:
        # builders: module functions whose return value is (a tuple of)
        # jax.jit(...) calls or names assigned from them
        for node in src.tree.body:
            if not isinstance(node, ast.FunctionDef):
                continue
            jit_locals = {s.targets[0].id
                          for s in ast.walk(node)
                          if isinstance(s, ast.Assign)
                          and len(s.targets) == 1
                          and isinstance(s.targets[0], ast.Name)
                          and _jit_call_of(s.value) is not None}
            for ret in ast.walk(node):
                if not isinstance(ret, ast.Return) or ret.value is None:
                    continue
                vals = ret.value.elts if isinstance(ret.value, ast.Tuple) \
                    else [ret.value]
                for v in vals:
                    if _jit_call_of(v) is not None or (
                            isinstance(v, ast.Name) and v.id in jit_locals):
                        builders.add(node.name)
                        break
    for src in files:
        for node in ast.walk(src.tree):
            if not (isinstance(node, ast.Assign) and len(node.targets) == 1):
                continue
            tgt = node.targets[0]
            val = node.value
            is_jit = _jit_call_of(val) is not None
            from_builder = (isinstance(val, ast.Call)
                            and isinstance(val.func, ast.Name)
                            and val.func.id in builders)
            if not (is_jit or from_builder):
                continue
            tgts = tgt.elts if isinstance(tgt, (ast.Tuple, ast.List)) \
                else [tgt]
            for x in tgts:
                if isinstance(x, ast.Name):
                    names.add(x.id)
                elif isinstance(x, ast.Attribute):
                    names.add(x.attr)
    return names


class _HazardScan:
    def __init__(self, src: SourceFile, jit_names: Set[str],
                 findings: List[Finding]):
        self.src = src
        self.jit_names = jit_names
        self.findings = findings

    def run(self):
        for node in ast.walk(self.src.tree):
            if isinstance(node, ast.FunctionDef):
                self._function(node)

    def _function(self, fn: ast.FunctionDef):
        assign_map: Dict[str, ast.expr] = {}
        pow2: Set[str] = set()
        for s in ast.walk(fn):
            if isinstance(s, ast.Assign) and len(s.targets) == 1 \
                    and isinstance(s.targets[0], ast.Name):
                assign_map[s.targets[0].id] = s.value
            elif isinstance(s, ast.AugAssign) \
                    and isinstance(s.target, ast.Name) \
                    and isinstance(s.op, ast.Mult):
                pow2.add(s.target.id)   # W *= 2: pow2-bucketed width

        def hazardous(expr: ast.expr, seen: Set[str], depth: int) -> bool:
            stack: List[ast.AST] = [expr]
            while stack:
                n = stack.pop()
                if isinstance(n, (ast.List, ast.ListComp)):
                    continue   # data literal: its LENGTH is the shape
                if isinstance(n, ast.Call):
                    fname = (n.func.id if isinstance(n.func, ast.Name)
                             else n.func.attr
                             if isinstance(n.func, ast.Attribute) else "")
                    if any(m in fname for m in _BUCKET_MARKERS) \
                            or fname in _CAST_FUNCS:
                        continue   # bucketed subtree / scalar value cast
                    if fname == "len":
                        return True
                if isinstance(n, ast.Name) and n.id not in seen \
                        and n.id not in pow2 and depth < 4 \
                        and n.id in assign_map:
                    if hazardous(assign_map[n.id], seen | {n.id},
                                 depth + 1):
                        return True
                stack.extend(ast.iter_child_nodes(n))
            return False

        reported: Set[int] = set()
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            f = node.func
            name = (f.attr if isinstance(f, ast.Attribute)
                    else f.id if isinstance(f, ast.Name) else None)
            if name not in self.jit_names or node.lineno in reported:
                continue
            for arg in list(node.args) + [k.value for k in node.keywords]:
                if hazardous(arg, set(), 0):
                    reported.add(node.lineno)
                    self.findings.append(Finding(
                        "RA204", self.src.rel, node.lineno,
                        f"jit call `{name}` takes an argument derived "
                        f"from unbucketed `len(...)` — per-step shape "
                        f"variation recompiles"))
                    break


def check(files: List[SourceFile]) -> List[Finding]:
    findings: List[Finding] = []
    jit_names = _jit_value_names(files)
    for src in files:
        finder = _JitFinder()
        finder.visit(src.tree)
        for jit in finder.found.values():
            _TraceChecker(src, jit, findings).run()
        _HazardScan(src, jit_names, findings).run()
    return findings
