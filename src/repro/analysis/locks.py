"""RA1xx — lock-discipline checks.

Discovers every ``threading.Lock/RLock/Condition`` attribute assigned in a
class (``self._x = threading.Lock()``), its guard set (from a
``# guards: _a/_b`` comment on the assignment line), and
``Condition(self._lock)`` aliases. Then walks every function tracking
which locks are held at each statement (``with <base>.<attr>:`` scopes
plus ``# held: _x`` function annotations) and emits:

  RA101  lock-order cycles in the cross-module acquisition graph
         (edges from lexically nested ``with`` blocks AND from calls made
         under a held lock to functions that acquire locks, resolved
         through a same-repo call-graph fixpoint)
  RA102  guarded attributes read/written outside a ``with`` on their lock
         (``__init__`` and the lock-creating function are exempt)
  RA103  blocking calls under a held lock: zero-arg ``.result()`` /
         ``.get()`` / ``.join()``, ``.wait()``/``.wait_for()`` with no or
         ``None`` timeout, ``.item()``, ``.block_until_ready()``,
         ``jax.block_until_ready``, ``jax.device_get``, ``np.asarray`` /
         ``np.array``, ``time.sleep``

The lock graph (``LockModel``) is exported for the runtime lock-order
recorder: lock ids are ``file:line`` of the creating assignment, exactly
what the recorder observes from patched ``threading`` factories.
"""
from __future__ import annotations

import ast
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from .core import Finding, SourceFile

_LOCK_FACTORIES = ("Lock", "RLock", "Condition")
_GUARDS_RE = re.compile(r"guards:?\s*(.*)")
_HELD_RE = re.compile(r"held:\s*([A-Za-z_]\w*(?:\s*[/,]\s*[A-Za-z_]\w*)*)")


@dataclass
class LockDef:
    lock_id: str                 # "file:line" of the creating assignment
    cls: str                     # "file::ClassName"
    cls_name: str
    canonical: str               # primary attribute name
    attrs: Set[str]              # all aliases ({_lock, _cv})
    kind: str                    # Lock | RLock | Condition
    guards: Set[str]
    file: str
    line: int
    created_in: str              # method that assigned it (usually __init__)

    @property
    def display(self) -> str:
        return f"{self.cls_name}.{self.canonical}"


@dataclass
class LockModel:
    locks: Dict[str, LockDef] = field(default_factory=dict)
    # (cls_key, attr_alias) -> LockDef
    by_class_attr: Dict[Tuple[str, str], LockDef] = field(default_factory=dict)
    # guarded attr name -> lock defs claiming it
    guard_index: Dict[str, List[LockDef]] = field(default_factory=dict)
    # (a_id, b_id) -> (file, line) of one witness acquisition of b under a
    edges: Dict[Tuple[str, str], Tuple[str, int]] = field(default_factory=dict)
    # (cls_key, attr) -> cls_key of the object stored there
    attr_types: Dict[Tuple[str, str], str] = field(default_factory=dict)

    def resolve(self, cls_key: Optional[str], attr: str) -> Optional[LockDef]:
        """Lock def for `<something>.<attr>`: class-scoped when the class
        is known, else by (unique) attribute name across the repo."""
        if cls_key is not None:
            d = self.by_class_attr.get((cls_key, attr))
            if d is not None:
                return d
        cands = {d.lock_id: d for (_, a), d in self.by_class_attr.items()
                 if a == attr}
        if len(cands) == 1:
            return next(iter(cands.values()))
        return None

    def sites(self) -> Set[str]:
        return set(self.locks)

    def edge_pairs(self) -> Set[Tuple[str, str]]:
        return set(self.edges)

    def has_path(self, a: str, b: str) -> bool:
        seen, stack = set(), [a]
        while stack:
            n = stack.pop()
            if n == b:
                return True
            if n in seen:
                continue
            seen.add(n)
            stack.extend(y for (x, y) in self.edges if x == n)
        return False


def _call_factory(node: ast.expr, threading_names: Set[str]) -> Optional[str]:
    """'Lock'/'RLock'/'Condition' if `node` is a call to that threading
    factory, else None."""
    if not isinstance(node, ast.Call):
        return None
    f = node.func
    if (isinstance(f, ast.Attribute) and f.attr in _LOCK_FACTORIES
            and isinstance(f.value, ast.Name)
            and f.value.id == "threading"):
        return f.attr
    if (isinstance(f, ast.Name) and f.id in _LOCK_FACTORIES
            and f.id in threading_names):
        return f.id
    return None


def _parse_guards(comment: str) -> Set[str]:
    m = _GUARDS_RE.search(comment)
    if not m:
        return set()
    return set(re.findall(r"[A-Za-z_]\w*", m.group(1)))


def _self_attr(node: ast.expr) -> Optional[str]:
    if (isinstance(node, ast.Attribute) and isinstance(node.value, ast.Name)
            and node.value.id == "self"):
        return node.attr
    return None


def _threading_imports(tree: ast.Module) -> Set[str]:
    out: Set[str] = set()
    for n in ast.walk(tree):
        if isinstance(n, ast.ImportFrom) and n.module == "threading":
            out.update(a.asname or a.name for a in n.names)
    return out


# -- model construction --------------------------------------------------

def build_model(files: List[SourceFile]) -> LockModel:
    model = LockModel()
    class_names: Dict[str, str] = {}          # simple name -> cls_key
    classes: List[Tuple[SourceFile, ast.ClassDef]] = []
    for src in files:
        for node in ast.walk(src.tree):
            if isinstance(node, ast.ClassDef):
                key = f"{src.rel}::{node.name}"
                class_names[node.name] = key
                classes.append((src, node))

    for src, cls in classes:
        cls_key = f"{src.rel}::{cls.name}"
        tnames = _threading_imports(src.tree)
        for fn in [n for n in cls.body if isinstance(n, ast.FunctionDef)]:
            for stmt in ast.walk(fn):
                if not isinstance(stmt, ast.Assign):
                    continue
                attr = (_self_attr(stmt.targets[0])
                        if len(stmt.targets) == 1 else None)
                if attr is None:
                    continue
                kind = _call_factory(stmt.value, tnames)
                if kind is not None:
                    # Condition(self._x) aliases an existing lock
                    if kind == "Condition" and stmt.value.args:
                        base = _self_attr(stmt.value.args[0])
                        existing = model.by_class_attr.get((cls_key, base))
                        if existing is not None:
                            existing.attrs.add(attr)
                            model.by_class_attr[(cls_key, attr)] = existing
                            extra = _parse_guards(
                                src.comment_at(stmt.lineno))
                            existing.guards |= extra
                            continue
                    d = LockDef(
                        lock_id=f"{src.rel}:{stmt.lineno}",
                        cls=cls_key, cls_name=cls.name, canonical=attr,
                        attrs={attr}, kind=kind,
                        guards=_parse_guards(src.comment_at(stmt.lineno)),
                        file=src.rel, line=stmt.lineno, created_in=fn.name)
                    model.locks[d.lock_id] = d
                    model.by_class_attr[(cls_key, attr)] = d
                    continue
                # self.X = ClassName(...): object attr typing for the
                # cross-class call graph (also `x or ClassName()` defaults)
                vals = (stmt.value.values
                        if isinstance(stmt.value, ast.BoolOp)
                        else [stmt.value])
                for v in vals:
                    if not isinstance(v, ast.Call):
                        continue
                    f = v.func
                    name = (f.id if isinstance(f, ast.Name)
                            else f.attr if isinstance(f, ast.Attribute)
                            else None)
                    if name in class_names:
                        model.attr_types[(cls_key, attr)] = class_names[name]

    for d in model.locks.values():
        for g in d.guards:
            model.guard_index.setdefault(g, []).append(d)
    return model


# -- checking ------------------------------------------------------------

_HeldEntry = Tuple[str, str]            # (lock_id, base expr string)


@dataclass
class _FuncInfo:
    key: str                            # "file::Class.method" / "file::fn"
    cls_key: Optional[str]
    node: ast.FunctionDef
    src: SourceFile
    direct_acquires: Set[str] = field(default_factory=set)
    # (callee_key, (held lock_ids...), line)
    calls: List[Tuple[str, Tuple[str, ...], int]] = field(
        default_factory=list)


class _FuncWalker:
    """Single-function pass: held-lock tracking, RA102/RA103 findings,
    direct acquisitions, and call-graph edges for the fixpoint."""

    BLOCK_FUNCS = {"time.sleep", "jax.block_until_ready", "jax.device_get",
                   "np.asarray", "np.array", "numpy.asarray", "numpy.array"}

    def __init__(self, info: _FuncInfo, model: LockModel,
                 class_names: Dict[str, str], findings: List[Finding]):
        self.info = info
        self.model = model
        self.class_names = class_names
        self.findings = findings
        self.src = info.src
        self.cls_key = info.cls_key
        # local var -> cls_key (from `v = ClassName(...)` assignments and
        # parameter type annotations, incl. string annotations)
        self.local_types: Dict[str, str] = {}
        a = info.node.args
        for arg in (a.posonlyargs + a.args + a.kwonlyargs):
            ann = arg.annotation
            name = None
            if isinstance(ann, ast.Name):
                name = ann.id
            elif isinstance(ann, ast.Attribute):
                name = ann.attr
            elif isinstance(ann, ast.Constant) and isinstance(ann.value, str):
                name = ann.value.strip('"\'')
            if name in class_names:
                self.local_types[arg.arg] = class_names[name]

    # entry -------------------------------------------------------------
    def run(self):
        fn = self.info.node
        held: List[_HeldEntry] = []
        note = _HELD_RE.search(self.src.comment_at(fn.lineno) or "")
        if note:
            for attr in re.findall(r"[A-Za-z_]\w*", note.group(1)):
                d = self.model.resolve(self.cls_key, attr)
                if d is not None:
                    held.append((d.lock_id, "self"))
        for stmt in fn.body:
            self._stmt(stmt, held)

    # helpers -----------------------------------------------------------
    def _resolve_lock_expr(self, expr: ast.expr
                           ) -> Optional[Tuple[str, str]]:
        """(lock_id, base_str) if `expr` is `<base>.<lock attr>`."""
        if not isinstance(expr, ast.Attribute):
            return None
        base_str = ast.unparse(expr.value)
        cls_key = None
        if base_str == "self":
            cls_key = self.cls_key
        else:
            cls_key = self._expr_cls(expr.value)
        d = self.model.resolve(cls_key, expr.attr)
        if d is None or expr.attr not in d.attrs:
            return None
        if base_str == "self" and cls_key is not None and d.cls != cls_key:
            return None          # same attr name, different class
        return d.lock_id, base_str

    def _expr_cls(self, expr: ast.expr) -> Optional[str]:
        if isinstance(expr, ast.Name):
            return self.local_types.get(expr.id)
        if isinstance(expr, ast.Attribute) and \
                isinstance(expr.value, ast.Name) and expr.value.id == "self" \
                and self.cls_key is not None:
            return self.model.attr_types.get((self.cls_key, expr.attr))
        return None

    def _lock_name(self, lock_id: str) -> str:
        return self.model.locks[lock_id].display

    def _emit(self, rule: str, line: int, msg: str):
        self.findings.append(Finding(rule, self.src.rel, line, msg))

    # statement walk ----------------------------------------------------
    def _stmt(self, stmt: ast.stmt, held: List[_HeldEntry]):
        if isinstance(stmt, ast.With):
            pushed = 0
            for item in stmt.items:
                r = self._resolve_lock_expr(item.context_expr)
                if r is None:
                    self._expr(item.context_expr, held)
                    continue
                lock_id, base = r
                self.info.direct_acquires.add(lock_id)
                self._note_acquire(lock_id, held, item.context_expr.lineno)
                held.append((lock_id, base))
                pushed += 1
            for s in stmt.body:
                self._stmt(s, held)
            for _ in range(pushed):
                held.pop()
            return
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # nested def: body executes later; analyze with no held locks
            for s in stmt.body:
                self._stmt(s, [])
            return
        if isinstance(stmt, ast.Assign):
            # local object typing for callee resolution
            if (len(stmt.targets) == 1
                    and isinstance(stmt.targets[0], ast.Name)
                    and isinstance(stmt.value, ast.Call)):
                f = stmt.value.func
                name = (f.id if isinstance(f, ast.Name)
                        else f.attr if isinstance(f, ast.Attribute) else None)
                if name in self.class_names:
                    self.local_types[stmt.targets[0].id] = \
                        self.class_names[name]
        for child in ast.iter_child_nodes(stmt):
            if isinstance(child, ast.stmt):
                self._stmt(child, held)
            elif isinstance(child, ast.expr):
                self._expr(child, held)
            elif isinstance(child, (ast.excepthandler, ast.match_case)):
                for s in child.body:
                    self._stmt(s, held)

    def _note_acquire(self, lock_id: str, held: List[_HeldEntry],
                      line: int):
        for h, _ in held:
            if h == lock_id:
                d = self.model.locks[lock_id]
                if d.kind == "Lock":       # non-reentrant: self-deadlock
                    self.model.edges.setdefault((h, lock_id),
                                                (self.src.rel, line))
                continue
            self.model.edges.setdefault((h, lock_id), (self.src.rel, line))

    # expression walk ----------------------------------------------------
    def _expr(self, expr: ast.expr, held: List[_HeldEntry]):
        # Lambdas are excluded from held-lock checks (their bodies run
        # later, maybe not under the locks held here) but still feed the
        # call graph: a sort-key lambda executes inside the enclosing
        # call, so `min(key=lambda i: self._key(...))` must contribute
        # pop -> _key for the lock-order fixpoint.
        stack: List[Tuple[ast.AST, bool]] = [(expr, False)]
        while stack:
            node, in_lambda = stack.pop()
            if isinstance(node, ast.Lambda):
                in_lambda = True
            elif isinstance(node, ast.Attribute) and not in_lambda:
                self._check_guarded(node, held)
            elif isinstance(node, ast.Call):
                if in_lambda:
                    callee = self._callee_key(node)
                    if callee is not None:
                        self.info.calls.append(
                            (callee, tuple(h for h, _ in held),
                             node.lineno))
                else:
                    self._check_call(node, held)
            stack.extend((c, in_lambda)
                         for c in ast.iter_child_nodes(node))

    def _check_guarded(self, node: ast.Attribute, held: List[_HeldEntry]):
        attr = node.attr
        defs = self.model.guard_index.get(attr)
        if not defs:
            return
        base_str = ast.unparse(node.value)
        if base_str == "self":
            cands = [d for d in defs if d.cls == self.cls_key]
        else:
            cands = defs if len({d.lock_id for d in defs}) == 1 else []
        if len(cands) != 1:
            return
        d = cands[0]
        fn = self.info.node.name
        if fn == "__init__" or fn == d.created_in:
            return
        # the guard is satisfied when the SAME lock def is held, taken on
        # the same base object (`with self._lock:` covers `self._x`;
        # `with eng._stage_lock:` covers `eng._sched`); base expressions
        # are compared textually
        if any(h == d.lock_id and b == base_str for h, b in held):
            return
        self._emit("RA102", node.lineno,
                   f"`{base_str}.{attr}` (guarded by `{d.display}`) "
                   f"accessed outside `with {d.canonical}`")

    def _check_call(self, node: ast.Call, held: List[_HeldEntry]):
        # call-graph edges recorded regardless of held (fixpoint input)
        callee = self._callee_key(node)
        if callee is not None:
            self.info.calls.append(
                (callee, tuple(h for h, _ in held), node.lineno))
        if not held:
            return
        inner = self._lock_name(held[-1][0])
        f = node.func
        dotted = ast.unparse(f) if isinstance(f, (ast.Attribute,
                                                  ast.Name)) else ""
        if dotted in self.BLOCK_FUNCS:
            self._emit("RA103", node.lineno,
                       f"blocking `{dotted}(...)` while holding `{inner}`")
            return
        if not isinstance(f, ast.Attribute):
            return
        m = f.attr
        nargs, kw = len(node.args), {k.arg for k in node.keywords}
        has_timeout = "timeout" in kw and not any(
            k.arg == "timeout" and isinstance(k.value, ast.Constant)
            and k.value.value is None for k in node.keywords)
        if m in ("result", "get", "join", "item") and nargs == 0 \
                and not has_timeout:
            what = {"result": "Future.result()", "get": "queue.get()",
                    "join": "join()", "item": ".item() device sync"}[m]
            self._emit("RA103", node.lineno,
                       f"blocking `{what}` with no timeout while "
                       f"holding `{inner}`")
        elif m == "wait" and nargs == 0 and not has_timeout:
            self._emit("RA103", node.lineno,
                       f"blocking `.wait()` with no timeout while "
                       f"holding `{inner}`")
        elif m == "wait_for" and nargs <= 1 and not has_timeout:
            self._emit("RA103", node.lineno,
                       f"blocking `.wait_for()` with no timeout while "
                       f"holding `{inner}`")
        elif m == "block_until_ready" and nargs == 0:
            self._emit("RA103", node.lineno,
                       f"blocking `.block_until_ready()` while "
                       f"holding `{inner}`")

    def _callee_key(self, node: ast.Call) -> Optional[str]:
        f = node.func
        if isinstance(f, ast.Name):
            return f"{self.src.rel}::{f.id}"
        if isinstance(f, ast.Attribute):
            if isinstance(f.value, ast.Name) and f.value.id == "self" \
                    and self.cls_key is not None:
                return f"{self.cls_key}.{f.attr}"
            ck = self._expr_cls(f.value)
            if ck is not None:
                return f"{ck}.{f.attr}"
        return None


def check(files: List[SourceFile], model: LockModel) -> List[Finding]:
    findings: List[Finding] = []
    class_names: Dict[str, str] = {}
    funcs: Dict[str, _FuncInfo] = {}
    for src in files:
        for node in ast.walk(src.tree):
            if isinstance(node, ast.ClassDef):
                class_names[node.name] = f"{src.rel}::{node.name}"
    for src in files:
        for node in src.tree.body:
            if isinstance(node, ast.FunctionDef):
                key = f"{src.rel}::{node.name}"
                funcs[key] = _FuncInfo(key, None, node, src)
            elif isinstance(node, ast.ClassDef):
                cls_key = f"{src.rel}::{node.name}"
                for fn in node.body:
                    if isinstance(fn, ast.FunctionDef):
                        key = f"{cls_key}.{fn.name}"
                        funcs[key] = _FuncInfo(key, cls_key, fn, src)

    for info in funcs.values():
        _FuncWalker(info, model, class_names, findings).run()

    # `# held:` annotations also feed the call graph: calling an annotated
    # function means acquiring nothing, but a call made WHILE holding locks
    # into a function that acquires more is an ordering edge — fixpoint:
    eff: Dict[str, Set[str]] = {k: set(i.direct_acquires)
                                for k, i in funcs.items()}
    changed = True
    while changed:
        changed = False
        for k, info in funcs.items():
            for callee, _, _ in info.calls:
                extra = eff.get(callee)
                if extra and not extra <= eff[k]:
                    eff[k] |= extra
                    changed = True
    for info in funcs.values():
        for callee, held_ids, line in info.calls:
            if not held_ids:
                continue
            for b in eff.get(callee, ()):
                for a in held_ids:
                    if a == b:
                        d = model.locks[a]
                        if d.kind != "Lock":
                            continue       # reentrant re-acquire is fine
                    model.edges.setdefault((a, b), (info.src.rel, line))

    findings += _cycle_findings(model)
    return findings


def _cycle_findings(model: LockModel) -> List[Finding]:
    """One RA101 per strongly-connected component with a cycle."""
    nodes = sorted({n for e in model.edges for n in e})
    index: Dict[str, int] = {}
    low: Dict[str, int] = {}
    on: Set[str] = set()
    stack: List[str] = []
    sccs: List[List[str]] = []
    counter = [0]
    adj = {n: sorted(y for (x, y) in model.edges if x == n) for n in nodes}

    def strongconnect(v: str):
        index[v] = low[v] = counter[0]
        counter[0] += 1
        stack.append(v)
        on.add(v)
        for w in adj[v]:
            if w not in index:
                strongconnect(w)
                low[v] = min(low[v], low[w])
            elif w in on:
                low[v] = min(low[v], index[w])
        if low[v] == index[v]:
            comp = []
            while True:
                w = stack.pop()
                on.discard(w)
                comp.append(w)
                if w == v:
                    break
            sccs.append(comp)

    for n in nodes:
        if n not in index:
            strongconnect(n)

    out: List[Finding] = []
    for comp in sccs:
        cyclic = len(comp) > 1 or (comp[0], comp[0]) in model.edges
        if not cyclic:
            continue
        names = sorted(model.locks[c].display for c in comp)
        witness = min((model.edges[(a, b)] for a in comp for b in comp
                       if (a, b) in model.edges),
                      key=lambda t: (t[0], t[1]))
        out.append(Finding("RA101", witness[0], witness[1],
                           "lock-order cycle: " + " <-> ".join(names)))
    return out
