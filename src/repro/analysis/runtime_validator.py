"""Runtime validators that cross-check the static model (ISSUE 6).

``LockOrderRecorder`` — patches the ``threading.Lock/RLock/Condition``
factories so every lock CREATED FROM repo code (the immediate caller
frame lives under ``src/repro``) is wrapped in a recording proxy. Each
acquisition while other locks are held records an order edge keyed by
the locks' creation sites (``file:line`` — exactly the lock ids of the
static ``LockModel``). ``check_against(model)`` then verifies (a) every
observed lock is statically known and (b) no observed edge reverses a
path in the merged static+observed graph (an actual-vs-predicted
lock-order inversion = latent deadlock).

``RecompileSentinel`` — snapshots the jit executable-cache size
(``PjitFunction._cache_size()``) of tracked callables; after a warmup
``mark()``, ``new_compiles()`` must stay empty through steady-state
decode (the zero-recompile acceptance criterion).

Both are debug instruments used by the test suite; production code never
imports them.
"""
from __future__ import annotations

import threading
import traceback
from typing import Dict, List, Optional, Set, Tuple

_FACTORIES = ("Lock", "RLock", "Condition")
_REAL = {name: getattr(threading, name) for name in _FACTORIES}


def _creation_site(root: str) -> Optional[Tuple[str, int]]:
    """(normalized file, line) of the nearest stack frame under `root`,
    or None when the lock is created by stdlib internals (Event, Queue,
    ...) — those are not part of the static model and stay unproxied."""
    stack = traceback.extract_stack()
    # skip this helper + the factory wrapper frames at the top
    for frame in reversed(stack[:-2]):
        posix = frame.filename.replace("\\", "/")
        idx = posix.find(root)
        if idx >= 0 and "/analysis/" not in posix[idx:]:
            return posix[idx:], frame.lineno
        if "/threading.py" in posix or "/queue.py" in posix \
                or "/concurrent/" in posix:
            return None
        # any non-repo frame between us and the factory means the lock
        # belongs to that library, not to repo code
        return None
    return None


class _LockProxy:
    """Recording wrapper around a real Lock/RLock. Delegates the private
    Condition protocol (`_is_owned`/`_release_save`/`_acquire_restore`)
    so ``threading.Condition(proxy)`` works, including RLock recursion
    save/restore around ``wait()``."""

    def __init__(self, real, site: Tuple[str, int],
                 recorder: "LockOrderRecorder"):
        self._real = real
        self.site = f"{site[0]}:{site[1]}"
        self._recorder = recorder

    def acquire(self, blocking=True, timeout=-1):
        ok = self._real.acquire(blocking, timeout)
        if ok:
            self._recorder._note_acquire(self)
        return ok

    def release(self):
        self._recorder._note_release(self)
        self._real.release()

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()
        return False

    def locked(self):
        return self._real.locked()

    # Condition protocol -------------------------------------------------
    def _is_owned(self):
        if hasattr(self._real, "_is_owned"):
            return self._real._is_owned()
        if self._real.acquire(False):
            self._real.release()
            return False
        return True

    def _release_save(self):
        self._recorder._note_release(self, full=True)
        if hasattr(self._real, "_release_save"):
            return self._real._release_save()
        self._real.release()
        return None

    def _acquire_restore(self, state):
        if hasattr(self._real, "_acquire_restore"):
            self._real._acquire_restore(state)
        else:
            self._real.acquire()
        self._recorder._note_acquire(self)


class LockOrderRecorder:
    """Context manager: record actual lock-acquisition order of every
    lock created by repo code while active."""

    def __init__(self, root: str = "src/repro"):
        self.root = root
        self.edges: Dict[Tuple[str, str], int] = {}   # (a, b) -> count
        self.sites: Set[str] = set()
        self._tls = threading.local()
        self._elock = _REAL["Lock"]()

    # -- factory patching ------------------------------------------------
    def __enter__(self):
        rec = self

        def make(kind):
            real_factory = _REAL[kind]

            def factory(*args, **kwargs):
                site = _creation_site(rec.root)
                if site is None:
                    return real_factory(*args, **kwargs)
                if kind == "Condition":
                    lock = args[0] if args else kwargs.get("lock")
                    if lock is None:
                        lock = _LockProxy(_REAL["RLock"](), site, rec)
                        rec.sites.add(lock.site)
                    return real_factory(lock)
                proxy = _LockProxy(real_factory(), site, rec)
                rec.sites.add(proxy.site)
                return proxy

            return factory

        for name in _FACTORIES:
            setattr(threading, name, make(name))
        return self

    def __exit__(self, *exc):
        for name in _FACTORIES:
            setattr(threading, name, _REAL[name])
        return False

    # -- recording -------------------------------------------------------
    def _held(self) -> List[List]:
        held = getattr(self._tls, "held", None)
        if held is None:
            held = self._tls.held = []
        return held

    def _note_acquire(self, proxy: _LockProxy):
        held = self._held()
        for entry in held:
            if entry[0] is proxy:
                entry[1] += 1          # reentrant re-acquire: no edge
                return
        if held:
            with self._elock:
                for entry in held:
                    if entry[0].site != proxy.site:
                        key = (entry[0].site, proxy.site)
                        self.edges[key] = self.edges.get(key, 0) + 1
        held.append([proxy, 1])

    def _note_release(self, proxy: _LockProxy, full: bool = False):
        held = self._held()
        for i in range(len(held) - 1, -1, -1):
            if held[i][0] is proxy:
                held[i][1] = 0 if full else held[i][1] - 1
                if held[i][1] <= 0:
                    held.pop(i)
                return

    # -- cross-check -----------------------------------------------------
    def check_against(self, model) -> List[str]:
        """Violations of the static lock model: unknown lock sites and
        observed edges that close a cycle with the static graph."""
        problems: List[str] = []
        observed = set(self.edges)
        static_sites = model.sites()
        for a, b in sorted(observed):
            for site in (a, b):
                if site not in static_sites:
                    problems.append(
                        f"runtime lock at {site} unknown to the static "
                        f"model (missing threading.* assignment "
                        f"discovery?)")
        # merged graph must be acyclic: an observed edge b->a closing a
        # static (or observed) path a->b is an ordering inversion
        merged = observed | model.edge_pairs()

        def has_path(graph, a, b):
            seen, stack = set(), [a]
            while stack:
                n = stack.pop()
                if n == b:
                    return True
                if n in seen:
                    continue
                seen.add(n)
                stack.extend(y for (x, y) in graph if x == n)
            return False

        for a, b in sorted(observed):
            if has_path(merged - {(a, b)}, b, a):
                problems.append(
                    f"lock-order inversion: observed {a} -> {b} but the "
                    f"graph already orders {b} before {a}")
        return sorted(set(problems))


class RecompileSentinel:
    """Jit cache-miss counter: track callables, ``mark()`` after warmup,
    then ``new_compiles()`` reports any steady-state retrace."""

    def __init__(self):
        self._fns: Dict[str, object] = {}
        self._base: Dict[str, int] = {}

    @staticmethod
    def _size(fn) -> Optional[int]:
        for probe in ("_cache_size",):
            f = getattr(fn, probe, None)
            if callable(f):
                try:
                    return int(f())
                except Exception:       # pragma: no cover
                    pass
        return None

    def track(self, name: str, fn) -> bool:
        if fn is None or self._size(fn) is None:
            return False
        self._fns[name] = fn
        self._base[name] = self._size(fn)
        return True

    def track_engine(self, engine) -> List[str]:
        """Track every jitted callable a continuous engine owns."""
        tracked = []
        for attr in ("_step_fn", "_refill_fn", "_splice_fn", "_snap_fn",
                     "_restore_fn", "_write_adapter_fn", "_prefill_fn",
                     "_first_fn"):
            if self.track(attr, getattr(engine, attr, None)):
                tracked.append(attr)
        kern = getattr(engine, "_prefill_kernels", None)
        if kern is not None:
            for attr in ("whole", "chunk", "finish"):
                if self.track(f"prefill.{attr}", getattr(kern, attr, None)):
                    tracked.append(f"prefill.{attr}")
        return tracked

    def mark(self):
        for name, fn in self._fns.items():
            self._base[name] = self._size(fn)

    def new_compiles(self) -> Dict[str, int]:
        out = {}
        for name, fn in self._fns.items():
            delta = (self._size(fn) or 0) - self._base[name]
            if delta > 0:
                out[name] = delta
        return out

    def cache_sizes(self) -> Dict[str, int]:
        return {name: self._size(fn) or 0
                for name, fn in self._fns.items()}
