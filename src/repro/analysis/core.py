"""Shared analysis infrastructure: source loading, findings, suppression
and the committed baseline.

A ``Finding`` is keyed for baseline purposes by ``(rule, file, message)``
— deliberately NOT by line number, so unrelated edits that shift code
don't invalidate the baseline. The baseline is a multiset: if the code
has two identical pre-existing findings and a third appears, the third is
NEW and fails ``--check``.

File paths are normalized to start at ``src/`` when they live under
``src/repro`` (stable keys regardless of the invoking cwd); paths outside
the tree (test fixtures) fall back to their basename.
"""
from __future__ import annotations

import ast
import io
import json
import re
import tokenize
from collections import Counter
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Set, Tuple

RULES: Dict[str, str] = {
    "RA101": "lock-order cycle (potential deadlock)",
    "RA102": "guarded attribute accessed outside its lock",
    "RA103": "blocking call while holding a lock",
    "RA105": "rec.record() phase is not a PHASE_INTENSITY literal",
    "RA106": "swallowed exception in a stage worker run() loop",
    "RA201": "Python control flow on a traced value in a jitted function",
    "RA202": "host sync on a traced value in a jitted function",
    "RA203": "mutation of captured state in a jitted function",
    "RA204": "jit call-site recompile hazard (unbucketed dynamic shape)",
    "RA301": "pallas index_map arity vs grid/scalar-prefetch mismatch",
    "RA302": "pallas index_map rank / ref index vs block shape mismatch",
    "RA303": "pallas kernel/invocation arity or scalar-prefetch order",
}

_NOQA_RE = re.compile(r"noqa(?::\s*(RA\d+(?:\s*,\s*RA\d+)*))?", re.I)


@dataclass(frozen=True)
class Finding:
    rule: str
    file: str          # normalized path (see normalize_rel)
    line: int
    message: str

    @property
    def key(self) -> Tuple[str, str, str]:
        return (self.rule, self.file, self.message)

    def format(self) -> str:
        return f"{self.file}:{self.line}: {self.rule} {self.message}"


def normalize_rel(path: Path) -> str:
    posix = path.resolve().as_posix()
    idx = posix.find("src/repro/")
    if idx >= 0:
        return posix[idx:]
    return path.name


@dataclass
class SourceFile:
    path: Path
    rel: str
    text: str
    tree: ast.Module
    comments: Dict[int, str]       # line -> comment text (sans leading '#')
    comment_only: Set[int]         # lines that hold ONLY a comment
    noqa: Dict[int, Set[str]]      # line -> suppressed rule ids ({'*'}=all)

    def comment_at(self, line: int) -> str:
        """Comment on `line`, plus any immediately-following comment-only
        continuation lines (multi-line annotations)."""
        parts = []
        if line in self.comments:
            parts.append(self.comments[line])
            nxt = line + 1
            while nxt in self.comment_only:
                parts.append(self.comments[nxt])
                nxt += 1
        return " ".join(parts)


def _extract_comments(text: str):
    comments: Dict[int, str] = {}
    comment_only: Set[int] = set()
    code_lines: Set[int] = set()
    try:
        toks = list(tokenize.generate_tokens(io.StringIO(text).readline))
    except (tokenize.TokenError, IndentationError):  # pragma: no cover
        return comments, comment_only
    for tok in toks:
        if tok.type == tokenize.COMMENT:
            comments[tok.start[0]] = tok.string.lstrip("#").strip()
        elif tok.type not in (tokenize.NL, tokenize.NEWLINE,
                              tokenize.INDENT, tokenize.DEDENT,
                              tokenize.ENDMARKER):
            for ln in range(tok.start[0], tok.end[0] + 1):
                code_lines.add(ln)
    comment_only.update(ln for ln in comments if ln not in code_lines)
    return comments, comment_only


def _extract_noqa(comments: Dict[int, str]) -> Dict[int, Set[str]]:
    noqa: Dict[int, Set[str]] = {}
    for ln, text in comments.items():
        m = _NOQA_RE.search(text)
        if not m:
            continue
        if m.group(1):
            noqa[ln] = {r.strip().upper() for r in m.group(1).split(",")}
        else:
            noqa[ln] = {"*"}
    return noqa


def load_source(path: Path) -> Optional[SourceFile]:
    try:
        text = path.read_text()
        tree = ast.parse(text)
    except (SyntaxError, UnicodeDecodeError, OSError):
        return None
    comments, comment_only = _extract_comments(text)
    return SourceFile(path=path, rel=normalize_rel(path), text=text,
                      tree=tree, comments=comments,
                      comment_only=comment_only,
                      noqa=_extract_noqa(comments))


def collect_files(paths: Iterable[str]) -> List[SourceFile]:
    seen: Set[Path] = set()
    out: List[SourceFile] = []
    for p in paths:
        root = Path(p)
        files = sorted(root.rglob("*.py")) if root.is_dir() else [root]
        for f in files:
            f = f.resolve()
            if f in seen or "__pycache__" in f.parts:
                continue
            seen.add(f)
            src = load_source(f)
            if src is not None:
                out.append(src)
    return out


def _suppressed(finding: Finding, src: SourceFile) -> bool:
    rules = src.noqa.get(finding.line)
    return bool(rules) and ("*" in rules or finding.rule in rules)


def analyze_paths(paths: Iterable[str]):
    """Run every checker family; returns (findings, lock_model).

    ``lock_model`` is the cross-module lock graph (``locks.LockModel``)
    the runtime validator cross-checks against."""
    from . import locks, pallas_rules, phases, robustness, tracing

    files = collect_files(paths)
    model = locks.build_model(files)
    findings: List[Finding] = []
    findings += locks.check(files, model)
    findings += tracing.check(files)
    findings += pallas_rules.check(files)
    findings += phases.check(files)
    findings += robustness.check(files)
    by_rel = {f.rel: f for f in files}
    findings = [f for f in findings
                if f.file not in by_rel or not _suppressed(f, by_rel[f.file])]
    findings.sort(key=lambda f: (f.file, f.line, f.rule, f.message))
    return findings, model


# -- baseline ------------------------------------------------------------

def default_baseline_path() -> Path:
    return Path(__file__).parent / "baseline.json"


def load_baseline(path: Optional[Path] = None) -> Counter:
    path = path or default_baseline_path()
    if not path.exists():
        return Counter()
    data = json.loads(path.read_text())
    base: Counter = Counter()
    for e in data.get("findings", []):
        base[(e["rule"], e["file"], e["message"])] += int(e.get("count", 1))
    return base


def write_baseline(findings: List[Finding],
                   path: Optional[Path] = None) -> Path:
    path = path or default_baseline_path()
    counts = Counter(f.key for f in findings)
    entries = [{"rule": r, "file": f, "message": m, "count": c}
               for (r, f, m), c in sorted(counts.items())]
    path.write_text(json.dumps({"version": 1, "findings": entries},
                               indent=2) + "\n")
    return path


def diff_against_baseline(findings: List[Finding],
                          baseline: Counter) -> List[Finding]:
    """Findings NOT covered by the baseline multiset (the --check gate)."""
    budget = Counter(baseline)
    new: List[Finding] = []
    for f in findings:
        if budget[f.key] > 0:
            budget[f.key] -= 1
        else:
            new.append(f)
    return new
