"""Project-specific static analysis for the MARLaaS repro (ISSUE 6).

Three AST-based checker families over ``src/``:

  RA1xx  lock discipline   (``analysis/locks.py``) + metrics phase
         literals (RA105, ``analysis/phases.py``)
  RA2xx  JAX trace hygiene (``analysis/tracing.py``)
  RA3xx  Pallas kernels    (``analysis/pallas_rules.py``)

plus a runtime validator (``analysis/runtime_validator.py``) that records
actual lock-acquisition order during tests and counts jit cache misses.

Run ``python -m repro.analysis --check src/`` (the CI gate) or see
``analysis/README.md`` for rule ids, the ``# guards:`` / ``# held:``
annotation conventions, ``# noqa: RA###`` suppression and baseline
regeneration.
"""
from .core import (  # noqa: F401
    Finding,
    RULES,
    analyze_paths,
    default_baseline_path,
    diff_against_baseline,
    load_baseline,
    write_baseline,
)
from .runtime_validator import (  # noqa: F401
    LockOrderRecorder,
    RecompileSentinel,
)
