"""Mamba2 (SSD — state-space duality) block: chunked train/prefill scan +
O(1) single-token decode. arXiv:2405.21060.

Layout: x/dt/B/C are produced by one fused in_proj; a depthwise causal conv
runs over (x, B, C) channels; the SSD core mixes intra-chunk (quadratic,
attention-like) and inter-chunk (recurrent) terms; output is gated-RMSNormed
and projected back.

State carried between calls (decode / chunk boundaries):
  ssm_state  [B, H, N, P]   (per-head state × headdim)
  conv_state [B, conv_dim, W-1]
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig, SSMConfig
from .common import LoraCtx, dense_init, proj, rmsnorm


class MambaParams(NamedTuple):
    in_proj: jax.Array      # [d, 2*d_in + 2*G*N + H]
    conv_w: jax.Array       # [conv_dim, W] depthwise
    conv_b: jax.Array       # [conv_dim]
    dt_bias: jax.Array      # [H]
    a_log: jax.Array        # [H]
    d_skip: jax.Array       # [H]
    norm_w: jax.Array       # [d_in] gated RMSNorm
    out_proj: jax.Array     # [d_in, d]


def dims(cfg: ModelConfig) -> Tuple[int, int, int, int, int]:
    s = cfg.ssm
    d_in = s.d_inner(cfg.d_model)
    H = s.num_heads(cfg.d_model)
    conv_dim = d_in + 2 * s.n_groups * s.state_dim
    return d_in, H, s.state_dim, s.n_groups, conv_dim


def mamba_init(key, cfg: ModelConfig, dtype) -> MambaParams:
    s = cfg.ssm
    d = cfg.d_model
    d_in, H, N, G, conv_dim = dims(cfg)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    proj_cols = 2 * d_in + 2 * G * N + H
    # dt bias st. softplus(bias) spans [dt_min, dt_max]
    u = jax.random.uniform(k3, (H,), jnp.float32)
    dt = jnp.exp(u * (jnp.log(s.dt_max) - jnp.log(s.dt_min)) + jnp.log(s.dt_min))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))                  # inv softplus
    return MambaParams(
        in_proj=dense_init(k1, d, proj_cols, dtype),
        conv_w=(jax.random.normal(k2, (conv_dim, s.conv_width), jnp.float32)
                * (1.0 / jnp.sqrt(s.conv_width))).astype(dtype),
        conv_b=jnp.zeros((conv_dim,), dtype),
        dt_bias=dt_bias.astype(jnp.float32),
        a_log=jnp.log(jax.random.uniform(k4, (H,), jnp.float32, 1.0, 16.0)),
        d_skip=jnp.ones((H,), jnp.float32),
        norm_w=jnp.zeros((d_in,), dtype),
        out_proj=dense_init(jax.random.fold_in(k1, 7), d_in, d, dtype),
    )


def _ssd_bf16() -> bool:
    import os
    return os.environ.get("REPRO_SSD_BF16", "0") == "1"


def _segsum(dA):
    """log-decay matrix: out[..., i, j] = sum_{j<k<=i} dA[..., k], -inf for j>i.
    dA: [..., Q] -> [..., Q, Q]."""
    Q = dA.shape[-1]
    cs = jnp.cumsum(dA, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]               # i,j -> cs_i - cs_j
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(mask, diff, -jnp.inf)


def _causal_conv_train(xbc, w, b, W: int, conv_state=None, seq_lens=None):
    """Depthwise causal conv. xbc: [B, S, ch]; w: [ch, W].
    conv_state: [B, ch, W-1] history (prefill continuation) or None.
    seq_lens: [B] true row lengths — the returned state is the window
    ending at each row's OWN last real token, not at the padded tail."""
    B, S, ch = xbc.shape
    x = xbc.transpose(0, 2, 1)                               # [B, ch, S]
    if conv_state is None:
        pad = jnp.zeros((B, ch, W - 1), x.dtype)
    else:
        pad = conv_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=-1)                  # [B, ch, S+W-1]
    # sliding window dot with depthwise filter
    out = jnp.zeros((B, ch, S), jnp.float32)
    for i in range(W):                                       # W is 4: unroll
        out = out + xp[:, :, i:i + S].astype(jnp.float32) * w[:, i][None, :, None].astype(jnp.float32)
    out = out + b[None, :, None].astype(jnp.float32)
    if seq_lens is None:
        new_state = xp[:, :, -(W - 1):]
    else:
        # real input j sits at xp column W-1+j, so the last W-1 real inputs
        # of a length-L row are columns [L, L+W-1)
        idx = seq_lens[:, None, None] + jnp.arange(W - 1)[None, None, :]
        new_state = jnp.take_along_axis(
            xp, jnp.broadcast_to(idx, (B, ch, W - 1)).astype(jnp.int32),
            axis=-1)
    return jax.nn.silu(out).astype(xbc.dtype).transpose(0, 2, 1), new_state


def ssd_chunked(x, dt, A, B_, C_, chunk: int, init_state=None):
    """SSD core. x: [B,S,H,P]; dt: [B,S,H] (post-softplus); A: [H] (negative);
    B_/C_: [B,S,G,N]. Returns (y [B,S,H,P], final_state [B,H,N,P])."""
    Bsz, S, H, P = x.shape
    G, N = B_.shape[2], B_.shape[3]
    rep = H // G
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C_ = jnp.pad(C_, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = S + pad
    nc = Sp // chunk

    def tohead(t):  # [B,S,G,N] -> [B,nc,Q,H,N]
        t = jnp.repeat(t, rep, axis=2)
        return t.reshape(Bsz, nc, chunk, H, N)

    # the intra-chunk [Q,Q] temporaries dominate SSD training memory; bf16
    # operands with fp32 accumulation halve them (§Perf C4) — decay/state
    # math stays fp32 (it exponentiates)
    cdt = jnp.bfloat16 if _ssd_bf16() else jnp.float32
    xc = x.reshape(Bsz, nc, chunk, H, P).astype(jnp.float32)
    dtc = dt.reshape(Bsz, nc, chunk, H).astype(jnp.float32)
    Bh, Ch = tohead(B_).astype(jnp.float32), tohead(C_).astype(jnp.float32)

    dA = dtc * A[None, None, None, :]                        # [B,nc,Q,H]
    dA_cs = jnp.cumsum(dA, axis=2)

    # intra-chunk (diagonal) term
    L = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))           # [B,nc,H,Q,Q]
    att = jnp.einsum("bnqhN,bnkhN->bnhqk", Ch.astype(cdt), Bh.astype(cdt),
                     preferred_element_type=jnp.float32) * L
    xdt = xc * dtc[..., None]
    y_diag = jnp.einsum("bnhqk,bnkhp->bnqhp", att.astype(cdt),
                        xdt.astype(cdt),
                        preferred_element_type=jnp.float32)

    # chunk boundary states
    decay_to_end = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)      # [B,nc,Q,H]
    states = jnp.einsum("bnkhN,bnkh,bnkhp->bnhNp", Bh, dtc * decay_to_end, xc)

    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])                # [B,nc,H]
    s0 = (jnp.zeros((Bsz, H, N, P), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def scan_fn(s_prev, inp):
        st, dec = inp                                        # [B,H,N,P], [B,H]
        s_new = s_prev * dec[:, :, None, None] + st
        return s_new, s_prev                                 # emit *entering* state

    states_t = states.transpose(1, 0, 2, 3, 4)               # [nc,B,H,N,P]
    decay_t = chunk_decay.transpose(1, 0, 2)                 # [nc,B,H]
    final, prev_states = jax.lax.scan(scan_fn, s0, (states_t, decay_t))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)       # [B,nc,H,N,P]

    # inter-chunk (off-diagonal) term
    y_off = jnp.einsum("bnqhN,bnhNp,bnqh->bnqhp", Ch, prev_states,
                       jnp.exp(dA_cs))
    y = (y_diag + y_off).reshape(Bsz, Sp, H, P)[:, :S]
    return y.astype(x.dtype), final


def mamba_block(x, p: MambaParams, cfg: ModelConfig,
                lora: Optional[LoraCtx] = None,
                ssm_state=None, conv_state=None, return_state: bool = False,
                seq_lens=None):
    """Full Mamba2 block over a sequence. x: [B, S, d].

    seq_lens [B] (prefill of a mixed-length batch): positions >= the row's
    true length become state no-ops (dt = 0 ⇒ decay 1, zero injection) and
    the conv state is taken at the row's own last real token, so the
    returned states equal an unpadded per-row run exactly. Without it, pad
    tokens pollute the recurrent state of every row shorter than the
    padded width (outputs at real positions are unaffected either way —
    the recurrence is causal)."""
    s = cfg.ssm
    d_in, H, N, G, conv_dim = dims(cfg)
    B, S, _ = x.shape
    zxbcdt = proj(x, p.in_proj, lora=lora, name="ssm_in")
    z, xr, Bc, Cc, dt_raw = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + G * N, 2 * d_in + 2 * G * N], axis=-1)
    xbc = jnp.concatenate([xr, Bc, Cc], axis=-1)             # [B,S,conv_dim]
    xbc, new_conv = _causal_conv_train(xbc, p.conv_w, p.conv_b, s.conv_width,
                                       conv_state, seq_lens)
    xr, Bc, Cc = jnp.split(xbc, [d_in, d_in + G * N], axis=-1)
    xh = xr.reshape(B, S, H, s.head_dim)
    Bh = Bc.reshape(B, S, G, N)
    Ch = Cc.reshape(B, S, G, N)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p.dt_bias)
    if seq_lens is not None:
        dt = dt * (jnp.arange(S)[None, :, None]
                   < seq_lens[:, None, None]).astype(dt.dtype)
    A = -jnp.exp(p.a_log)
    y, final_state = ssd_chunked(xh, dt, A, Bh, Ch, s.chunk_size, ssm_state)
    y = y + xh.astype(jnp.float32).astype(y.dtype) * p.d_skip[None, None, :, None].astype(y.dtype)
    y = y.reshape(B, S, d_in)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                p.norm_w, cfg.norm_eps)
    out = proj(y, p.out_proj, lora=lora, name="ssm_out")
    if return_state:
        return out, (final_state, new_conv)
    return out


def mamba_decode_step(x, p: MambaParams, cfg: ModelConfig,
                      ssm_state, conv_state, lora: Optional[LoraCtx] = None):
    """One-token step. x: [B, d]; ssm_state: [B,H,N,P];
    conv_state: [B, conv_dim, W-1]. Returns (y [B,d], new states)."""
    s = cfg.ssm
    d_in, H, N, G, conv_dim = dims(cfg)
    B = x.shape[0]
    zxbcdt = proj(x, p.in_proj, lora=lora, name="ssm_in")
    z, xr, Bc, Cc, dt_raw = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + G * N, 2 * d_in + 2 * G * N], axis=-1)
    xbc = jnp.concatenate([xr, Bc, Cc], axis=-1)             # [B, conv_dim]
    # conv: history is conv_state [B, conv_dim, W-1]
    full = jnp.concatenate([conv_state.astype(xbc.dtype), xbc[:, :, None]], axis=-1)
    conv_out = jnp.einsum("bcw,cw->bc", full.astype(jnp.float32),
                          p.conv_w.astype(jnp.float32)) + p.conv_b.astype(jnp.float32)
    xbc_o = jax.nn.silu(conv_out).astype(xbc.dtype)
    new_conv = full[:, :, 1:]
    xr, Bc, Cc = jnp.split(xbc_o, [d_in, d_in + G * N], axis=-1)
    xh = xr.reshape(B, H, s.head_dim).astype(jnp.float32)
    Bh = jnp.repeat(Bc.reshape(B, G, N), H // G, axis=1).astype(jnp.float32)
    Ch = jnp.repeat(Cc.reshape(B, G, N), H // G, axis=1).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p.dt_bias)   # [B,H]
    A = -jnp.exp(p.a_log)
    dA = jnp.exp(dt * A[None, :])                            # [B,H]
    st = ssm_state.astype(jnp.float32)
    st = st * dA[:, :, None, None] + jnp.einsum(
        "bhN,bh,bhp->bhNp", Bh, dt, xh)
    y = jnp.einsum("bhN,bhNp->bhp", Ch, st)
    y = y + xh * p.d_skip[None, :, None]
    y = y.reshape(B, d_in).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                p.norm_w, cfg.norm_eps)
    out = proj(y, p.out_proj, lora=lora, name="ssm_out")
    return out, (st, new_conv)
