"""Dense MLP variants: swiglu (most archs), squared-ReLU (nemotron-4),
gelu (seamless)."""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig
from .common import LoraCtx, dense_init, proj


class MLPParams(NamedTuple):
    w_in: jax.Array                  # [d, ff] (up; or gate+up fused for swiglu)
    w_out: jax.Array                 # [ff, d]


def mlp_init(key, d: int, ff: int, act: str, dtype) -> MLPParams:
    k1, k2 = jax.random.split(key)
    in_cols = 2 * ff if act == "swiglu" else ff
    return MLPParams(w_in=dense_init(k1, d, in_cols, dtype),
                     w_out=dense_init(k2, ff, d, dtype))


def mlp_apply(x, p: MLPParams, act: str, lora: Optional[LoraCtx] = None,
              prefix: str = "mlp"):
    h = proj(x, p.w_in, lora=lora, name=f"{prefix}_in")
    if act == "swiglu":
        gate, up = jnp.split(h, 2, axis=-1)
        h = jax.nn.silu(gate) * up
    elif act == "squared_relu":
        h = jnp.square(jax.nn.relu(h))
    elif act == "gelu":
        h = jax.nn.gelu(h)
    else:
        raise ValueError(act)
    return proj(h, p.w_out, lora=lora, name=f"{prefix}_out")
