"""Model assembly: init / train-forward / prefill / decode for all families.

Families (configs.base): dense, moe, ssm (mamba2), hybrid (zamba2),
encdec (seamless backbone), vlm (chameleon — tokens only, early fusion).

Uniform stacks (dense/moe/ssm/vlm) lax.scan over a stacked layer axis so
compile time is O(1) in depth; heterogeneous stacks (hybrid, encdec cross)
use indexed python loops over stacked params.

The KV cache is a plain dict pytree (donate-able):
  k, v        [L_attn, B, Smax, KVH, hd]
  pos         [B] int32 — valid entries per row
  ssm, conv   [L_ssm, B, H, N, P], [L_ssm, B, conv_dim, W-1]
  xk, xv      [L, B, S_enc, KVH, hd]  (encdec cross-attention memory)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig
from repro.train.sharding import constrain
from .attention import (AttnParams, attention_chunked, attention_decode,
                        attention_decode_paged, attention_prefill_chunk,
                        attn_init, qkv)
from .common import (LoraCtx, dense_init, dtype_of, embed_init, proj, rmsnorm,
                     rmsnorm_init, softcap)
from .mamba2 import MambaParams, dims as ssm_dims, mamba_block, mamba_decode_step, mamba_init
from .mlp import MLPParams, mlp_apply, mlp_init
from .moe import MoEParams, moe_apply, moe_init

Params = Dict[str, Any]


# ===========================================================================
# init
# ===========================================================================

def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init_params(key, cfg: ModelConfig) -> Params:
    dt = dtype_of(cfg.dtype)
    keys = jax.random.split(key, cfg.num_layers + 8)
    p: Params = {"embed": embed_init(keys[0], cfg.vocab_size, cfg.d_model, dt),
                 "final_norm": rmsnorm_init(cfg.d_model, dt)}
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(keys[1], cfg.d_model, cfg.vocab_size, dt)

    def dense_layer(k):
        k1, k2 = jax.random.split(k)
        return {"ln1": rmsnorm_init(cfg.d_model, dt),
                "attn": attn_init(k1, cfg, dt),
                "ln2": rmsnorm_init(cfg.d_model, dt),
                "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.mlp_act, dt)}

    def moe_layer(k):
        k1, k2 = jax.random.split(k)
        return {"ln1": rmsnorm_init(cfg.d_model, dt),
                "attn": attn_init(k1, cfg, dt),
                "ln2": rmsnorm_init(cfg.d_model, dt),
                "moe": moe_init(k2, cfg, dt)}

    def mamba_layer(k):
        return {"ln1": rmsnorm_init(cfg.d_model, dt),
                "mamba": mamba_init(k, cfg, dt)}

    if cfg.family in ("dense", "vlm"):
        p["layers"] = _stack([dense_layer(keys[2 + i]) for i in range(cfg.num_layers)])
    elif cfg.family == "moe":
        p["layers"] = _stack([moe_layer(keys[2 + i]) for i in range(cfg.num_layers)])
    elif cfg.family == "ssm":
        p["layers"] = _stack([mamba_layer(keys[2 + i]) for i in range(cfg.num_layers)])
    elif cfg.family == "hybrid":
        p["layers"] = _stack([mamba_layer(keys[2 + i]) for i in range(cfg.num_layers)])
        ks = jax.random.split(keys[2 + cfg.num_layers], 2)
        p["shared"] = {"ln1": rmsnorm_init(cfg.d_model, dt),
                       "attn": attn_init(ks[0], cfg, dt),
                       "ln2": rmsnorm_init(cfg.d_model, dt),
                       "mlp": mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp_act, dt)}
    elif cfg.family == "encdec":
        enc = [dense_layer(jax.random.fold_in(keys[2], i)) for i in range(cfg.encoder_layers)]
        p["encoder"] = _stack(enc)

        def dec_layer(k):
            k1, k2, k3 = jax.random.split(k, 3)
            return {"ln1": rmsnorm_init(cfg.d_model, dt),
                    "attn": attn_init(k1, cfg, dt),
                    "lnx": rmsnorm_init(cfg.d_model, dt),
                    "xattn": attn_init(k2, cfg, dt),
                    "ln2": rmsnorm_init(cfg.d_model, dt),
                    "mlp": mlp_init(k3, cfg.d_model, cfg.d_ff, cfg.mlp_act, dt)}
        p["layers"] = _stack([dec_layer(keys[3 + i]) for i in range(cfg.num_layers)])
    else:
        raise ValueError(cfg.family)
    return p


# ===========================================================================
# cache
# ===========================================================================

def init_cache(cfg: ModelConfig, batch: int, max_len: int, *,
               enc_len: int = 0, dtype=None) -> Params:
    dt = dtype or dtype_of(cfg.dtype)
    c: Params = {"pos": jnp.zeros((batch,), jnp.int32)}
    n_attn = 0
    if cfg.family in ("dense", "moe", "vlm"):
        n_attn = cfg.num_layers
    elif cfg.family == "hybrid":
        n_attn = cfg.num_layers // cfg.hybrid_attn_every
    elif cfg.family == "encdec":
        n_attn = cfg.num_layers
        c["xk"] = jnp.zeros((cfg.num_layers, batch, enc_len, cfg.num_kv_heads,
                             cfg.head_dim), dt)
        c["xv"] = jnp.zeros_like(c["xk"])
    if n_attn:
        c["k"] = jnp.zeros((n_attn, batch, max_len, cfg.num_kv_heads,
                            cfg.head_dim), dt)
        c["v"] = jnp.zeros_like(c["k"])
    if cfg.ssm is not None:
        d_in, H, N, G, conv_dim = ssm_dims(cfg)
        c["ssm"] = jnp.zeros((cfg.num_layers, batch, H, N, cfg.ssm.head_dim),
                             jnp.float32)
        c["conv"] = jnp.zeros((cfg.num_layers, batch, conv_dim,
                               cfg.ssm.conv_width - 1), dt)
    return c


def _n_attn_layers(cfg: ModelConfig) -> int:
    if cfg.family in ("dense", "moe", "vlm", "encdec"):
        return cfg.num_layers
    if cfg.family == "hybrid":
        return cfg.num_layers // cfg.hybrid_attn_every
    return 0


def init_paged_cache(cfg: ModelConfig, batch: int, *, pool_pages: int,
                     page_size: int, max_pages_per_row: int,
                     dtype=None) -> Params:
    """Block-pool KV cache (ISSUE 5): instead of a dense
    ``[L, B, max_len, KVH, hd]`` reservation per slot, attention K/V live
    in a SHARED pool of ``pool_pages`` fixed-size pages
    (``kp``/``vp``: [L_attn, pool_pages+1, page, KVH, hd]) and each slot
    names its pages through a block table ``tbl: [B, max_pages_per_row]``.
    Physical page ``pool_pages`` is the scratch page: sentinel table
    entries (== pool_pages) route frozen/empty-lane writes and
    masked-anyway reads there, so no kernel needs bounds handling. The
    host-side allocator (rollout/kvcache.py) owns the free list; this
    function only lays out device memory. Recurrent SSM/conv state is
    per-row and fixed-size, so it stays dense exactly as in
    ``init_cache``. ``encdec`` is not paged (cross-attention memory is
    write-once; use the dense cache)."""
    if cfg.family == "encdec":
        raise ValueError("paged KV cache unsupported for encdec")
    dt = dtype or dtype_of(cfg.dtype)
    c: Params = {"pos": jnp.zeros((batch,), jnp.int32)}
    n_attn = _n_attn_layers(cfg)
    if n_attn:
        c["tbl"] = jnp.full((batch, max_pages_per_row), pool_pages,
                            jnp.int32)
        c["kp"] = jnp.zeros((n_attn, pool_pages + 1, page_size,
                             cfg.num_kv_heads, cfg.head_dim), dt)
        c["vp"] = jnp.zeros_like(c["kp"])
    if cfg.ssm is not None:
        d_in, H, N, G, conv_dim = ssm_dims(cfg)
        c["ssm"] = jnp.zeros((cfg.num_layers, batch, H, N, cfg.ssm.head_dim),
                             jnp.float32)
        c["conv"] = jnp.zeros((cfg.num_layers, batch, conv_dim,
                               cfg.ssm.conv_width - 1), dt)
    return c


def _decode_write_mode() -> str:
    """"where" (mesh-agnostic merge) or "scatter" (in-place; requires the
    cache S dim unsharded — the serve mesh guarantees it)."""
    import os
    return os.environ.get("REPRO_DECODE_WRITE", "where")


def _write_kv(ck, cv, k_new, v_new, pos):
    """Write [B, S, KVH, hd] (or S=1) at per-row offsets `pos` ([B]).

    Decode path uses an elementwise masked merge instead of a per-row
    scatter: a scatter at data-dependent rows forces GSPMD to fully
    rematerialize (replicate) the sequence-sharded cache every layer
    (≈11× HBM overshoot measured — EXPERIMENTS.md §Perf iter A1), while the
    where-merge partitions exactly along the existing cache sharding."""
    B, S = k_new.shape[0], k_new.shape[1]
    if S == 1:
        if _decode_write_mode() == "scatter":
            # shard-aligned in-place write: correct choice when the cache's
            # S dim is UNSHARDED (serve mesh, tp | kv_heads) — touches only
            # [B, 1, KVH, hd] instead of rewriting the cache (§Perf A4)
            b_idx = jnp.arange(B)
            ck = ck.at[b_idx, pos].set(k_new[:, 0].astype(ck.dtype))
            cv = cv.at[b_idx, pos].set(v_new[:, 0].astype(cv.dtype))
            return ck, cv
        Smax = ck.shape[1]
        hit = (jnp.arange(Smax)[None, :] == pos[:, None])[:, :, None, None]
        ck = jnp.where(hit, k_new.astype(ck.dtype), ck)
        cv = jnp.where(hit, v_new.astype(cv.dtype), cv)
    else:  # prefill from 0 (right-padded prompts)
        ck = jax.lax.dynamic_update_slice(ck, k_new.astype(ck.dtype), (0, 0, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v_new.astype(cv.dtype), (0, 0, 0, 0))
    return ck, cv


# ===========================================================================
# layer bodies
# ===========================================================================

def _window_for(cfg: ModelConfig, layer_idx):
    """Static per-layer sliding windows as an array (scan-friendly);
    0 = global."""
    if not cfg.local_global_period or not cfg.sliding_window:
        return None
    import numpy as np
    w = np.array([0 if cfg.is_global_attn_layer(i) else cfg.sliding_window
                  for i in range(cfg.num_layers)], np.int32)
    return jnp.asarray(w)


def _dense_block_seq(x, lp, cfg, lora, window, positions, q_chunk, causal=True):
    h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
    q, k, v = qkv(h, lp["attn"], cfg, positions, lora)
    o = attention_chunked(q, k, v, cfg, causal=causal,
                          window=window, q_chunk=q_chunk)
    o = o.reshape(x.shape[0], x.shape[1], cfg.q_dim)
    x = x + proj(o, lp["attn"].wo, lora=lora, name="attn_o")
    h = rmsnorm(x, lp["ln2"], cfg.norm_eps)
    if "moe" in lp:
        y, aux = moe_apply(h, lp["moe"], cfg, lora)
    else:
        y, aux = mlp_apply(h, lp["mlp"], cfg.mlp_act, lora), 0.0
    return x + y, (k, v), aux


def _dense_block_decode(x, lp, cfg, lora, window, ck, cv, pos,
                        use_kernel=False):
    """x: [B, d] one token; ck/cv: [B, Smax, KVH, hd]."""
    B = x.shape[0]
    h = rmsnorm(x, lp["ln1"], cfg.norm_eps)[:, None, :]      # [B,1,d]
    q, k, v = qkv(h, lp["attn"], cfg, pos[:, None], lora)
    ck, cv = _write_kv(ck, cv, k, v, pos)
    o = attention_decode(q[:, 0], ck, cv, pos + 1, cfg, window=window,
                         use_kernel=use_kernel)
    o = o.reshape(B, cfg.q_dim)
    x = x + proj(o, lp["attn"].wo, lora=lora, name="attn_o")
    h = rmsnorm(x, lp["ln2"], cfg.norm_eps)
    if "moe" in lp:
        y, _ = moe_apply(h[:, None, :], lp["moe"], cfg, lora)
        y = y[:, 0]
    else:
        y = mlp_apply(h, lp["mlp"], cfg.mlp_act, lora)
    return x + y, ck, cv


def _paged_block_decode(x, lp, cfg, lora, window, kp, vp, tbl, pos,
                        use_kernel=False):
    """Paged twin of ``_dense_block_decode``: x: [B, d] one token; kp/vp:
    [n_pages+1, page, KVH, hd] (this layer's slice of the shared pool);
    tbl: [B, max_pages]. The token's K/V scatters into physical page
    ``tbl[b, pos // page]`` at offset ``pos % page`` — frozen/empty lanes
    whose table entry is the sentinel scatter into the scratch page, which
    is never validly read."""
    B = x.shape[0]
    page = kp.shape[1]
    h = rmsnorm(x, lp["ln1"], cfg.norm_eps)[:, None, :]      # [B,1,d]
    q, k, v = qkv(h, lp["attn"], cfg, pos[:, None], lora)
    pidx = jnp.take_along_axis(tbl, (pos // page)[:, None], axis=1)[:, 0]
    kp = kp.at[pidx, pos % page].set(k[:, 0].astype(kp.dtype))
    vp = vp.at[pidx, pos % page].set(v[:, 0].astype(vp.dtype))
    o = attention_decode_paged(q[:, 0], kp, vp, tbl, pos + 1, cfg,
                               window=window, use_kernel=use_kernel)
    o = o.reshape(B, cfg.q_dim)
    x = x + proj(o, lp["attn"].wo, lora=lora, name="attn_o")
    h = rmsnorm(x, lp["ln2"], cfg.norm_eps)
    if "moe" in lp:
        y, _ = moe_apply(h[:, None, :], lp["moe"], cfg, lora)
        y = y[:, 0]
    else:
        y = mlp_apply(h, lp["mlp"], cfg.mlp_act, lora)
    return x + y, kp, vp


# ===========================================================================
# sequence forward (train / prefill) — returns hidden states (+ cache)
# ===========================================================================

def _lora_layer_slice(lora: Optional[LoraCtx], i=None, sub="layers"):
    """Adapter slices for the per-layer subtree ("layers") or the hybrid
    shared block ("shared"). `i=None` keeps the stacked tree (scan xs).
    Leaves are [L, (T,) d, r]; `leaf[i]` works for both single and batched
    modes because the task dim sits on axis 1 (see lora.adapters)."""
    if lora is None or lora.mode == "off" or not lora.tree:
        return None
    tree = lora.tree.get(sub)
    if not tree:
        return None
    if i is not None:
        tree = jax.tree.map(lambda t: t[i], tree)
    return tree


def forward_seq(params: Params, tokens, cfg: ModelConfig,
                lora: Optional[LoraCtx] = None, cache: Optional[Params] = None,
                *, enc_embeds=None, q_chunk: int = 512,
                inputs_embeds=None,
                seq_lens=None) -> Tuple[jax.Array, Optional[Params], jax.Array]:
    """Full-sequence forward. Returns (hidden [B,S,d], cache', aux_loss).

    - train: cache=None
    - prefill: cache provided; K/V written; cache["pos"] must be set by caller
      afterwards (per-row prompt lengths). For recurrent families
      (ssm/hybrid) pass `seq_lens` [B] too: the returned ssm/conv states are
      then exact at each row's true length instead of absorbing pad-token
      contributions out to the padded width (attention K/V needs no mask —
      reads beyond `pos` never happen and decode overwrites in place).
    """
    B, S = tokens.shape[:2] if tokens is not None else inputs_embeds.shape[:2]
    if inputs_embeds is None:
        x = params["embed"][tokens]                          # [B,S,d]
        if cfg.family == "encdec":
            pass
    else:
        x = inputs_embeds
    # NOTE: no activation constraint here — batch sharding propagates from
    # the dp-sharded token array, and a with_sharding_constraint inside the
    # (remat'd, microbatch-scanned) region trips a GSPMD dynamic-slice bug
    # (see EXPERIMENTS.md §Dry-run).
    positions = jnp.arange(S)[None, :]
    windows = _window_for(cfg, None)
    aux_total = jnp.zeros((), jnp.float32)

    want_cache = cache is not None

    if cfg.family in ("dense", "moe", "vlm", "encdec"):
        enc_memory = None
        if cfg.family == "encdec":
            enc_memory = _encode(params, enc_embeds, cfg, q_chunk)

        def body(carry, xs):
            x, aux = carry
            lp, lora_i, win = xs["lp"], xs.get("lora"), xs.get("win")
            lctx = lora.at_layer(lora_i) if (lora is not None and lora_i is not None) else None
            w = win if win is not None else 0
            xo, (k, v), a = _dense_block_seq(x, lp, cfg, lctx, w, positions,
                                             q_chunk)
            if cfg.family == "encdec":
                xo = _cross_attn_seq(xo, lp, cfg, enc_memory, q_chunk)
            ys = (k, v) if want_cache else None
            return (xo, aux + a), ys

        xs = {"lp": params["layers"]}
        lt = _lora_layer_slice(lora)
        if lt is not None:
            xs["lora"] = lt
        if windows is not None:
            xs["win"] = windows
        scan_body = body
        if cfg.remat:
            scan_body = jax.checkpoint(body)
        blk = cfg.remat_block
        if (cfg.scan_layers and blk and not want_cache
                and cfg.num_layers % blk == 0):
            # two-level remat (§Perf B2): outer scan over L/blk blocks with
            # block-level checkpoint stores only L/blk layer inputs instead
            # of L; the block backward recomputes its inner scan (which
            # re-remats per layer) — memory ÷blk for one extra forward.
            xs_blocked = jax.tree.map(
                lambda t: t.reshape((cfg.num_layers // blk, blk)
                                    + t.shape[1:]), xs)

            @jax.checkpoint
            def block_body(carry, xs_b):
                return jax.lax.scan(scan_body, carry, xs_b)

            (x, aux_total), _ = jax.lax.scan(block_body, (x, aux_total),
                                             xs_blocked)
        elif cfg.scan_layers:
            (x, aux_total), ys = jax.lax.scan(scan_body, (x, aux_total), xs)
            if want_cache:
                ks, vs = ys
        else:
            ks_l, vs_l = [], []
            for i in range(cfg.num_layers):
                xi = jax.tree.map(lambda t: t[i], xs)
                (x, aux_total), ys = scan_body((x, aux_total), xi)
                if want_cache:
                    ks_l.append(ys[0])
                    vs_l.append(ys[1])
            if want_cache:
                ks, vs = jnp.stack(ks_l), jnp.stack(vs_l)
        if want_cache:
            Smax = cache["k"].shape[2]
            ck, cv = cache["k"], cache["v"]
            ck = jax.lax.dynamic_update_slice(ck, ks.astype(ck.dtype), (0, 0, 0, 0, 0))
            cv = jax.lax.dynamic_update_slice(cv, vs.astype(cv.dtype), (0, 0, 0, 0, 0))
            cache = dict(cache, k=ck, v=cv)
            if cfg.family == "encdec":
                cache = _encdec_fill_cross_cache(params, cache, enc_memory, cfg)

    elif cfg.family == "ssm":
        def body(carry, xs):
            x = carry
            lp, lora_i = xs["lp"], xs.get("lora")
            lctx = lora.at_layer(lora_i) if (lora is not None and lora_i is not None) else None
            h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
            y, (st, cs) = mamba_block(h, lp["mamba"], cfg, lctx,
                                      return_state=True, seq_lens=seq_lens)
            ys = (st, cs) if want_cache else None
            return x + y, ys

        xs = {"lp": params["layers"]}
        lt = _lora_layer_slice(lora)
        if lt is not None:
            xs["lora"] = lt
        scan_body = jax.checkpoint(body) if cfg.remat else body
        if cfg.scan_layers:
            x, ys = jax.lax.scan(scan_body, x, xs)
            if want_cache:
                sts, css = ys
        else:
            sts_l, css_l = [], []
            for i in range(cfg.num_layers):
                xi = jax.tree.map(lambda t: t[i], xs)
                x, ys = scan_body(x, xi)
                if want_cache:
                    sts_l.append(ys[0]); css_l.append(ys[1])
            if want_cache:
                sts, css = jnp.stack(sts_l), jnp.stack(css_l)
        if want_cache:
            cache = dict(cache, ssm=sts.astype(cache["ssm"].dtype),
                         conv=css.astype(cache["conv"].dtype))

    elif cfg.family == "hybrid" and cfg.scan_layers and not want_cache:
        # grouped scan (§Perf C1): layers [G·k + tail] scan over G groups of
        # (k mamba blocks + the shared attention block). Compile-time O(1)
        # in depth (vs 17-min unrolled compiles) and the group-level remat
        # collapses the unrolled loop's concurrently-live SSD temporaries.
        k_every = cfg.hybrid_attn_every
        G = cfg.num_layers // k_every
        tail = cfg.num_layers - G * k_every
        lt_all = _lora_layer_slice(lora)          # [L, ...] stacked or None
        slt_all = _lora_layer_slice(lora, sub="shared")

        def take(tree, lo, hi):
            return jax.tree.map(lambda t: t[lo:hi], tree) \
                if tree is not None else None

        def reshape_groups(tree, n, k):
            return jax.tree.map(
                lambda t: t[: n * k].reshape((n, k) + t.shape[1:]), tree) \
                if tree is not None else None

        def mamba_one(x, lp, lt):
            lctx = lora.at_layer(lt) if lt is not None else None
            h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
            y, _ = mamba_block(h, lp["mamba"], cfg, lctx, return_state=True)
            return x + y, None

        def group_body(x, xs_g):
            x, _ = jax.lax.scan(
                lambda c, xg: mamba_one(c, xg["lp"], xg.get("lora")),
                x, xs_g["inner"])
            slctx = (lora.at_layer(xs_g["slora"])
                     if xs_g.get("slora") is not None else None)
            x, _, _ = _dense_block_seq(x, params["shared"], cfg, slctx, 0,
                                       positions, q_chunk)
            return x, None

        if cfg.remat:
            group_body = jax.checkpoint(group_body)
        xs_g = {"inner": {"lp": reshape_groups(params["layers"], G, k_every)}}
        if lt_all is not None:
            xs_g["inner"]["lora"] = reshape_groups(lt_all, G, k_every)
        if slt_all is not None:
            xs_g["slora"] = jax.tree.map(lambda t: t[:G], slt_all)
        x, _ = jax.lax.scan(group_body, x, xs_g)
        if tail:
            def tail_body(c, xg):
                return mamba_one(c, xg["lp"], xg.get("lora"))
            tail_xs = {"lp": take(params["layers"], G * k_every,
                                  cfg.num_layers)}
            if lt_all is not None:
                tail_xs["lora"] = take(lt_all, G * k_every, cfg.num_layers)
            tb = jax.checkpoint(tail_body) if cfg.remat else tail_body
            x, _ = jax.lax.scan(tb, x, tail_xs)

    elif cfg.family == "hybrid":
        k_every = cfg.hybrid_attn_every
        ks_l, vs_l, sts_l, css_l = [], [], [], []
        inv = 0

        def run_mamba(h, mp, lt_tree):
            lctx = lora.at_layer(lt_tree) if lt_tree is not None else None
            y, (st, cs) = mamba_block(h, mp, cfg, lctx, return_state=True,
                                      seq_lens=seq_lens)
            return y, st, cs
        if cfg.remat:
            run_mamba = jax.checkpoint(run_mamba)

        for i in range(cfg.num_layers):
            lp = jax.tree.map(lambda t: t[i], params["layers"])
            lt = _lora_layer_slice(lora, i)
            h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
            y, st, cs = run_mamba(h, lp["mamba"], lt)
            x = x + y
            sts_l.append(st); css_l.append(cs)
            if k_every and (i + 1) % k_every == 0:
                sp = params["shared"]
                slt = _lora_layer_slice(lora, inv, sub="shared")
                slctx = lora.at_layer(slt) if slt is not None else None
                x, (k, v), _ = _dense_block_seq(x, sp, cfg, slctx, 0,
                                                positions, q_chunk)
                ks_l.append(k); vs_l.append(v)
                inv += 1
        if want_cache:
            cache = dict(cache)
            if ks_l:
                ks, vs = jnp.stack(ks_l), jnp.stack(vs_l)
                ck = jax.lax.dynamic_update_slice(cache["k"], ks.astype(cache["k"].dtype), (0, 0, 0, 0, 0))
                cv = jax.lax.dynamic_update_slice(cache["v"], vs.astype(cache["v"].dtype), (0, 0, 0, 0, 0))
                cache["k"], cache["v"] = ck, cv
            cache["ssm"] = jnp.stack(sts_l).astype(cache["ssm"].dtype)
            cache["conv"] = jnp.stack(css_l).astype(cache["conv"].dtype)
    else:
        raise ValueError(cfg.family)

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return x, cache, aux_total


def _encode(params, enc_embeds, cfg, q_chunk):
    """Seamless encoder: bidirectional transformer over stub frontend
    embeddings [B, S_enc, d]."""
    x = enc_embeds
    positions = jnp.arange(x.shape[1])[None, :]

    def body(x, lp):
        xo, _, _ = _dense_block_seq(x, lp, cfg, None, 0, positions, q_chunk,
                                    causal=False)
        return xo, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["encoder"])
    return rmsnorm(x, params["final_norm"], cfg.norm_eps)


def _cross_attn_seq(x, lp, cfg, enc_memory, q_chunk):
    """Decoder cross-attention to encoder memory (no mask, no rope)."""
    h = rmsnorm(x, lp["lnx"], cfg.norm_eps)
    B, S, _ = h.shape
    p = lp["xattn"]
    q = proj(h, p.wq).reshape(B, S, cfg.num_heads, cfg.head_dim)
    k = proj(enc_memory, p.wk).reshape(B, -1, cfg.num_kv_heads, cfg.head_dim)
    v = proj(enc_memory, p.wv).reshape(B, -1, cfg.num_kv_heads, cfg.head_dim)
    o = attention_chunked(q, k, v, cfg, causal=False, window=0, q_chunk=q_chunk)
    return x + proj(o.reshape(B, S, cfg.q_dim), p.wo)


def _encdec_fill_cross_cache(params, cache, enc_memory, cfg):
    """Precompute per-layer cross-attn K/V from encoder memory."""
    def one(lp):
        p = lp["xattn"]
        B, Se, _ = enc_memory.shape
        k = proj(enc_memory, p.wk).reshape(B, Se, cfg.num_kv_heads, cfg.head_dim)
        v = proj(enc_memory, p.wv).reshape(B, Se, cfg.num_kv_heads, cfg.head_dim)
        return k, v

    ks, vs = jax.lax.map(one, params["layers"])
    return dict(cache, xk=ks.astype(cache["xk"].dtype),
                xv=vs.astype(cache["xv"].dtype))


# ===========================================================================
# chunk-incremental prefill (disaggregated prefill stage)
# ===========================================================================

def _dense_block_chunk(x, lp, cfg, lora, window, positions, ck, cv,
                       start: int):
    """One dense block over a prefill CHUNK at absolute offset `start`.
    x: [B, C, d]; ck/cv: [B, Smax, KVH, hd] per-layer cache. Writes the
    chunk's K/V at [start, start+C) and attends causally over [0, start+C).
    Same qkv / proj / mlp ops as `_dense_block_seq` — only the mask offset
    and the cache-resident keys differ."""
    C = x.shape[1]
    h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
    q, k, v = qkv(h, lp["attn"], cfg, positions, lora)
    ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, start, 0, 0))
    cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, start, 0, 0))
    o = attention_prefill_chunk(q, ck[:, :start + C], cv[:, :start + C], cfg,
                                q_start=start, window=window)
    o = o.reshape(x.shape[0], C, cfg.q_dim)
    x = x + proj(o, lp["attn"].wo, lora=lora, name="attn_o")
    h = rmsnorm(x, lp["ln2"], cfg.norm_eps)
    if "moe" in lp:
        y, _ = moe_apply(h, lp["moe"], cfg, lora)
    else:
        y = mlp_apply(h, lp["mlp"], cfg.mlp_act, lora)
    return x + y, ck, cv


def forward_prefill_chunk(params: Params, tokens, cfg: ModelConfig,
                          lora: Optional[LoraCtx] = None,
                          cache: Optional[Params] = None, *,
                          start: int = 0,
                          seq_lens=None) -> Tuple[jax.Array, Params]:
    """One fixed-size chunk of an incremental prefill (paper §4.1: the
    disaggregated prefill stage processes long prompts chunk-by-chunk so a
    huge prompt cannot monopolize the stage).

    tokens: [B, C] — absolute positions ``start .. start+C`` of the prompt.
    `start` must be a PYTHON INT (static under jit; jit one variant per
    offset). The cache carries everything between chunks: attention K/V is
    written in place at the chunk's offset, recurrent ssm/conv states are
    read, advanced through `mamba_block`'s state-carry path, and written
    back. `seq_lens` [B] is the VALID length within this chunk (== C for
    every chunk but the padded last one).

    Exactness: attention chunks decompose exactly (causal masking), SSD
    chunks decompose exactly when `start` is a multiple of
    ``cfg.ssm.chunk_size`` (the internal scan boundaries then coincide) —
    the prefill worker rounds its chunk size up to guarantee this. Returns
    (hidden [B, C, d] final-normed, cache'); only the LAST chunk's hidden
    states are meaningful at the row's final real position.
    """
    B, C = tokens.shape
    x = params["embed"][tokens]
    positions = (start + jnp.arange(C))[None, :]
    windows = _window_for(cfg, None)

    if cfg.family in ("dense", "moe", "vlm"):
        def body(x, xs):
            lp, ck, cv, lora_i, win = (xs["lp"], xs["ck"], xs["cv"],
                                       xs.get("lora"), xs.get("win"))
            lctx = lora.at_layer(lora_i) if (lora is not None and lora_i is not None) else None
            w = win if win is not None else 0
            x, ck, cv = _dense_block_chunk(x, lp, cfg, lctx, w, positions,
                                           ck, cv, start)
            return x, (ck, cv)

        xs = {"lp": params["layers"], "ck": cache["k"], "cv": cache["v"]}
        lt = _lora_layer_slice(lora)
        if lt is not None:
            xs["lora"] = lt
        if windows is not None:
            xs["win"] = windows
        if cfg.scan_layers:
            x, (cks, cvs) = jax.lax.scan(body, x, xs)
        else:
            cks_l, cvs_l = [], []
            for i in range(cfg.num_layers):
                xi = jax.tree.map(lambda t: t[i], xs)
                x, (ck, cv) = body(x, xi)
                cks_l.append(ck); cvs_l.append(cv)
            cks, cvs = jnp.stack(cks_l), jnp.stack(cvs_l)
        cache = dict(cache, k=cks, v=cvs)

    elif cfg.family == "ssm":
        def body(x, xs):
            lp, st0, cs0, lora_i = xs["lp"], xs["st"], xs["cs"], xs.get("lora")
            lctx = lora.at_layer(lora_i) if (lora is not None and lora_i is not None) else None
            h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
            y, (st, cs) = mamba_block(h, lp["mamba"], cfg, lctx,
                                      ssm_state=st0, conv_state=cs0,
                                      return_state=True, seq_lens=seq_lens)
            return x + y, (st, cs.astype(cs0.dtype))

        xs = {"lp": params["layers"], "st": cache["ssm"], "cs": cache["conv"]}
        lt = _lora_layer_slice(lora)
        if lt is not None:
            xs["lora"] = lt
        if cfg.scan_layers:
            x, (sts, css) = jax.lax.scan(body, x, xs)
        else:
            sts_l, css_l = [], []
            for i in range(cfg.num_layers):
                xi = jax.tree.map(lambda t: t[i], xs)
                x, (st, cs) = body(x, xi)
                sts_l.append(st); css_l.append(cs)
            sts, css = jnp.stack(sts_l), jnp.stack(css_l)
        cache = dict(cache, ssm=sts.astype(cache["ssm"].dtype), conv=css)

    elif cfg.family == "hybrid":
        k_every = cfg.hybrid_attn_every
        sts_l, css_l = [], []
        cks, cvs = cache.get("k"), cache.get("v")
        inv = 0
        for i in range(cfg.num_layers):
            lp = jax.tree.map(lambda t: t[i], params["layers"])
            lt = _lora_layer_slice(lora, i)
            lctx = lora.at_layer(lt) if lt is not None else None
            h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
            y, (st, cs) = mamba_block(h, lp["mamba"], cfg, lctx,
                                      ssm_state=cache["ssm"][i],
                                      conv_state=cache["conv"][i],
                                      return_state=True, seq_lens=seq_lens)
            x = x + y
            sts_l.append(st)
            css_l.append(cs.astype(cache["conv"].dtype))
            if k_every and (i + 1) % k_every == 0:
                sp = params["shared"]
                slt = _lora_layer_slice(lora, inv, sub="shared")
                slctx = lora.at_layer(slt) if slt is not None else None
                x, ck, cv = _dense_block_chunk(x, sp, cfg, slctx, 0,
                                               positions, cks[inv], cvs[inv],
                                               start)
                cks = cks.at[inv].set(ck)
                cvs = cvs.at[inv].set(cv)
                inv += 1
        cache = dict(cache, ssm=jnp.stack(sts_l).astype(cache["ssm"].dtype),
                     conv=jnp.stack(css_l))
        if cks is not None:
            cache["k"], cache["v"] = cks, cvs
    else:
        raise NotImplementedError(
            f"chunked prefill unsupported for family {cfg.family!r} "
            f"(the prefill worker falls back to whole-prompt calls)")

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return x, cache


# ===========================================================================
# decode step
# ===========================================================================

def decode_step(params: Params, new_tokens, cache: Params, cfg: ModelConfig,
                lora: Optional[LoraCtx] = None,
                advance=None, use_kernel: bool = False
                ) -> Tuple[jax.Array, Params]:
    """One token for every row. new_tokens: [B] int32.

    `advance` ([B] int32 0/1, default all-ones) freezes rows awaiting
    external tool responses: a frozen row's K/V slot is written (and
    overwritten on resume) but its `pos` does not move, so its cache never
    accumulates garbage. Returns (logits [B, V], cache').

    The cache may be dense (``init_cache``) or paged
    (``init_paged_cache`` — detected by its ``tbl`` block table): the
    paged path scatters the token's K/V into the row's current page and
    attends through the block table, bit-identical to the dense math.
    ``use_kernel`` routes attention through the Pallas flash-decode
    kernels (``gqa_decode`` / ``paged_gqa_decode``) where the window is
    static; the einsum oracle runs otherwise."""
    B = new_tokens.shape[0]
    pos = cache["pos"]
    paged = "tbl" in cache
    if advance is None:
        advance = jnp.ones((B,), jnp.int32)
    x = params["embed"][new_tokens]                          # [B, d]
    windows = _window_for(cfg, None)

    if cfg.family in ("dense", "moe", "vlm", "encdec"):
        def body(x, xs):
            lp, lora_i, win = xs["lp"], xs.get("lora"), xs.get("win")
            lctx = lora.at_layer(lora_i) if (lora is not None and lora_i is not None) else None
            w = win if win is not None else 0
            if paged:
                x, kp, vp = _paged_block_decode(x, lp, cfg, lctx, w,
                                                xs["kp"], xs["vp"],
                                                cache["tbl"], pos,
                                                use_kernel)
                ys = (kp, vp)
            else:
                x, ck, cv = _dense_block_decode(x, lp, cfg, lctx, w,
                                                xs["ck"], xs["cv"], pos,
                                                use_kernel)
                ys = (ck, cv)
            if cfg.family == "encdec":
                x = _cross_attn_decode(x, lp, cfg, xs["xk"], xs["xv"])
            return x, ys

        xs = {"lp": params["layers"]}
        if paged:
            xs["kp"], xs["vp"] = cache["kp"], cache["vp"]
        else:
            xs["ck"], xs["cv"] = cache["k"], cache["v"]
        if cfg.family == "encdec":
            xs["xk"], xs["xv"] = cache["xk"], cache["xv"]
        lt = _lora_layer_slice(lora)
        if lt is not None:
            xs["lora"] = lt
        if windows is not None:
            xs["win"] = windows
        if cfg.scan_layers:
            x, (cks, cvs) = jax.lax.scan(body, x, xs)
        else:
            cks_l, cvs_l = [], []
            for i in range(cfg.num_layers):
                xi = jax.tree.map(lambda t: t[i], xs)
                x, (ck, cv) = body(x, xi)
                cks_l.append(ck); cvs_l.append(cv)
            cks, cvs = jnp.stack(cks_l), jnp.stack(cvs_l)
        if paged:
            cache = dict(cache, kp=cks, vp=cvs, pos=pos + advance)
        else:
            cache = dict(cache, k=cks, v=cvs, pos=pos + advance)

    elif cfg.family == "ssm":
        adv_f = advance.astype(jnp.float32)[:, None, None, None]

        def body(x, xs):
            lp, st0, cs0, lora_i = xs["lp"], xs["st"], xs["cs"], xs.get("lora")
            lctx = lora.at_layer(lora_i) if (lora is not None and lora_i is not None) else None
            h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
            y, (st, cs) = mamba_decode_step(h, lp["mamba"], cfg, st0, cs0, lctx)
            st = st * adv_f + st0 * (1.0 - adv_f)
            cs = jnp.where(advance[:, None, None] > 0, cs, cs0)
            return x + y, (st, cs.astype(xs["cs"].dtype))

        xs = {"lp": params["layers"], "st": cache["ssm"], "cs": cache["conv"]}
        lt = _lora_layer_slice(lora)
        if lt is not None:
            xs["lora"] = lt
        x, (sts, css) = jax.lax.scan(body, x, xs)
        cache = dict(cache, ssm=sts, conv=css, pos=pos + advance)

    elif cfg.family == "hybrid":
        k_every = cfg.hybrid_attn_every
        sts_l, css_l = [], []
        cks = cache.get("kp") if paged else cache.get("k")
        cvs = cache.get("vp") if paged else cache.get("v")
        inv = 0
        for i in range(cfg.num_layers):
            lp = jax.tree.map(lambda t: t[i], params["layers"])
            lt = _lora_layer_slice(lora, i)
            lctx = lora.at_layer(lt) if lt is not None else None
            h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
            y, (st, cs) = mamba_decode_step(h, lp["mamba"], cfg,
                                            cache["ssm"][i], cache["conv"][i],
                                            lctx)
            x = x + y
            sts_l.append(st); css_l.append(cs.astype(cache["conv"].dtype))
            if k_every and (i + 1) % k_every == 0:
                sp = params["shared"]
                slt = _lora_layer_slice(lora, inv, sub="shared")
                slctx = lora.at_layer(slt) if slt is not None else None
                if paged:
                    x, ck, cv = _paged_block_decode(
                        x, sp, cfg, slctx, 0, cks[inv], cvs[inv],
                        cache["tbl"], pos, use_kernel)
                else:
                    x, ck, cv = _dense_block_decode(x, sp, cfg, slctx, 0,
                                                    cks[inv], cvs[inv], pos,
                                                    use_kernel)
                cks = cks.at[inv].set(ck)
                cvs = cvs.at[inv].set(cv)
                inv += 1
        cache = dict(cache, ssm=jnp.stack(sts_l), conv=jnp.stack(css_l),
                     pos=pos + advance)
        if cks is not None:
            if paged:
                cache["kp"], cache["vp"] = cks, cvs
            else:
                cache["k"], cache["v"] = cks, cvs
    else:
        raise ValueError(cfg.family)

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = lm_logits(x, params, cfg)
    return logits, cache


def _cross_attn_decode(x, lp, cfg, xk, xv):
    """x: [B, d]; xk/xv: [B, S_enc, KVH, hd] (full memory, no mask)."""
    h = rmsnorm(x, lp["lnx"], cfg.norm_eps)
    p = lp["xattn"]
    B = x.shape[0]
    q = proj(h, p.wq).reshape(B, cfg.num_heads, cfg.head_dim)
    Se = xk.shape[1]
    o = attention_decode(q, xk, xv, jnp.full((B,), Se, jnp.int32), cfg)
    return x + proj(o.reshape(B, cfg.q_dim), p.wo)


# ===========================================================================
# logits
# ===========================================================================

def lm_logits(h, params: Params, cfg: ModelConfig):
    w = params["lm_head"] if not cfg.tie_embeddings else params["embed"].T
    logits = (h @ w.astype(h.dtype)).astype(jnp.float32)
    return softcap(logits, cfg.logit_softcap)


def forward_train(params: Params, tokens, cfg: ModelConfig,
                  lora: Optional[LoraCtx] = None, *, enc_embeds=None,
                  q_chunk: int = 512):
    """Teacher-forced full-sequence logits [B, S, V] (+ aux loss)."""
    h, _, aux = forward_seq(params, tokens, cfg, lora, None,
                            enc_embeds=enc_embeds, q_chunk=q_chunk)
    return lm_logits(h, params, cfg), aux
