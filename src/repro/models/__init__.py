from .common import LoraCtx, OFF, proj, rmsnorm, softcap, dtype_of
from .model import (decode_step, forward_prefill_chunk, forward_seq,
                    forward_train, init_cache, init_paged_cache, init_params,
                    lm_logits)

__all__ = ["LoraCtx", "OFF", "proj", "rmsnorm", "softcap", "dtype_of",
           "decode_step", "forward_prefill_chunk", "forward_seq",
           "forward_train", "init_cache", "init_paged_cache", "init_params",
           "lm_logits"]
