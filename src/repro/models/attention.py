"""GQA attention: chunked-query training/prefill path + cached decode path.

Variants covered (per assigned archs): grouped KV heads, QKV bias (qwen1.5),
qk-norm (chameleon/qwen3), score softcap (gemma2), sliding-window +
local/global alternation (gemma2), bidirectional (seamless encoder) and
cross-attention (seamless decoder).

`window` may be a python int OR a traced scalar (gemma2 passes a per-layer
window array through the layer scan); 0 means global attention. All masking
uses data-dependent `jnp.where`, never python branches.

Memory: the training path scans over query chunks so peak live score memory
is [B, H, q_chunk, S] instead of [B, H, S, S]; combined with per-layer remat
this keeps 32k-prefill lowerable at full config. The decode path is a single
masked softmax over the cache (the Pallas ``gqa_decode`` kernel and the
shard_map flash-decode in ``launch`` are the optimized variants).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig
from .common import LoraCtx, apply_rope, dense_init, proj, rmsnorm, rmsnorm_init, softcap

_NO_WINDOW = jnp.iinfo(jnp.int32).max - 1


class AttnParams(NamedTuple):
    wq: jax.Array
    wk: jax.Array
    wv: jax.Array
    wo: jax.Array
    bq: Optional[jax.Array] = None
    bk: Optional[jax.Array] = None
    bv: Optional[jax.Array] = None
    q_norm: Optional[jax.Array] = None
    k_norm: Optional[jax.Array] = None


def attn_init(key, cfg: ModelConfig, dtype) -> AttnParams:
    kq, kk, kv, ko = jax.random.split(key, 4)
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    return AttnParams(
        wq=dense_init(kq, d, qd, dtype),
        wk=dense_init(kk, d, kvd, dtype),
        wv=dense_init(kv, d, kvd, dtype),
        wo=dense_init(ko, qd, d, dtype),
        bq=jnp.zeros((qd,), dtype) if cfg.qkv_bias else None,
        bk=jnp.zeros((kvd,), dtype) if cfg.qkv_bias else None,
        bv=jnp.zeros((kvd,), dtype) if cfg.qkv_bias else None,
        q_norm=rmsnorm_init(cfg.head_dim, dtype) if cfg.qk_norm else None,
        k_norm=rmsnorm_init(cfg.head_dim, dtype) if cfg.qk_norm else None,
    )


def qkv(x, p: AttnParams, cfg: ModelConfig, positions, lora: Optional[LoraCtx],
        rope: bool = True):
    """Project + reshape to heads (+ qk-norm + RoPE). x: [B, S, d]."""
    B, S, _ = x.shape
    q = proj(x, p.wq, p.bq, lora=lora, name="attn_q").reshape(B, S, cfg.num_heads, cfg.head_dim)
    k = proj(x, p.wk, p.bk, lora=lora, name="attn_k").reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    v = proj(x, p.wv, p.bv, lora=lora, name="attn_v").reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rmsnorm(q, p.q_norm, cfg.norm_eps)
        k = rmsnorm(k, p.k_norm, cfg.norm_eps)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def repeat_kv(k, n_rep: int):
    """[B, S, KVH, hd] -> [B, S, KVH*n_rep, hd]."""
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, n_rep, d)).reshape(b, s, h * n_rep, d)


def _effective_window(window):
    """int-or-traced window; 0 → 'no window' sentinel."""
    w = jnp.asarray(window, jnp.int32)
    return jnp.where(w > 0, w, _NO_WINDOW)


def _pair_mask(q_pos, k_pos, *, causal: bool, window):
    """[Sq, Sk] boolean mask (True = attend)."""
    diff = q_pos[:, None] - k_pos[None, :]
    m = diff < _effective_window(window)
    if causal:
        m &= diff >= 0
    return m


def attention_dense(q, k, v, cfg: ModelConfig, *, causal: bool, window=0):
    """Plain softmax attention. q:[B,Sq,H,hd], k/v:[B,Sk,KVH,hd]."""
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    k = repeat_kv(k, H // cfg.num_kv_heads)
    v = repeat_kv(v, H // cfg.num_kv_heads)
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    s = softcap(s, cfg.attn_softcap)
    mask = _pair_mask(jnp.arange(Sq), jnp.arange(Sk), causal=causal, window=window)
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)


def attention_chunked(q, k, v, cfg: ModelConfig, *, causal: bool,
                      window=0, q_chunk: int = 512):
    """Query-chunked attention: scan over q chunks; peak memory
    [B, H, q_chunk, Sk]. Used for train/prefill at long sequence length."""
    B, Sq, H, hd = q.shape
    if Sq <= q_chunk:
        return attention_dense(q, k, v, cfg, causal=causal, window=window)
    assert Sq % q_chunk == 0, (Sq, q_chunk)
    Sk = k.shape[1]
    k = repeat_kv(k, H // cfg.num_kv_heads)
    v = repeat_kv(v, H // cfg.num_kv_heads)
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    nq = Sq // q_chunk
    qc = q.reshape(B, nq, q_chunk, H, hd).transpose(1, 0, 2, 3, 4)
    k_pos = jnp.arange(Sk)
    win = _effective_window(window)

    def body(carry, inp):
        qi, i = inp
        q_pos = i * q_chunk + jnp.arange(q_chunk)
        s = jnp.einsum("bqhd,bkhd->bhqk", qi, k).astype(jnp.float32) * scale
        s = softcap(s, cfg.attn_softcap)
        diff = q_pos[:, None] - k_pos[None, :]
        m = diff < win
        if causal:
            m &= diff >= 0
        s = jnp.where(m[None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1).astype(qi.dtype)
        return carry, jnp.einsum("bhqk,bkhd->bqhd", p, v)

    _, out = jax.lax.scan(body, None, (qc, jnp.arange(nq)))
    return out.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, hd)


def attention_prefill_chunk(q, k, v, cfg: ModelConfig, *, q_start: int,
                            window=0):
    """Chunk-incremental prefill attention: queries sit at ABSOLUTE
    positions ``q_start .. q_start+Sq``, keys/values are the cache read
    back over ``[0, k_len)`` (prior chunks + this one, already RoPE'd at
    their absolute positions when written).

    Numerically this is `attention_dense` with an offset causal mask: the
    same repeat_kv / einsum / softcap / softmax op sequence, so a prompt
    prefilled chunk-by-chunk reproduces the fused whole-prompt prefill
    token-for-token (masked lanes contribute exact zeros either way)."""
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    k = repeat_kv(k, H // cfg.num_kv_heads)
    v = repeat_kv(v, H // cfg.num_kv_heads)
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k.astype(q.dtype)).astype(jnp.float32) * scale
    s = softcap(s, cfg.attn_softcap)
    mask = _pair_mask(q_start + jnp.arange(Sq), jnp.arange(Sk),
                      causal=True, window=window)
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(q.dtype))


def _static_window(window) -> bool:
    """True when `window` is a python int (the Pallas decode kernels take
    it as a static arg; gemma2's per-layer window array is TRACED through
    the layer scan and falls back to the einsum path)."""
    return isinstance(window, int)


def attention_decode(q, cache_k, cache_v, pos, cfg: ModelConfig, *, window=0,
                     use_kernel: bool = False):
    """Single-token decode. q: [B, H, hd]; cache: [B, Smax, KVH, hd];
    pos: [B] number of valid cache entries (incl. the just-written token).

    GQA is computed in grouped-einsum form — materializing repeat_kv'd
    caches costs rep× the decode step's HBM traffic (measured 10GB/step at
    granite decode_32k — EXPERIMENTS.md §Perf iter A2). Under
    ``use_kernel=True`` the Pallas ``gqa_decode`` flash-decode kernel runs
    instead (when the window is static and the cache length tiles evenly);
    the einsum path is retained as the oracle it is parity-tested against.
    """
    B, H, hd = q.shape
    Smax = cache_k.shape[1]
    if (use_kernel and _static_window(window)
            and (Smax <= 512 or Smax % 512 == 0)):
        from repro.kernels.gqa_decode import gqa_decode
        return gqa_decode(q, cache_k, cache_v, pos,
                          softcap=float(cfg.attn_softcap or 0.0),
                          window=int(window))
    KVH = cache_k.shape[2]
    rep = H // KVH
    qg = q.reshape(B, KVH, rep, hd)
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    s = jnp.einsum("bgrd,bkgd->bgrk", qg, cache_k).astype(jnp.float32) * scale
    s = softcap(s, cfg.attn_softcap)
    idx = jnp.arange(Smax)
    valid = idx[None, :] < pos[:, None]                       # [B, Smax]
    valid &= (pos[:, None] - 1 - idx[None, :]) < _effective_window(window)
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    o = jnp.einsum("bgrk,bkgd->bgrd", p, cache_v)
    return o.reshape(B, H, hd)


def attention_decode_paged(q, kp, vp, tbl, pos, cfg: ModelConfig, *,
                           window=0, use_kernel: bool = False):
    """Single-token decode over the block-pool (paged) KV cache.

    q: [B, H, hd]; kp/vp: [n_pages+1, page, KVH, hd] — the shared page
    pool (physical page ``n_pages`` is the scratch page that sentinel
    block-table entries point at); tbl: [B, max_pages] int32 physical page
    ids; pos: [B] valid entries.

    Oracle path: gather each row's pages into a contiguous
    [B, max_pages·page, KVH, hd] view and reuse ``attention_decode`` — the
    gathered values are bit-identical to what a dense cache would hold at
    the same positions, and every position ≥ pos (incl. anything a
    sentinel entry dragged in from the scratch page) is masked, so paged
    output == dense output exactly. Under ``use_kernel=True`` the Pallas
    ``paged_gqa_decode`` kernel reads the pages in place via a
    scalar-prefetched block table instead (no contiguous gather ever
    materializes)."""
    if use_kernel and _static_window(window):
        from repro.kernels.paged_decode import paged_gqa_decode
        return paged_gqa_decode(q, kp, vp, tbl, pos,
                                softcap=float(cfg.attn_softcap or 0.0),
                                window=int(window))
    B = q.shape[0]
    page, KVH, hd = kp.shape[1], kp.shape[2], kp.shape[3]
    n_pg = tbl.shape[1]
    ck = jnp.take(kp, tbl, axis=0).reshape(B, n_pg * page, KVH, hd)
    cv = jnp.take(vp, tbl, axis=0).reshape(B, n_pg * page, KVH, hd)
    return attention_decode(q, ck, cv, pos, cfg, window=window)
