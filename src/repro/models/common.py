"""Shared building blocks: init helpers, RMSNorm, RoPE, projections.

Everything is functional: params are pytrees of jnp arrays; per-layer weights
are stacked on a leading layer axis (lax.scan-ready).

LoRA hook: every linear projection funnels through :func:`proj`, which takes
an optional ``LoraCtx``. That one seam gives us (a) single-task adapter
injection for training and (b) batched multi-LoRA application for cross-task
rollout (paper §4.5) — see ``repro.lora``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype, scale: float = None):
    scale = scale if scale is not None else 1.0 / np.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def rmsnorm(x, weight, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(jnp.square(x), axis=-1, keepdims=True) + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dt)
    # (1 + w): gemma-style zero-centered scale; init weight to 0.


def rmsnorm_init(d: int, dtype):
    return jnp.zeros((d,), dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: [..., S, H, hd]; positions: broadcastable to [..., S]."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta))               # [hd/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    sin = jnp.sin(angles)[..., None, :]                      # [..., S, 1, hd/2]
    cos = jnp.cos(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# the LoRA-aware projection seam
# ---------------------------------------------------------------------------

class LoraCtx:
    """Carries adapter state through a forward pass.

    mode = "off"     — no adapters (base model / reference policy)
    mode = "single"  — one task's adapters (training, single-task rollout)
    mode = "batched" — stacked [T, ...] adapters + per-row task ids
                       (multi-LoRA cross-task rollout, paper §4.5)
    """

    def __init__(self, mode: str, tree=None, row_task_ids=None,
                 scaling: float = 1.0, use_kernel: bool = False):
        self.mode = mode
        self.tree = tree            # {target: {"a": ..., "b": ...}} (stacked L)
        self.row_task_ids = row_task_ids
        self.scaling = scaling
        self.use_kernel = use_kernel
        self._layer = None          # set inside the layer loop/scan

    def at_layer(self, layer_tree):
        """Return a shallow ctx bound to one layer's adapter slices."""
        c = LoraCtx(self.mode, layer_tree, self.row_task_ids, self.scaling,
                    self.use_kernel)
        return c

    def delta(self, x, name: str):
        """LoRA contribution for projection `name`, or None."""
        if self.mode == "off" or self.tree is None or name not in self.tree:
            return None
        a = self.tree[name]["a"]
        b = self.tree[name]["b"]
        if self.mode == "single":
            h = x.astype(a.dtype) @ a            # [..., r]
            return (self.scaling * (h @ b)).astype(x.dtype)
        # batched multi-LoRA: a [T, d, r], b [T, r, dout]; rows carry task ids
        from repro.lora.multilora import multi_lora_delta
        return multi_lora_delta(x, a, b, self.row_task_ids, self.scaling,
                                use_kernel=self.use_kernel)


OFF = LoraCtx("off")


def proj(x, w, b=None, *, lora: Optional[LoraCtx] = None, name: str = ""):
    """y = x @ w (+ b) (+ lora delta). x: [..., d_in], w: [d_in, d_out]."""
    y = x @ w.astype(x.dtype)
    if b is not None:
        y = y + b.astype(y.dtype)
    if lora is not None:
        d = lora.delta(x, name)
        if d is not None:
            y = y + d.astype(y.dtype)
    return y


def softcap(x, cap: float):
    return jnp.tanh(x / cap) * cap if cap else x
