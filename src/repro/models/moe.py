"""Fine-grained Mixture-of-Experts (deepseek-moe / dbrx).

TPU-native dispatch: tokens are *sorted by expert id* and gathered into a
dense [E, C, d] buffer (capacity C), then a single batched matmul runs all
experts — the same sorted-grouped-matmul idiom our SGMV multi-LoRA kernel
uses (DESIGN.md §3). This keeps HLO FLOPs at ≈ top_k·capacity_factor× the
useful expert compute, instead of the E× blow-up of one-hot dense dispatch.

Sharding: the [E, C, d] buffer is constrained to P('model', None, None) at
full scale → XLA inserts the expert-parallel all-to-all.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig, MoEConfig
from .common import LoraCtx, dense_init
from .mlp import MLPParams, mlp_apply, mlp_init


class MoEParams(NamedTuple):
    router: jax.Array                    # [d, E]
    w_in: jax.Array                      # [E, d, ff(*2 for swiglu)]
    w_out: jax.Array                     # [E, ff, d]
    shared: Optional[MLPParams]          # fused shared experts (or None)


def moe_init(key, cfg: ModelConfig, dtype) -> MoEParams:
    m = cfg.moe
    kr, ki, ko, ks = jax.random.split(key, 4)
    d, ff, E = cfg.d_model, m.expert_d_ff, m.num_experts
    in_cols = 2 * ff if cfg.mlp_act == "swiglu" else ff
    scale = 1.0 / jnp.sqrt(d)
    w_in = (jax.random.normal(ki, (E, d, in_cols), jnp.float32) * scale).astype(dtype)
    w_out = (jax.random.normal(ko, (E, ff, d), jnp.float32) * (1.0 / jnp.sqrt(ff))).astype(dtype)
    shared = (mlp_init(ks, d, m.num_shared * ff, cfg.mlp_act, dtype)
              if m.num_shared else None)
    return MoEParams(router=dense_init(kr, d, E, dtype, scale=0.02),
                     w_in=w_in, w_out=w_out, shared=shared)


def _expert_capacity(n_tokens: int, m: MoEConfig) -> int:
    c = int(n_tokens * m.top_k * m.capacity_factor / m.num_experts) + 1
    return max(8, -(-c // 8) * 8)  # round up to 8


def route(x_flat, router_w, m: MoEConfig):
    """Returns (weights [T,k], expert_ids [T,k], router_probs [T,E])."""
    logits = (x_flat.astype(jnp.float32) @ router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    w, ids = jax.lax.top_k(probs, m.top_k)
    w = w / jnp.sum(w, axis=-1, keepdims=True)               # renormalize
    return w, ids, probs


def moe_apply(x, p: MoEParams, cfg: ModelConfig, lora: Optional[LoraCtx] = None):
    """x: [B, S, d] -> [B, S, d]. Sorted-gather grouped expert matmul."""
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    xf = x.reshape(T, d)
    w, ids, probs = route(xf, p.router, m)                   # [T,k]

    A = T * m.top_k                                          # assignments
    flat_ids = ids.reshape(A)                                # expert per assignment
    flat_tok = jnp.repeat(jnp.arange(T), m.top_k)            # token per assignment
    order = jnp.argsort(flat_ids)                            # sort by expert
    sorted_e = flat_ids[order]
    sorted_t = flat_tok[order]

    C = _expert_capacity(T, m)
    # rank of each assignment within its expert (positions in sorted order)
    in_e_rank = jnp.arange(A) - jnp.searchsorted(sorted_e, sorted_e, side="left")
    keep = in_e_rank < C                                     # capacity drop
    slot = sorted_e * C + in_e_rank                          # [A] in [0, E*C)
    # park all drops on ONE dummy row (never read back — collisions are fine)
    slot = jnp.where(keep, slot, m.num_experts * C)

    buf = jnp.zeros((m.num_experts * C + 1, d), x.dtype)
    buf = buf.at[slot].set(xf[sorted_t])
    buf = buf[: m.num_experts * C].reshape(m.num_experts, C, d)
    from repro.train.sharding import constrain
    buf = constrain(buf, "tp", None, None)        # expert-parallel dispatch

    # grouped expert matmul (dense batched einsum over the expert axis)
    h = jnp.einsum("ecd,edf->ecf", buf, p.w_in.astype(x.dtype))
    if cfg.mlp_act == "swiglu":
        g, u = jnp.split(h, 2, axis=-1)
        h = jax.nn.silu(g) * u
    elif cfg.mlp_act == "squared_relu":
        h = jnp.square(jax.nn.relu(h))
    else:
        h = jax.nn.gelu(h)
    out_buf = jnp.einsum("ecf,efd->ecd", h, p.w_out.astype(x.dtype))

    # combine back: gather each assignment's slot value, weight, segment-sum
    gathered = out_buf.reshape(m.num_experts * C, d)
    safe_slot = jnp.where(keep, slot, 0)
    vals = jnp.where(keep[:, None], gathered[safe_slot], 0.0)
    a_w = w.reshape(A)[order].astype(x.dtype)
    y = jnp.zeros((T, d), x.dtype).at[sorted_t].add(vals * a_w[:, None])

    y = y.reshape(B, S, d)
    if p.shared is not None:
        # keep [B, S, d] so batched multi-LoRA per-row task ids line up
        y = y + mlp_apply(x, p.shared, cfg.mlp_act, lora=lora, prefix="mlp")
    aux = load_balance_loss(probs, ids, m)
    return y, aux


def load_balance_loss(probs, ids, m: MoEConfig):
    """Switch-style aux loss: E * sum_e f_e * P_e."""
    E = m.num_experts
    f = jnp.mean(jax.nn.one_hot(ids, E, dtype=jnp.float32).sum(1), axis=0)
    P = jnp.mean(probs, axis=0)
    return E * jnp.sum(f / m.top_k * P)
