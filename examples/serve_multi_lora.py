"""Multi-LoRA batched serving: one fused batch answers prompts for several
tenants' adapters simultaneously (paper §4.5 rollout path, serving-only).

    PYTHONPATH=src python examples/serve_multi_lora.py --tenants 4
"""
import argparse
import dataclasses
import random

import jax

from repro.configs import REGISTRY, reduced
from repro.data import tokenizer as tok
from repro.envs.tasks import make_env
from repro.lora.adapters import init_lora
from repro.models import init_params
from repro.rollout.engine import RolloutEngine, RolloutRequest


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tenants", type=int, default=4)
    ap.add_argument("--per-tenant", type=int, default=2)
    ap.add_argument("--use-kernel", action="store_true",
                    help="route adapter matmuls through the Pallas SGMV "
                         "kernel (interpret mode on CPU)")
    args = ap.parse_args()

    cfg = dataclasses.replace(reduced(REGISTRY["granite-3-2b"],
                                      dtype="float32"),
                              vocab_size=tok.VOCAB_SIZE)
    params = init_params(jax.random.PRNGKey(0), cfg)
    adapters = [init_lora(jax.random.PRNGKey(100 + t), cfg)
                for t in range(args.tenants)]
    engine = RolloutEngine(cfg, params, max_len=64, seed=0,
                           use_kernel=args.use_kernel)
    env = make_env("gsm8k")
    rng = random.Random(0)

    reqs = []
    for t in range(args.tenants):
        for _ in range(args.per_tenant):
            prompt, truth = env.sample_prompt(rng)
            reqs.append(RolloutRequest(f"tenant-{t}", t, prompt, truth, env,
                                       max_new_tokens=6, temperature=0.8))
    results, stats = engine.generate(reqs, adapters)
    print(f"served {len(reqs)} requests for {args.tenants} tenants in ONE "
          f"fused batch: {stats.decode_steps} decode steps, "
          f"{stats.wall_seconds:.2f}s wall")
    for r in results:
        txt = tok.decode_with_specials(r["tokens"])
        print(f"  {r['task_id']:10s} {txt!r}")


if __name__ == "__main__":
    main()
