"""Quickstart: single-task GRPO fine-tuning with LoRA on a tiny base model.

    PYTHONPATH=src python examples/quickstart.py [--steps 8]

Builds a reduced granite-family model, rolls out arithmetic prompts,
verifies rewards, and applies GRPO updates through the same PolicyUpdate
the service uses. Prints the reward curve.
"""
import argparse
import dataclasses
import random
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import REGISTRY, reduced
from repro.data import tokenizer as tok
from repro.envs.tasks import make_env
from repro.lora.adapters import init_lora
from repro.models import init_params
from repro.rollout.engine import RolloutEngine, RolloutRequest, to_trajectory_batch
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import TrainConfig, init_opt_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=8)
    ap.add_argument("--groups", type=int, default=2)
    ap.add_argument("--group-size", type=int, default=4)
    ap.add_argument("--arch", default="granite-3-2b")
    args = ap.parse_args()

    cfg = dataclasses.replace(reduced(REGISTRY[args.arch], dtype="float32"),
                              vocab_size=tok.VOCAB_SIZE)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    adapters = init_lora(key, cfg)
    tc = TrainConfig(group_size=args.group_size,
                     adamw=AdamWConfig(lr=3e-3))
    opt = init_opt_state(cfg, tc, params, adapters)
    step = jax.jit(make_train_step(cfg, tc))
    engine = RolloutEngine(cfg, params, max_len=64, seed=0)
    env = make_env("gsm8k", max_operand=9)
    rng = random.Random(0)

    print(f"arch={cfg.name} params={sum(x.size for x in jax.tree.leaves(params)):,}")
    for v in range(args.steps):
        reqs = []
        for _ in range(args.groups):
            prompt, truth = env.sample_prompt(rng)
            for _ in range(args.group_size):
                reqs.append(RolloutRequest("quickstart", 0, prompt, truth,
                                           env, max_new_tokens=4,
                                           temperature=1.0))
        t0 = time.time()
        results, stats = engine.generate(reqs, [adapters])
        tb = to_trajectory_batch(results, "quickstart", v, args.group_size,
                                 pad_to=64)
        batch = {"tokens": jnp.asarray(tb.tokens),
                 "prompt_lens": jnp.asarray(tb.prompt_lens),
                 "total_lens": jnp.asarray(tb.total_lens),
                 "rewards": jnp.asarray(tb.rewards),
                 "loss_mask": jnp.asarray(tb.meta["loss_mask"])}
        adapters, opt, m = step(params, adapters, opt, batch)
        print(f"step {v:2d}  reward={np.mean(tb.rewards):.3f}  "
              f"loss={float(m['loss']):+.4f}  entropy={float(m['entropy']):.2f}  "
              f"({time.time()-t0:.1f}s)")
    print("done — the adapters are the tenant's θ^(v); the base never moved.")


if __name__ == "__main__":
    main()
