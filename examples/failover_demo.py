"""Fault tolerance demo: crash mid-run, restore the atomic snapshot, finish.

    PYTHONPATH=src python examples/failover_demo.py
"""
import dataclasses
import random
import tempfile

import jax

from repro.checkpoint.store import latest_checkpoint, load_checkpoint
from repro.configs import REGISTRY, reduced
from repro.core.manager import TaskSpec
from repro.core.runtime import FailureInjector, MARLaaSRuntime, RuntimeConfig
from repro.data import tokenizer as tok
from repro.envs.tasks import make_env
from repro.models import init_params


def main():
    cfg = dataclasses.replace(reduced(REGISTRY["granite-3-2b"],
                                      dtype="float32"),
                              vocab_size=tok.VOCAB_SIZE)
    params = init_params(jax.random.PRNGKey(0), cfg)
    ckpt = tempfile.mkdtemp(prefix="marlaas_ckpt_")

    rt = MARLaaSRuntime(cfg, params,
                        RuntimeConfig(policy="marlaas", max_len=48,
                                      checkpoint_dir=ckpt,
                                      checkpoint_every=1),
                        failure=FailureInjector(fail_after_commits=3))
    for i in range(2):
        rt.submit_task(TaskSpec(f"gsm-{i}", "gsm8k", group_size=2,
                                num_groups=1, max_new_tokens=4,
                                target_steps=4))
    try:
        rt.run(timeout_s=600)
    except RuntimeError as e:
        done = sum(s.steps_done for s in rt.mgr.tasks.values())
        print(f"CRASH after {done} commits: {e}")

    snap = latest_checkpoint(ckpt)
    print(f"restoring from {snap}")
    rt2 = MARLaaSRuntime(cfg, params, RuntimeConfig(policy="marlaas",
                                                    max_len=48, seed=1))
    load_checkpoint(snap, rt2.mgr)
    for tid, st in rt2.mgr.tasks.items():
        rt2.envs[tid] = make_env(st.spec.env_name)
        rt2.datagens[tid] = random.Random(17)
        print(f"  {tid}: resumed at v{st.version} "
              f"({st.steps_done}/{st.spec.target_steps} steps)")
    rt2.run(timeout_s=600)
    print("finished after restart:",
          {tid: f"v{st.version}" for tid, st in rt2.mgr.tasks.items()})
    assert rt2.mgr.all_done()


if __name__ == "__main__":
    main()
