"""Fault tolerance demo (ISSUE 10): survive worker kills in place, then
crash mid-run and restart from the newest atomic snapshot.

    PYTHONPATH=src python examples/failover_demo.py

Two layers of defense are exercised, in escalation order:

  1. restart-in-place — deterministic chaos kills a prefill worker
     mid-job; the StageSupervisor recovers the stranded prompt, respawns
     the worker with backoff, and the run never notices;
  2. checkpoint-restart — an injected hard crash after 3 train commits
     kills the whole runtime; ``run_with_recovery`` finds the newest
     valid snapshot, builds a fresh runtime, re-adopts tasks (adapters,
     optimizer state, episode queues, counters) via
     ``adopt_checkpoint``, and finishes the job.

Tool-call retry and tenant quarantine (the other half of the
fault-tolerance layer) are covered by tests/test_chaos.py and
benchmarks/bench_chaos.py — they need agentic tenants with a forced
tool-call pattern to stay deterministic, which is too much machinery
for a demo.
"""
import dataclasses
import tempfile

import jax

from repro.configs import REGISTRY, reduced
from repro.core.chaos import ChaosConfig
from repro.core.manager import TaskSpec
from repro.core.runtime import FailureInjector, MARLaaSRuntime, RuntimeConfig
from repro.data import tokenizer as tok
from repro.models import init_params


def main():
    cfg = dataclasses.replace(reduced(REGISTRY["granite-3-2b"],
                                      dtype="float32"),
                              vocab_size=tok.VOCAB_SIZE)
    params = init_params(jax.random.PRNGKey(0), cfg)
    ckpt = tempfile.mkdtemp(prefix="marlaas_ckpt_")

    rt = MARLaaSRuntime(cfg, params,
                        RuntimeConfig(policy="marlaas", max_len=48,
                                      checkpoint_dir=ckpt,
                                      checkpoint_every=1,
                                      checkpoint_keep_last=3,
                                      disagg_prefill=True,
                                      prefill_workers=1,
                                      chaos=ChaosConfig(
                                          seed=0,
                                          prefill_worker_kill=1.0,
                                          max_faults_per_site=1)),
                        failure=FailureInjector(fail_after_commits=3))
    for i in range(2):
        rt.submit_task(TaskSpec(f"gsm-{i}", "gsm8k", group_size=2,
                                num_groups=1, max_new_tokens=4,
                                target_steps=4))

    # the injected crash escalates past the supervisor; run_with_recovery
    # restores from the newest snapshot into a fresh runtime and returns
    # whichever runtime instance actually finished
    done = rt.run_with_recovery(timeout_s=600, max_restarts=2)

    c = done.rec.counters_snapshot()
    print(f"chaos fired: {dict(done.chaos.counts()) if done.chaos else {}}")
    print(f"supervisor worker restarts: "
          f"{c.get('supervisor_prefill_worker_restarts', 0)} "
          f"(jobs recovered: "
          f"{c.get('supervisor_prefill_worker_jobs_recovered', 0)})")
    print(f"checkpoint restarts: {c.get('checkpoint_restarts', 0)}")
    for tid, st in done.mgr.task_items():
        print(f"  {tid}: v{st.version} "
              f"({st.steps_done}/{st.spec.target_steps} steps)")
    acc = done.row_accounting()
    assert acc["completed"] == (acc["trained"] + acc["stale_dropped"]
                                + acc["discarded_tails"] + acc["failed"]
                                + acc["quarantine_dropped"]
                                + acc["orphaned"]), acc
    assert done.mgr.all_done()
    print("finished: every issued row accounted for", acc)


if __name__ == "__main__":
    main()
