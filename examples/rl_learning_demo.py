"""Learning demo (paper Fig 1 shape): SFT warmup → GRPO lifts verifiable
reward. Tiny model, single CPU core, ~2 minutes.

    PYTHONPATH=src python examples/rl_learning_demo.py
"""
import dataclasses
import random

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import REGISTRY, LoRAConfig, reduced
from repro.data import tokenizer as tok
from repro.envs.tasks import make_env
from repro.lora.adapters import init_lora
from repro.models import init_params
from repro.rollout.engine import RolloutEngine, RolloutRequest, to_trajectory_batch
from repro.train.optimizer import AdamWConfig
from repro.train.sft import make_sft_step, sft_init
from repro.train.train_step import TrainConfig, init_opt_state, make_train_step


def build_sft_batch(env, rng, rows, S):
    tokens = np.zeros((rows, S), np.int32)
    p_lens = np.zeros((rows,), np.int32)
    t_lens = np.zeros((rows,), np.int32)
    for j in range(rows):
        prompt, truth = env.sample_prompt(rng)
        answer = tok.encode(truth) + [tok.EOS]
        seq = prompt + answer
        tokens[j, :len(seq)] = seq
        p_lens[j], t_lens[j] = len(prompt), len(seq)
    return {"tokens": jnp.asarray(tokens),
            "prompt_lens": jnp.asarray(p_lens),
            "total_lens": jnp.asarray(t_lens)}


def main():
    cfg = dataclasses.replace(reduced(REGISTRY["granite-3-2b"],
                                      dtype="float32"),
                              vocab_size=tok.VOCAB_SIZE,
                              lora=LoRAConfig(rank=8, alpha=32.0))
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    env = make_env("copy", length=3, alphabet="0123456789")
    rng = random.Random(0)

    # ---- stage 1: SFT warmup of the (shared) base on the task format ----
    sft = jax.jit(make_sft_step(cfg, AdamWConfig(lr=3e-3), trainable="full"))
    sopt = sft_init(params)
    for i in range(45):
        batch = build_sft_batch(env, rng, 16, 24)
        params, sopt, m = sft(None, params, sopt, batch)
        if i % 50 == 0:
            print(f"sft {i:3d}: loss={float(m['loss']):.3f}")
    print(f"sft done: loss={float(m['loss']):.3f} — base now knows the "
          f"format; tenants specialize via LoRA + GRPO:")

    # ---- stage 2: per-tenant GRPO on verifiable reward ----
    adapters = init_lora(key, cfg)
    tc = TrainConfig(group_size=8, adamw=AdamWConfig(lr=4e-3))
    opt = init_opt_state(cfg, tc, params, adapters)
    step = jax.jit(make_train_step(cfg, tc))
    engine = RolloutEngine(cfg, params, max_len=48, seed=0)
    rews, exact = [], []
    for v in range(40):
        reqs = []
        for _ in range(3):
            prompt, truth = env.sample_prompt(rng)
            for _ in range(8):
                reqs.append(RolloutRequest("t", 0, prompt, truth, env, 4, 1.0))
        results, _ = engine.generate(reqs, [adapters])
        tb = to_trajectory_batch(results, "t", v, 8, pad_to=48)
        batch = {"tokens": jnp.asarray(tb.tokens),
                 "prompt_lens": jnp.asarray(tb.prompt_lens),
                 "total_lens": jnp.asarray(tb.total_lens),
                 "rewards": jnp.asarray(tb.rewards),
                 "loss_mask": jnp.asarray(tb.meta["loss_mask"])}
        adapters, opt, m = step(params, adapters, opt, batch)
        rews.append(float(np.mean(tb.rewards)))
        exact.append(float(np.mean(tb.rewards >= 1.0)))
        if v % 5 == 0:
            print(f"grpo v{v:2d}: reward={rews[-1]:.3f} exact={exact[-1]:.2f}")
    a, b = np.mean(rews[:5]), np.mean(rews[-5:])
    print(f"\nreward first5={a:.3f} → last5={b:.3f} "
          f"({'improved' if b > a else 'flat'})")


if __name__ == "__main__":
    main()
