"""End-to-end driver (deliverable b): multi-tenant asynchronous RL training
— Algorithm 1 on real threads with real GRPO updates.

    PYTHONPATH=src python examples/multi_tenant_train.py \
        --tasks 3 --steps 5 --policy marlaas [--preset 100m]

Tenants (gsm8k / amc12 / agentic search, round-robin) share one frozen base
model; each owns LoRA adapters + optimizer state in the multi-task manager.
Rollouts are fused cross-task multi-LoRA batches; training is serialized;
environment tool calls overlap decode. Prints per-task reward curves and the
paper's system metrics (util/idle/TTFS/TPTS).

--preset tiny (default) runs in ~a minute on 1 CPU core; --preset 100m
builds a ~100M-param base (use on a real machine; a few hundred steps of
GRPO at that scale is hours on laptop CPUs, minutes on accelerators).
"""
import argparse
import dataclasses
import json

import jax

from repro.configs import REGISTRY, reduced, ModelConfig, LoRAConfig
from repro.core.chaos import ChaosConfig
from repro.core.manager import TaskSpec
from repro.core.metrics import summarize
from repro.core.runtime import MARLaaSRuntime, RuntimeConfig
from repro.data import tokenizer as tok
from repro.models import init_params

# tenant env rotations: classic = the paper's three archetypes; agentic =
# multi-turn tool-heavy tenants mixed with plain math (the env-stage
# workload — pair with --env-stage)
MIXES = {
    "classic": ["gsm8k", "amc12", "search"],
    "agentic": ["gsm8k", "hopsearch", "calcrepl", "guess"],
}
AGENTIC_ENVS = {"search", "hopsearch", "calcrepl", "guess"}


def base_config(preset: str) -> ModelConfig:
    if preset == "tiny":
        return dataclasses.replace(
            reduced(REGISTRY["granite-3-2b"], dtype="float32"),
            vocab_size=tok.VOCAB_SIZE)
    if preset == "100m":
        return dataclasses.replace(
            REGISTRY["granite-3-2b"], num_layers=12, d_model=768,
            num_heads=12, num_kv_heads=4, head_dim=64, d_ff=2048,
            vocab_size=tok.VOCAB_SIZE, dtype="float32", remat=False,
            lora=LoRAConfig(rank=16))
    raise ValueError(preset)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tasks", type=int, default=3)
    ap.add_argument("--steps", type=int, default=5)
    ap.add_argument("--policy", default="marlaas",
                    choices=["marlaas", "multilora_sync", "single_disagg"])
    ap.add_argument("--preset", default="tiny", choices=["tiny", "100m"])
    ap.add_argument("--timeout", type=float, default=3600.0)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--disagg-prefill", action="store_true",
                    help="async prefill stage (Fig 5): prefills run on "
                         "worker threads, decode only splices")
    ap.add_argument("--prefill-workers", type=int, default=1)
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked prefill size (0 = whole prompt)")
    ap.add_argument("--env-stage", action="store_true",
                    help="disaggregated env-interaction stage: rows park "
                         "on tool calls instead of freezing in their slot")
    ap.add_argument("--env-workers", type=int, default=2)
    ap.add_argument("--env-inflight-per-tenant", type=int, default=0,
                    help="max concurrent tool calls per tenant in the env "
                         "stage (0 = uncapped)")
    ap.add_argument("--max-turns", type=int, default=0,
                    help="per-episode tool-turn budget (0 = env default)")
    ap.add_argument("--paged-kv", action="store_true",
                    help="paged KV-cache block pool: shared fixed-size "
                         "pages + block tables instead of a dense "
                         "[slots, max_len] cache; park/preempt resume "
                         "restores saved pages instead of replaying")
    ap.add_argument("--kv-page-size", type=int, default=16,
                    help="tokens per KV page (max_len must divide)")
    ap.add_argument("--kv-pool-pages", type=int, default=0,
                    help="page-pool size (0 = dense-equivalent auto)")
    ap.add_argument("--no-resume-restore", action="store_true",
                    help="paged mode: disable snapshot/restore resume "
                         "(always token-replay — the parity baseline)")
    ap.add_argument("--snapshot-budget-bytes", type=int, default=0,
                    help="host arena for parked KV snapshots (0 = "
                         "unlimited; overflow falls back to replay)")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="paged mode: disable the copy-on-write prefix "
                         "cache (ISSUE 8) and allocate every row's pages "
                         "privately. When enabled (the default with "
                         "--paged-kv), sharing happens at three levels: "
                         "(1) GRPO-group sharing — same-prompt group "
                         "siblings map their block tables onto one "
                         "prefilled page set and fork pages copy-on-write "
                         "on first divergent decode write; (2) device-"
                         "resident snapshots — park/preempt of a row "
                         "whose pages are in-pool retains them on device "
                         "and resume is a block-table splice (host "
                         "snapshots demoted to a spill tier); (3) radix "
                         "prefix reuse — new requests and tool-turn "
                         "resumes match their longest cached page-aligned "
                         "prefix and prefill only the suffix")
    ap.add_argument("--mix", default="classic", choices=sorted(MIXES),
                    help="tenant env rotation; 'agentic' is the multi-turn "
                         "tool-heavy mix the env stage targets")
    ap.add_argument("--async-train", action="store_true",
                    help="event-driven off-policy trainer (ROADMAP §2): "
                         "train micro-batches the moment enough complete "
                         "GRPO groups arrive instead of waiting for "
                         "full-round assembly")
    ap.add_argument("--max-staleness", type=int, default=1,
                    help="bounded staleness window in versions (async "
                         "only): rollout may run this many rounds ahead "
                         "of the last commit; 0 = on-policy, identical "
                         "to the synchronous baseline")
    ap.add_argument("--min-train-rows", type=int, default=0,
                    help="micro-batch threshold in rows, rounded up to "
                         "complete GRPO groups (0 = a full round)")
    ap.add_argument("--chaos-seed", type=int, default=0,
                    help="deterministic fault-injection seed (ISSUE 10); "
                         "each site gets an independent RNG stream, so "
                         "the same seed replays the same fault script")
    ap.add_argument("--chaos-prefill-kill", type=float, default=0.0,
                    metavar="P", help="P(kill a prefill worker per job "
                                      "pickup); the supervisor recovers "
                                      "the job and respawns with backoff")
    ap.add_argument("--chaos-env-kill", type=float, default=0.0,
                    metavar="P", help="P(kill an env-stage worker per "
                                      "tool-call pickup)")
    ap.add_argument("--chaos-tool-transient", type=float, default=0.0,
                    metavar="P", help="P(transient tool error per call); "
                                      "retried with exponential backoff")
    ap.add_argument("--chaos-tool-permanent", type=float, default=0.0,
                    metavar="P", help="P(permanent tool error per call); "
                                      "fails the episode and counts "
                                      "toward the tenant's circuit "
                                      "breaker")
    ap.add_argument("--chaos-snapshot-drop", type=float, default=0.0,
                    metavar="P", help="P(drop a parked-row KV snapshot); "
                                      "resume falls back to token replay")
    ap.add_argument("--chaos-torn-checkpoint", type=float, default=0.0,
                    metavar="P", help="P(tear a checkpoint mid-publish); "
                                      "restart must fall back to the "
                                      "previous valid snapshot")
    ap.add_argument("--chaos-max-faults", type=int, default=0,
                    metavar="N", help="cap each site at N faults total "
                                      "(0 = uncapped)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="end-to-end episode tracing (ISSUE 9): write a "
                         "Perfetto-loadable Chrome trace JSON here (open "
                         "at ui.perfetto.dev) and print the critical-path "
                         "latency report (per-tenant p50/p95/p99 and the "
                         "dominant bottleneck stage)")
    args = ap.parse_args()

    chaos = ChaosConfig(
        seed=args.chaos_seed,
        prefill_worker_kill=args.chaos_prefill_kill,
        env_worker_kill=args.chaos_env_kill,
        tool_error_transient=args.chaos_tool_transient,
        tool_error_permanent=args.chaos_tool_permanent,
        snapshot_drop=args.chaos_snapshot_drop,
        torn_checkpoint=args.chaos_torn_checkpoint,
        max_faults_per_site=args.chaos_max_faults)

    cfg = base_config(args.preset)
    params = init_params(jax.random.PRNGKey(0), cfg)
    n_par = sum(x.size for x in jax.tree.leaves(params))
    print(f"base model: {cfg.name}-{args.preset} ({n_par/1e6:.1f}M params), "
          f"policy={args.policy}")

    rt = MARLaaSRuntime(cfg, params, RuntimeConfig(
        policy=args.policy, max_len=64, seed=0,
        checkpoint_dir=args.checkpoint_dir,
        checkpoint_every=5 if args.checkpoint_dir else 0,
        disagg_prefill=args.disagg_prefill,
        prefill_workers=args.prefill_workers,
        prefill_chunk=args.prefill_chunk,
        env_stage=args.env_stage,
        env_workers=args.env_workers,
        env_inflight_per_tenant=args.env_inflight_per_tenant,
        max_turns=args.max_turns,
        paged_kv=args.paged_kv,
        kv_page_size=args.kv_page_size,
        kv_pool_pages=args.kv_pool_pages,
        resume_restore=not args.no_resume_restore,
        snapshot_budget_bytes=args.snapshot_budget_bytes,
        prefix_cache=not args.no_prefix_cache,
        async_train=args.async_train,
        max_staleness=args.max_staleness,
        min_train_rows=args.min_train_rows,
        chaos=chaos if chaos.enabled else None,
        trace=bool(args.trace_out)))
    envs = MIXES[args.mix]
    for i in range(args.tasks):
        env = envs[i % len(envs)]
        rt.submit_task(TaskSpec(f"{env}-{i}", env, group_size=4, num_groups=1,
                                max_new_tokens=12 if env in AGENTIC_ENVS
                                else 6,
                                target_steps=args.steps, lr=3e-3))
    rt.run(timeout_s=args.timeout)

    print("\nper-task reward curves (graded verifier reward ∈ [0,1]):")
    for tid, st in rt.mgr.tasks.items():
        curve = " ".join(f"{r:.2f}" for r in st.reward_history)
        print(f"  {tid:12s} v{st.version}: {curve}")
    print("\nsystem metrics:")
    print(json.dumps({k: round(v, 3) for k, v in
                      summarize(rt.mgr, rt.rec).items()}, indent=2))
    if rt.chaos is not None:
        c = rt.rec.counters_snapshot()
        fault = {k: v for k, v in sorted(c.items())
                 if k.startswith(("chaos_", "supervisor_", "quarantine_"))
                 or k in ("env_retries", "env_recovered", "env_wedged")}
        acc = rt.row_accounting()
        print(f"\nchaos: injected={dict(rt.chaos.counts())}")
        print(f"fault handling: {json.dumps(fault)}")
        print(f"row accounting: {json.dumps(acc)}")
    if args.paged_kv:
        st = rt.cengine.stats
        print(f"\npaged KV: restores={st.restores} replays={st.replays} "
              f"replay_tokens={st.replay_tokens} "
              f"replay_tokens_saved={st.replay_tokens_saved} "
              f"snapshot_drops={st.snapshot_drops} "
              f"pool_exhausted={st.pool_exhausted} "
              f"prefix_hits={st.prefix_hits} "
              f"prefix_hit_tokens={st.prefix_hit_tokens} "
              f"cow_forks={st.cow_forks} "
              f"device_resident_resumes={st.device_resident_resumes} "
              f"fused_forced_tokens={st.fused_forced_tokens} "
              f"pool={rt.cengine.page_stats()}")
    if args.trace_out:
        from repro.obs.report import analyze, format_report, load_episodes
        trace = rt.tracer.dump_json(args.trace_out)
        print(f"\ntrace written to {args.trace_out} "
              f"(open at ui.perfetto.dev; "
              f"{rt.tracer.dropped_events} events dropped)")
        print(format_report(analyze(load_episodes(trace))))


if __name__ == "__main__":
    main()
