"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.checkpoint.store import flat_to_tree, tree_to_flat
from repro.core.admission import AdmissionConfig, AdmissionController
from repro.core.manager import MultiTaskManager, TaskSpec
from repro.configs import get_config
from repro.data import tokenizer as tok
from repro.kernels import ref
from repro.kernels.sgmv import sgmv
from repro.rl.grpo import group_advantages
from repro.rl.types import TrajectoryBatch

SETTINGS = dict(max_examples=25, deadline=None)


@given(groups=st.integers(1, 6), g=st.integers(2, 8),
       scale=st.floats(0.1, 100.0),
       seed=st.integers(0, 2 ** 16))
@settings(**SETTINGS)
def test_advantages_scale_invariant_and_centered(groups, g, scale, seed):
    """Group advantages: per-group mean 0; invariant to affine reward scaling."""
    r = np.random.RandomState(seed).uniform(0, 1, groups * g).astype(np.float32)
    a1 = np.asarray(group_advantages(jnp.asarray(r), g))
    a2 = np.asarray(group_advantages(jnp.asarray(r * scale + 3.0), g))
    np.testing.assert_allclose(a1.reshape(groups, g).mean(1), 0, atol=1e-4)
    if r.reshape(groups, g).std(1).min() > 1e-3:
        np.testing.assert_allclose(a1, a2, rtol=0.2, atol=0.05)


@given(ops=st.lists(st.sampled_from(["push_a", "push_b", "pop"]),
                    min_size=1, max_size=40))
@settings(**SETTINGS)
def test_buffer_fifo_property(ops):
    """Q_buffer pops in exact global FIFO order, whatever the interleave."""
    m = MultiTaskManager()
    vers = {"a": 0, "b": 0}
    for tid in vers:
        m.submit(TaskSpec(tid, "gsm8k", target_steps=10 ** 6))
        m.admit(tid)
    pushed, popped = [], []
    for op in ops:
        if op == "pop":
            b = m.pop_batch()
            if b is not None:
                popped.append((b.task_id, b.version))
                m.commit(b.task_id, None, None, b.version)
        else:
            tid = op[-1]
            if m.next_policy(tid) is None:
                continue
            v = vers[tid]
            z = np.zeros((1, 2), np.float32)
            m.enqueue(TrajectoryBatch(tid, v, z.astype(np.int32),
                                      np.ones(1, np.int32),
                                      np.full(1, 2, np.int32),
                                      np.zeros(1, np.float32), 1))
            pushed.append((tid, v))
            vers[tid] += 1
    assert popped == pushed[:len(popped)]


@given(budget_units=st.integers(1, 10),
       sizes=st.lists(st.integers(1, 4), min_size=1, max_size=12))
@settings(**SETTINGS)
def test_admission_never_exceeds_budget(budget_units, sizes):
    cfg = get_config("granite-3-2b")
    unit = 10 ** 6
    ac = AdmissionController(cfg, AdmissionConfig(
        memory_budget_bytes=budget_units * unit, strict=True))
    # monkeypatch the estimator to controlled sizes
    import repro.core.admission as adm
    orig = adm.task_state_bytes
    try:
        it = iter(sizes)
        sizes_map = {}

        def fake(cfg_, spec, prompt_len=64, dtype_bytes=2):
            return sizes_map[spec.task_id]

        adm.task_state_bytes = fake
        for i, s in enumerate(sizes):
            sizes_map[f"t{i}"] = s * unit
            ac.try_admit(TaskSpec(f"t{i}", "gsm8k"))
            assert ac.used_bytes <= budget_units * unit or len(ac.admitted()) == 1
    finally:
        adm.task_state_bytes = orig


@given(text=st.text(alphabet=sorted(tok.CHAR_TO_ID), max_size=50))
@settings(**SETTINGS)
def test_tokenizer_roundtrip(text):
    assert tok.decode(tok.encode(text)) == text


@given(seed=st.integers(0, 2 ** 16), R=st.integers(1, 40),
       T=st.integers(1, 6))
@settings(max_examples=10, deadline=None)
def test_sgmv_random_shapes(seed, R, T):
    rs = np.random.RandomState(seed)
    d = int(rs.choice([16, 40, 64]))
    r = int(rs.choice([4, 8]))
    dout = int(rs.choice([24, 32, 80]))
    x = jnp.asarray(rs.randn(R, d).astype(np.float32))
    a = jnp.asarray(0.1 * rs.randn(T, d, r).astype(np.float32))
    b = jnp.asarray(0.1 * rs.randn(T, r, dout).astype(np.float32))
    ids = jnp.asarray(rs.randint(0, T, R).astype(np.int32))
    np.testing.assert_allclose(np.asarray(sgmv(x, a, b, ids)),
                               np.asarray(ref.sgmv_ref(x, a, b, ids)),
                               rtol=2e-5, atol=2e-5)


@given(seed=st.integers(0, 2 ** 16))
@settings(**SETTINGS)
def test_checkpoint_tree_roundtrip(seed):
    rs = np.random.RandomState(seed)
    tree = {"layers": {"attn_q": {"a": rs.randn(2, 3), "b": rs.randn(3)}},
            "step": np.int32(7)}
    back = flat_to_tree(tree_to_flat(tree))
    assert back["layers"]["attn_q"]["a"].shape == (2, 3)
    np.testing.assert_allclose(back["layers"]["attn_q"]["a"],
                               tree["layers"]["attn_q"]["a"])
    assert int(back["step"]) == 7


@given(p_len=st.integers(1, 6), gen=st.integers(1, 6))
@settings(**SETTINGS)
def test_completion_mask_counts_generated(p_len, gen):
    S = p_len + gen + 2
    tb = TrajectoryBatch(
        task_id="t", version=0,
        tokens=np.zeros((1, S), np.int32),
        prompt_lens=np.array([p_len], np.int32),
        total_lens=np.array([p_len + gen], np.int32),
        rewards=np.zeros(1, np.float32), group_size=1)
    m = tb.completion_mask()
    assert m.sum() == gen            # exactly one loss slot per generated tok
    assert m[0, p_len - 1] == 1.0 and m[0, p_len + gen - 1] == 0.0
