"""Hypothesis property tests for the preemptive scheduler (ISSUE 2).

1. Preempting a row at ANY decode step and prefix-replaying it yields the
   same final tokens/logprobs as an uninterrupted run — across attention,
   SSM, and hybrid cache families.
2. ANY interleaving of adapter installs/evictions through the LRU residency
   map leaves the stacked LoRA buffer behaving identically (on surviving
   rows) to a buffer rebuilt from scratch.

Engines/params are built once per family and reused across examples
(requests carry explicit seeds, so tokens are independent of the engine's
submission counter and of pop order).
"""
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from conftest import tiny_lm
from repro.envs.tasks import make_env
from repro.lora.adapters import init_lora
from repro.lora.multilora import (AdapterResidency, multi_lora_delta,
                                  multi_lora_delta_ref)
from repro.models import init_params
from repro.rollout.engine import (ContinuousRolloutEngine, RolloutEngine,
                                  RolloutRequest)

FAMILIES = {"attention": "granite-3-2b", "ssm": "mamba2-780m",
            "hybrid": "zamba2-1.2b"}
_CACHE = {}


def _family(fam: str):
    """(cfg, params, trees, reqs, reference results, reusable engine) —
    built once per family, reused by every hypothesis example."""
    if fam not in _CACHE:
        cfg = tiny_lm(FAMILIES[fam])
        params = init_params(jax.random.PRNGKey(0), cfg)
        trees = [init_lora(jax.random.PRNGKey(1), cfg),
                 init_lora(jax.random.PRNGKey(2), cfg)]
        env = make_env("gsm8k")
        rng = random.Random(7)
        reqs = []
        for i in range(3):
            prompt, truth = env.sample_prompt(rng)
            reqs.append(RolloutRequest(
                f"t{i % 2}", i % 2, prompt, truth, env,
                max_new_tokens=5 + 2 * i, seed=i))   # explicit per-row keys
        ref_eng = RolloutEngine(cfg, params, max_len=64, seed=0)
        ref, _ = ref_eng.generate(reqs, trees)       # uninterrupted oracle
        eng = ContinuousRolloutEngine(cfg, params, max_slots=2,
                                      max_adapters=2, max_len=64, seed=0)
        for i, tree in enumerate(trees):
            eng.set_adapters(i, tree)
        _CACHE[fam] = (reqs, ref, eng)
    return _CACHE[fam]


def _run_with_preemption(eng, reqs, preempt_step, victim):
    """Drive the engine manually, preempting `victim` after `preempt_step`
    engine iterations; returns completions keyed by request position and
    the number of rows actually preempted."""
    pos_of = {eng.submit(r): i for i, r in enumerate(reqs)}
    comps, preempted, iters = {}, 0, 0
    while not eng.idle() and iters < 400:
        eng.step()
        iters += 1
        if iters == preempt_step:
            preempted = eng.preempt_tenant(victim)
        for c in eng.drain_completions():
            comps[pos_of[c.submit_index]] = c
    assert len(comps) == len(reqs), "engine failed to drain"
    return comps, preempted


@pytest.mark.parametrize("fam", sorted(FAMILIES))
def test_preempt_replay_parity_property(fam):
    """Property (hypothesis inner loop per family so model build/compile is
    paid once): any (preempt step, victim) produces bit-identical output."""
    reqs, ref, eng = _family(fam)
    observed_preemption = {"n": 0}

    @given(preempt_step=st.integers(1, 14), victim=st.sampled_from(["t0", "t1"]))
    @settings(max_examples=8, deadline=None)
    def check(preempt_step, victim):
        comps, preempted = _run_with_preemption(eng, reqs, preempt_step,
                                                victim)
        observed_preemption["n"] += preempted
        for i, r in enumerate(ref):
            c = comps[i]
            assert list(c.tokens) == r["tokens"], (
                f"{fam}: token mismatch after preempting {victim} "
                f"at step {preempt_step}")
            assert list(c.gen_loss_mask) == r["gen_loss_mask"]
            np.testing.assert_allclose(c.gen_logprobs, r["gen_logprobs"],
                                       atol=1e-5)

    check()
    # the property must have actually exercised preemption+replay
    assert observed_preemption["n"] > 0
    assert eng.stats.preemptions > 0 and eng.stats.replays > 0


# -- adapter buffer: evict/reload interleavings ---------------------------

D, R, DOUT, CAP, N_TENANTS = 8, 4, 6, 3, 6
_rs = np.random.RandomState(0)
TREES = [{"a": jnp.asarray(0.1 * _rs.randn(D, R), jnp.float32),
          "b": jnp.asarray(0.1 * _rs.randn(R, DOUT), jnp.float32)}
         for _ in range(N_TENANTS)]


@given(ops=st.lists(st.tuples(st.integers(0, N_TENANTS - 1),
                              st.booleans()),
                    min_size=1, max_size=30),
       seed=st.integers(0, 2 ** 16))
@settings(max_examples=30, deadline=None)
def test_adapter_evict_reload_matches_scratch_rebuild(ops, seed):
    """Any acquire/evict interleaving (with arbitrary in-use pinning) leaves
    the stacked buffer equivalent — via multi_lora_delta on the surviving
    rows — to one rebuilt from scratch from the resident tenants. Evicted
    slots may hold stale weights; correctness requires they are simply
    never routed to."""
    buf = {"a": jnp.zeros((CAP, D, R), jnp.float32),
           "b": jnp.zeros((CAP, R, DOUT), jnp.float32)}

    def install(slot, tree):
        buf["a"] = buf["a"].at[slot].set(tree["a"])
        buf["b"] = buf["b"].at[slot].set(tree["b"])

    res = AdapterResidency(CAP, install)
    busy = set()
    for tenant, explicit_evict in ops:
        t = f"t{tenant}"
        if explicit_evict:
            res.evict(t)
            busy.discard(t)
        else:
            slot = res.acquire(t, TREES[tenant],
                               in_use=lambda x: x in busy)
            if slot is not None:
                busy.add(t)                     # pin until next toggle
            if len(busy) == CAP:
                busy.clear()                    # let future evictions happen

    resident = res.resident()
    if not resident:
        return
    # rebuild from scratch: ONLY surviving tenants, at their final slots
    fresh = {"a": jnp.zeros((CAP, D, R), jnp.float32),
             "b": jnp.zeros((CAP, R, DOUT), jnp.float32)}
    for t, slot in resident.items():
        tree = TREES[int(t[1:])]
        fresh["a"] = fresh["a"].at[slot].set(tree["a"])
        fresh["b"] = fresh["b"].at[slot].set(tree["b"])

    rs = np.random.RandomState(seed)
    slots = sorted(resident.values())
    x = jnp.asarray(rs.randn(len(slots), D), jnp.float32)
    ids = jnp.asarray(slots, jnp.int32)
    got = multi_lora_delta(x, buf["a"], buf["b"], ids, scaling=2.0)
    want = multi_lora_delta_ref(x, fresh["a"], fresh["b"], ids, scaling=2.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)
    # residency invariants: distinct slots, within capacity
    assert len(set(resident.values())) == len(resident) <= CAP
