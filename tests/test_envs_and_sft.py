"""Coverage: verifiable-reward environments, SFT warmup, elastic restore."""
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_lm
from repro.data import tokenizer as tok
from repro.envs.tasks import make_env
from repro.models import init_params
from repro.train.optimizer import AdamWConfig
from repro.train.sft import make_sft_step, sft_init


def test_arithmetic_verifier_grades():
    env = make_env("gsm8k")
    rng = random.Random(0)
    prompt, truth = env.sample_prompt(rng)
    exact = tok.encode(truth) + [tok.EOS]
    assert env.verify(truth, exact) == 1.0
    assert env.verify(truth, tok.encode("zz")) < 0.5
    # partial credit: first digit right
    if len(truth) > 1:
        partial = tok.encode(truth[0] + "z")
        assert 0 < env.verify(truth, partial) < 1.0


def test_search_env_tool_and_verify():
    env = make_env("search", kb_size=8)
    rng = random.Random(1)
    prompt, truth = env.sample_prompt(rng)
    entity, fact = truth
    resp = env.tool_call(prompt)
    assert tok.decode(resp) == fact
    # answer after ENDRESP graded; tool echo before it ignored
    comp = [tok.RESP] + resp + [tok.ENDRESP] + tok.encode(fact) + [tok.EOS]
    assert env.verify(truth, comp) == 1.0
    assert env.verify(truth, tok.encode("99x")) <= 0.8


def test_env_latency_sampling_nonnegative():
    env = make_env("search")
    rng = random.Random(2)
    for _ in range(50):
        assert env.sample_env_latency(rng) >= 0.0


def test_sft_reduces_loss(rng_key):
    cfg = tiny_lm()
    params = init_params(rng_key, cfg)
    env = make_env("copy", length=2, alphabet="01")
    rng = random.Random(0)
    sft = jax.jit(make_sft_step(cfg, AdamWConfig(lr=3e-3), trainable="full"))
    opt = sft_init(params)
    losses = []
    for _ in range(25):
        rows, S = 8, 12
        tokens = np.zeros((rows, S), np.int32)
        p_l = np.zeros((rows,), np.int32)
        t_l = np.zeros((rows,), np.int32)
        for j in range(rows):
            prompt, truth = env.sample_prompt(rng)
            seq = prompt + tok.encode(truth) + [tok.EOS]
            tokens[j, :len(seq)] = seq
            p_l[j], t_l[j] = len(prompt), len(seq)
        batch = {"tokens": jnp.asarray(tokens),
                 "prompt_lens": jnp.asarray(p_l),
                 "total_lens": jnp.asarray(t_l)}
        params, opt, m = sft(None, params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.7, losses[::6]


def test_elastic_restore_trains_under_new_context(tmp_path, rng_key):
    """Snapshot written on one 'cluster', restored and trained on another
    (host arrays are mesh-agnostic; device placement happens lazily)."""
    from repro.checkpoint.store import load_checkpoint, save_checkpoint
    from repro.core.manager import MultiTaskManager, TaskSpec
    from repro.lora.adapters import init_lora
    from repro.train.optimizer import AdamWConfig
    from repro.train.train_step import (TrainConfig, init_opt_state,
                                        make_train_step)
    cfg = tiny_lm()
    params = init_params(rng_key, cfg)
    lora = init_lora(rng_key, cfg)
    tc = TrainConfig(group_size=2, adamw=AdamWConfig(lr=1e-3))
    opt = init_opt_state(cfg, tc, params, lora)
    mgr = MultiTaskManager()
    mgr.submit(TaskSpec("t", "gsm8k", target_steps=5), lora, opt)
    path = save_checkpoint(str(tmp_path), mgr)

    mgr2 = MultiTaskManager()
    load_checkpoint(path, mgr2)
    st = mgr2.tasks["t"]
    step = jax.jit(make_train_step(cfg, tc))
    R, S = 4, 16
    batch = {"tokens": jax.random.randint(rng_key, (R, S), 0, cfg.vocab_size),
             "prompt_lens": jnp.full((R,), 4, jnp.int32),
             "total_lens": jnp.full((R,), 12, jnp.int32),
             "rewards": jax.random.uniform(rng_key, (R,))}
    # restored host-numpy trees feed straight into the jitted step
    new_lora, new_opt, metrics = step(params, st.adapters, st.opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
